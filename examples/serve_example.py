"""Batched serving demo: prefill + KV/SSD-cache decode across architectures,
including the attention-free mamba2 and the MLA latent cache of deepseek-v2.

  PYTHONPATH=src python examples/serve_example.py --arch qwen2-1.5b --gen 24
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.serve import generate
from repro.models import lm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    key = jax.random.PRNGKey(0)
    params = lm.init_lm(key, cfg)
    prompt = jax.random.randint(jax.random.fold_in(key, 1),
                                (args.batch, args.prompt_len), 0, cfg.vocab_size)
    t0 = time.time()
    out = generate(params, cfg, prompt.astype(jnp.int32), args.gen,
                   temperature=args.temperature, key=jax.random.fold_in(key, 2))
    dt = time.time() - t0
    print(f"{cfg.name}: generated [{args.batch} x {args.gen}] tokens in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s incl. compile)")
    for b in range(min(2, args.batch)):
        print(f"  seq{b}: {out[b, :16].tolist()}")


if __name__ == "__main__":
    main()
