"""Quickstart: train a tiny decoder-only LM with asynchronous pipeline parallelism
and the paper's delay-corrected Nesterov method, next to the synchronous baseline.

  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs import get_config
from repro.core.engine import AsyncTrainer, EngineCfg
from repro.data.synthetic import make_batch_fn


def main():
    cfg = get_config("nanogpt-134m", reduced=True)  # 8 layers -> 8 pipeline stages
    ecfg = EngineCfg(n_stages=8, lr=1e-3, constant_lr=True, total_steps=200)
    batch_fn, src = make_batch_fn(cfg, k_micro=1, batch=8, seq=64, seed=0)
    print(f"model: {cfg.name}, stages=8, per-stage delays = "
          f"{AsyncTrainer(cfg, ecfg, 'ours').taus}")
    print(f"synthetic-data entropy floor ~ {src.entropy_floor():.3f} nats\n")

    for method in ("gpipe", "ours"):
        trainer = AsyncTrainer(cfg, ecfg, method)
        state = trainer.init(jax.random.PRNGKey(0))
        step = trainer.jit_step()
        for i in range(200):
            state, m = step(state, batch_fn(i))
            if (i + 1) % 50 == 0:
                extra = (f"  gap={float(m['stage1_gap_rmse']):.2e}"
                         if "stage1_gap_rmse" in m else "")
                print(f"[{method:6s}] step {i+1:4d}  loss={float(m['loss']):.4f}{extra}")
        print()


if __name__ == "__main__":
    main()
