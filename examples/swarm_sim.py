"""SWARM-style decentralized training (paper Sec. 5.7): stage-wise data parallelism
with async local updates, periodic stage sync, and optional int8+error-feedback
compression for the slow links.

  PYTHONPATH=src python examples/swarm_sim.py --steps 120 [--compress]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.engine import EngineCfg
from repro.core.swarm import SwarmCfg, SwarmTrainer
from repro.data.synthetic import make_batch_fn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--sync-every", type=int, default=8)
    ap.add_argument("--compress", action="store_true")
    args = ap.parse_args()

    cfg = get_config("nanogpt-134m", reduced=True)
    print(f"# SWARM sim: {args.replicas} workers/stage x 4 stages, "
          f"sync every {args.sync_every}, compress={args.compress}")
    for name, method, lr in [("SWARM (sync)", "gpipe", 2e-3),
                             ("SWARM-Async + Ours-No-WS", "ours_nows", 2e-3)]:
        sw = SwarmTrainer(cfg, EngineCfg(n_stages=4, lr=lr, constant_lr=True,
                                         collect_metrics=False), method,
                          SwarmCfg(replicas=args.replicas,
                                   sync_every=1 if method == "gpipe" else args.sync_every,
                                   compress=args.compress))
        state = sw.init(jax.random.PRNGKey(0))
        step = sw.jit_step()
        fns = [make_batch_fn(cfg, 1, 4, 64, seed=100 * r)[0]
               for r in range(args.replicas)]
        losses = []
        for i in range(args.steps):
            b = jax.tree.map(lambda *xs: jnp.stack(xs), *[f(i) for f in fns])
            state, m = step(state, b)
            losses.append(float(m["loss"]))
            if (i + 1) % max(args.steps // 4, 1) == 0:
                print(f"[{name:28s}] step {i+1:4d}  loss={losses[-1]:.4f}")
        print(f"[{name:28s}] final = {np.mean(losses[-10:]):.4f}\n")


if __name__ == "__main__":
    main()
