"""End-to-end driver: the paper's Figure-2 experiment (method comparison), scaled by
--preset. 'full' uses the paper's actual 134M base config (needs a real accelerator
for reasonable wall time); 'small' runs in minutes on CPU.

  PYTHONPATH=src python examples/paper_repro.py --preset small --steps 400
  PYTHONPATH=src python examples/paper_repro.py --preset full --config nanogpt-1b
"""
import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_config
from repro.core.engine import AsyncTrainer, EngineCfg
from repro.data.synthetic import make_batch_fn

METHODS = ["gpipe", "pipedream", "pipemare", "ours", "ours_nows"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=["small", "full"], default="small")
    ap.add_argument("--config", default="nanogpt-134m")
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--methods", default=",".join(METHODS))
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    reduced = args.preset == "small"
    cfg = get_config(args.config, reduced=reduced)
    # paper Sec. 5.1: 8 stages, microbatch 8, lr 3e-4 (1e-4 @1B), cosine to lr/10
    stages = 8
    if reduced:
        batch, seq, lr, warmup = 8, 64, 1e-3, max(args.steps // 20, 10)
    else:
        batch, seq, lr = 8, (1024 if "1b" in args.config else 512), \
            (1e-4 if "1b" in args.config else 3e-4)
        warmup = 3000
    ecfg = EngineCfg(n_stages=stages, lr=lr, warmup_steps=warmup,
                     total_steps=args.steps)
    batch_fn, src = make_batch_fn(cfg, 1, batch, seq, seed=0)
    print(f"# {cfg.name} | steps={args.steps} stages={stages} floor={src.entropy_floor():.3f}")

    curves = {}
    for method in args.methods.split(","):
        trainer = AsyncTrainer(cfg, ecfg, method)
        state = trainer.init(jax.random.PRNGKey(0))
        step = trainer.jit_step()
        losses = []
        for i in range(args.steps):
            state, m = step(state, batch_fn(i))
            losses.append(float(m["loss"]))
            if (i + 1) % max(args.steps // 8, 1) == 0:
                print(f"[{method:10s}] {i+1:6d}  {losses[-1]:.4f}", flush=True)
        curves[method] = losses
        print(f"[{method:10s}] final(avg10) = {np.mean(losses[-10:]):.4f}  "
              f"ppl = {np.exp(np.mean(losses[-10:])):.2f}\n")

    order = sorted(curves, key=lambda m: np.mean(curves[m][-10:]))
    print("# ranking (best first):", " < ".join(order))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(curves, f)


if __name__ == "__main__":
    main()
