"""Roofline table from the dry-run artifacts (artifacts/roofline.json).

Run `PYTHONPATH=src python -m repro.launch.roofline --all --out artifacts/roofline.json`
first (512-device lowering; kept out of the default bench run)."""
from __future__ import annotations

import json
import os

from common import ART, emit_csv


def main():
    path = os.path.join(ART, "roofline.json")
    if not os.path.exists(path):
        print("# artifacts/roofline.json missing — run repro.launch.roofline --all")
        return []
    recs = json.load(open(path))
    rows = []
    for r in recs:
        if "skipped" in r:
            rows.append((f"roofline/{r['cell']}", 0, "skipped"))
            continue
        if "error" in r:
            rows.append((f"roofline/{r['cell']}", 0, f"error={r['error'][:50]}"))
            continue
        bound_ms = max(r["compute_ms"], r["memory_ms"], r["collective_ms"])
        rows.append((
            f"roofline/{r['cell']}", bound_ms * 1e3,
            f"dom={r['dominant']};comp_ms={r['compute_ms']};mem_ms={r['memory_ms']};"
            f"coll_ms={r['collective_ms']};useful={r['useful_flops_ratio']};"
            f"roofline_frac={r['roofline_fraction']}"))
    emit_csv(rows)
    return rows


if __name__ == "__main__":
    main()
