"""Fig. 8: SWARM-style decentralized stage-DP.

SWARM (sync), SWARM-Async (local updates + periodic stage-wise sync, lower lr for
stability as in the paper), SWARM-Async + Ours-No-WS. Also exercises the int8+EF
compressed sync (beyond-paper, for the low-bandwidth links SWARM targets), and
the fully-async gossip mesh (DESIGN.md §13) — barrier replaced by sync events,
with and without the ZeRO-1 sharded optimizer."""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from common import emit_csv, save_json
from repro.configs import get_config
from repro.core.engine import EngineCfg
from repro.core.swarm import MeshCfg, MeshTrainer, SwarmCfg, SwarmTrainer
from repro.data.synthetic import make_batch_fn


def run_swarm(method, *, sync_every, lr, steps, compress=False, seed=0):
    cfg = get_config("nanogpt_134m", reduced=True)
    sw = SwarmTrainer(cfg, EngineCfg(n_stages=4, lr=lr, constant_lr=True,
                                     collect_metrics=False), method,
                      SwarmCfg(replicas=2, sync_every=sync_every, compress=compress))
    state = sw.init(jax.random.PRNGKey(seed))
    step = sw.jit_step()
    f1, _ = make_batch_fn(cfg, 1, 4, 64, seed=seed)
    f2, _ = make_batch_fn(cfg, 1, 4, 64, seed=seed + 100)
    losses = []
    t0 = time.time()
    for i in range(steps):
        b = jax.tree.map(lambda a, c: jnp.stack([a, c]), f1(i), f2(i))
        state, m = step(state, b)
        losses.append(float(m["loss"]))
    return {"loss": losses, "final": float(np.mean(losses[-10:])),
            "wall_s": time.time() - t0}


def run_mesh(method, *, period, lr, steps, opt_shard=False, seed=0):
    # gossip mesh twin of run_swarm: no barrier — sync is runtime events, each
    # replica free-runs and absorbs whatever partner snapshots have arrived
    cfg = get_config("nanogpt_134m", reduced=True)
    mt = MeshTrainer(cfg, EngineCfg(n_stages=4, lr=lr, constant_lr=True,
                                    collect_metrics=False), method,
                     MeshCfg(replicas=2, period=period, opt_shard=opt_shard,
                             seed=seed))
    bfs = [make_batch_fn(cfg, 1, 4, 64, seed=seed + 100 * r)[0]
           for r in range(2)]
    t0 = time.time()
    out = mt.run_gossip(bfs, steps, key=jax.random.PRNGKey(seed))
    finals = [ls[-1] for ls in out["losses"]]
    return {"loss": out["losses"], "final": float(np.mean(finals)),
            "wall_s": time.time() - t0, "absorbed": out["absorbed"],
            "stale_dropped": out["stale_dropped"],
            "opt_bytes_per_replica": out["opt_bytes_per_replica"],
            "opt_bytes_replicated": out["opt_bytes_replicated"]}


def main(steps=150):
    runs = {
        "swarm_sync": ("gpipe", 1, 2e-3, False),
        "swarm_async": ("pipedream", 8, 5e-4, False),  # paper: lower lr or diverges
        "swarm_ours_nows": ("ours_nows", 8, 2e-3, False),
        "swarm_ours_nows_int8ef": ("ours_nows", 8, 2e-3, True),
    }
    rows, full = [], {}
    for name, (m, se, lr, comp) in runs.items():
        r = run_swarm(m, sync_every=se, lr=lr, steps=steps, compress=comp)
        full[name] = r
        rows.append((f"fig8/{name}", round(1e6 * r["wall_s"] / steps, 1),
                     f"final_loss={r['final']:.4f}"))
    mesh_runs = {
        "mesh_gossip_ours": ("ours", 8, 2e-3, False),
        "mesh_gossip_ours_zero1": ("ours", 8, 2e-3, True),
    }
    for name, (m, pd, lr, shard) in mesh_runs.items():
        r = run_mesh(m, period=pd, lr=lr, steps=steps, opt_shard=shard)
        full[name] = r
        rows.append((f"fig8/{name}", round(1e6 * r["wall_s"] / steps, 1),
                     f"final_loss={r['final']:.4f};"
                     f"absorbed={r['absorbed']};"
                     f"opt_bytes_replica={r['opt_bytes_per_replica']};"
                     f"opt_bytes_replicated={r['opt_bytes_replicated']}"))
    save_json("fig8_swarm.json", full)
    emit_csv(rows)
    print(f"# ours_nows beats sync: {full['swarm_ours_nows']['final'] <= full['swarm_sync']['final'] + 0.05}; "
          f"int8+EF delta: {full['swarm_ours_nows_int8ef']['final'] - full['swarm_ours_nows']['final']:+.4f}")
    return full


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    a = ap.parse_args()
    main(a.steps)
