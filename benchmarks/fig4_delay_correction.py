"""Fig. 4: other delay-correction mechanisms vs ours, + weight-discrepancy RMSE.

PipeDream-LR (lr discount), LR-SecondOrder (diag-Fisher Taylor), Polynomial+FFT
forecasting, XPipe (weight prediction), vs Ours; plus NAG composed with each
(paper: NAG improves them, but NAG alone is best)."""
from __future__ import annotations

import argparse

from common import emit_csv, run_method, save_json

METHODS = ["pipedream", "pipedream_lr", "lr_second_order", "polyfft", "xpipe",
           "ours", "ours_lr", "ours_second_order", "ours_polyfft"]


def main(steps=200, stages=8):
    rows, full = [], {}
    for m in METHODS:
        r = run_method(m, steps=steps, stages=stages)
        full[m] = r
        gap = r["gap"][-1] if r["gap"] else float("nan")
        rows.append((f"fig4/{m}", round(1e6 * r["wall_s"] / steps, 1),
                     f"final_loss={r['final']:.4f};stage1_gap={gap:.3e}"))
    save_json("fig4_delay_correction.json", full)
    emit_csv(rows)
    best = min(full, key=lambda m: full[m]["final"])
    print(f"# best method: {best} (paper claim: ours)")
    return full


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    a = ap.parse_args()
    main(a.steps)
