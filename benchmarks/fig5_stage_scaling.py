"""Fig. 5: scaling the number of stages — loss degradation vs pipeline-time win.

Loss: ours vs gpipe at P in {4, 8, 12} (reduced model depth scaled to P so each
stage keeps >=1 layer). Runtime: the 1F1B utilization model —
  GPipe iteration time ~ (M + P - 1)/M microbatch-times (bubble),
  async (ours)        ~ 1.0 (100% utilization by construction),
plus a per-stage communication overhead c per boundary. We report the relative
iteration-time increase vs P=4 for both (paper: 8.5x for GPipe vs 2.5x for ours at
P=24 with per-layer stages)."""
from __future__ import annotations

import argparse

from common import emit_csv, run_method, save_json


def time_model(P, M=4, t_layer=1.0, L=24, c=0.15):
    """Returns (gpipe_iter, async_iter) in arbitrary units for an L-layer model
    split into P stages, M microbatches, c = per-boundary comm overhead."""
    t_stage = t_layer * L / P + c
    gpipe = (M + P - 1) * t_stage
    async_ = M * t_stage
    return gpipe, async_


def main(steps=150):
    rows, full = [], {}
    for P in (4, 8, 12):
        for m in ("gpipe", "ours"):
            # paper Fig. 5: the layer count scales with stages (1 layer = 1 stage)
            r = run_method(m, steps=steps, stages=P, n_periods=P)
            full[f"{m}_P{P}"] = r
            # paper setup: 1 layer per stage -> per-stage time constant, L = P
            g_t, a_t = time_model(P, L=P)
            g4, a4 = time_model(4, L=4)
            t_rel = (g_t / g4) if m == "gpipe" else (a_t / a4)
            bubble = (P - 1) / (4 + P - 1) if m == "gpipe" else 0.0
            rows.append((f"fig5/{m}_P{P}", round(1e6 * r["wall_s"] / steps, 1),
                         f"final_loss={r['final']:.4f};bubble={bubble:.2f};rel_time={t_rel:.2f}"))
    save_json("fig5_stage_scaling.json", full)
    emit_csv(rows)
    return full


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    a = ap.parse_args()
    main(a.steps)
