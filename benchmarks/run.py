"""Benchmark driver: one section per paper table/figure. Prints
``name,us_per_call,derived`` CSV rows (plus # comment lines with claim checks).

  PYTHONPATH=src python -m benchmarks.run [--steps N] [--quick]
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--quick", action="store_true", help="fewer steps everywhere")
    args, _ = ap.parse_known_args()
    steps = 60 if args.quick else args.steps

    from repro.analysis import sanitize
    sanitize.apply(verbose=True)

    import lint_report
    import kernel_bench
    import runtime_bench
    import table1_methods
    import fig4_delay_correction
    import fig5_stage_scaling
    import fig6_momentum_ablation
    import fig7_discount_ablation
    import fig8_swarm
    import roofline_report
    import serve_bench

    print("# === repro-lint: static invariants (artifacts/LINT_report.json) ===")
    lint_report.main()
    print("# === kernels (interpret mode) ===")
    kernel_bench.main()
    print("# === runtime: event-driven vs jit engine ===")
    runtime_bench.main(steps=max(20, steps // 4))
    print("# === serving: continuous batching under Poisson load ===")
    serve_bench.main(requests=8 if args.quick else 16)
    print("# === Table 1: methods ===")
    table1_methods.main(steps=steps)
    print("# === Fig 4: delay-correction mechanisms ===")
    fig4_delay_correction.main(steps=steps)
    print("# === Fig 5: stage scaling ===")
    fig5_stage_scaling.main(steps=max(60, steps // 2))
    print("# === Fig 6: momentum ablation ===")
    fig6_momentum_ablation.main(steps=steps)
    print("# === Fig 7: gradient-discount ablation ===")
    fig7_discount_ablation.main(steps=steps)
    print("# === Fig 8: SWARM stage-DP ===")
    fig8_swarm.main(steps=max(60, steps // 2))
    print("# === Roofline (from dry-run artifacts) ===")
    roofline_report.main()


if __name__ == "__main__":
    main()
