"""`serve` rows: continuous-batching service under Poisson load.

Drives a keyed Poisson request trace (core/events.poisson_trace) through the
ServeEngine (launch/serve.py) on the reduced model and reports service-level
objectives: throughput (total + steady-state, excluding the compile-paying
first step), time-to-first-token and per-output-token latency at p50/p99.
The same trace is also replayed through the compute-free twin
(core/runtime.simulate_serve_schedule) so scheduling effects (admission
queueing, page pressure) are separable from compute cost.

Every run writes ``artifacts/BENCH_serve.json`` (schema: docs/cli.md) so the
serving trajectory is tracked across PRs. CPU wall-times are call-overhead
tracking, not accelerator perf — same caveat as kernel_bench.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from common import emit_csv, save_json
from repro.configs import get_config
from repro.core import events
from repro.core.runtime import simulate_serve_schedule
from repro.launch import serve


def _pct(xs, q):
    return float(np.percentile(np.asarray(xs, np.float64), q))


def main(requests=12, rate=8.0, seed=0, arch="nanogpt_134m",
         prompt_lens=(4, 16), gen_lens=(2, 8), n_slots=4, page_size=4,
         n_pages=32, temperature=0.0):
    cfg = get_config(arch, reduced=True)
    params = serve.make_demo_inputs(cfg, seed, 1, 1)[0]
    scfg = serve.ServeCfg(n_slots=n_slots, page_size=page_size,
                          n_pages=n_pages,
                          max_pages_per_seq=-(-max(prompt_lens[1] + gen_lens[1],
                                                   page_size) // page_size),
                          temperature=temperature, seed=seed)
    trace = events.poisson_trace(requests, rate=rate, seed=seed,
                                 prompt_lens=prompt_lens, gen_lens=gen_lens)
    out = serve.ServeEngine(params, cfg, scfg).run(trace)

    # shed/rejected requests never start and carry no latency samples
    ttft = [r["ttft_s"] for r in out["results"].values() if r and "ttft_s" in r]
    tpot = [r["tpot_s"] for r in out["results"].values() if r and "tpot_s" in r]
    sim = simulate_serve_schedule(trace, n_slots=n_slots, page_size=page_size,
                                  n_pages=n_pages)
    rows = [
        ("serve/steady_tok_s", round(out["steady_tok_s"], 1),
         f"total_tok_s={out['tok_s']:.1f};requests={requests};rate={rate}"),
        ("serve/ttft_us/p50", round(_pct(ttft, 50) * 1e6, 1),
         f"p99_us={_pct(ttft, 99) * 1e6:.1f}"),
        ("serve/tpot_us/p50", round(_pct(tpot, 50) * 1e6, 1),
         f"p99_us={_pct(tpot, 99) * 1e6:.1f}"),
        ("serve/pages_high_water", out["pages"]["high_water"],
         f"total={out['pages']['total']}"),
        ("serve/sim_twin_tok_s", round(sim["tok_s"], 1),
         f"decode_util={sim['utilization']['decode']:.2f};"
         f"peak_pages={sim['peak_pages']}"),
    ]
    emit_csv(rows)
    save_json("BENCH_serve.json", {
        "meta": {"platform": jax.default_backend(), "jax": jax.__version__,
                 "arch": arch, "requests": requests, "rate": rate,
                 "seed": seed, "prompt_lens": list(prompt_lens),
                 "gen_lens": list(gen_lens), "n_slots": n_slots,
                 "page_size": page_size, "n_pages": n_pages,
                 "temperature": temperature},
        "service": {
            "tok_s": out["tok_s"],
            "steady_tok_s": out["steady_tok_s"],
            "makespan_s": out["makespan_s"],
            "gen_tokens": out["gen_tokens"],
            "decode_steps": out["decode_steps"],
            "ttft_s": {"p50": _pct(ttft, 50), "p99": _pct(ttft, 99),
                       "max": float(max(ttft))},
            "tpot_s": {"p50": _pct(tpot, 50), "p99": _pct(tpot, 99),
                       "max": float(max(tpot))},
            "completed": out["completed"],
            "rejected": out["rejected"],
            "shed": out["shed"],
            "evicted": out["evicted"],
            "pages": out["pages"],
        },
        "sim_twin": {k: sim[k] for k in
                     ("makespan", "tok_s", "utilization", "peak_pages",
                      "queue_high_water")} | {
            "ttft_p50": _pct(sim["ttft"], 50),
            "ttft_p99": _pct(sim["ttft"], 99)},
    })
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--rate", type=float, default=8.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args()
    main(requests=args.requests, rate=args.rate, seed=args.seed,
         n_slots=args.slots)
