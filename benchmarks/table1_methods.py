"""Table 1: validation-quality comparison of async PP methods (scaled-down).

GPipe (sync) vs PipeDream vs PipeMare vs Ours vs Ours-No-WS, 8 stages, identical
synthetic data. The paper's claim to reproduce: Ours <= GPipe < PipeDream/PipeMare,
Ours-No-WS ~ GPipe. Memory class column matches the paper's Table 1.
"""
from __future__ import annotations

import argparse

from common import emit_csv, run_method, save_json
from repro.core.methods import get_method

METHODS = ["gpipe", "pipedream", "pipemare", "ours", "ours_nows"]


def main(steps=200, stages=8):
    rows, full = [], {}
    for m in METHODS:
        r = run_method(m, steps=steps, stages=stages)
        full[m] = r
        rows.append((f"table1/{m}", round(1e6 * r["wall_s"] / steps, 1),
                     f"final_loss={r['final']:.4f};ppl={r['ppl']:.2f};mem={get_method(m).memory}"))
    save_json("table1_methods.json", full)
    emit_csv(rows)
    # the paper's ordering claims, checked:
    ok1 = full["ours"]["final"] <= full["gpipe"]["final"] + 0.05
    ok2 = full["gpipe"]["final"] < min(full["pipedream"]["final"], full["pipemare"]["final"])
    ok3 = full["ours_nows"]["final"] <= full["pipedream"]["final"]
    print(f"# claims: ours<=gpipe:{ok1} gpipe<async-baselines:{ok2} nows<=pipedream:{ok3}"
          f" (floor={full['ours']['floor']:.3f})")
    return full


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--stages", type=int, default=8)
    a = ap.parse_args()
    main(a.steps, a.stages)
