"""Shared benchmark harness utilities."""
from __future__ import annotations

import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_config
from repro.core.engine import AsyncTrainer, EngineCfg
from repro.data.synthetic import make_batch_fn

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")


def run_method(method, *, arch="nanogpt_134m", steps=150, stages=8, lr=1e-3,
               batch=8, seq=64, seed=0, collect=True, straggler=None,
               warmup=0, log_every=0, n_periods=None):
    """Train `method` on the synthetic task; returns dict of curves."""
    cfg = get_config(arch, reduced=True)
    if n_periods is not None:  # paper Fig. 5: layers scale with stage count
        import dataclasses
        cfg = dataclasses.replace(cfg, n_periods=n_periods)
    ecfg = EngineCfg(n_stages=stages, lr=lr, warmup_steps=warmup, total_steps=steps,
                     constant_lr=warmup == 0, collect_metrics=collect,
                     straggler_delays=straggler)
    tr = AsyncTrainer(cfg, ecfg, method)
    state = tr.init(jax.random.PRNGKey(seed))
    step = tr.jit_step()
    batch_fn, src = make_batch_fn(cfg, 1, batch, seq, seed=seed)
    out = {"loss": [], "gap": [], "cos": []}
    t0 = time.time()
    for i in range(steps):
        state, m = step(state, batch_fn(i))
        out["loss"].append(float(m["loss"]))
        if "stage1_gap_rmse" in m:
            out["gap"].append(float(m["stage1_gap_rmse"]))
            out["cos"].append(float(m["stage1_align_cos"]))
        if log_every and (i + 1) % log_every == 0:
            print(f"  {method} step {i+1}: {out['loss'][-1]:.3f}", file=sys.stderr)
    out["wall_s"] = time.time() - t0
    out["floor"] = src.entropy_floor()
    out["final"] = float(np.mean(out["loss"][-10:]))
    out["ppl"] = float(np.exp(out["final"]))
    return out


def tail(xs, n=10):
    return float(np.mean(xs[-n:]))


def emit_csv(rows, header=("name", "us_per_call", "derived")):
    print(",".join(header))
    for r in rows:
        print(",".join(str(x) for x in r))


def save_json(name, obj):
    os.makedirs(ART, exist_ok=True)
    with open(os.path.join(ART, name), "w") as f:
        json.dump(obj, f, indent=1)
