"""Fig. 6 (+ Fig. 11): momentum-coefficient ablation and look-ahead/delay alignment.

Ours with beta1 in {0.9, 0.99}, adaptive (Eq. 13 stage momentum), and Ours-No-WS
with/without lr discounting; reports cos(Delta_t, d_t) at stage 1 — the empirical
Prop.-1 check at system scale."""
from __future__ import annotations

import argparse
import dataclasses

import numpy as np

from common import emit_csv, run_method, save_json
from repro.core.methods import METHODS as REG, Method


def main(steps=200, stages=8):
    # register ad-hoc variants
    variants = {
        "ours_b0.9": Method("ours_b0.9", optimizer="nadam", opt_kw=(("b1", 0.9),)),
        "ours_b0.99": REG["ours"],
        "ours_adaptive": REG["ours_adaptive_mom"],
        "ours_nows": REG["ours_nows"],
        # published-form ablation: keep the literal stage-keyed Eq. 13
        # momentum (tau_source axis: see core/methods.py / DESIGN.md §10)
        "ours_nows_nolr": Method("ours_nows_nolr", optimizer="nadam",
                                 bwd_point="current", stage_momentum=True,
                                 tau_source="stage_index", memory="O(N)"),
    }
    rows, full = [], {}
    for name, meth in variants.items():
        r = run_method(meth, steps=steps, stages=stages)
        full[name] = r
        cos_late = float(np.mean(r["cos"][-30:])) if r["cos"] else float("nan")
        rows.append((f"fig6/{name}", round(1e6 * r["wall_s"] / steps, 1),
                     f"final_loss={r['final']:.4f};align_cos={cos_late:.3f}"))
    save_json("fig6_momentum_ablation.json", full)
    emit_csv(rows)
    c9 = np.mean(full["ours_b0.9"]["cos"][-30:])
    c99 = np.mean(full["ours_b0.99"]["cos"][-30:])
    print(f"# alignment: b1=0.9 -> {c9:.3f}, b1=0.99 -> {c99:.3f} "
          f"(paper claim: higher momentum aligns better)")
    return full


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    a = ap.parse_args()
    main(a.steps)
