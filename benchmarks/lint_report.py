"""Bench section: run repro-lint and persist artifacts/LINT_report.json.

Keeps the lint status (rule counts, suppressions in use) in the bench
trajectory so suppression-count growth is visible run over run, the same
way perf numbers are.  Prints the standard ``name,value,derived`` CSV row.
"""
import os

import common

from repro.analysis import engine as lint_engine
from repro.analysis.lint import build_report

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def main() -> dict:
    result = lint_engine.lint_tree(ROOT)
    payload = build_report(result, ROOT)
    common.save_json("LINT_report.json", payload)
    counts = ",".join(f"{k}:{v}" for k, v in sorted(result.counts().items()))
    print(f"lint_findings,{len(result.findings)},[{counts}]")
    print(f"lint_suppressions,{len(result.suppressions)},"
          f"{[s.rule for s in result.suppressions]}")
    if result.findings:
        for f in result.findings:
            print(f"# LINT {f.render()}")
    return payload


if __name__ == "__main__":
    main()
