"""Fig. 7: the (1-gamma_t) gradient discount is what makes delayed NAG work.

Ours vs PipeDream-NAG-Base (same optimizer with the discount removed); the paper
reports an order-of-magnitude larger stage-1 weight discrepancy without it."""
from __future__ import annotations

import argparse

import numpy as np

from common import emit_csv, run_method, save_json


def main(steps=200, stages=8):
    rows, full = [], {}
    for m in ("ours", "nag_base"):
        r = run_method(m, steps=steps, stages=stages, lr=5e-4)
        full[m] = r
        rows.append((f"fig7/{m}", round(1e6 * r["wall_s"] / steps, 1),
                     f"final_loss={r['final']:.4f};stage1_gap={np.mean(r['gap'][-20:]):.3e}"))
    save_json("fig7_discount_ablation.json", full)
    emit_csv(rows)
    ratio = np.mean(full["nag_base"]["gap"][-20:]) / max(np.mean(full["ours"]["gap"][-20:]), 1e-12)
    print(f"# gap ratio nag_base/ours = {ratio:.1f}x (paper: ~order of magnitude); "
          f"loss {full['nag_base']['final']:.3f} vs {full['ours']['final']:.3f}")
    return full


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    a = ap.parse_args()
    main(a.steps)
