"""Kernel microbenchmarks: pallas (interpret on CPU) vs pure-jnp oracle.

Wall-times on CPU interpret mode are NOT TPU perf — correctness + call-overhead
tracking only; the TPU perf story is in the roofline analysis."""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from common import emit_csv
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.nag_update import nag_update
from repro.kernels.ssd_scan import ssd_scan


def timeit(fn, *a, n=5, **kw):
    out = fn(*a, **kw)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(n):
        out = fn(*a, **kw)
    jax.block_until_ready(out)
    return (time.time() - t0) / n * 1e6


def main():
    rows = []
    key = jax.random.PRNGKey(0)

    B, H, Hkv, S, d = 1, 4, 2, 512, 64
    q = jax.random.normal(key, (B, H, S, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, Hkv, S, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, Hkv, S, d))
    fa = jax.jit(lambda *x: flash_attention(*x, causal=True, block_q=128, block_k=128))
    fr = jax.jit(lambda *x: ref.attention_ref(*x, causal=True))
    err = float(jnp.max(jnp.abs(fa(q, k, v) - fr(q, k, v))))
    rows.append(("kernel/flash_attention", round(timeit(fa, q, k, v), 1),
                 f"ref_us={timeit(fr, q, k, v):.1f};maxerr={err:.1e}"))

    b, S2, Hh, P, G, N = 1, 512, 4, 32, 1, 32
    x = jax.random.normal(key, (b, S2, Hh, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 3), (b, S2, Hh))) * 0.1
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 4), (Hh,)) * 0.3)
    B_ = jax.random.normal(jax.random.fold_in(key, 5), (b, S2, G, N)) * 0.3
    C_ = jax.random.normal(jax.random.fold_in(key, 6), (b, S2, G, N)) * 0.3
    sk = jax.jit(lambda *a_: ssd_scan(*a_, chunk=128)[0])
    sr = jax.jit(lambda *a_: ref.ssd_ref(*a_)[0])
    err = float(jnp.max(jnp.abs(sk(x, dt, A, B_, C_) - sr(x, dt, A, B_, C_))))
    rows.append(("kernel/ssd_scan", round(timeit(sk, x, dt, A, B_, C_), 1),
                 f"ref_us={timeit(sr, x, dt, A, B_, C_):.1f};maxerr={err:.1e}"))

    n = 1 << 16
    p = jax.random.normal(key, (n,))
    m = jnp.zeros(n)
    v2 = jnp.ones(n) * 0.01
    g = jax.random.normal(jax.random.fold_in(key, 7), (n,))
    kw = dict(lr=1e-3, mu_t=0.95, mu_next=0.96, mu_prod=0.9, mu_prod_next=0.87, bc2=0.05)
    nk = jax.jit(lambda *a_: nag_update(*a_, **kw)[0])
    nr = jax.jit(lambda *a_: ref.nag_update_ref(*a_, b1=0.99, b2=0.95, eps=1e-8,
                                                wd=0.01, **kw)[0])
    err = float(jnp.max(jnp.abs(nk(p, m, v2, g) - nr(p, m, v2, g))))
    rows.append(("kernel/nag_update", round(timeit(nk, p, m, v2, g), 1),
                 f"ref_us={timeit(nr, p, m, v2, g):.1f};maxerr={err:.1e}"))
    emit_csv(rows)
    return rows


if __name__ == "__main__":
    main()
