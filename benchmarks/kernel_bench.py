"""Kernel microbenchmarks + end-to-end engine-tick dispatch benchmark.

Micro rows: per kernel, a ``fwd`` row (pallas interpret on CPU vs the pure-jnp
oracle) and a ``bwd`` row (jax.grad through dispatch_grad — the dedicated
backward kernels where registered — vs ref autodiff). The ``engine_tick_fwd_bwd/*``
rows time a full AsyncTrainer 'ours' tick — forward AND backward AND optimizer
— with the dispatch layer set to 'ref' (unfused tree-map optimizer + unfused
XLA model ops) vs the dispatched backend (fused flat-buffer nag_update + fused
model kernels fwd+bwd), so the fused-path win is measured end to end rather
than asserted.

Wall-times on CPU interpret mode are NOT TPU perf — correctness + call-overhead
tracking only; the TPU perf story is in the roofline analysis. On CPU the
engine-tick comparison therefore defaults to pitting 'ref' against the fused
path with --engine-backend=ref semantics (same backend, fused vs tree-map
optimizer), isolating the pass-count effect the flat buffer exists for; pass
--engine-backend=pallas on TPU for the real fused-kernel tick.

Every run also writes ``artifacts/BENCH_kernels.json`` (machine-readable rows +
environment metadata) so the perf trajectory is tracked across PRs.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from common import emit_csv, save_json
from repro.kernels import dispatch as kdispatch
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.nag_update import nag_update
from repro.kernels.paged_attention import paged_attn_decode, paged_attn_decode_ref
from repro.kernels.rmsnorm_residual import rmsnorm_residual, rmsnorm_residual_ref
from repro.kernels.ssd_scan import ssd_scan


def timeit(fn, *a, n=5, **kw):
    out = fn(*a, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*a, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


def _grad_pair(name, args, kwargs):
    """(kernel-bwd grad fn, ref-autodiff grad fn) for op `name`, both jitted."""
    def loss(backend):
        def f(*xs):
            out = kdispatch.dispatch_grad(name, *xs, backend=backend, **kwargs)
            return sum(jnp.sum(l.astype(jnp.float32)) for l in jax.tree.leaves(out))
        return f

    argnums = tuple(range(len(args)))
    return (jax.jit(jax.grad(loss("interpret"), argnums=argnums)),
            jax.jit(jax.grad(loss("ref"), argnums=argnums)))


def micro_rows():
    rows = []
    key = jax.random.PRNGKey(0)

    B, H, Hkv, S, d = 1, 4, 2, 512, 64
    q = jax.random.normal(key, (B, H, S, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, Hkv, S, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, Hkv, S, d))
    attn_kw = dict(causal=True, block_q=128, block_k=128)
    fa = jax.jit(lambda *x: flash_attention(*x, **attn_kw))
    fr = jax.jit(lambda *x: ref.attention_ref(*x, causal=True))
    err = float(jnp.max(jnp.abs(fa(q, k, v) - fr(q, k, v))))
    rows.append(("kernel/flash_attention/fwd", round(timeit(fa, q, k, v), 1),
                 f"ref_us={timeit(fr, q, k, v):.1f};maxerr={err:.1e}"))
    gk, gr = _grad_pair("flash_attention", (q, k, v), attn_kw)
    rows.append(("kernel/flash_attention/bwd", round(timeit(gk, q, k, v), 1),
                 f"ref_us={timeit(gr, q, k, v):.1f}"))

    b, S2, Hh, P, G, N = 1, 512, 4, 32, 1, 32
    x = jax.random.normal(key, (b, S2, Hh, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 3), (b, S2, Hh))) * 0.1
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 4), (Hh,)) * 0.3)
    B_ = jax.random.normal(jax.random.fold_in(key, 5), (b, S2, G, N)) * 0.3
    C_ = jax.random.normal(jax.random.fold_in(key, 6), (b, S2, G, N)) * 0.3
    sk = jax.jit(lambda *a_: ssd_scan(*a_, chunk=128)[0])
    sr = jax.jit(lambda *a_: ref.ssd_ref(*a_)[0])
    err = float(jnp.max(jnp.abs(sk(x, dt, A, B_, C_) - sr(x, dt, A, B_, C_))))
    rows.append(("kernel/ssd_scan/fwd", round(timeit(sk, x, dt, A, B_, C_), 1),
                 f"ref_us={timeit(sr, x, dt, A, B_, C_):.1f};maxerr={err:.1e}"))
    gk, gr = _grad_pair("ssd_scan", (x, dt, A, B_, C_), dict(chunk=128))
    rows.append(("kernel/ssd_scan/bwd", round(timeit(gk, x, dt, A, B_, C_), 1),
                 f"ref_us={timeit(gr, x, dt, A, B_, C_):.1f}"))

    n = 1 << 16
    p = jax.random.normal(key, (n,))
    m = jnp.zeros(n)
    v2 = jnp.ones(n) * 0.01
    g = jax.random.normal(jax.random.fold_in(key, 7), (n,))
    kw = dict(lr=1e-3, mu_t=0.95, mu_next=0.96, mu_prod=0.9, mu_prod_next=0.87, bc2=0.05)
    nk = jax.jit(lambda *a_: nag_update(*a_, **kw)[0])
    nr = jax.jit(lambda *a_: ref.nag_update_ref(*a_, b1=0.99, b2=0.95, eps=1e-8,
                                                wd=0.01, **kw)[0])
    err = float(jnp.max(jnp.abs(nk(p, m, v2, g) - nr(p, m, v2, g))))
    rows.append(("kernel/nag_update/fwd", round(timeit(nk, p, m, v2, g), 1),
                 f"ref_us={timeit(nr, p, m, v2, g):.1f};maxerr={err:.1e}"))
    # nag_update is an optimizer step, not a differentiated-through model op —
    # its bwd is the ref-VJP fallback; time it anyway for fallback-cost tracking
    gk, gr = _grad_pair("nag_update", (p, m, v2, g), dict(**kw, block=1024))
    rows.append(("kernel/nag_update/bwd", round(timeit(gk, p, m, v2, g), 1),
                 f"ref_us={timeit(gr, p, m, v2, g):.1f};fallback=ref_vjp"))

    # paged decode attention (serving path): inference-only, fwd row only
    Bp, Hp, Hkvp, dp, PS, NP, MAXP = 4, 4, 2, 64, 16, 64, 8
    qd = jax.random.normal(key, (Bp, Hp, dp))
    kp = jax.random.normal(jax.random.fold_in(key, 10), (NP, PS, Hkvp, dp))
    vp = jax.random.normal(jax.random.fold_in(key, 11), (NP, PS, Hkvp, dp))
    pt = jax.random.permutation(
        jax.random.fold_in(key, 12), NP)[:Bp * MAXP].reshape(Bp, MAXP)
    lens = jax.random.randint(jax.random.fold_in(key, 13), (Bp,), 1, MAXP * PS)
    pk = jax.jit(lambda *a_: paged_attn_decode(*a_, interpret=True))
    pr = jax.jit(paged_attn_decode_ref)
    err = float(jnp.max(jnp.abs(pk(qd, kp, vp, pt, lens) -
                                pr(qd, kp, vp, pt, lens))))
    rows.append(("kernel/paged_attn_decode/fwd",
                 round(timeit(pk, qd, kp, vp, pt, lens), 1),
                 f"ref_us={timeit(pr, qd, kp, vp, pt, lens):.1f};maxerr={err:.1e}"))

    x = jax.random.normal(key, (8, 128, 256))
    h = jax.random.normal(jax.random.fold_in(key, 8), (8, 128, 256))
    sc = jax.random.normal(jax.random.fold_in(key, 9), (256,)) * 0.1
    rk = jax.jit(lambda *a_: rmsnorm_residual(*a_)[1])
    rr = jax.jit(lambda *a_: rmsnorm_residual_ref(*a_)[1])
    err = float(jnp.max(jnp.abs(rk(x, h, sc) - rr(x, h, sc))))
    rows.append(("kernel/rmsnorm_residual/fwd", round(timeit(rk, x, h, sc), 1),
                 f"ref_us={timeit(rr, x, h, sc):.1f};maxerr={err:.1e}"))
    gk, gr = _grad_pair("rmsnorm_residual", (x, h, sc), {})
    rows.append(("kernel/rmsnorm_residual/bwd", round(timeit(gk, x, h, sc), 1),
                 f"ref_us={timeit(gr, x, h, sc):.1f}"))
    return rows


def engine_tick_rows(backend: str, ticks: int = 10):
    """Full engine ticks (fwd+bwd+optimizer), dispatched vs unfused: the
    end-to-end number.

    'ref' row: kernel_backend='ref' + tree-map optimizer (the seed hot path).
    'dispatched' row: kernel_backend=backend, fused flat-buffer optimizer (+
    fused model kernels, forward and backward, when backend != 'ref').
    """
    import os

    from repro.configs import get_config
    from repro.core.engine import AsyncTrainer, EngineCfg
    from repro.data.synthetic import make_batch_fn

    # the env var would override BOTH rows' cfg fields and silently turn the
    # 'unfused' baseline into the dispatched backend — clear it for the measure
    env_backend = os.environ.pop(kdispatch.ENV_VAR, None)

    def tick_us(kernel_backend, fused):
        cfg = get_config("nanogpt_134m", reduced=True,
                         kernel_backend=kernel_backend)
        ecfg = EngineCfg(n_stages=4, lr=1e-3, constant_lr=True,
                         collect_metrics=False, kernel_backend=kernel_backend,
                         fused_optimizer=fused)
        tr = AsyncTrainer(cfg, ecfg, "ours")
        state = tr.init(jax.random.PRNGKey(0))
        step = tr.jit_step(donate=False)
        batch_fn, _ = make_batch_fn(cfg, 1, 8, 64, seed=0)
        state, m = step(state, batch_fn(0))  # compile
        jax.block_until_ready(m["loss"])
        t0 = time.perf_counter()
        for i in range(ticks):
            state, m = step(state, batch_fn(i))
        jax.block_until_ready(m["loss"])
        return (time.perf_counter() - t0) / ticks * 1e6, tr.opt.kind

    try:
        base_us, base_kind = tick_us("ref", False)
        disp_us, disp_kind = tick_us(backend, True)
    finally:
        if env_backend is not None:
            os.environ[kdispatch.ENV_VAR] = env_backend
    return [
        ("engine_tick_fwd_bwd/unfused", round(base_us, 1),
         f"opt={base_kind};backend=ref"),
        ("engine_tick_fwd_bwd/dispatched", round(disp_us, 1),
         f"opt={disp_kind};backend={backend};speedup={base_us / disp_us:.2f}x"),
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine-backend", default="ref",
                    help="dispatch backend for the engine-tick rows "
                         "(ref on CPU; pallas on TPU; interpret = slow, debug only)")
    ap.add_argument("--ticks", type=int, default=10)
    ap.add_argument("--skip-engine", action="store_true")
    args = ap.parse_args()
    rows = micro_rows()
    if not args.skip_engine:
        rows += engine_tick_rows(args.engine_backend, ticks=args.ticks)
    emit_csv(rows)
    save_json("BENCH_kernels.json", {
        "meta": {"platform": jax.default_backend(),
                 "jax": jax.__version__,
                 "engine_backend": None if args.skip_engine else args.engine_backend,
                 "ticks": args.ticks},
        "rows": [{"name": nm, "us_per_call": us, "derived": dv}
                 for nm, us, dv in rows],
    })
    return rows


if __name__ == "__main__":
    main()
