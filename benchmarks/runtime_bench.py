"""`runtime` rows: event-driven async runtime vs the single-jit engine.

Measures real ticks/s of both execution paths on the reduced model (the jit
engine amortizes everything into one compiled program; the event runtime pays
per-stage dispatch for deployment fidelity), plus compute-free schedule
simulations quantifying straggler/jitter cost in simulated-clock units.

Three calibration/adaptation/equivalence sections (DESIGN.md §10) also land in
artifacts/BENCH_runtime.json:

- `trace_*`: per-op fwd/bwd latencies measured from a real run
  (RuntimeCfg.record_trace) saved as artifacts/TRACE_runtime.json, then
  replayed through the compute-free simulator — measured, not synthetic,
  distributions.
- `adapt_*`: `ours_delay_adaptive` with tau_source="observed" (delay-keyed
  momentum) vs its stage-index twin under straggler / jitter / churn and the
  recorded trace — the payoff of reacting to measured staleness.
- `k_equiv_K*`: at K ∈ {1, 2, 4}, event runtime vs (a) the engine's grouped
  per-microbatch [P, K] stash replay and (b) the OLD single-point
  idealization (all K microbatches at Eq. 5's scalar) — the measured answer
  to "which replay strategy matters at realistic K": (a) tracks the runtime
  at fp tolerance, (b) drifts as soon as K > 1.

- `chaos_*`: fault-injection A/B/C (DESIGN.md §11) — the same seed run
  fault-free, with faults injected (quarantine + transport retry only), and
  with the full recovery stack (divergence watchdog rolling back to verified
  checkpoints): the measured loss gap and wall overhead of surviving
  `nan_grad`/`drop`/`dup` fault loads.

- `mesh_*`: cross-replica sync A/B (DESIGN.md §13) — the barrier SwarmTrainer
  vs the fully-async gossip MeshTrainer (sync as runtime events, no barrier)
  vs gossip with the ZeRO-1 sharded optimizer, same seeds/data. Each row
  carries the per-replica optimizer-state bytes next to the replicated
  baseline — the measured memory payoff of sharding.

Sections run individually via --sections (comma list of
throughput,trace,adapt,sim,k_equiv,chaos,mesh); a partial run merges its rows
into an existing BENCH_runtime.json instead of clobbering the other sections.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from common import ART, emit_csv, save_json
from repro.configs import get_config
from repro.core import delay
from repro.core.engine import AsyncTrainer, EngineCfg
from repro.core.methods import get_method
from repro.core.runtime import EventRuntime, RuntimeCfg, simulate_schedule
from repro.data.synthetic import make_batch_fn

SECTIONS = ("throughput", "trace", "adapt", "sim", "k_equiv", "chaos", "mesh")


def main(steps=40, stages=4, sections=None):
    sections = set(sections or SECTIONS)
    unknown = sections - set(SECTIONS)
    if unknown:
        raise SystemExit(f"unknown --sections {sorted(unknown)}; "
                         f"choose from {SECTIONS}")
    cfg = get_config("nanogpt_134m", reduced=True)
    ecfg = EngineCfg(n_stages=stages, lr=1e-3, constant_lr=True,
                     collect_metrics=False)
    batch_fn, _ = make_batch_fn(cfg, 1, 4, 64, seed=0)
    rows, full = [], {}
    ev_dt = jit_dt = None

    if "throughput" in sections:
        # jit engine ticks/s
        tr = AsyncTrainer(cfg, ecfg, "ours")
        state = tr.init(jax.random.PRNGKey(0))
        step = tr.jit_step()
        state, _ = step(state, batch_fn(0))  # compile
        t0 = time.time()
        for i in range(1, steps):
            state, m = step(state, batch_fn(i))
        jax.block_until_ready(m["loss"])
        jit_dt = (time.time() - t0) / max(steps - 1, 1)
        rows.append(("runtime/jit_engine", round(1e6 * jit_dt, 1),
                     f"ticks_s={1.0 / jit_dt:.2f}"))

        # event runtime ticks/s (fixed delays — same semantics, real execution
        # order; the loop keeps losses on device and host-syncs once at drain)
        rt = EventRuntime(AsyncTrainer(cfg, ecfg, "ours"))
        rt.init(jax.random.PRNGKey(0))
        rt.run(batch_fn, 1)  # compile per-stage kernels
        t0 = time.time()
        res = rt.run(batch_fn, steps - 1)
        ev_dt = (time.time() - t0) / max(steps - 1, 1)
        rows.append(("runtime/event_fixed", round(1e6 * ev_dt, 1),
                     f"ticks_s={1.0 / ev_dt:.2f};overhead_x={ev_dt / jit_dt:.2f}"))
        full["event_fixed"] = {"losses": res.losses,
                               "utilization": list(res.utilization),
                               "max_tau_obs": list(res.max_tau_obs)}

        # event runtime under churn: one stage leaves mid-run and rejoins; the
        # outage is paid in stash/mailbox memory + observed tau, never a drain
        half = max(steps // 2, 2)
        rt = EventRuntime(AsyncTrainer(cfg, ecfg, "ours"),
                          RuntimeCfg(churn=f"1,{3 * half},{3 * (steps // 8 or 1)}"))
        rt.init(jax.random.PRNGKey(0))
        rt.run(batch_fn, 1)
        t0 = time.time()
        resc = rt.run(batch_fn, steps - 1)
        ch_dt = (time.time() - t0) / max(steps - 1, 1)
        rows.append(("runtime/event_churn", round(1e6 * ch_dt, 1),
                     f"ticks_s={1.0 / ch_dt:.2f};"
                     f"outage={max(resc.outage_time):.0f};"
                     f"max_tau={max(resc.max_tau_obs):.0f};"
                     f"mbox_hw={max(hw for s in range(1, stages) for hw in resc.mailbox_high_water[s])}"))
        full["event_churn"] = {
            "losses": resc.losses, "utilization": list(resc.utilization),
            "max_tau_obs": list(resc.max_tau_obs),
            "outage_time": list(resc.outage_time),
            "max_stash": list(resc.max_stash),
            "mailbox_high_water": [list(hw) for hw in resc.mailbox_high_water]}

    trace_path = os.path.join(ART, "TRACE_runtime.json")
    if sections & {"trace", "adapt"}:
        # trace calibration: measure real per-op latencies (the --record-trace
        # hook; mb 0 pays compile, so the recorder is reset after a warmup
        # tick), save the TraceDelay JSON, and replay the MEASURED
        # distribution through the compute-free simulator
        rec_ticks = max(steps // 4, 8)
        rt = EventRuntime(AsyncTrainer(cfg, ecfg, "ours"),
                          RuntimeCfg(record_trace=True))
        rt.init(jax.random.PRNGKey(0))
        rt.run(batch_fn, 1)
        rt.reset_recorder()  # drop the compile-inflated first-tick samples
        rt.run(batch_fn, rec_ticks)
        os.makedirs(ART, exist_ok=True)
        rt.recorder.save(trace_path)
        tr_traces = rt.recorder.traces()
        mean_fwd = float(np.mean([x for row in tr_traces["fwd"] for x in row]))
        mean_bwd = float(np.mean([x for row in tr_traces["bwd"] for x in row]))
        sim_t = simulate_schedule(P=stages, K=1, n_ticks=rec_ticks,
                                  delay_model=f"trace:{trace_path}")
        rows.append(("runtime/sim_trace_replay",
                     round(1e6 * sim_t["makespan"] / rec_ticks, 1),
                     f"util_min={min(sim_t['utilization']):.2f};"
                     f"max_tau={max(sim_t['max_tau_obs']):.0f};"
                     f"mean_fwd_us={1e6 * mean_fwd:.0f};"
                     f"mean_bwd_us={1e6 * mean_bwd:.0f}"))
        full["trace_replay"] = {
            "trace_path": os.path.relpath(trace_path, ART),
            "recorded_ticks": rec_ticks,
            "mean_fwd_s": mean_fwd, "mean_bwd_s": mean_bwd,
            "utilization": list(sim_t["utilization"]),
            "max_tau_obs": list(sim_t["max_tau_obs"]),
            "max_stash": list(sim_t["max_stash"])}

    if "adapt" in sections:
        # observed-tau-adaptive momentum vs the stage-index Eq. 13 keying,
        # under regimes where measured staleness actually departs from the
        # Eq. 5 schedule — stragglers, jitter, churn, and the recorded trace
        m_obs = get_method("ours_delay_adaptive")
        m_idx = dataclasses.replace(m_obs,
                                    name="ours_delay_adaptive_stage_index",
                                    tau_source="stage_index")
        adapt_ticks = max(steps // 2, 12)
        mid = 3 * (adapt_ticks // 2)
        regimes = [("straggler", "straggler:1,4.0", None, 8),
                   ("jitter", "jitter:0.4", None, 8),
                   ("churn", "fixed", f"1,{mid},{mid // 3}", None),
                   ("trace", f"trace:{trace_path}", None, None)]
        for tag, spec, churn, in_flight in regimes:
            pair, wall = {}, {}
            for vtag, meth in (("obs", m_obs), ("idx", m_idx)):
                rte = EventRuntime(AsyncTrainer(cfg, ecfg, meth),
                                   RuntimeCfg(delay_model=spec, churn=churn,
                                              in_flight=in_flight))
                rte.init(jax.random.PRNGKey(0))  # same key -> identical init
                rte.run(batch_fn, 1)  # compile per-stage jits outside the timer
                t0 = time.time()
                pair[vtag] = rte.run(batch_fn, adapt_ticks)
                wall[vtag] = (time.time() - t0) / adapt_ticks
            dl = np.abs(np.asarray(pair["obs"].losses)
                        - np.asarray(pair["idx"].losses))
            rows.append((f"runtime/adapt_{tag}", round(1e6 * wall["obs"], 1),
                         f"final_obs={pair['obs'].losses[-1]:.4f};"
                         f"final_idx={pair['idx'].losses[-1]:.4f};"
                         f"max_dloss={dl.max():.4f};"
                         f"max_tau={max(pair['obs'].max_tau_obs):.0f}"))
            full[f"adapt_{tag}"] = {
                "delay_model": spec, "churn": churn, "ticks": adapt_ticks,
                "obs_losses": pair["obs"].losses,
                "idx_losses": pair["idx"].losses,
                "mean_dloss": float(dl.mean()), "max_dloss": float(dl.max()),
                "max_tau_obs": list(pair["obs"].max_tau_obs),
                "taus_last": list(pair["obs"].taus[-1])}

    if "sim" in sections:
        # schedule-only simulations: throughput cost of delay + membership
        sim_cells = [("fixed", None), ("jitter:0.3", None),
                     ("straggler:0,4.0", None),
                     ("fixed", "1,200,100"), ("jitter:0.3", "1,200,100")]
        for spec, churn in sim_cells:
            sim = simulate_schedule(P=stages, K=1, n_ticks=200,
                                    delay_model=spec, churn=churn)
            tag = spec.split(":")[0] + ("_churn" if churn else "")
            derived = (f"util_min={min(sim['utilization']):.2f};"
                       f"max_tau={max(sim['max_tau_obs']):.0f}")
            if churn:
                derived += (f";outage={max(sim['outage_time']):.0f};"
                            f"max_stash={max(sim['max_stash'])}")
            rows.append((f"runtime/sim_{tag}",
                         round(1e6 * sim["makespan"] / 200, 1), derived))
            full[f"sim_{spec}" + (f"_churn_{churn}" if churn else "")] = {
                "utilization": list(sim["utilization"]),
                "max_tau_obs": list(sim["max_tau_obs"]),
                "max_stash": list(sim["max_stash"]),
                "outage_time": list(sim["outage_time"]),
                "mailbox_high_water": [list(hw) for hw in sim["mailbox_high_water"]]}

    if "k_equiv" in sections:
        # K>1 per-microbatch replay equivalence A/B: event runtime vs the
        # engine's grouped [P, K] stash replay (the default at K>1) and vs
        # the pre-grouping single-point idealization (Eq. 5 scalar broadcast,
        # the legacy [P]-vector path). grouped tracks the runtime at fp
        # tolerance at every K; legacy only at K=1, where the two coincide.
        k_ticks = max(steps // 5, 6)
        for K in (1, 2, 4):
            kb_fn, _ = make_batch_fn(cfg, K, 2, 64, seed=0)
            ek = dataclasses.replace(ecfg, update_interval=K)

            rt = EventRuntime(AsyncTrainer(cfg, ek, "ours"))
            rt.init(jax.random.PRNGKey(0))
            res = rt.run(kb_fn, k_ticks)

            def engine_losses(taus_of_t):
                tr = AsyncTrainer(cfg, ek, "ours")
                s = tr.init(jax.random.PRNGKey(0))
                step = tr.jit_step(donate=False)
                losses, dts = [], []
                for t in range(k_ticks):
                    t0 = time.time()
                    s, m = step(s, kb_fn(t), taus_of_t(t))
                    losses.append(float(m["loss"]))
                    dts.append(time.time() - t0)
                # first tick pays compile; report the steady-state mean
                return losses, float(np.mean(dts[1:] or dts))

            grouped, g_dt = engine_losses(lambda t: None)  # [P, K] default
            legacy, _ = engine_losses(
                lambda t, v=jnp.asarray(delay.stage_delays(stages, K),
                                        jnp.int32): v)
            dl_g = float(np.abs(np.asarray(grouped)
                                - np.asarray(res.losses)).max())
            dl_l = float(np.abs(np.asarray(legacy)
                                - np.asarray(res.losses)).max())
            rows.append((f"runtime/k_equiv_K{K}", round(1e6 * g_dt, 1),
                         f"max_dloss_grouped={dl_g:.2e};"
                         f"max_dloss_legacy={dl_l:.2e};ticks={k_ticks}"))
            full[f"k_equiv_K{K}"] = {
                "K": K, "ticks": k_ticks,
                "runtime_losses": res.losses,
                "engine_grouped_losses": grouped,
                "engine_legacy_losses": legacy,
                "max_dloss_grouped": dl_g, "max_dloss_legacy": dl_l,
                "tau_groups_last": [list(g) for g in res.tau_groups[-1]],
                "stage_mb_delays": [list(r) for r in
                                    delay.stage_mb_delays(stages, K)]}

    if "chaos" in sections:
        # fault-injection A/B/C: identical seed + data, (a) fault-free,
        # (b) faults injected with only the always-on quarantine + transport
        # retry defending, (c) faults + the full recovery stack (watchdog
        # rollback to verified checkpoints). The (c)-vs-(a) loss gap and wall
        # overhead are the measured price of surviving the fault load
        # (DESIGN.md §11).
        import tempfile

        from repro.launch.train import run_event_loop

        chaos_ticks = max(steps // 2, 12)
        chaos_spec = "nan_grad=0.05,drop=0.03,dup=0.03"

        rt = EventRuntime(AsyncTrainer(cfg, ecfg, "ours"))
        rt.init(jax.random.PRNGKey(0))
        rt.run(batch_fn, 1)  # compile outside the timer
        t0 = time.time()
        base = rt.run(batch_fn, chaos_ticks)
        base_dt = (time.time() - t0) / chaos_ticks

        rt = EventRuntime(AsyncTrainer(cfg, ecfg, "ours"),
                          RuntimeCfg(faults=chaos_spec))
        rt.init(jax.random.PRNGKey(0))
        rt.run(batch_fn, 1)
        t0 = time.time()
        inj = rt.run(batch_fn, chaos_ticks)
        inj_dt = (time.time() - t0) / chaos_ticks

        with tempfile.TemporaryDirectory() as ckdir:
            t0 = time.time()
            _, rec = run_event_loop(
                AsyncTrainer(cfg, ecfg, "ours"), batch_fn, chaos_ticks,
                seed=0, ckpt_dir=ckdir, ckpt_every=max(chaos_ticks // 3, 4),
                faults=chaos_spec, watchdog="on", max_rollbacks=50,
                log_fn=lambda *_: None)
            rec_dt = (time.time() - t0) / chaos_ticks

        dl_inj = abs(inj.losses[-1] - base.losses[-1])
        dl_rec = abs(rec.losses[-1] - base.losses[-1])
        rows.append(("runtime/chaos_fault_free", round(1e6 * base_dt, 1),
                     f"final={base.losses[-1]:.4f};ticks={chaos_ticks}"))
        rows.append(("runtime/chaos_injected", round(1e6 * inj_dt, 1),
                     f"final={inj.losses[-1]:.4f};dloss={dl_inj:.4f};"
                     f"skipped={sum(inj.nonfinite_skipped)};"
                     f"retx={inj.retransmits};dup={inj.duplicates}"))
        rows.append(("runtime/chaos_recovery", round(1e6 * rec_dt, 1),
                     f"final={rec.losses[-1]:.4f};dloss={dl_rec:.4f};"
                     f"rollbacks={rec.rollbacks};"
                     f"skipped={rec.nonfinite_skipped};"
                     f"overhead_x={rec_dt / base_dt:.2f}"))
        full["chaos"] = {
            "faults": chaos_spec, "ticks": chaos_ticks,
            "fault_free": {"losses": base.losses, "tick_s": base_dt},
            "injected": {"losses": inj.losses, "tick_s": inj_dt,
                         "nonfinite_skipped": list(inj.nonfinite_skipped),
                         "retransmits": inj.retransmits,
                         "duplicates": inj.duplicates,
                         "final_dloss": dl_inj},
            "recovery": {"losses": rec.losses, "tick_s": rec_dt,
                         "nonfinite_skipped": rec.nonfinite_skipped,
                         "retransmits": rec.retransmits,
                         "rollbacks": rec.rollbacks,
                         "final_dloss": dl_rec,
                         "overhead_x": rec_dt / base_dt},
        }

    if "mesh" in sections:
        # cross-replica sync A/B (DESIGN.md §13): barrier SwarmTrainer vs the
        # fully-async gossip MeshTrainer vs gossip + ZeRO-1 sharded optimizer,
        # same key and per-replica data streams. The derived column pairs the
        # per-replica optimizer-state bytes with the replicated baseline —
        # sharding's memory payoff measured, not computed on paper.
        from repro.core.swarm import MeshCfg, MeshTrainer, SwarmCfg, SwarmTrainer

        R, period = 2, 2
        mesh_ticks = max(steps // 5, 6)
        bfs = [make_batch_fn(cfg, 1, 2, 64, seed=r)[0] for r in range(R)]
        mecfg = dataclasses.replace(ecfg, n_stages=2)
        key = jax.random.PRNGKey(0)

        t0 = time.time()
        sw = SwarmTrainer(cfg, mecfg, "ours",
                          SwarmCfg(replicas=R, sync_every=period))
        bres = sw.run_event(bfs, mesh_ticks, key=key)
        b_dt = (time.time() - t0) / mesh_ticks

        cells = [("gossip", MeshCfg(replicas=R, period=period)),
                 ("gossip_zero1", MeshCfg(replicas=R, period=period,
                                          opt_shard=True))]
        mesh_res = {}
        for tag, mcfg in cells:
            t0 = time.time()
            mt = MeshTrainer(cfg, mecfg, "ours", mcfg)
            mesh_res[tag] = mt.run_gossip(bfs, mesh_ticks, key=key)
            mesh_res[tag]["tick_s"] = (time.time() - t0) / mesh_ticks

        b_final = [ls[-1] for ls in bres["losses"]]
        b_bytes = mesh_res["gossip"]["opt_bytes_replicated"]
        rows.append(("runtime/mesh_barrier", round(1e6 * b_dt, 1),
                     f"final={np.mean(b_final):.4f};syncs={bres['n_syncs']};"
                     f"opt_bytes_replica={b_bytes};"
                     f"opt_bytes_replicated={b_bytes}"))
        for tag in mesh_res:
            mres = mesh_res[tag]
            m_final = [ls[-1] for ls in mres["losses"]]
            rows.append((f"runtime/mesh_{tag}",
                         round(1e6 * mres["tick_s"], 1),
                         f"final={np.mean(m_final):.4f};"
                         f"absorbed={mres['absorbed']};"
                         f"stale_dropped={mres['stale_dropped']};"
                         f"opt_bytes_replica={mres['opt_bytes_per_replica']};"
                         f"opt_bytes_replicated={mres['opt_bytes_replicated']}"))
        full["mesh"] = {
            "replicas": R, "period": period, "ticks": mesh_ticks,
            "barrier": {"losses": bres["losses"], "tick_s": b_dt,
                        "n_syncs": bres["n_syncs"],
                        "opt_bytes_per_replica": b_bytes,
                        "opt_bytes_replicated": b_bytes},
            **{tag: {"losses": mres["losses"], "tick_s": mres["tick_s"],
                     "absorbed": mres["absorbed"],
                     "stale_dropped": mres["stale_dropped"],
                     "unabsorbed": mres["unabsorbed"],
                     "makespan": mres["makespan"],
                     "opt_bytes_per_replica": mres["opt_bytes_per_replica"],
                     "opt_bytes_replicated": mres["opt_bytes_replicated"]}
               for tag, mres in mesh_res.items()},
        }

    if sections != set(SECTIONS):
        # partial run: keep the other sections' entries in the artifact
        path = os.path.join(ART, "BENCH_runtime.json")
        if os.path.exists(path):
            with open(path) as f:
                merged = json.load(f)
            merged.update(full)
            full = merged
    save_json("BENCH_runtime.json", full)
    emit_csv(rows)
    if ev_dt is not None:
        print(f"# event runtime overhead vs jit engine: {ev_dt / jit_dt:.2f}x "
              f"(per-stage dispatch + python event loop; deployment-faithful order)")
    return full


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--sections", default=None,
                    help=f"comma list of {','.join(SECTIONS)} (default: all); "
                         "a partial run merges into the existing artifact")
    a = ap.parse_args()
    main(a.steps, sections=a.sections.split(",") if a.sections else None)
