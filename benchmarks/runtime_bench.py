"""`runtime` rows: event-driven async runtime vs the single-jit engine.

Measures real ticks/s of both execution paths on the reduced model (the jit
engine amortizes everything into one compiled program; the event runtime pays
per-stage dispatch for deployment fidelity), plus compute-free schedule
simulations quantifying straggler/jitter cost in simulated-clock units.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from common import emit_csv, save_json
from repro.configs import get_config
from repro.core.engine import AsyncTrainer, EngineCfg
from repro.core.runtime import EventRuntime, RuntimeCfg, simulate_schedule
from repro.data.synthetic import make_batch_fn


def main(steps=40, stages=4):
    cfg = get_config("nanogpt_134m", reduced=True)
    ecfg = EngineCfg(n_stages=stages, lr=1e-3, constant_lr=True,
                     collect_metrics=False)
    batch_fn, _ = make_batch_fn(cfg, 1, 4, 64, seed=0)
    rows, full = [], {}

    # jit engine ticks/s
    tr = AsyncTrainer(cfg, ecfg, "ours")
    state = tr.init(jax.random.PRNGKey(0))
    step = tr.jit_step()
    state, _ = step(state, batch_fn(0))  # compile
    t0 = time.time()
    for i in range(1, steps):
        state, m = step(state, batch_fn(i))
    jax.block_until_ready(m["loss"])
    jit_dt = (time.time() - t0) / max(steps - 1, 1)
    rows.append(("runtime/jit_engine", round(1e6 * jit_dt, 1),
                 f"ticks_s={1.0 / jit_dt:.2f}"))

    # event runtime ticks/s (fixed delays — same semantics, real execution order)
    rt = EventRuntime(AsyncTrainer(cfg, ecfg, "ours"))
    rt.init(jax.random.PRNGKey(0))
    rt.run(batch_fn, 1)  # compile per-stage kernels
    t0 = time.time()
    res = rt.run(batch_fn, steps - 1)
    ev_dt = (time.time() - t0) / max(steps - 1, 1)
    rows.append(("runtime/event_fixed", round(1e6 * ev_dt, 1),
                 f"ticks_s={1.0 / ev_dt:.2f};overhead_x={ev_dt / jit_dt:.2f}"))
    full["event_fixed"] = {"losses": res.losses, "utilization": list(res.utilization),
                           "max_tau_obs": list(res.max_tau_obs)}

    # schedule-only simulations: throughput cost of delay regimes (no tensors)
    for spec in ("fixed", "jitter:0.3", "straggler:0,4.0"):
        sim = simulate_schedule(P=stages, K=1, n_ticks=200, delay_model=spec)
        rows.append((f"runtime/sim_{spec.split(':')[0]}",
                     round(1e6 * sim["makespan"] / 200, 1),
                     f"util_min={min(sim['utilization']):.2f};"
                     f"max_tau={max(sim['max_tau_obs']):.0f}"))
        full[f"sim_{spec}"] = sim["utilization"]

    save_json("runtime_bench.json", full)
    emit_csv(rows)
    print(f"# event runtime overhead vs jit engine: {ev_dt / jit_dt:.2f}x "
          f"(per-stage dispatch + python event loop; deployment-faithful order)")
    return full


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    a = ap.parse_args()
    main(a.steps)
