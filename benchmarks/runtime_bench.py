"""`runtime` rows: event-driven async runtime vs the single-jit engine.

Measures real ticks/s of both execution paths on the reduced model (the jit
engine amortizes everything into one compiled program; the event runtime pays
per-stage dispatch for deployment fidelity), plus compute-free schedule
simulations quantifying straggler/jitter cost in simulated-clock units.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from common import emit_csv, save_json
from repro.configs import get_config
from repro.core.engine import AsyncTrainer, EngineCfg
from repro.core.runtime import EventRuntime, RuntimeCfg, simulate_schedule
from repro.data.synthetic import make_batch_fn


def main(steps=40, stages=4):
    cfg = get_config("nanogpt_134m", reduced=True)
    ecfg = EngineCfg(n_stages=stages, lr=1e-3, constant_lr=True,
                     collect_metrics=False)
    batch_fn, _ = make_batch_fn(cfg, 1, 4, 64, seed=0)
    rows, full = [], {}

    # jit engine ticks/s
    tr = AsyncTrainer(cfg, ecfg, "ours")
    state = tr.init(jax.random.PRNGKey(0))
    step = tr.jit_step()
    state, _ = step(state, batch_fn(0))  # compile
    t0 = time.time()
    for i in range(1, steps):
        state, m = step(state, batch_fn(i))
    jax.block_until_ready(m["loss"])
    jit_dt = (time.time() - t0) / max(steps - 1, 1)
    rows.append(("runtime/jit_engine", round(1e6 * jit_dt, 1),
                 f"ticks_s={1.0 / jit_dt:.2f}"))

    # event runtime ticks/s (fixed delays — same semantics, real execution
    # order; the loop keeps losses on device and host-syncs once at drain)
    rt = EventRuntime(AsyncTrainer(cfg, ecfg, "ours"))
    rt.init(jax.random.PRNGKey(0))
    rt.run(batch_fn, 1)  # compile per-stage kernels
    t0 = time.time()
    res = rt.run(batch_fn, steps - 1)
    ev_dt = (time.time() - t0) / max(steps - 1, 1)
    rows.append(("runtime/event_fixed", round(1e6 * ev_dt, 1),
                 f"ticks_s={1.0 / ev_dt:.2f};overhead_x={ev_dt / jit_dt:.2f}"))
    full["event_fixed"] = {"losses": res.losses, "utilization": list(res.utilization),
                           "max_tau_obs": list(res.max_tau_obs)}

    # event runtime under churn: one stage leaves mid-run and rejoins; the
    # outage is paid in stash/mailbox memory + observed tau, never a drain
    half = max(steps // 2, 2)
    rt = EventRuntime(AsyncTrainer(cfg, ecfg, "ours"),
                      RuntimeCfg(churn=f"1,{3 * half},{3 * (steps // 8 or 1)}"))
    rt.init(jax.random.PRNGKey(0))
    rt.run(batch_fn, 1)
    t0 = time.time()
    resc = rt.run(batch_fn, steps - 1)
    ch_dt = (time.time() - t0) / max(steps - 1, 1)
    rows.append(("runtime/event_churn", round(1e6 * ch_dt, 1),
                 f"ticks_s={1.0 / ch_dt:.2f};"
                 f"outage={max(resc.outage_time):.0f};"
                 f"max_tau={max(resc.max_tau_obs):.0f};"
                 f"mbox_hw={max(hw for s in range(1, stages) for hw in resc.mailbox_high_water[s])}"))
    full["event_churn"] = {
        "losses": resc.losses, "utilization": list(resc.utilization),
        "max_tau_obs": list(resc.max_tau_obs),
        "outage_time": list(resc.outage_time),
        "max_stash": list(resc.max_stash),
        "mailbox_high_water": [list(hw) for hw in resc.mailbox_high_water]}

    # schedule-only simulations: throughput cost of delay + membership regimes
    sim_cells = [("fixed", None), ("jitter:0.3", None), ("straggler:0,4.0", None),
                 ("fixed", "1,200,100"), ("jitter:0.3", "1,200,100")]
    for spec, churn in sim_cells:
        sim = simulate_schedule(P=stages, K=1, n_ticks=200, delay_model=spec,
                                churn=churn)
        tag = spec.split(":")[0] + ("_churn" if churn else "")
        derived = (f"util_min={min(sim['utilization']):.2f};"
                   f"max_tau={max(sim['max_tau_obs']):.0f}")
        if churn:
            derived += (f";outage={max(sim['outage_time']):.0f};"
                        f"max_stash={max(sim['max_stash'])}")
        rows.append((f"runtime/sim_{tag}", round(1e6 * sim["makespan"] / 200, 1),
                     derived))
        full[f"sim_{spec}" + (f"_churn_{churn}" if churn else "")] = {
            "utilization": list(sim["utilization"]),
            "max_tau_obs": list(sim["max_tau_obs"]),
            "max_stash": list(sim["max_stash"]),
            "outage_time": list(sim["outage_time"]),
            "mailbox_high_water": [list(hw) for hw in sim["mailbox_high_water"]]}

    save_json("BENCH_runtime.json", full)
    emit_csv(rows)
    print(f"# event runtime overhead vs jit engine: {ev_dt / jit_dt:.2f}x "
          f"(per-stage dispatch + python event loop; deployment-faithful order)")
    return full


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    a = ap.parse_args()
    main(a.steps)
