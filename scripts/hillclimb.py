import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"

"""Perf hillclimb driver for the three chosen cells (EXPERIMENTS.md §Perf).

Each experiment: hypothesis -> change -> re-lower -> compare roofline terms.
Baselines are the paper-faithful records already in artifacts/roofline.json
(measured with the pre-optimization code). Appends results to
artifacts/hillclimb.json as they land (resumable).

  PYTHONPATH=src python scripts/hillclimb.py [exp-name ...]
"""
import json
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.analysis import sanitize
from repro.launch import roofline as R
from repro.parallel import sharding as shd

sanitize.apply(verbose=True)  # REPRO_SANITIZE=1 fail-fast mode

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")
OUT = os.path.join(ART, "hillclimb.json")


def load():
    return json.load(open(OUT)) if os.path.exists(OUT) else {}


def save(d):
    json.dump(d, open(OUT, "w"), indent=1)


# experiment registry: name -> callable returning a roofline record
EXPS = {}


def exp(name):
    def deco(f):
        EXPS[name] = f
        return f
    return deco


# --- internlm2_20b/train_4k (paper-representative dense train) ---------------

@exp("internlm2/V1_onepass")
def _():
    # H1: the staged backward re-runs the forward (2x fwd + bwd). One-pass VJP
    # (valid whenever Wbwd==Wfwd, i.e. all weight-stashing methods) removes one
    # forward: predict compute -20%, memory -15%. Code change in core/staged.py.
    return R.measure_train("internlm2-20b", "train_4k")


@exp("internlm2/V2_scores_bf16")
def _():
    # H2: f32 attention score/prob tensors are the largest HBM stream at S=4096
    # (per layer ~[2,48/16,4096,4096]x4B x multiple traversals). bf16 storage
    # with f32 row statistics: predict memory -25%+.
    return R.measure_train("internlm2-20b", "train_4k",
                           cfg_overrides={"attn_scores_bf16": True})


@exp("internlm2/V3_accum8")
def _():
    # H3: collectives ~ FSDP param all-gathers repeat per microbatch (K=16).
    # K=8 (microbatch 32 -> 2/device) halves re-gathers and param re-reads;
    # memory headroom for activations comes from V1+V2. Predict collective -45%.
    return R.measure_train("internlm2-20b", "train_4k", accum=8,
                           cfg_overrides={"attn_scores_bf16": True})


# --- dbrx_132b/train_4k (most collective-bound train) ------------------------

@exp("dbrx/V1_onepass_bf16")
def _():
    # H1+H2 applied to the MoE cell.
    return R.measure_train("dbrx-132b", "train_4k",
                           cfg_overrides={"attn_scores_bf16": True})


@exp("dbrx/V2_capacity1")
def _():
    # H5: expert capacity factor 1.25 -> 1.0: -20% expert compute/bytes AND
    # -20% dispatch all-to-all traffic (drops rise slightly; standard practice).
    import dataclasses
    from repro.configs import get_config
    mc = dataclasses.replace(get_config("dbrx-132b").moe, capacity_factor=1.0)
    return R.measure_train("dbrx-132b", "train_4k",
                           cfg_overrides={"attn_scores_bf16": True, "moe": mc})


@exp("dbrx/V3_accum8")
def _():
    # H3 on dbrx: K=16 -> 8 halves the per-step FSDP re-gather volume.
    import dataclasses
    from repro.configs import get_config
    mc = dataclasses.replace(get_config("dbrx-132b").moe, capacity_factor=1.0)
    return R.measure_train("dbrx-132b", "train_4k", accum=8,
                           cfg_overrides={"attn_scores_bf16": True, "moe": mc})


# --- gemma3_12b/decode_32k (worst roofline fraction) --------------------------

@exp("gemma3/V1_splitk")
def _():
    # H7: kv_heads=8 < model=16 made XLA all-gather the whole 26 GB cache per
    # token. Split-K layout (cache sequence sharded over 'model'): scores stay
    # shard-local; only softmax stats + [B,H,1,hd] partials cross chips.
    # Predict collective -95%+.
    assert shd.DECODE_SPLITK
    return R.measure_serve("gemma3-12b", "decode_32k")


@exp("gemma3/V0_baseline_check")
def _():
    # re-measure the pre-split-K layout with current code (A/B control)
    shd.DECODE_SPLITK = False
    try:
        return R.measure_serve("gemma3-12b", "decode_32k")
    finally:
        shd.DECODE_SPLITK = True


def main():
    want = sys.argv[1:] or list(EXPS)
    done = load()
    for name in want:
        if name in done:
            print(f"# {name}: cached", flush=True)
            continue
        print(f"# running {name}", flush=True)
        try:
            rec = EXPS[name]()
        except Exception as e:
            rec = {"error": f"{type(e).__name__}: {e}"}
        done[name] = rec
        save(done)
        keep = {k: rec.get(k) for k in ("compute_ms", "memory_ms", "collective_ms",
                                        "dominant", "useful_flops_ratio",
                                        "roofline_fraction", "error")}
        print(json.dumps({name: keep}), flush=True)


if __name__ == "__main__":
    main()
