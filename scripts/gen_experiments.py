"""Regenerate EXPERIMENTS.md tables from artifacts/*.json."""
import json, os, sys

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")

def dryrun_table(path, tag):
    if not os.path.exists(path): return f"_({tag} artifacts missing)_\n"
    if path.endswith(".jsonl"):
        recs = [json.loads(l) for l in open(path)]
    else:
        recs = json.load(open(path))
    out = [f"| cell | kind | GB/device | FLOPs/dev | bytes/dev | collectives (per-dev bytes) | compile |",
           f"|---|---|---|---|---|---|---|"]
    for r in recs:
        if "skipped" in r:
            out.append(f"| {r['cell']} | — | — | — | — | SKIP: {r['skipped'][:60]}... | — |")
            continue
        if "error" in r:
            out.append(f"| {r['cell']} | — | — | — | — | ERROR {r['error'][:50]} | — |")
            continue
        coll = "; ".join(f"{k.replace('collective-','c-')}={v/1e9:.2f}G" for k, v in sorted(r["collective_bytes"].items()))
        out.append(f"| {r['cell']} | {r.get('kind','')} | {r['per_device_bytes']/1e9:.1f} | "
                   f"{r['flops']:.2e} | {r['bytes_accessed']:.2e} | {coll} | {r['compile_s']}s |")
    return "\n".join(out) + "\n"

def roofline_table(path):
    if not os.path.exists(path): return "_(roofline artifacts missing)_\n"
    recs = json.load(open(path))
    out = ["| cell | kind | compute (ms) | memory (ms) | collective (ms) | dominant | MODEL/HLO flops | roofline frac | next lever |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if "skipped" in r:
            out.append(f"| {r['cell']} | — | — | — | — | — | — | — | SKIP (sub-quadratic only) |")
            continue
        if "error" in r:
            out.append(f"| {r['cell']} | ERROR | — | — | — | — | — | — | {r['error'][:40]} |")
            continue
        lever = {
            "memory": "cut fp32 score/bias traffic (flash-attn kernel, bf16 accum)",
            "compute": "remove staged-VJP refwd + remat policy on attn outputs",
            "collective": "overlap FSDP all-gathers with compute; shard KV over seq",
        }[r["dominant"]]
        out.append(f"| {r['cell']} | {r['kind']} | {r['compute_ms']:.0f} | {r['memory_ms']:.0f} | "
                   f"{r['collective_ms']:.0f} | **{r['dominant']}** | {r['useful_flops_ratio']:.3f} | "
                   f"{r['roofline_fraction']:.4f} | {lever} |")
    return "\n".join(out) + "\n"

if __name__ == "__main__":
    mode = sys.argv[1] if len(sys.argv) > 1 else "all"
    if mode in ("dryrun", "all"):
        print("### single-pod (16x16 = 256 chips)\n")
        print(dryrun_table(os.path.join(ART, "dryrun_single.json"), "single-pod"))
    if mode in ("multi", "all"):
        print("\n### multi-pod (2x16x16 = 512 chips)\n")
        print(dryrun_table(os.path.join(ART, "dryrun_multi.jsonl"), "multi-pod"))
    if mode in ("roofline", "all"):
        print("\n### roofline\n")
        print(roofline_table(os.path.join(ART, "roofline.json")))
