"""Fault-tolerant training loop: periodic checkpoints, exact resume, preemption
simulation, and straggler handling hooks.

Straggler mitigation (beyond-paper, DESIGN.md §5): in async PP a straggling stage is
*just a larger tau_i* — there is no barrier for it to hold up. The two levers are
(1) delay-adaptive momentum: raise gamma_i toward 1 with observed delay (Prop. 1
says the look-ahead then keeps correcting the larger delay), implemented via
EngineCfg.straggler_delays + Method.stage_momentum/`adaptive_gamma`;
(2) the engine's stash depth already sizes itself to tau_i, so a straggler costs
memory, not throughput.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import checkpoint as ckpt


class SimulatedPreemption(Exception):
    """Raised by a fault hook to model a node loss / SIGTERM."""


def adaptive_gamma(tau: int, tau_max: int, lo: float = 0.9, hi: float = 0.99) -> float:
    """Delay-adaptive momentum: larger observed delay -> gamma closer to 1."""
    if tau_max <= 0:
        return lo
    return lo + (hi - lo) * min(tau / tau_max, 1.0)


@dataclass
class LoopResult:
    losses: list = field(default_factory=list)
    metrics: list = field(default_factory=list)
    resumed_from: int = -1
    wall_s: float = 0.0
    # fault-recovery observability (run_event_loop; zero on fault-free runs)
    nonfinite_skipped: int = 0  # updates skipped by the non-finite quarantine
    rollbacks: int = 0  # watchdog-triggered checkpoint rollbacks
    retransmits: int = 0  # dropped messages re-sent by the runtime transport


def train_loop(trainer, batch_fn: Callable[[int], dict], steps: int, *,
               ckpt_dir: Optional[str] = None, ckpt_every: int = 0, keep: int = 3,
               key=None, state=None, fault_hook: Callable[[int], None] | None = None,
               log_every: int = 0, log_fn=print) -> tuple:
    """Run (or resume) training. Returns (state, LoopResult).

    Resume: if ckpt_dir has a checkpoint, restores it and continues from its step.
    fault_hook(step) may raise SimulatedPreemption — the loop checkpoints on the way
    out so a rerun resumes exactly.
    """
    res = LoopResult()
    if state is None:
        if key is None:
            raise ValueError(
                "train_loop: pass key= (or a pre-built state=) — a hardcoded "
                "PRNGKey(0) fallback would decouple the run from --seed")
        state = trainer.init(key)
    start = 0
    if ckpt_dir:
        # integrity-verified resume: a truncated/corrupt newest checkpoint
        # falls back to the previous step instead of crashing the run
        restored, meta, path, _ = ckpt.restore_latest(ckpt_dir, state)
        if restored is not None:
            state = restored
            start = meta["step"]
            res.resumed_from = start
    step_fn = trainer.jit_step()
    t0 = time.perf_counter()
    i = start
    try:
        while i < steps:
            batch = batch_fn(i)
            state, m = step_fn(state, batch)
            res.losses.append(float(m["loss"]))
            res.metrics.append({k: float(v) for k, v in m.items()})
            i += 1
            if ckpt_dir and ckpt_every and i % ckpt_every == 0:
                ckpt.save_step(ckpt_dir, state, i, keep=keep)
            if log_every and i % log_every == 0:
                log_fn(f"step {i}: loss={res.losses[-1]:.4f}")
            if fault_hook is not None:
                fault_hook(i)
    except SimulatedPreemption:
        if ckpt_dir:
            ckpt.save_step(ckpt_dir, state, i, keep=keep)
        res.wall_s = time.perf_counter() - t0
        raise
    res.wall_s = time.perf_counter() - t0
    return state, res
