"""repro-lint: static analysis for this repo's determinism invariants.

Every equivalence contract in the reproduction (event==engine tick-for-tick,
bitwise fault-model no-op, trace-replay determinism) rests on conventions
that have each been violated and hand-fixed at least once.  This package
machine-checks them:

  RNG001  PRNG key reuse (same key consumed by two jax.random draws)
  RNG002  hardcoded ``jax.random.PRNGKey(literal)`` in library code
  DET001  stateful nondeterminism (global np.random, wall-clock time.time)
  SYNC001 host sync inside for/while bodies on the event-loop hot paths
  DON001  use of a buffer after it was passed to a donate_argnums position
  REG001  registry/docs consistency (dispatch ops, README method table,
          BENCH artifact references)

Entry points:

  python -m repro.analysis.lint [--format=text|json]   # CLI, exit 1 on findings
  repro.analysis.engine.lint_tree(root)                # library API
  repro.analysis.sanitize.apply()                      # REPRO_SANITIZE=1 mode
"""

from . import engine  # noqa: F401
from . import sanitize  # noqa: F401
