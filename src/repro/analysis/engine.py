"""Lint engine: findings, pragmas, baseline, file discovery, rule registry.

Rules come in two shapes:

* file rules — ``check_file(ctx)`` is called once per scanned source file
  with a :class:`FileContext` (path, AST, raw lines, import aliases).  The
  rule's ``scope(relpath)`` predicate decides which files it looks at.
* repo rules — ``check_repo(root)`` is called once per lint run with the
  repository root; used for cross-file registry/docs consistency (REG001).

Suppression has exactly two mechanisms, both explicit and both budgeted:

* a pragma comment ``# lint: allow-<slug>(reason)`` on the offending line or
  the line directly above it (reason string mandatory), and
* a checked-in baseline file (``lint_baseline.json``) whose entries carry a
  rule id, path, optional ``contains`` line-content match, and a reason.

The engine reports every suppression it honors so the CLI/report can surface
suppression-count growth.
"""
from __future__ import annotations

import ast
import json
import os
import re
from dataclasses import dataclass, field, asdict


@dataclass(frozen=True)
class Finding:
    rule: str          # rule id, e.g. "SYNC001"
    path: str          # path relative to the lint root (posix separators)
    line: int          # 1-based line number (0 for repo-level findings)
    message: str

    def render(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        return f"{loc}: {self.rule} {self.message}"


@dataclass
class Suppression:
    rule: str
    path: str
    line: int
    reason: str
    via: str  # "pragma" | "baseline"


@dataclass
class FileContext:
    relpath: str               # posix-style path relative to the lint root
    tree: ast.Module
    lines: list                # raw source lines (no trailing newline)
    aliases: dict              # import alias -> full module path


@dataclass
class LintResult:
    findings: list = field(default_factory=list)
    suppressions: list = field(default_factory=list)
    errors: list = field(default_factory=list)  # unparseable files etc.

    def counts(self) -> dict:
        out = {}
        for f in self.findings:
            out[f.rule] = out.get(f.rule, 0) + 1
        return out

    def to_json(self) -> dict:
        return {
            "findings": [asdict(f) for f in self.findings],
            "counts": self.counts(),
            "suppressions": [asdict(s) for s in self.suppressions],
            "errors": list(self.errors),
            "total": len(self.findings),
        }


# --------------------------------------------------------------------------
# rule registry

RULES = {}  # id -> rule instance


def register_rule(rule):
    if rule.id in RULES:
        raise ValueError(f"duplicate lint rule id {rule.id}")
    RULES[rule.id] = rule
    return rule


class Rule:
    """Base class; subclasses set id/slug/doc and override one check hook."""

    id = ""
    slug = ""   # pragma slug: "# lint: allow-<slug>(reason)"
    doc = ""    # one-line rationale (rendered into docs/lint.md's table)

    def scope(self, relpath: str) -> bool:
        """Which files (relative to the lint root) this rule scans."""
        return relpath.startswith("src/repro/")

    def check_file(self, ctx: FileContext):
        return []

    def check_repo(self, root: str):
        return []


# --------------------------------------------------------------------------
# shared AST helpers (used by the rule modules)

def collect_aliases(tree: ast.Module) -> dict:
    """Map local names to full module paths for dotted-call resolution.

    ``import numpy as np``        -> {"np": "numpy"}
    ``import jax.numpy as jnp``   -> {"jnp": "jax.numpy"}
    ``from jax import random``    -> {"random": "jax.random"}
    ``from time import time``     -> {"time": "time.time"}
    """
    aliases = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                aliases[a.asname or a.name.split(".")[0]] = (
                    a.name if a.asname else a.name.split(".")[0]
                )
                if a.asname is None and "." in a.name:
                    # `import jax.numpy` binds `jax`; record the full path
                    # under the dotted spelling so qualname() can resolve it.
                    aliases[a.name.split(".")[0]] = a.name.split(".")[0]
        elif isinstance(node, ast.ImportFrom) and node.module and node.level == 0:
            for a in node.names:
                aliases[a.asname or a.name] = f"{node.module}.{a.name}"
    return aliases


def qualname(node, aliases: dict):
    """Resolve a Name/Attribute chain to a dotted module path, or None.

    ``np.random.seed`` with {"np": "numpy"} -> "numpy.random.seed".
    """
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    root = aliases.get(node.id, node.id)
    parts.append(root)
    return ".".join(reversed(parts))


def expr_symbol(node):
    """Dotted symbol for a Name/Attribute lvalue-ish expr ("self._key"), or None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(node.id)
    return ".".join(reversed(parts))


def functions_of(tree: ast.Module):
    """Yield every function/async-function node (module order)."""
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


# --------------------------------------------------------------------------
# pragma + baseline suppression

_PRAGMA = re.compile(r"#\s*lint:\s*allow-([a-z0-9-]+)\(([^)]*)\)")


def pragmas_in(lines) -> dict:
    """Map line number -> list of (slug, reason) pragmas covering that line.

    A pragma covers its own line and the line directly below it (so it can
    sit above a long expression).
    """
    cover = {}
    for i, text in enumerate(lines, start=1):
        for m in _PRAGMA.finditer(text):
            slug, reason = m.group(1), m.group(2).strip()
            cover.setdefault(i, []).append((slug, reason))
            cover.setdefault(i + 1, []).append((slug, reason))
    return cover


def load_baseline(path):
    """Parse lint_baseline.json; returns a list of suppress entries."""
    if not path or not os.path.exists(path):
        return []
    with open(path) as f:
        data = json.load(f)
    entries = data.get("suppress", [])
    for e in entries:
        if not e.get("reason", "").strip():
            raise ValueError(f"baseline entry missing reason: {e}")
        if "rule" not in e or "path" not in e:
            raise ValueError(f"baseline entry needs rule+path: {e}")
    return entries


def _baseline_matches(entry, finding: Finding, lines) -> bool:
    if entry["rule"] != finding.rule or entry["path"] != finding.path:
        return False
    if "contains" in entry:
        if not (1 <= finding.line <= len(lines)):
            return False
        return entry["contains"] in lines[finding.line - 1]
    if "line" in entry:
        return int(entry["line"]) == finding.line
    return True


# --------------------------------------------------------------------------
# discovery + driver

_SKIP_DIRS = {"__pycache__", ".git"}


def iter_source_files(root: str):
    """Yield posix relpaths of all .py files under src/repro/."""
    base = os.path.join(root, "src", "repro")
    for dirpath, dirnames, filenames in os.walk(base):
        dirnames[:] = [d for d in sorted(dirnames) if d not in _SKIP_DIRS]
        for fn in sorted(filenames):
            if fn.endswith(".py"):
                rel = os.path.relpath(os.path.join(dirpath, fn), root)
                yield rel.replace(os.sep, "/")


def _load_ctx(root: str, relpath: str):
    path = os.path.join(root, *relpath.split("/"))
    with open(path, encoding="utf-8") as f:
        src = f.read()
    tree = ast.parse(src, filename=relpath)
    lines = src.splitlines()
    return FileContext(relpath, tree, lines, collect_aliases(tree))


def lint_tree(root: str, rules=None, baseline_path="__default__") -> LintResult:
    """Lint the repo at ``root`` with all (or the given) rules."""
    # Importing .rules populates RULES as a side effect.
    from . import rules as _rules  # noqa: F401

    if rules is None:
        rules = [RULES[rid] for rid in sorted(RULES)]
    if baseline_path == "__default__":
        baseline_path = os.path.join(root, "lint_baseline.json")
    baseline = load_baseline(baseline_path)

    result = LintResult()
    file_rules = [r for r in rules if type(r).check_file is not Rule.check_file]
    repo_rules = [r for r in rules if type(r).check_repo is not Rule.check_repo]

    ctx_cache = {}
    for relpath in iter_source_files(root):
        active = [r for r in file_rules if r.scope(relpath)]
        if not active:
            continue
        try:
            ctx = _load_ctx(root, relpath)
        except (SyntaxError, UnicodeDecodeError) as e:
            result.errors.append(f"{relpath}: {e}")
            continue
        ctx_cache[relpath] = ctx
        cover = pragmas_in(ctx.lines)
        for rule in active:
            for f in rule.check_file(ctx):
                _file_dispatch(result, rule, f, cover, ctx.lines, baseline)

    for rule in repo_rules:
        for f in rule.check_repo(root):
            lines = ctx_cache[f.path].lines if f.path in ctx_cache else []
            _file_dispatch(result, rule, f, {}, lines, baseline)

    # stable ordering: path, line, rule
    result.findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return result


def _file_dispatch(result, rule, finding, cover, lines, baseline):
    # pragma suppression (slug must match the rule, reason must be non-empty)
    for slug, reason in cover.get(finding.line, []):
        if slug == rule.slug and reason:
            result.suppressions.append(
                Suppression(finding.rule, finding.path, finding.line, reason, "pragma")
            )
            return
    for entry in baseline:
        if _baseline_matches(entry, finding, lines):
            result.suppressions.append(
                Suppression(finding.rule, finding.path, finding.line,
                            entry["reason"], "baseline")
            )
            return
    result.findings.append(finding)


def find_root(start=None) -> str:
    """Walk up from ``start`` (default cwd) to the directory holding src/repro."""
    d = os.path.abspath(start or os.getcwd())
    while True:
        if os.path.isdir(os.path.join(d, "src", "repro")):
            return d
        parent = os.path.dirname(d)
        if parent == d:
            raise SystemExit("lint: could not locate repo root (src/repro)")
        d = parent
