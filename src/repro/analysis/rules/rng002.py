"""RNG002: hardcoded ``jax.random.PRNGKey(literal)`` in library code.

Library code under ``src/repro/`` (core/kernels/models/optim/...) must
derive its randomness from a caller-provided key or a config seed —
a hardcoded ``PRNGKey(0)`` silently decouples results from ``--seed``
(the swarm/ft fallback bug fixed in this PR).

Exemptions:

* launchers (``src/repro/launch/``) — they own the seed and mint the root
  key from CLI args;
* keys appearing directly inside a ``jax.eval_shape(...)`` call — shape
  probes never execute, so the literal cannot bias results.
"""
from __future__ import annotations

import ast

from ..engine import Finding, Rule, register_rule, qualname


class RNG002(Rule):
    id = "RNG002"
    slug = "hardcoded-key"
    doc = ("Hardcoded jax.random.PRNGKey(<literal>) in library code "
           "decouples results from --seed; derive from a passed key or "
           "cfg seed instead.")

    def scope(self, relpath):
        return (relpath.startswith("src/repro/")
                and not relpath.startswith("src/repro/launch/")
                and not relpath.startswith("src/repro/analysis/"))

    def check_file(self, ctx):
        findings = []
        self._walk(ctx.tree, ctx, in_eval_shape=False, findings=findings)
        return findings

    def _walk(self, node, ctx, in_eval_shape, findings):
        for child in ast.iter_child_nodes(node):
            child_in_es = in_eval_shape
            if isinstance(child, ast.Call):
                qn = qualname(child.func, ctx.aliases)
                if qn == "jax.eval_shape":
                    child_in_es = True
                elif qn in ("jax.random.PRNGKey", "jax.random.key"):
                    args = child.args
                    if (not in_eval_shape and args
                            and isinstance(args[0], ast.Constant)):
                        findings.append(Finding(
                            self.id, ctx.relpath, child.lineno,
                            f"hardcoded {qn.split('.')[-1]}"
                            f"({args[0].value!r}) in library code",
                        ))
            self._walk(child, ctx, child_in_es, findings)


register_rule(RNG002())
