"""DET001: stateful nondeterminism in core paths.

Two patterns break replayability:

* global numpy RNG state — ``np.random.seed`` / module-level samplers like
  ``np.random.uniform``.  The repo's convention is counter-based Philox
  generators keyed by (seed, ids): ``np.random.Generator(np.random.Philox(
  key=...))`` as in ``events.py``/``faults.py``.  Constructing ``Generator``
  / ``Philox`` / ``default_rng`` is therefore allowed; touching the global
  stream is not.
* wall-clock ``time.time`` where ``time.perf_counter`` is the timing
  convention (PR 3) — wall clock is subject to NTP steps and makes measured
  traces irreproducible.
"""
from __future__ import annotations

import ast

from ..engine import Finding, Rule, register_rule, qualname

# numpy.random constructors for the keyed, instance-based API (allowed)
_ALLOWED_NP_RANDOM = {
    "Generator", "Philox", "default_rng", "PCG64", "SeedSequence",
    "BitGenerator", "RandomState",  # RandomState(seed) is instance-based too
}


class DET001(Rule):
    id = "DET001"
    slug = "nondet"
    doc = ("Global np.random state or wall-clock time.time in library code; "
           "use keyed np.random.Generator(Philox) and time.perf_counter.")

    def check_file(self, ctx):
        findings = []
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            qn = qualname(node.func, ctx.aliases)
            if qn is None:
                continue
            if qn == "time.time":
                findings.append(Finding(
                    self.id, ctx.relpath, node.lineno,
                    "wall-clock time.time(); use time.perf_counter() "
                    "(PR 3 timing convention)",
                ))
            elif qn.startswith("numpy.random."):
                attr = qn.split(".")[-1]
                if attr not in _ALLOWED_NP_RANDOM:
                    findings.append(Finding(
                        self.id, ctx.relpath, node.lineno,
                        f"global-state np.random.{attr}(); use a keyed "
                        "np.random.Generator(np.random.Philox(key=...)) "
                        "as in events.py/faults.py",
                    ))
        return findings


register_rule(DET001())
