"""Rule modules; importing this package registers every rule."""
from . import rng001  # noqa: F401
from . import rng002  # noqa: F401
from . import det001  # noqa: F401
from . import sync001  # noqa: F401
from . import don001  # noqa: F401
from . import reg001  # noqa: F401
