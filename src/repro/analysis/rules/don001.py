"""DON001: use of a buffer after it was passed to a donate_argnums position.

``jax.jit(..., donate_argnums=(i,))`` lets XLA alias the input buffer into
the output — after the call the Python reference is a deleted array, and
touching it raises (GPU/TPU) or silently reads stale memory (some
backends).  The serve decode cache and fused engine state rely on donation
for in-place updates; the contract is "the call's result REPLACES the
donated reference, immediately".

Module-local analysis:

* collect ``<target> = jax.jit(fn, ..., donate_argnums=...)`` bindings
  (plain names and ``self._attr`` targets) with their donated positions;
* at each call site of a collected binding, resolve the argument expression
  at every donated position to a symbol (``name`` or dotted ``self.attr``);
* flag a read of that symbol after the call (before it is re-stored), in
  statement order within the enclosing function body — including the
  loop-carried case where the call sits in a loop and the symbol is not
  re-stored by the call statement itself.
"""
from __future__ import annotations

import ast

from ..engine import Finding, Rule, register_rule, qualname, expr_symbol


def _donated_positions(call: ast.Call):
    for kw in call.keywords:
        if kw.arg == "donate_argnums":
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, int):
                return (v.value,)
            if isinstance(v, (ast.Tuple, ast.List)):
                out = []
                for e in v.elts:
                    if isinstance(e, ast.Constant) and isinstance(e.value, int):
                        out.append(e.value)
                return tuple(out)
    return ()


def _stored_symbols(node):
    """Symbols stored by an assignment statement (incl. tuple targets)."""
    out = set()
    if isinstance(node, ast.Assign):
        targets = node.targets
    elif isinstance(node, (ast.AnnAssign, ast.AugAssign)):
        targets = [node.target]
    else:
        return out
    def rec(t):
        if isinstance(t, (ast.Tuple, ast.List)):
            for e in t.elts:
                rec(e)
        else:
            s = expr_symbol(t)
            if s:
                out.add(s)
    for t in targets:
        rec(t)
    return out


def _reads_symbol(node, sym: str) -> bool:
    """Does this AST subtree read `sym` (as a Load)?"""
    for n in ast.walk(node):
        if expr_symbol(n) == sym and isinstance(
                getattr(n, "ctx", None), ast.Load):
            # expr_symbol matches the full dotted chain only; also reject
            # partial prefixes by construction (exact match required).
            return True
    return False


class DON001(Rule):
    id = "DON001"
    slug = "donated-use"
    doc = ("A buffer passed to a donate_argnums position is read again "
           "after the call; the call's result must replace the donated "
           "reference immediately.")

    def check_file(self, ctx):
        donators = {}  # symbol -> donated positions
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
                call = node.value
                if qualname(call.func, ctx.aliases) in ("jax.jit", "jax.pjit"):
                    pos = _donated_positions(call)
                    if pos:
                        for t in node.targets:
                            s = expr_symbol(t)
                            if s:
                                donators[s] = pos
        if not donators:
            return []
        findings = []
        for fn in ast.walk(ctx.tree):
            if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._check_body(fn.body, donators, ctx, findings, in_loop=False)
        return findings

    # -- body scanning ----------------------------------------------------

    def _check_body(self, body, donators, ctx, findings, in_loop):
        for i, stmt in enumerate(body):
            for call in self._calls_in(stmt, donators, ctx):
                donated = self._donated_args(call, donators)
                if not donated:
                    continue
                stored = _stored_symbols(stmt)
                for sym in donated:
                    self._check_after(body, i, stmt, sym, stored, ctx,
                                      findings, call, in_loop)
            # recurse into nested blocks
            for sub, loop in self._sub_blocks(stmt, in_loop):
                self._check_body(sub, donators, ctx, findings, loop)

    def _sub_blocks(self, stmt, in_loop):
        if isinstance(stmt, (ast.For, ast.AsyncFor, ast.While)):
            yield stmt.body, True
            yield stmt.orelse, in_loop
        elif isinstance(stmt, ast.If):
            yield stmt.body, in_loop
            yield stmt.orelse, in_loop
        elif isinstance(stmt, ast.With):
            yield stmt.body, in_loop
        elif isinstance(stmt, ast.Try):
            yield stmt.body, in_loop
            for h in stmt.handlers:
                yield h.body, in_loop
            yield stmt.orelse, in_loop
            yield stmt.finalbody, in_loop

    def _calls_in(self, stmt, donators, ctx):
        if isinstance(stmt, (ast.For, ast.AsyncFor, ast.While, ast.If,
                             ast.With, ast.Try, ast.FunctionDef,
                             ast.AsyncFunctionDef, ast.ClassDef)):
            return []  # nested blocks handled by recursion
        out = []
        for n in ast.walk(stmt):
            if isinstance(n, ast.Call) and expr_symbol(n.func) in donators:
                out.append(n)
        return out

    def _donated_args(self, call, donators):
        pos = donators[expr_symbol(call.func)]
        out = []
        for p in pos:
            if p < len(call.args):
                s = expr_symbol(call.args[p])
                if s:
                    out.append(s)
        return out

    def _check_after(self, body, i, stmt, sym, stored, ctx, findings, call,
                     in_loop):
        if sym in stored:
            return  # the call statement re-stores the donated reference
        # reads later in the same (straight-line) body, before a re-store
        for later in body[i + 1:]:
            if _reads_symbol(later, sym):
                findings.append(Finding(
                    self.id, ctx.relpath, later.lineno,
                    f"`{sym}` read after being donated to "
                    f"`{expr_symbol(call.func)}` at line {call.lineno}",
                ))
                break
            if sym in _stored_symbols(later):
                break
        else:
            # loop carry: next iteration re-enters the top of the body
            if in_loop and sym not in stored:
                for earlier in body[: i + 1]:
                    if sym in _stored_symbols(earlier):
                        break
                    if _reads_symbol(earlier, sym):
                        findings.append(Finding(
                            self.id, ctx.relpath, call.lineno,
                            f"`{sym}` donated to "
                            f"`{expr_symbol(call.func)}` inside a loop "
                            "without being reassigned from the result — "
                            "next iteration reads a donated buffer",
                        ))
                        break


register_rule(DON001())
