"""SYNC001: host sync inside for/while bodies on the event-loop hot paths.

``float()`` / ``.item()`` / ``np.asarray()`` / ``jax.device_get()`` on a
device value blocks the host until the device catches up.  Inside a loop
body that serializes dispatch — the PR 4 stall class, where a per-forward
``float(loss)`` throttled the whole event runtime.  The repo's convention
is ONE gather at a documented drain boundary (``core/runtime.py``), with
everything else staying on device.

Scope: ``src/repro/core/`` and ``src/repro/launch/serve.py`` (the two
event-loop hot paths).  Findings are suppressible ONLY via an explicit
``# lint: allow-host-sync(reason)`` pragma — there is deliberately no
baseline escape hatch for this rule in-tree, so every sanctioned sync
boundary is visible at the call site.

To avoid flagging host-side parsing/bookkeeping (``float(parts[1])`` on a
spec string is not a sync), ``float``/``np.asarray`` are only flagged when
their argument is *device-tainted*: it contains a call into ``jax.*`` /
``jax.numpy.*``, a call through a module-level ``jax.jit`` binding (e.g.
``self._decode = jax.jit(...)``), or a name assigned from such a call
anywhere in the enclosing function (flow-insensitive union).  Explicit
host conversions (``jax.device_get``, ``np.asarray``, ``float``) do not
taint their results, and names containing ``host`` are exempt by the
repo's naming convention for already-gathered values (``loss_host``).
``.item()`` / ``jax.device_get`` / ``*.block_until_ready`` are flagged
unconditionally — they only exist to force a sync.
"""
from __future__ import annotations

import ast

from ..engine import Finding, Rule, register_rule, qualname, expr_symbol

# calls whose *result* lives on the host even if their args were on device
_HOST_CONVERSIONS = ("jax.device_get", "numpy.asarray", "numpy.array",
                     "float", "int", "bool", "tuple", "list")


def _jit_bindings(tree, aliases):
    """Symbols bound to jax.jit/pjit at module scope (incl. self._attrs)."""
    out = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            if qualname(node.value.func, aliases) in ("jax.jit", "jax.pjit"):
                for t in node.targets:
                    s = expr_symbol(t)
                    if s:
                        out.add(s)
    return out


class SYNC001(Rule):
    id = "SYNC001"
    slug = "host-sync"
    doc = ("float()/.item()/np.asarray()/jax.device_get() on device values "
           "inside for/while bodies serializes dispatch (the PR 4 stall "
           "class); gather once at a drain boundary instead.")

    def scope(self, relpath):
        return (relpath.startswith("src/repro/core/")
                or relpath == "src/repro/launch/serve.py")

    def check_file(self, ctx):
        jits = _jit_bindings(ctx.tree, ctx.aliases)
        findings = []
        for fn in ast.walk(ctx.tree):
            if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                tainted = self._taint(fn, ctx, jits)
                for loop in ast.walk(fn):
                    if isinstance(loop, (ast.For, ast.AsyncFor, ast.While)):
                        for stmt in loop.body:
                            self._scan(stmt, ctx, jits, tainted, findings)
        # dedupe: nested loops visit inner statements twice
        seen, out = set(), []
        for f in findings:
            k = (f.line, f.message)
            if k not in seen:
                seen.add(k)
                out.append(f)
        return out

    # -- device-taint collection ------------------------------------------

    def _taint(self, fn, ctx, jits):
        """Flow-insensitive: symbols ever assigned a device-flavored value."""
        tainted = set()
        for _ in range(2):  # two passes to catch forward-defined chains
            for node in ast.walk(fn):
                if isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
                    value = getattr(node, "value", None)
                    if value is None or not self._is_device_expr(
                            value, ctx, jits, tainted):
                        continue
                    targets = (node.targets if isinstance(node, ast.Assign)
                               else [node.target])
                    for t in targets:
                        self._taint_target(t, tainted)
        return tainted

    def _taint_target(self, t, tainted):
        if isinstance(t, (ast.Tuple, ast.List)):
            for e in t.elts:
                self._taint_target(e, tainted)
        else:
            s = expr_symbol(t)
            if s and "host" not in s.lower():
                tainted.add(s)

    def _is_device_expr(self, expr, ctx, jits, tainted) -> bool:
        if isinstance(expr, ast.Call):
            qn = qualname(expr.func, ctx.aliases)
            if qn in _HOST_CONVERSIONS:
                return False  # explicit gather: result is host-side
        return self._mentions_device(expr, ctx, jits, tainted)

    def _mentions_device(self, expr, ctx, jits, tainted) -> bool:
        for n in ast.walk(expr):
            if isinstance(n, ast.Call):
                qn = qualname(n.func, ctx.aliases)
                if qn and qn.startswith("jax.") and qn not in _HOST_CONVERSIONS:
                    return True
                if expr_symbol(n.func) in jits:
                    return True
            sym = expr_symbol(n)
            if sym in tainted:
                return True
        return False

    # -- loop-body scanning ------------------------------------------------

    def _scan(self, node, ctx, jits, tainted, findings):
        for call in ast.walk(node):
            if not isinstance(call, ast.Call):
                continue
            qn = qualname(call.func, ctx.aliases)
            if qn == "jax.device_get" or (
                    qn is not None and qn.endswith(".block_until_ready")):
                findings.append(Finding(
                    self.id, ctx.relpath, call.lineno,
                    f"{qn} inside a loop body — gather once at a drain "
                    "boundary instead",
                ))
            elif (isinstance(call.func, ast.Attribute)
                    and call.func.attr == "item" and not call.args):
                findings.append(Finding(
                    self.id, ctx.relpath, call.lineno,
                    ".item() inside a loop body forces a device sync per "
                    "iteration",
                ))
            elif qn in ("float", "numpy.asarray", "numpy.array"):
                if not call.args:
                    continue
                arg = call.args[0]
                root = arg
                while isinstance(root, (ast.Attribute, ast.Subscript)):
                    root = root.value
                if isinstance(root, ast.Name) and "host" in root.id.lower():
                    continue  # gathered-value naming convention
                if self._mentions_device(arg, ctx, jits, tainted):
                    label = "float" if qn == "float" else qn
                    findings.append(Finding(
                        self.id, ctx.relpath, call.lineno,
                        f"{label}(...) on a device value inside a loop body "
                        "forces a per-iteration device sync",
                    ))


register_rule(SYNC001())
