"""RNG001: PRNG key reuse — the PR 7 bug class.

A JAX PRNG key is a one-shot value: once consumed by a draw (or handed to a
helper that draws from it), the only legitimate next uses are ``split`` /
``fold_in``.  Consuming the same key twice silently correlates what should
be independent randomness — PR 7's reused init/prompt key made every demo
prompt a function of the parameter init.

Per-function linear analysis:

* key variables enter the tracked set from key-typed parameters (``key``,
  ``*_key``, ``rng`` ...), from assignments whose RHS is a ``jax.random``
  key constructor (``PRNGKey``/``key``/``split``/``fold_in``/``clone``), or
  from tuple-unpacking a ``split``.
* a tracked key is *consumed* when passed to any call except the
  non-consuming derivation set (``split``/``fold_in``/key constructors).
  Helpers like ``init_lm(key, cfg)`` count: by repo convention a function
  that takes a key owns it.
* reassignment makes a key fresh again; ``if`` branches are analyzed from a
  copy of the state and merged by union-of-consumed; loop bodies run twice
  so a key consumed on iteration N and not re-derived before iteration N+1
  is caught.
"""
from __future__ import annotations

import ast

from ..engine import Finding, Rule, register_rule, qualname, functions_of

# jax.random attributes that *derive* or *construct* keys rather than
# consuming them.
_NONCONSUMING = {
    "PRNGKey", "key", "split", "fold_in", "clone",
    "wrap_key_data", "key_data", "key_impl",
}

_KEY_CONSTRUCTORS = {"PRNGKey", "key", "split", "fold_in", "clone", "wrap_key_data"}


def _is_key_param(name: str) -> bool:
    return (
        name == "key"
        or name.endswith("_key")
        or name.startswith("key_")
        or name in ("rng", "rng_key", "prng_key")
    )


def _jax_random_attr(call: ast.Call, aliases) -> str | None:
    qn = qualname(call.func, aliases)
    if qn and qn.startswith("jax.random."):
        return qn.split(".")[-1]
    return None


class _KeyState:
    """Tracked key vars: name -> None (fresh) | consumption line (consumed)."""

    def __init__(self):
        self.keys = {}

    def copy(self):
        s = _KeyState()
        s.keys = dict(self.keys)
        return s

    def merge(self, *others):
        # union of tracked vars; a var consumed on any path stays consumed
        for o in others:
            for k, v in o.keys.items():
                if k not in self.keys or self.keys[k] is None:
                    self.keys[k] = v


class RNG001(Rule):
    id = "RNG001"
    slug = "key-reuse"
    doc = ("A PRNG key is consumed by two or more draws without an "
           "intervening split/fold_in (the PR 7 bug class).")

    def check_file(self, ctx):
        findings = []
        for fn in functions_of(ctx.tree):
            self._check_function(fn, ctx, findings)
        # dedupe (loop double-pass can report the same site twice)
        seen, out = set(), []
        for f in findings:
            if (f.path, f.line, f.message) not in seen:
                seen.add((f.path, f.line, f.message))
                out.append(f)
        return out

    # -- per-function walk ------------------------------------------------

    def _check_function(self, fn, ctx, findings):
        state = _KeyState()
        args = fn.args
        for a in (list(args.posonlyargs) + list(args.args)
                  + list(args.kwonlyargs)
                  + ([args.vararg] if args.vararg else [])
                  + ([args.kwarg] if args.kwarg else [])):
            if _is_key_param(a.arg):
                state.keys[a.arg] = None
        self._walk_body(fn.body, state, ctx, findings)

    def _walk_body(self, body, state, ctx, findings):
        for stmt in body:
            self._walk_stmt(stmt, state, ctx, findings)

    def _walk_stmt(self, stmt, state, ctx, findings):
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
            return  # nested defs get their own walk via functions_of
        if isinstance(stmt, ast.If):
            s_then, s_else = state.copy(), state.copy()
            self._scan_expr(stmt.test, state, ctx, findings)
            self._walk_body(stmt.body, s_then, ctx, findings)
            self._walk_body(stmt.orelse, s_else, ctx, findings)
            state.keys = {}
            state.merge(s_then, s_else)
            return
        if isinstance(stmt, (ast.For, ast.AsyncFor, ast.While)):
            if isinstance(stmt, ast.While):
                self._scan_expr(stmt.test, state, ctx, findings)
            else:
                self._scan_expr(stmt.iter, state, ctx, findings)
                self._bind_target(stmt.target, state, fresh=False)
            # two passes: second pass simulates iteration N+1 with the
            # key state left behind by iteration N
            self._walk_body(stmt.body, state, ctx, findings)
            self._walk_body(stmt.body, state, ctx, findings)
            self._walk_body(stmt.orelse, state, ctx, findings)
            return
        if isinstance(stmt, (ast.Try,)):
            s_try = state.copy()
            self._walk_body(stmt.body, s_try, ctx, findings)
            handlers = []
            for h in stmt.handlers:
                s_h = state.copy()
                self._walk_body(h.body, s_h, ctx, findings)
                handlers.append(s_h)
            state.keys = {}
            state.merge(s_try, *handlers)
            self._walk_body(stmt.orelse, state, ctx, findings)
            self._walk_body(stmt.finalbody, state, ctx, findings)
            return
        if isinstance(stmt, ast.With):
            for item in stmt.items:
                self._scan_expr(item.context_expr, state, ctx, findings)
            self._walk_body(stmt.body, state, ctx, findings)
            return
        if isinstance(stmt, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            value = stmt.value
            if value is not None:
                self._scan_expr(value, state, ctx, findings)
            targets = stmt.targets if isinstance(stmt, ast.Assign) else [stmt.target]
            fresh = value is not None and self._is_key_expr(value, state, ctx)
            for t in targets:
                self._bind_target(t, state, fresh=fresh)
            return
        # Expr / Return / Raise / Assert / Delete / etc: scan expressions
        for child in ast.iter_child_nodes(stmt):
            if isinstance(child, ast.expr):
                self._scan_expr(child, state, ctx, findings)

    # -- expression scanning ----------------------------------------------

    def _scan_expr(self, expr, state, ctx, findings):
        """Find calls in evaluation order and apply consumption rules."""
        for node in ast.walk(expr):
            if not isinstance(node, ast.Call):
                continue
            attr = _jax_random_attr(node, ctx.aliases)
            consuming = attr is None or attr not in _NONCONSUMING
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                for name in self._key_names_in(arg, state):
                    if consuming:
                        prior = state.keys.get(name)
                        if prior is not None:
                            findings.append(Finding(
                                self.id, ctx.relpath, node.lineno,
                                f"key `{name}` already consumed at line "
                                f"{prior} is consumed again without an "
                                f"intervening split/fold_in",
                            ))
                        state.keys[name] = node.lineno

    def _key_names_in(self, arg, state):
        """Tracked key names referenced directly by this argument expr."""
        out = []
        if isinstance(arg, ast.Name) and arg.id in state.keys:
            out.append(arg.id)
        elif isinstance(arg, ast.IfExp):
            for sub in (arg.body, arg.orelse):
                if isinstance(sub, ast.Name) and sub.id in state.keys:
                    out.append(sub.id)
        elif isinstance(arg, ast.Starred):
            out.extend(self._key_names_in(arg.value, state))
        return out

    def _is_key_expr(self, value, state, ctx) -> bool:
        """Does this RHS produce a key (so the target becomes tracked)?"""
        if isinstance(value, ast.Call):
            attr = _jax_random_attr(value, ctx.aliases)
            return attr in _KEY_CONSTRUCTORS
        if isinstance(value, ast.Name):
            return value.id in state.keys
        if isinstance(value, ast.IfExp):
            return (self._is_key_expr(value.body, state, ctx)
                    or self._is_key_expr(value.orelse, state, ctx))
        return False

    def _bind_target(self, target, state, fresh: bool):
        if isinstance(target, ast.Name):
            if fresh or _is_key_param(target.id):
                state.keys[target.id] = None
            elif target.id in state.keys:
                del state.keys[target.id]  # rebound to a non-key value
        elif isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                self._bind_target(elt, state, fresh=fresh)
        # attribute/subscript targets are not tracked


register_rule(RNG001())
