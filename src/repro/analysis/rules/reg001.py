"""REG001: registry/docs consistency (the promoted docs-rot checks).

Three sub-checks, shared verbatim with ``tests/test_docs.py`` so the lint
CLI and the test suite cannot drift apart (ISSUE 9 satellite):

* ``dispatch``     — every op registered in ``kernels/dispatch.py`` has
  parity cases, and either a registered ``bwd`` or a documented ref-VJP
  fallback (a "ref-VJP" note at the registration site);
* ``method-table`` — the README "## Method registry" table lists exactly
  ``sorted(METHODS)`` with the registered optimizer/points/tau-source/
  memory cells;
* ``bench-artifacts`` — every ``artifacts/BENCH_*.json`` a doc names must
  exist, unless the sentence flags it stale/planned (ISSUE 7's trigger).

The helpers return plain problem strings; the Rule wraps them in Findings.
"""
from __future__ import annotations

import ast
import glob
import os
import re

from ..engine import Finding, Rule, register_rule

# markdown table row whose first cell is a backticked method name
_ROW = re.compile(r"^\|\s*`([^`]+)`\s*\|(.+)\|\s*$")
_BENCH = re.compile(r"\b(BENCH_\w+\.json)\b")
_STALE = re.compile(r"\b(stale|planned|future|TODO)\b", re.I)


def doc_files(root):
    """The docs scanned for rot: top-level + everything under docs/."""
    out = ["README.md", "DESIGN.md", "ROADMAP.md"]
    for p in sorted(glob.glob(os.path.join(root, "docs", "*.md"))):
        out.append(os.path.relpath(p, root).replace(os.sep, "/"))
    return [d for d in out if os.path.exists(os.path.join(root, d))]


# -- sub-check: README method table ----------------------------------------

def readme_method_rows(root):
    """Every data row of the README's '## Method registry' table — including
    rows whose method no longer exists in the registry (stale-row detection
    requires NOT filtering by METHODS membership here)."""
    rows = {}
    in_section = False
    with open(os.path.join(root, "README.md")) as f:
        for line in f:
            if line.startswith("## "):
                in_section = line.strip() == "## Method registry"
                continue
            m = _ROW.match(line.strip())
            if in_section and m:
                cells = [c.strip() for c in m.group(2).split("|")]
                rows[m.group(1)] = cells
    return rows


def method_table_problems(root):
    from repro.core.methods import METHODS

    problems = []
    rows = readme_method_rows(root)
    missing = sorted(set(METHODS) - set(rows))
    stale = sorted(set(rows) - set(METHODS))
    if missing:
        problems.append(f"README method table missing {missing}")
    if stale:
        problems.append(f"README method table has stale rows {stale}")
    if list(rows) != sorted(rows):
        problems.append("README method table rows not sorted by name")
    for name, cells in rows.items():
        if name not in METHODS:
            continue
        m = METHODS[name]
        # | optimizer | fwd point | bwd point | corrections | tau source | memory |
        if len(cells) != 6:
            problems.append(f"README row for {name} has {len(cells)} cells, want 6")
            continue
        for i, (label, want) in enumerate([
                ("optimizer", m.optimizer), ("fwd point", m.fwd_point),
                ("bwd point", m.bwd_point), (None, None),
                ("tau source", m.tau_source), ("memory", m.memory)]):
            if label is not None and cells[i] != want:
                problems.append(
                    f"README row {name}: {label} {cells[i]!r} != registered {want!r}")
    return problems


# -- sub-check: BENCH artifact references -----------------------------------

def bench_artifact_problems(root, docs=None):
    problems = []
    for doc in docs or doc_files(root):
        with open(os.path.join(root, doc)) as f:
            lines = f.read().splitlines()
        missing = set()
        for ln in lines:
            for name in _BENCH.findall(ln):
                if _STALE.search(ln):
                    continue
                if not os.path.exists(os.path.join(root, "artifacts", name)):
                    missing.add(name)
        if missing:
            problems.append(
                f"{doc} names benchmark artifacts that don't exist: "
                f"{sorted(missing)} — run benchmarks/run.py to regenerate, "
                "or mark the mention stale")
    return problems


# -- sub-check: kernel dispatch registry ------------------------------------

_DISPATCH_SRC = "src/repro/kernels/dispatch.py"


def _register_site_mentions_ref_vjp(root):
    """Map op name -> whether its register() call site documents the
    ref-VJP fallback (a 'ref-VJP' note inside or directly above the call)."""
    path = os.path.join(root, *_DISPATCH_SRC.split("/"))
    with open(path) as f:
        src = f.read()
    lines = src.splitlines()
    tree = ast.parse(src)
    out = {}
    for node in ast.walk(tree):
        if (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
                and node.func.id == "register" and node.args
                and isinstance(node.args[0], ast.Constant)):
            lo = max(0, node.lineno - 4)  # up to 3 comment lines above
            hi = node.end_lineno
            segment = "\n".join(lines[lo:hi])
            out[node.args[0].value] = "ref-vjp" in segment.lower()
    return out


def dispatch_registry_problems(root):
    from repro.kernels import dispatch

    problems = []
    documented = _register_site_mentions_ref_vjp(root)
    for name in dispatch.registered_ops():
        op = dispatch.get_op(name)
        if not op.cases:
            problems.append(f"dispatch op {name} has no parity cases")
        if op.bwd is None and not documented.get(name, False):
            problems.append(
                f"dispatch op {name} has no registered bwd and no documented "
                "ref-VJP fallback at its register() site")
    for name in documented:
        if name not in dispatch.registered_ops():
            problems.append(f"register() call for unknown op {name}")
    return problems


# -- the lint rule ----------------------------------------------------------

class REG001(Rule):
    id = "REG001"
    slug = "registry-docs"
    doc = ("Registry/docs drift: dispatch ops need parity cases and a bwd or "
           "documented ref-VJP fallback; README method table and BENCH "
           "artifact references must match reality.")

    def check_repo(self, root):
        findings = []
        for msg in dispatch_registry_problems(root):
            findings.append(Finding(self.id, _DISPATCH_SRC, 0, msg))
        for msg in method_table_problems(root):
            findings.append(Finding(self.id, "README.md", 0, msg))
        for msg in bench_artifact_problems(root):
            findings.append(Finding(self.id, msg.split(" ", 1)[0], 0, msg))
        return findings


register_rule(REG001())
