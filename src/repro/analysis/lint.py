"""CLI: ``python -m repro.analysis.lint [--format=text|json] [--root DIR]``.

Exit status: 0 when the tree is clean (suppressions allowed), 1 when any
finding survives, 2 on usage/setup errors.  ``--write-report PATH`` emits
the same JSON payload to a file (used by benchmarks/run.py to keep
``artifacts/LINT_report.json`` in the bench trajectory).
"""
from __future__ import annotations

import argparse
import json
import sys

from . import engine


def build_report(result: engine.LintResult, root: str) -> dict:
    payload = result.to_json()
    payload["root"] = root
    payload["rules"] = {rid: engine.RULES[rid].doc for rid in sorted(engine.RULES)}
    payload["suppression_count"] = len(result.suppressions)
    return payload


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="repro-lint: determinism/host-sync/donation static analysis")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--root", default=None,
                    help="repo root (default: walk up from cwd to src/repro)")
    ap.add_argument("--baseline", default="__default__",
                    help="baseline JSON path ('' to disable; default "
                         "<root>/lint_baseline.json)")
    ap.add_argument("--write-report", default=None, metavar="PATH",
                    help="also write the JSON payload to PATH")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule ids to run (default: all)")
    args = ap.parse_args(argv)

    root = args.root or engine.find_root()
    from . import rules as _rules  # noqa: F401  (populate the registry)
    rules = None
    if args.rules:
        try:
            rules = [engine.RULES[r.strip()] for r in args.rules.split(",")]
        except KeyError as e:
            ap.error(f"unknown rule id {e.args[0]!r}; "
                     f"known: {', '.join(sorted(engine.RULES))}")
    baseline = None if args.baseline == "" else args.baseline
    result = engine.lint_tree(root, rules=rules, baseline_path=baseline)
    payload = build_report(result, root)

    if args.write_report:
        with open(args.write_report, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)

    if args.format == "json":
        json.dump(payload, sys.stdout, indent=1, sort_keys=True)
        sys.stdout.write("\n")
    else:
        for f in result.findings:
            print(f.render())
        for e in result.errors:
            print(f"ERROR {e}")
        counts = result.counts()
        summary = ", ".join(f"{k}={v}" for k, v in sorted(counts.items())) or "clean"
        print(f"repro-lint: {len(result.findings)} finding(s) [{summary}], "
              f"{len(result.suppressions)} suppression(s) in use")
        for s in result.suppressions:
            print(f"  suppressed {s.rule} {s.path}:{s.line} via {s.via}: {s.reason}")

    if result.errors:
        return 2
    return 1 if result.findings else 0


if __name__ == "__main__":
    sys.exit(main())
