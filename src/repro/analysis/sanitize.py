"""REPRO_SANITIZE=1 runtime sanitizer mode.

One env var flips the whole stack into fail-fast mode:

* ``jax_debug_nans`` — any NaN materialized by a jitted computation raises
  ``FloatingPointError`` at the op that produced it.  Under sanitize an
  injected ``nan_grad`` fault is therefore *caught at the poison site*
  instead of being silently quarantined by the engine's non-finite guard.
* ``jax_enable_checks`` — JAX's internal invariant checks (transpose
  correctness, weak-type promotion, ...).
* runtime strictness — the event runtime's drain/quarantine bookkeeping is
  upgraded from counters to hard errors: a quarantined non-finite update
  raises instead of incrementing ``nonfinite_skipped`` (see
  ``core/runtime.py``), so sanitized CI runs cannot paper over a poisoned
  gradient.

Wire-up points: ``tests/conftest.py`` (whole test suite), the
``launch/train.py`` / ``launch/serve.py`` / ``launch/dryrun.py`` mains, and
``benchmarks/run.py`` — all call :func:`apply` once at startup.
"""
from __future__ import annotations

import os

ENV_VAR = "REPRO_SANITIZE"

_FALSEY = ("", "0", "off", "false", "no")


def enabled() -> bool:
    """Is sanitizer mode requested via the environment?"""
    return os.environ.get(ENV_VAR, "").strip().lower() not in _FALSEY


def apply(verbose: bool = False) -> bool:
    """Apply sanitizer config to the current JAX process if enabled.

    Returns True when sanitize mode is active.  Idempotent; safe to call
    from every entry point.
    """
    if not enabled():
        return False
    import jax

    jax.config.update("jax_debug_nans", True)
    jax.config.update("jax_enable_checks", True)
    if verbose:
        print(f"[sanitize] {ENV_VAR}=1: jax_debug_nans + jax_enable_checks "
              "+ strict drain/quarantine asserts")
    return True
