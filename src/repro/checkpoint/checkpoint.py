"""Mesh-agnostic checkpointing for AsyncState + elastic restage.

Arrays are saved device-gathered (unsharded logical values) into a single .npz with
path-string keys, so a checkpoint written on any mesh restores onto any other mesh
(the caller re-device_puts with target shardings). `restage` additionally moves a
checkpoint between different pipeline-stage counts (elastic scaling): params and
moment buffers are merged to the monolithic layout and re-split; stashes are
re-warmed from the restored params (staleness history resets — documented behaviour
on elastic events).
"""
from __future__ import annotations

import json
import os
import re
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from jax.tree_util import tree_flatten_with_path, keystr

from repro.models import lm as _lm


def _flat(state):
    leaves, treedef = tree_flatten_with_path(state)
    return {keystr(path): np.asarray(jax.device_get(x)) for path, x in leaves}, treedef


def save(path: str, state, step: int, metadata: dict | None = None):
    """Atomic save: write tmp then rename."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    arrs, _ = _flat(state)
    meta = dict(metadata or {})
    meta["step"] = int(step)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".", suffix=".tmp")
    os.close(fd)
    with open(tmp, "wb") as f:
        np.savez(f, __meta__=json.dumps(meta), **arrs)
    os.replace(tmp, path)


def restore(path: str, like):
    """Restore into the structure of `like` (shapes/dtypes validated)."""
    with np.load(path, allow_pickle=False) as z:
        meta = json.loads(str(z["__meta__"]))
        leaves, treedef = tree_flatten_with_path(like)
        out = []
        for p, l in leaves:
            k = keystr(p)
            if k not in z:
                raise KeyError(f"checkpoint missing {k}")
            a = z[k]
            if tuple(a.shape) != tuple(l.shape):
                raise ValueError(f"shape mismatch at {k}: ckpt {a.shape} vs state {l.shape}")
            out.append(jnp.asarray(a, l.dtype))
    return jax.tree.unflatten(treedef, out), meta


def latest(ckpt_dir: str):
    """(path, step) of the newest ckpt-<step>.npz in dir, or (None, -1)."""
    if not os.path.isdir(ckpt_dir):
        return None, -1
    best, best_step = None, -1
    for f in os.listdir(ckpt_dir):
        m = re.fullmatch(r"ckpt-(\d+)\.npz", f)
        if m and int(m.group(1)) > best_step:
            best, best_step = os.path.join(ckpt_dir, f), int(m.group(1))
    return best, best_step


def save_step(ckpt_dir: str, state, step: int, keep: int = 3, metadata=None):
    save(os.path.join(ckpt_dir, f"ckpt-{step}.npz"), state, step, metadata)
    # retention
    steps = sorted(
        int(re.fullmatch(r"ckpt-(\d+)\.npz", f).group(1))
        for f in os.listdir(ckpt_dir) if re.fullmatch(r"ckpt-(\d+)\.npz", f))
    for s in steps[:-keep]:
        os.remove(os.path.join(ckpt_dir, f"ckpt-{s}.npz"))


def restage(state, trainer_old, trainer_new):
    """Elastic stage-count change: old AsyncState -> new trainer's AsyncState.

    Params and optimizer moment buffers merge to monolithic and re-split under the
    new stage partition. Stash ring buffers re-warm from the current weights.
    """
    merged_params = trainer_old.merge_params(state)
    new_state = trainer_new.init_from_params(merged_params)

    # migrate adam moments where structurally possible (same leaf paths)
    def merge_stage_trees(trees, key_):
        class _Holder:
            params = tuple(t[key_] for t in trees)
        return trainer_old.merge_params(_Holder)

    try:
        if all(("m" in o and "v" in o) for o in state.opt):
            m_merged = merge_stage_trees(list(state.opt), "m")
            v_merged = merge_stage_trees(list(state.opt), "v")
            new_stages, _ = _lm.split_stages(m_merged, trainer_new.model_cfg, trainer_new.P)
            new_v, _ = _lm.split_stages(v_merged, trainer_new.model_cfg, trainer_new.P)
            opt = []
            for i, o in enumerate(new_state.opt):
                oo = dict(o)
                oo["m"], oo["v"] = new_stages[i], new_v[i]
                oo["count"] = state.opt[0]["count"]
                if "mu_prod" in oo:
                    oo["mu_prod"] = state.opt[0].get("mu_prod", oo["mu_prod"])
                opt.append(oo)
            new_state = new_state._replace(opt=tuple(opt), step=state.step)
        else:
            new_state = new_state._replace(step=state.step)
    except Exception:
        new_state = new_state._replace(step=state.step)
    return new_state
