"""Mesh-agnostic checkpointing for AsyncState + elastic restage.

Arrays are saved device-gathered (unsharded logical values) into a single .npz with
path-string keys, so a checkpoint written on any mesh restores onto any other mesh
(the caller re-device_puts with target shardings). `restage` additionally moves a
checkpoint between different pipeline-stage counts (elastic scaling): params and
moment buffers are merged to the monolithic layout and re-split; stashes are
re-warmed from the restored params (staleness history resets — documented behaviour
on elastic events).
"""
from __future__ import annotations

import json
import logging
import os
import re
import tempfile
import zipfile
import zlib

import jax
import jax.numpy as jnp
import numpy as np

from jax.tree_util import tree_flatten_with_path, keystr

from repro.models import lm as _lm

logger = logging.getLogger(__name__)


class CorruptCheckpointError(RuntimeError):
    """A checkpoint file failed integrity verification (truncated zip, missing
    arrays, or a per-array checksum mismatch). `restore_latest` treats it —
    along with any other read failure — as 'fall back to the previous step'."""


def _flat(state):
    leaves, treedef = tree_flatten_with_path(state)
    return {keystr(path): np.asarray(jax.device_get(x)) for path, x in leaves}, treedef


def _crc(a: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(a).tobytes())


def save(path: str, state, step: int, metadata: dict | None = None):
    """Atomic, durable save: write tmp, fsync it, rename, fsync the directory.

    Without the fsyncs os.replace only orders the rename against other
    *metadata* operations — after a power loss the new name could point at a
    zero-length or partially-written file, which is exactly the torn state
    `restore_latest` + per-array checksums recover from. `__meta__` carries a
    `crc32` map (keystr path -> checksum) verified on restore.
    """
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    arrs, _ = _flat(state)
    meta = dict(metadata or {})
    meta["step"] = int(step)
    meta["crc32"] = {k: _crc(v) for k, v in arrs.items()}
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".", suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, __meta__=json.dumps(meta), **arrs)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        try:
            os.remove(tmp)
        except OSError:
            pass
        raise
    dfd = os.open(os.path.dirname(path) or ".", os.O_RDONLY)
    try:
        os.fsync(dfd)
    finally:
        os.close(dfd)


def _restore_exact(path: str, like):
    try:
        z_ctx = np.load(path, allow_pickle=False)
    except (OSError, ValueError, EOFError, zipfile.BadZipFile) as e:
        # torn zip / bad magic / half a central directory
        raise CorruptCheckpointError(f"{path}: unreadable ({e})") from e
    with z_ctx as z:
        try:
            meta = json.loads(str(z["__meta__"]))
        except Exception as e:
            raise CorruptCheckpointError(f"{path}: bad __meta__ ({e})") from e
        crcs = meta.get("crc32")  # absent in pre-integrity checkpoints
        leaves, treedef = tree_flatten_with_path(like)
        out = []
        for p, l in leaves:
            k = keystr(p)
            if k not in z:
                raise KeyError(f"checkpoint missing {k}")
            try:
                a = z[k]
            except Exception as e:  # member truncated mid-array
                raise CorruptCheckpointError(f"{path}: {k} unreadable ({e})") from e
            if tuple(a.shape) != tuple(l.shape):
                raise ValueError(f"shape mismatch at {k}: ckpt {a.shape} vs state {l.shape}")
            if crcs is not None and k in crcs and _crc(a) != crcs[k]:
                raise CorruptCheckpointError(
                    f"{path}: checksum mismatch at {k} (bit rot or torn write)")
            out.append(jnp.asarray(a, l.dtype))
    return jax.tree.unflatten(treedef, out), meta


def _f32_sds(tree):
    return jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), tree)


def _ckpt_opt_layout(path: str):
    """Peek the optimizer layout of an AsyncState checkpoint from key names
    alone (no array reads): 'shard' | 'shards' | 'flat' | 'tree' | None."""
    with np.load(path, allow_pickle=False) as z:
        keys = [k for k in z.files if ".opt[" in k]
    for tag, tok in (("shard", "['shard']"), ("shards", "['shards']"),
                     ("flat", "['flat']"), ("tree", "['m']")):
        if any(tok in k for k in keys):
            return tag
    return None


def restore(path: str, like):
    """Restore into the structure of `like` (shapes/dtypes validated).

    AsyncState checkpoints additionally restore across *optimizer layouts*:

      - tree-map ('m'/'v') <-> fused flat-buffer ('flat'): interconvertible
        via flatten_tree/unflatten_like, so a run saved under one kernel
        backend resumes under another — e.g. CPU-ref debugging a TPU-pallas
        run's checkpoint, or flipping REPRO_KERNEL_BACKEND.
      - replicated (tree or flat) -> ZeRO-1 owner-shard ('shard'): the
        target rank's segment is sliced out at the like's (rank, world) —
        shard boundaries are re-derived from the like, so a replicated
        checkpoint restores onto any replica count.
      - a single-rank 'shard' checkpoint canNOT restore a replicated like:
        it holds only 1/world of the moments. Gather all replica states with
        `zero1_merge_states` and save that instead (raised as ValueError).
    """
    from repro.optim import optimizers as _opt

    try:
        return _restore_exact(path, like)
    except KeyError as e:
        if not (hasattr(like, "opt") and hasattr(like, "params") and
                hasattr(like, "_replace")):
            raise
        # only a missing optimizer-moment key signals a layout mismatch; any
        # other missing key is a genuinely incomplete checkpoint — re-raise it
        # rather than masking it behind an alternate-layout KeyError
        msg = str(e)
        if ".opt[" not in msg or not any(
                t in msg for t in ("['m']", "['v']", "['flat']", "['shard']",
                                   "['rank']", "['world']")):
            raise
        ck_layout = _ckpt_opt_layout(path)
        if ck_layout in ("shard", "shards"):
            raise ValueError(
                f"{path}: checkpoint holds a ZeRO-1 sharded optimizer layout "
                f"({ck_layout!r}) which cannot be expanded from one file — "
                "gather the replica states with checkpoint.zero1_merge_states "
                "and save the merged (replicated) state instead") from e
        if ck_layout is None:
            raise
        want_shard = any("shard" in o for o in like.opt)
        # build the alternate-layout template (ShapeDtypeStructs only — no
        # model-sized allocations) and convert after loading
        drop = ("m", "v", "flat", "shard", "rank", "world")
        alt_opt = []
        for o, sp in zip(like.opt, like.params):
            oo = {k: v for k, v in o.items() if k not in drop}
            if ck_layout == "tree":
                oo["m"], oo["v"] = _f32_sds(sp), _f32_sds(sp)
            else:  # ckpt is fused flat
                n = int(sum(np.prod(x.shape) for x in jax.tree.leaves(sp)))
                flat = jax.ShapeDtypeStruct((n,), jnp.float32)
                oo["flat"] = {"p": flat, "m": flat, "v": flat}
            alt_opt.append(oo)
        loaded, meta = _restore_exact(path, like._replace(opt=tuple(alt_opt)))
        opt = []
        for o_like, o_got, sp in zip(like.opt, loaded.opt, loaded.params):
            oo = {k: v for k, v in o_got.items() if k not in drop}
            if ck_layout == "tree":
                pf = _opt.flatten_tree(sp)
                mf = _opt.flatten_tree(o_got["m"])
                vf = _opt.flatten_tree(o_got["v"])
            else:
                pf, mf, vf = (o_got["flat"]["p"], o_got["flat"]["m"],
                              o_got["flat"]["v"])
            if want_shard:
                rank = int(np.asarray(o_like["rank"]))
                world = int(np.asarray(o_like["world"]))
                oo["shard"] = {"p": _opt.zero1_shard(pf, rank, world),
                               "m": _opt.zero1_shard(mf, rank, world),
                               "v": _opt.zero1_shard(vf, rank, world)}
                oo["rank"] = jnp.asarray(rank, jnp.int32)
                oo["world"] = jnp.asarray(world, jnp.int32)
            elif "flat" in o_like:
                oo["flat"] = {"p": pf, "m": mf, "v": vf}
            else:
                oo["m"] = _opt.unflatten_like(mf, _f32_sds(sp))
                oo["v"] = _opt.unflatten_like(vf, _f32_sds(sp))
            opt.append(oo)
        return loaded._replace(opt=tuple(opt)), meta


def _steps_desc(ckpt_dir: str) -> list:
    """All (path, step) candidates in the dir, newest first."""
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for f in os.listdir(ckpt_dir):
        m = re.fullmatch(r"ckpt-(\d+)\.npz", f)
        if m:
            out.append((os.path.join(ckpt_dir, f), int(m.group(1))))
    return sorted(out, key=lambda x: -x[1])


def _readable(path: str) -> bool:
    """Cheap validity probe: the zip opens and `__meta__` reads back (the zip
    layer CRC-checks the member). Does NOT verify per-array checksums — that
    costs a full read and happens in restore(); a file passing here can still
    fail restore, which is why restore_latest keeps stepping down."""
    try:
        with np.load(path, allow_pickle=False) as z:
            json.loads(str(z["__meta__"]))
        return True
    except Exception:
        return False


def latest(ckpt_dir: str):
    """(path, step) of the newest *readable* ckpt-<step>.npz in dir, or
    (None, -1). A truncated/corrupt newest file is skipped (with a warning)
    and the previous step wins — a torn write must never brick resume."""
    for path, step in _steps_desc(ckpt_dir):
        if _readable(path):
            return path, step
        logger.warning("skipping corrupt checkpoint %s", path)
    return None, -1


def restore_latest(ckpt_dir: str, like):
    """Restore the newest checkpoint that passes FULL integrity verification,
    stepping down through older files on any failure (truncation, checksum
    mismatch, structural mismatch). Returns (state, meta, path, step) or
    (None, None, None, -1) when nothing in the directory is restorable."""
    for path, step in _steps_desc(ckpt_dir):
        try:
            state, meta = restore(path, like)
            return state, meta, path, step
        except Exception as e:
            logger.warning("checkpoint %s failed restore (%s); "
                           "falling back to previous step", path, e)
    return None, None, None, -1


def save_step(ckpt_dir: str, state, step: int, keep: int = 3, metadata=None):
    save(os.path.join(ckpt_dir, f"ckpt-{step}.npz"), state, step, metadata)
    # retention — tolerant: a concurrently-deleted or permission-locked stale
    # file must not kill the training loop mid-run
    steps = sorted(
        int(re.fullmatch(r"ckpt-(\d+)\.npz", f).group(1))
        for f in os.listdir(ckpt_dir) if re.fullmatch(r"ckpt-(\d+)\.npz", f))
    for s in steps[:-keep]:
        stale = os.path.join(ckpt_dir, f"ckpt-{s}.npz")
        try:
            os.remove(stale)
        except OSError as e:
            logger.warning("retention: could not remove %s (%s); continuing",
                           stale, e)


def zero1_merge_states(states) -> "object":
    """Gather a list of per-rank ZeRO-1 'shard'-layout AsyncStates into ONE
    replicated fused-flat-layout AsyncState — the all-gather that makes a
    sharded run checkpointable/restageable as a whole.

    Owner-authoritative: each rank contributes its own (p, m, v) segment;
    concatenate-and-trim recovers the exact unsharded vectors, so
    `zero1_shard_states(zero1_merge_states(ss), R')` at any R' is bit-exact
    on params and moments (tests/test_mesh.py restage roundtrip). Stashes
    re-warm from the merged params; step/count/mu_prod come from rank 0
    (identical across ranks after any full absorption round).
    """
    from repro.optim import optimizers as _opt

    if not states:
        raise ValueError("zero1_merge_states: need at least one rank state")
    for st in states:
        if not all("shard" in o for o in st.opt):
            raise ValueError("zero1_merge_states: every state must hold the "
                             "ZeRO-1 'shard' optimizer layout")
    by_rank = sorted(states, key=lambda st: int(np.asarray(st.opt[0]["rank"])))
    world = int(np.asarray(by_rank[0].opt[0]["world"]))
    ranks = [int(np.asarray(st.opt[0]["rank"])) for st in by_rank]
    if ranks != list(range(world)) or len(states) != world:
        raise ValueError(f"zero1_merge_states: need ranks 0..{world - 1} "
                         f"exactly once, got {ranks}")
    base = by_rank[0]
    params, opt, stashes = [], [], []
    for i, sp in enumerate(base.params):
        n = int(sum(np.prod(x.shape) for x in jax.tree.leaves(sp)))
        pf = _opt.zero1_unshard([st.opt[i]["shard"]["p"] for st in by_rank], n)
        mf = _opt.zero1_unshard([st.opt[i]["shard"]["m"] for st in by_rank], n)
        vf = _opt.zero1_unshard([st.opt[i]["shard"]["v"] for st in by_rank], n)
        mp = _opt.unflatten_like(pf, sp)
        params.append(mp)
        opt.append({"flat": {"p": pf, "m": mf, "v": vf},
                    "count": base.opt[i]["count"],
                    "mu_prod": base.opt[i]["mu_prod"]})
        stashes.append(jax.tree.map(
            lambda s, p: jnp.broadcast_to(
                p[None].astype(s.dtype), s.shape).copy(), base.stashes[i], mp))
    return base._replace(params=tuple(params), stashes=tuple(stashes),
                         opt=tuple(opt))


def zero1_shard_states(state, world: int) -> list:
    """Scatter a replicated AsyncState (fused-flat or tree-map optimizer
    layout) into `world` per-rank ZeRO-1 'shard'-layout AsyncStates, re-deriving
    the shard boundaries S = ceil(n / world) at the target replica count.
    Inverse of `zero1_merge_states` up to stash re-warming; params are
    replicated to every rank (the mesh keeps them loosely synced via gossip).
    """
    from repro.optim import optimizers as _opt

    if world < 1:
        raise ValueError(f"world must be >= 1, got {world}")
    flats = []
    for sp, o in zip(state.params, state.opt):
        if "flat" in o:
            pf, mf, vf = o["flat"]["p"], o["flat"]["m"], o["flat"]["v"]
        elif "m" in o and "v" in o:
            pf = _opt.flatten_tree(sp)
            mf, vf = _opt.flatten_tree(o["m"]), _opt.flatten_tree(o["v"])
        else:
            raise ValueError("zero1_shard_states: state must hold a replicated "
                             "('flat' or 'm'/'v') optimizer layout")
        if "mu_prod" not in o:
            raise ValueError("zero1_shard_states: the 'shard' layout is "
                             "nadam-family only (state has no mu_prod)")
        flats.append((pf, mf, vf, o))
    out = []
    for r in range(world):
        opt = []
        for pf, mf, vf, o in flats:
            opt.append({"shard": {"p": _opt.zero1_shard(pf, r, world),
                                  "m": _opt.zero1_shard(mf, r, world),
                                  "v": _opt.zero1_shard(vf, r, world)},
                        "count": o["count"], "mu_prod": o["mu_prod"],
                        "rank": jnp.asarray(r, jnp.int32),
                        "world": jnp.asarray(world, jnp.int32)})
        out.append(state._replace(opt=tuple(opt)))
    return out


def _stage_moments(state):
    """Per-stage (m, v) as param-shaped fp32 trees, from any full-information
    optimizer layout: tree-map ('m'/'v' trees), fused flat-buffer ('flat'
    contiguous vectors), or the ZeRO-1 collective ('shards', unsharded here).
    None if none matches; a single-rank 'shard' layout raises — it holds only
    1/world of the moments, so treating it like 'no moments' would silently
    drop the other ranks' state (the pre-ISSUE-10 restage bug)."""
    from repro.optim import optimizers as _opt

    if any("shard" in o for o in state.opt):
        raise ValueError(
            "state holds a single-rank ZeRO-1 'shard' optimizer layout; "
            "gather the replica states with checkpoint.zero1_merge_states "
            "before restaging/merging — one rank alone cannot supply the "
            "full moment buffers")
    if all(("m" in o and "v" in o) for o in state.opt):
        return [o["m"] for o in state.opt], [o["v"] for o in state.opt]
    likes = [_f32_sds(sp) for sp in state.params]  # shape templates, no alloc
    if all("flat" in o for o in state.opt):
        m = [_opt.unflatten_like(o["flat"]["m"], lk) for o, lk in zip(state.opt, likes)]
        v = [_opt.unflatten_like(o["flat"]["v"], lk) for o, lk in zip(state.opt, likes)]
        return m, v
    if all("shards" in o for o in state.opt):
        m, v = [], []
        for o, sp, lk in zip(state.opt, state.params, likes):
            n = int(sum(np.prod(x.shape) for x in jax.tree.leaves(sp)))
            m.append(_opt.unflatten_like(
                _opt.zero1_unshard([s["m"] for s in o["shards"]], n), lk))
            v.append(_opt.unflatten_like(
                _opt.zero1_unshard([s["v"] for s in o["shards"]], n), lk))
        return m, v
    return None


def restage(state, trainer_old, trainer_new):
    """Elastic stage-count change: old AsyncState -> new trainer's AsyncState.

    Params and optimizer moment buffers merge to monolithic and re-split under the
    new stage partition (fused flat-buffer optimizer states are unflattened to
    param-shaped trees first, and re-flattened for the new trainer when it is
    also fused). Stash ring buffers re-warm from the restored params. A
    single-rank ZeRO-1 'shard' state raises up front: it holds only 1/world of
    the moments, and the old silent fallback would restage the params while
    dropping every rank's moments on the floor — gather the replica states
    with `zero1_merge_states` first, restage the merged state, then re-shard
    at the target replica count with `zero1_shard_states` (the R=2<->R=4
    roundtrip is bit-exact, tests/test_mesh.py).
    """
    from repro.optim import optimizers as _opt

    if any("shard" in o for o in state.opt):
        _stage_moments(state)  # raises with the zero1_merge_states guidance
    merged_params = trainer_old.merge_params(state)
    new_state = trainer_new.init_from_params(merged_params)

    # migrate adam moments where structurally possible (same leaf paths)
    def merge_stage_trees(stage_trees):
        class _Holder:
            params = tuple(stage_trees)
        return trainer_old.merge_params(_Holder)

    try:
        moments = _stage_moments(state)
        if moments is not None:
            m_merged = merge_stage_trees(moments[0])
            v_merged = merge_stage_trees(moments[1])
            new_m, _ = _lm.split_stages(m_merged, trainer_new.model_cfg, trainer_new.P)
            new_v, _ = _lm.split_stages(v_merged, trainer_new.model_cfg, trainer_new.P)
            opt = []
            for i, o in enumerate(new_state.opt):
                oo = dict(o)
                if "flat" in oo:
                    oo["flat"] = dict(oo["flat"])
                    oo["flat"]["m"] = _opt.flatten_tree(new_m[i])
                    oo["flat"]["v"] = _opt.flatten_tree(new_v[i])
                else:
                    oo["m"], oo["v"] = new_m[i], new_v[i]
                oo["count"] = state.opt[0]["count"]
                if "mu_prod" in oo:
                    oo["mu_prod"] = state.opt[0].get("mu_prod", oo["mu_prod"])
                opt.append(oo)
            new_state = new_state._replace(opt=tuple(opt), step=state.step)
        else:
            new_state = new_state._replace(step=state.step)
    except Exception:
        new_state = new_state._replace(step=state.step)
    return new_state
