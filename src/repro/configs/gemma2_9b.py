"""gemma2-9b [dense] — local+global alternating, logit softcap [arXiv:2408.00118; hf].

42L d_model=3584 16H (GQA kv=8) head_dim=256 d_ff=14336 vocab=256000.
Sliding window 4096 on alternating layers; attn softcap 50, final softcap 30.
"""
from repro.models.layers import BlockDef, ModelCfg

_LOCAL = BlockDef(mixer="attn", mlp="geglu", window=4096, rope_theta=1e4)
_GLOBAL = BlockDef(mixer="attn", mlp="geglu", rope_theta=1e4)


def config() -> ModelCfg:
    return ModelCfg(
        name="gemma2-9b",
        family="dense",
        d_model=3584,
        n_heads=16,
        n_kv_heads=8,
        head_dim=256,
        d_ff=14336,
        vocab_size=256000,
        attn_softcap=50.0,
        final_softcap=30.0,
        use_post_norm=True,
        tie_embeddings=True,
        pattern=(_LOCAL, _GLOBAL),
        n_periods=21,
        xent_chunk=512,
    )


def reduced() -> ModelCfg:
    import jax.numpy as jnp

    return ModelCfg(
        name="gemma2-9b-reduced",
        family="dense",
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        attn_softcap=50.0,
        final_softcap=30.0,
        use_post_norm=True,
        tie_embeddings=True,
        pattern=(BlockDef(mixer="attn", mlp="geglu", window=8), BlockDef(mixer="attn", mlp="geglu")),
        n_periods=2,
        dtype=jnp.float32,
        remat=False,
    )
