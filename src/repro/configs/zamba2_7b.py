"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention blocks [arXiv:2411.15242].

81L d_model=3584, shared attn 32H (MHA kv=32, head_dim=112) d_ff=14336, ssm_state=64.
Layout adaptation: pattern = (ssm x5, shared_attn) x 13 periods + 3 prelude ssm = 81
layers. The shared transformer block (attn+MLP) has ONE param set reused at every
occurrence, with a per-occurrence output projection (zamba2's per-occurrence LoRA
adapted to a full linear; noted in DESIGN.md).
"""
from repro.models.layers import BlockDef, ModelCfg, SSMCfg

_SSM = BlockDef(mixer="ssm", mlp="none")
_SHARED = BlockDef(mixer="shared_attn", mlp="none")


def config() -> ModelCfg:
    return ModelCfg(
        name="zamba2-7b",
        family="hybrid",
        d_model=3584,
        n_heads=32,
        n_kv_heads=32,
        head_dim=112,
        d_ff=14336,
        vocab_size=32000,
        tie_embeddings=True,
        prelude=(_SSM,) * 3,
        pattern=(_SSM,) * 5 + (_SHARED,),
        n_periods=13,
        ssm=SSMCfg(d_state=64, d_conv=4, expand=2, head_dim=64, n_groups=1, chunk=256),
        xent_chunk=512,
    )


def reduced() -> ModelCfg:
    import jax.numpy as jnp

    return ModelCfg(
        name="zamba2-7b-reduced",
        family="hybrid",
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        tie_embeddings=True,
        prelude=(_SSM,),
        pattern=(_SSM, _SSM, _SHARED),
        n_periods=2,
        ssm=SSMCfg(d_state=16, d_conv=4, expand=2, head_dim=16, n_groups=1, chunk=16),
        dtype=jnp.float32,
        remat=False,
    )
