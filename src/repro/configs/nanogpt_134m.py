"""Paper base model: NanoGPT-style decoder-only, ~134M params.

ctx 512, d_model=768, 12 heads, 8 layers (each layer = one pipeline stage in the
paper). GPT-2 tokenizer vocab (50257). RoPE replaces learned positions (adaptation).
"""
from repro.models.layers import BlockDef, ModelCfg


def config() -> ModelCfg:
    return ModelCfg(
        name="nanogpt-134m",
        family="dense",
        d_model=768,
        n_heads=12,
        n_kv_heads=12,
        head_dim=64,
        d_ff=3072,
        vocab_size=50257,
        tie_embeddings=True,
        pattern=(BlockDef(mixer="attn", mlp="gelu"),),
        n_periods=8,
    )


def reduced() -> ModelCfg:
    import jax.numpy as jnp

    return ModelCfg(
        name="nanogpt-134m-reduced",
        family="dense",
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=256,
        vocab_size=256,
        tie_embeddings=True,
        pattern=(BlockDef(mixer="attn", mlp="gelu"),),
        n_periods=8,
        dtype=jnp.float32,
        remat=False,
    )
