"""gemma3-12b [dense] — 5:1 local:global, 128k context [hf:google/gemma-3].

48L d_model=3840 16H (GQA kv=8) head_dim=256 d_ff=15360 vocab=262144.
Local window 1024 (rope 10k); global layers rope 1M. QK-norm, pre+post norms, GeGLU.
"""
from repro.models.layers import BlockDef, ModelCfg

_LOCAL = BlockDef(mixer="attn", mlp="geglu", window=1024, rope_theta=1e4)
_GLOBAL = BlockDef(mixer="attn", mlp="geglu", rope_theta=1e6)


def config() -> ModelCfg:
    return ModelCfg(
        name="gemma3-12b",
        family="dense",
        d_model=3840,
        n_heads=16,
        n_kv_heads=8,
        head_dim=256,
        d_ff=15360,
        vocab_size=262144,
        qk_norm=True,
        use_post_norm=True,
        tie_embeddings=True,
        pattern=(_LOCAL,) * 5 + (_GLOBAL,),
        n_periods=8,
        xent_chunk=512,
    )


def reduced() -> ModelCfg:
    import jax.numpy as jnp

    return ModelCfg(
        name="gemma3-12b-reduced",
        family="dense",
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        qk_norm=True,
        use_post_norm=True,
        tie_embeddings=True,
        pattern=(BlockDef(mixer="attn", mlp="geglu", window=8, rope_theta=1e4),) * 2
        + (BlockDef(mixer="attn", mlp="geglu", rope_theta=1e6),),
        n_periods=2,
        dtype=jnp.float32,
        remat=False,
    )
