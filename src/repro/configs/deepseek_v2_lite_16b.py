"""deepseek-v2-lite-16b [moe] — MLA kv_lora=512, shared+routed experts [arXiv:2405.04434].

27L d_model=2048 16H, MLA (kv_lora=512, nope=128, rope=64, v=128), vocab=102400.
MoE: 64 routed top-6 + 2 shared, d_ff(expert)=1408; first layer dense d_ff=10944.
(The pool line lists both "64e top-6" and "160 routed"; 64 routed + 2 shared is the
published V2-Lite config — see DESIGN.md §7.)
"""
from repro.models.layers import BlockDef, ModelCfg, MLACfg, MoECfg


def config() -> ModelCfg:
    return ModelCfg(
        name="deepseek-v2-lite-16b",
        family="moe",
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        head_dim=128,
        d_ff=10944,  # dense first layer
        vocab_size=102400,
        tie_embeddings=False,
        prelude=(BlockDef(mixer="attn", mlp="swiglu"),),
        pattern=(BlockDef(mixer="attn", mlp="moe"),),
        n_periods=26,
        mla=MLACfg(kv_lora=512, qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128),
        moe=MoECfg(n_experts=64, top_k=6, d_ff_expert=1408, n_shared=2, d_ff_shared=2816),
        xent_chunk=512,
    )


def reduced() -> ModelCfg:
    import jax.numpy as jnp

    return ModelCfg(
        name="deepseek-v2-lite-16b-reduced",
        family="moe",
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        tie_embeddings=False,
        prelude=(BlockDef(mixer="attn", mlp="swiglu"),),
        pattern=(BlockDef(mixer="attn", mlp="moe"),),
        n_periods=2,
        mla=MLACfg(kv_lora=32, qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16),
        moe=MoECfg(n_experts=4, top_k=2, d_ff_expert=32, n_shared=1, d_ff_shared=64),
        dtype=jnp.float32,
        remat=False,
    )
