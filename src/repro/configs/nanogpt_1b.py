"""Paper 1B model: ctx 1024, d_model=2688, 24 heads, 8 layers (8 stages)."""
from repro.models.layers import BlockDef, ModelCfg


def config() -> ModelCfg:
    return ModelCfg(
        name="nanogpt-1b",
        family="dense",
        d_model=2688,
        n_heads=24,
        n_kv_heads=24,
        head_dim=112,
        d_ff=10752,
        vocab_size=50257,
        tie_embeddings=True,
        pattern=(BlockDef(mixer="attn", mlp="gelu"),),
        n_periods=8,
    )


def reduced() -> ModelCfg:
    import jax.numpy as jnp

    return ModelCfg(
        name="nanogpt-1b-reduced",
        family="dense",
        d_model=96,
        n_heads=4,
        n_kv_heads=4,
        head_dim=24,
        d_ff=384,
        vocab_size=256,
        tie_embeddings=True,
        pattern=(BlockDef(mixer="attn", mlp="gelu"),),
        n_periods=8,
        dtype=jnp.float32,
        remat=False,
    )
