"""internlm2-20b [dense] — GQA [arXiv:2403.17297; hf].

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92544.
"""
from repro.models.layers import BlockDef, ModelCfg


def config() -> ModelCfg:
    return ModelCfg(
        name="internlm2-20b",
        family="dense",
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        head_dim=128,
        d_ff=16384,
        vocab_size=92544,
        tie_embeddings=False,
        pattern=(BlockDef(mixer="attn", mlp="swiglu", rope_theta=1e6),),
        n_periods=48,
        xent_chunk=512,
    )


def reduced() -> ModelCfg:
    import jax.numpy as jnp

    return ModelCfg(
        name="internlm2-20b-reduced",
        family="dense",
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        tie_embeddings=False,
        pattern=(BlockDef(mixer="attn", mlp="swiglu", rope_theta=1e6),),
        n_periods=3,
        dtype=jnp.float32,
        remat=False,
    )
