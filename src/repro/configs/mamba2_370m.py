"""mamba2-370m [ssm] — SSD (state-space duality) [arXiv:2405.21060].

48L d_model=1024, attention-free (d_ff=0), vocab=50280, ssm_state=128.
"""
from repro.models.layers import BlockDef, ModelCfg, SSMCfg


def config() -> ModelCfg:
    return ModelCfg(
        name="mamba2-370m",
        family="ssm",
        d_model=1024,
        n_heads=1,  # unused (attn-free)
        n_kv_heads=1,
        head_dim=64,
        d_ff=0,
        vocab_size=50280,
        tie_embeddings=True,
        pattern=(BlockDef(mixer="ssm", mlp="none"),),
        n_periods=48,
        ssm=SSMCfg(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1, chunk=256),
    )


def reduced() -> ModelCfg:
    import jax.numpy as jnp

    return ModelCfg(
        name="mamba2-370m-reduced",
        family="ssm",
        d_model=64,
        n_heads=1,
        n_kv_heads=1,
        head_dim=16,
        d_ff=0,
        vocab_size=256,
        tie_embeddings=True,
        pattern=(BlockDef(mixer="ssm", mlp="none"),),
        n_periods=2,
        ssm=SSMCfg(d_state=16, d_conv=4, expand=2, head_dim=16, n_groups=1, chunk=16),
        dtype=jnp.float32,
        remat=False,
    )
