"""dbrx-132b [moe] — 16 experts top-4, fine-grained [hf:databricks/dbrx-base].

40L d_model=6144 48H (GQA kv=8) d_ff(expert)=10752 vocab=100352, MoE 16e top-4.
"""
from repro.models.layers import BlockDef, ModelCfg, MoECfg


def config() -> ModelCfg:
    return ModelCfg(
        name="dbrx-132b",
        family="moe",
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        head_dim=128,
        d_ff=10752,
        vocab_size=100352,
        tie_embeddings=False,
        pattern=(BlockDef(mixer="attn", mlp="moe", rope_theta=5e5),),
        n_periods=40,
        moe=MoECfg(n_experts=16, top_k=4, d_ff_expert=10752, n_shared=0),
        xent_chunk=512,
    )


def reduced() -> ModelCfg:
    import jax.numpy as jnp

    return ModelCfg(
        name="dbrx-132b-reduced",
        family="moe",
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=96,
        vocab_size=256,
        tie_embeddings=False,
        pattern=(BlockDef(mixer="attn", mlp="moe", rope_theta=5e5),),
        n_periods=2,
        moe=MoECfg(n_experts=4, top_k=2, d_ff_expert=96, n_shared=0),
        dtype=jnp.float32,
        remat=False,
    )
