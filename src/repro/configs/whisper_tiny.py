"""whisper-tiny [audio] — enc-dec, conv frontend stubbed [arXiv:2212.04356].

4L encoder + 4L decoder, d_model=384, 6H (MHA kv=6) head_dim=64, d_ff=1536,
vocab=51865. The conv1d audio frontend is a STUB: `input_specs` supplies
precomputed frame embeddings [B, n_frames, d_model]. RoPE replaces whisper's
learned absolute positions (TPU-idiomatic adaptation; noted in DESIGN.md).
"""
from repro.models.layers import BlockDef, ModelCfg

_ENC = BlockDef(mixer="attn", mlp="gelu", causal=False)
_DEC = BlockDef(mixer="attn", mlp="gelu", cross_attn=True)


def config() -> ModelCfg:
    return ModelCfg(
        name="whisper-tiny",
        family="audio",
        d_model=384,
        n_heads=6,
        n_kv_heads=6,
        head_dim=64,
        d_ff=1536,
        vocab_size=51865,
        tie_embeddings=True,
        enc_pattern=(_ENC,),
        enc_periods=4,
        n_frames=1500,
        pattern=(_DEC,),
        n_periods=4,
    )


def reduced() -> ModelCfg:
    import jax.numpy as jnp

    return ModelCfg(
        name="whisper-tiny-reduced",
        family="audio",
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        tie_embeddings=True,
        enc_pattern=(_ENC,),
        enc_periods=2,
        n_frames=16,
        pattern=(_DEC,),
        n_periods=2,
        dtype=jnp.float32,
        remat=False,
    )
