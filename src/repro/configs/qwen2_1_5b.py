"""qwen2-1.5b [dense] — GQA, QKV bias [arXiv:2407.10671; hf].

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.
"""
from repro.models.layers import BlockDef, ModelCfg


def config() -> ModelCfg:
    return ModelCfg(
        name="qwen2-1.5b",
        family="dense",
        d_model=1536,
        n_heads=12,
        n_kv_heads=2,
        head_dim=128,
        d_ff=8960,
        vocab_size=151936,
        qkv_bias=True,
        tie_embeddings=True,
        pattern=(BlockDef(mixer="attn", mlp="swiglu", rope_theta=1e6),),
        n_periods=28,
        xent_chunk=512,
    )


def reduced() -> ModelCfg:
    import jax.numpy as jnp

    return ModelCfg(
        name="qwen2-1.5b-reduced",
        family="dense",
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        qkv_bias=True,
        tie_embeddings=True,
        pattern=(BlockDef(mixer="attn", mlp="swiglu", rope_theta=1e6),),
        n_periods=3,
        dtype=jnp.float32,
        remat=False,
    )
