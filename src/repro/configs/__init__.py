"""Config registry: 10 assigned architectures + the paper's own models.

Each module exposes ``config() -> ModelCfg`` (full published config) and
``reduced() -> ModelCfg`` (same family, tiny — for CPU smoke tests).
"""
from __future__ import annotations

import dataclasses
import importlib

ARCH_IDS = [
    "mamba2_370m",
    "gemma3_12b",
    "internlm2_20b",
    "qwen2_1_5b",
    "gemma2_9b",
    "paligemma_3b",
    "whisper_tiny",
    "dbrx_132b",
    "deepseek_v2_lite_16b",
    "zamba2_7b",
    # paper's own models
    "nanogpt_134m",
    "nanogpt_1b",
]

# canonical assigned names -> module ids
ALIASES = {
    "mamba2-370m": "mamba2_370m",
    "gemma3-12b": "gemma3_12b",
    "internlm2-20b": "internlm2_20b",
    "qwen2-1.5b": "qwen2_1_5b",
    "gemma2-9b": "gemma2_9b",
    "paligemma-3b": "paligemma_3b",
    "whisper-tiny": "whisper_tiny",
    "dbrx-132b": "dbrx_132b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "zamba2-7b": "zamba2_7b",
    "nanogpt-134m": "nanogpt_134m",
    "nanogpt-1b": "nanogpt_1b",
}

ASSIGNED = ARCH_IDS[:10]

# LM shape set (assigned): name -> (seq_len, global_batch, step kind)
SHAPES = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}

# archs allowed to run long_500k (sub-quadratic sequence mixing)
SUBQUADRATIC = {"mamba2_370m", "zamba2_7b"}


def norm_name(name: str) -> str:
    return ALIASES.get(name, name.replace("-", "_").replace(".", "_"))


def get_config(name: str, reduced: bool = False, **overrides):
    mod = importlib.import_module(f"repro.configs.{norm_name(name)}")
    cfg = mod.reduced() if reduced else mod.config()
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def cell_runnable(arch: str, shape: str):
    """(runnable, reason). All 40 cells documented; skips per DESIGN.md §4."""
    a = norm_name(arch)
    if shape == "long_500k" and a not in SUBQUADRATIC:
        return False, "full-attention arch: 500k decode requires sub-quadratic mixing (skip per assignment; see DESIGN.md)"
    return True, ""
