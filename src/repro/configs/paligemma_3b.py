"""paligemma-3b [vlm] — SigLIP + gemma [arXiv:2407.07726; hf].

Backbone only (18L d_model=2048 8H GQA kv=1 d_ff=16384 vocab=257216); the SigLIP
vision tower is a STUB: `input_specs` supplies 256 precomputed patch embeddings that
occupy the first positions, with prefix-LM (bidirectional) masking over the prefix.
"""
from repro.models.layers import BlockDef, ModelCfg


def config() -> ModelCfg:
    return ModelCfg(
        name="paligemma-3b",
        family="vlm",
        d_model=2048,
        n_heads=8,
        n_kv_heads=1,
        head_dim=256,
        d_ff=16384,
        vocab_size=257216,
        tie_embeddings=True,
        pattern=(BlockDef(mixer="attn", mlp="geglu"),),
        n_periods=18,
        n_prefix_img=256,
        prefix_lm=True,
        xent_chunk=512,
    )


def reduced() -> ModelCfg:
    import jax.numpy as jnp

    return ModelCfg(
        name="paligemma-3b-reduced",
        family="vlm",
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        tie_embeddings=True,
        pattern=(BlockDef(mixer="attn", mlp="geglu"),),
        n_periods=3,
        n_prefix_img=8,
        prefix_lm=True,
        dtype=jnp.float32,
        remat=False,
    )
