"""repro — async pipeline-parallel JAX framework around the delay-corrected
Nesterov method (ICML 2025). See README.md / DESIGN.md."""

__version__ = "1.0.0"
