"""Sharding rules: param-name-keyed PartitionSpecs for FSDP ('data') x TP ('model').

Strategy (MaxText-style):
  - TP: attention heads / MoE experts / ffn hidden / vocab on 'model'
  - FSDP: the embed/d_model axis of every weight on 'data' (params, moments, stash)
  - a dim is sharded only if divisible by the axis size (else replicated) and no
    mesh axis is used twice in one spec
  - stacked leading axes (scan periods, stash time, swarm replicas) are unsharded

`spec_for_tree` walks any params/opt/stash pytree and returns a matching tree of
PartitionSpecs; `extra_leading` accounts for stash time axes etc.
"""
from __future__ import annotations

import re
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.tree_util import tree_flatten_with_path, keystr


# param-name -> per-dim logical roles, innermost dims (leading stack dims padded None)
# roles: 'embed' (FSDP/data), 'heads','kv_heads','ffn','experts','vocab','kv_lora' (TP/model), None
_RULES = [
    (r"tok_embed$", ("vocab", "embed")),
    (r"(lm_head|head_w)$", ("embed", "vocab")),
    (r"(wq|c_wq)$", ("embed", "heads", None)),
    (r"(wk|wv|c_wk|c_wv)$", ("embed", "kv_heads", None)),
    (r"(wo|c_wo)$", ("heads", None, "embed")),
    (r"(bq)$", ("heads", None)),
    (r"(bk|bv)$", ("kv_heads", None)),
    (r"w_dkv$", ("embed", "model_flat")),
    (r"(w_uk|w_uv)$", ("kv_lora", "heads", None)),
    (r"(w_gate|w_up)$", ("embed", "ffn")),
    (r"w_down$", ("ffn", "embed")),
    (r"router$", ("embed", None)),
    (r"(moe_gate|moe_up)$", ("experts", "embed", None)),
    (r"moe_down$", ("experts", None, "embed")),
    (r"in_proj$", ("embed", "model_flat")),
    (r"out_proj$", ("model_flat", "embed")),
    (r"shared_out_proj$", ("embed", "model_flat")),
    (r"conv_w$", (None, "model_flat")),
    (r"conv_b$", ("model_flat",)),
    (r"(A_log|ssm_D|dt_bias)$", (None,)),
    (r"(scale)$", (None,)),  # norms
]

_ROLE_AXIS = {
    "embed": "data",
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "ffn": "model",
    "experts": "model",
    "kv_lora": "data",
    "model_flat": "model",
}


def _mesh_size(mesh: Mesh, axis: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape))[axis]


def norm_path(path: str) -> str:
    """keystr -> slash form: ".params[0]['scan']['b0']['wq']" -> "params/0/scan/b0/wq"."""
    p = re.sub(r"\['([^']+)'\]", r"/\1", path)
    p = re.sub(r"\[(\d+)\]", r"/\1", p)
    p = p.replace(".", "/")
    return p.strip("/")


def spec_for(path: str, shape, mesh: Mesh, *, extra_data_axis: Optional[str] = None):
    """PartitionSpec for one param leaf identified by its key path."""
    path = norm_path(path)
    roles = None
    for pat, r in _RULES:
        if re.search(pat, path):
            roles = r
            break
    nd = len(shape)
    if roles is None:
        return P(*([None] * nd))
    lead = nd - len(roles)  # stacked axes (periods / stash time / replicas)
    spec = [None] * nd
    used = set()
    for j, role in enumerate(roles):
        if role is None:
            continue
        axis = _ROLE_AXIS[role]
        dim = lead + j
        size = _mesh_size(mesh, axis)
        names = (axis,)
        if axis == "data" and extra_data_axis and extra_data_axis in mesh.axis_names:
            if shape[dim] % (size * _mesh_size(mesh, extra_data_axis)) == 0 and extra_data_axis not in used:
                names = (extra_data_axis, axis)
                size = size * _mesh_size(mesh, extra_data_axis)
        if axis in used or any(n in used for n in names):
            continue
        if shape[dim] % size != 0 or shape[dim] < size:
            # try single-axis fallback when the compound fails
            if len(names) > 1 and shape[dim] % _mesh_size(mesh, axis) == 0 and axis not in used:
                names = (axis,)
            else:
                continue
        spec[dim] = names if len(names) > 1 else names[0]
        used.update(names)
    return P(*spec)


def spec_for_tree(tree, mesh: Mesh, *, extra_data_axis: Optional[str] = None):
    leaves, treedef = tree_flatten_with_path(tree)
    specs = [
        spec_for(keystr(p), np.shape(l), mesh, extra_data_axis=extra_data_axis)
        for p, l in leaves
    ]
    return jax.tree.unflatten(treedef, specs)


def sharding_for_tree(tree, mesh: Mesh, **kw):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_for_tree(tree, mesh, **kw),
        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Activations / batch / caches
# ---------------------------------------------------------------------------


def batch_spec(mesh: Mesh, ndim: int, *, leading_micro: bool, pod_data: bool = False):
    """tokens/labels [K?, B, S] or frames/patches [K?, B, S, D]: batch on data(+pod)."""
    b_axes = ("pod", "data") if (pod_data and "pod" in mesh.axis_names) else "data"
    spec = [None] * ndim
    spec[1 if leading_micro else 0] = b_axes
    return P(*spec)


# Flash-decoding-style cache layout (§Perf H7): when kv_heads don't divide the
# model axis, shard the cache *sequence* over 'model' (split-K): scores stay
# local per shard and only softmax statistics + a [B,H,1,hd] partial all-reduce
# cross shards — instead of all-gathering the whole cache every token.
DECODE_SPLITK = True


def cache_spec(path: str, shape, mesh: Mesh, batch_sharded: bool = True):
    """KV/SSD cache leaves: batch on 'data' when divisible, else seq on 'data';
    kv_heads on 'model' when divisible, else split-K over the sequence."""
    path = norm_path(path)
    nd = len(shape)
    spec = [None] * nd
    dsz = _mesh_size(mesh, "data")
    msz = _mesh_size(mesh, "model")
    if re.search(r"(/k$|/v$)", path):
        # [periods?, B, Smax, Hkv, hd]
        lead = nd - 4
        B, S, H, hd = shape[lead:]
        if B % dsz == 0:
            spec[lead] = "data"
        elif S % dsz == 0:
            spec[lead + 1] = "data"
        if H % msz == 0:
            spec[lead + 2] = "model"
        elif DECODE_SPLITK and S % msz == 0 and spec[lead + 1] is None:
            spec[lead + 1] = "model"
        elif hd % msz == 0:
            spec[lead + 3] = "model"
        return P(*spec)
    if "c_kv" in path or "k_rope" in path:
        lead = nd - 3
        B, S, D = shape[lead:]
        if B % dsz == 0:
            spec[lead] = "data"
        elif S % dsz == 0:
            spec[lead + 1] = "data"
        if DECODE_SPLITK and S % msz == 0 and spec[lead + 1] is None:
            spec[lead + 1] = "model"  # split-K over latents
        elif D % msz == 0 and "c_kv" in path:
            spec[lead + 2] = "model"
        return P(*spec)
    if "state" in path:  # [periods?, B, H, N, P]
        lead = nd - 4
        B, H, N, Pd = shape[lead:]
        if B % dsz == 0:
            spec[lead] = "data"
        if H % msz == 0:
            spec[lead + 1] = "model"
        return P(*spec)
    if "conv" in path:  # [periods?, B, d_conv-1, ch]
        lead = nd - 3
        B, _, ch = shape[lead:]
        if B % dsz == 0:
            spec[lead] = "data"
        if ch % msz == 0:
            spec[lead + 2] = "model"
        return P(*spec)
    return P(*spec)


def cache_spec_tree(tree, mesh: Mesh):
    leaves, treedef = tree_flatten_with_path(tree)
    specs = [cache_spec(keystr(p), np.shape(l), mesh) for p, l in leaves]
    return jax.tree.unflatten(treedef, specs)
