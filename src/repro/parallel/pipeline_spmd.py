"""Cross-pod SPMD 1F1B async pipeline (multi-pod mesh: 'pod' = pipeline axis).

This is the paper's deployment setting made SPMD: pipeline stages live on separate
pods joined by slow links; each pod updates its stage weights *locally per
microbatch* (K=1 async, no global barrier), with PipeDream weight stashing for
correct backprop — the engine's semantics realized as a genuinely pipelined SPMD
program.

Structure: pure-GSPMD collective pipelining — every per-pod tree carries a
leading [n_pods] axis sharded on 'pod'; the per-slot compute is `jax.vmap` over
that axis (so GSPMD places each pod's compute on its pod's devices, with
'data'/'model' auto-sharded exactly like the single-pod program, FSDP x TP), and
the activation/error wires are `jnp.roll` shifts of the pod axis, which XLA
lowers to collective-permutes over the slow inter-pod links. This formulation
avoids partial-manual shard_map entirely — XLA's manual-subgroup partitioner
hard-CHECKs on permute collectives on several released versions — at two costs:
fill/drain bubble slots compute on zero wires (masked out; the bubble fraction
is the usual (P-1)/(M+2P-2)), and `lax.cond`s with pod-varying predicates lower
to selects, so the head phase (final norm + vocab projection + xent) runs on
every pod each slot instead of only the last (~(P-1)x redundant head FLOPs;
hoisting the head out of the vmapped VJP via a two-stage vjp is the known
follow-up if the head ever dominates a multi-pod profile).

Stage 0 (embedding + prelude + whisper encoder) runs OUTSIDE the manual region
under plain pjit, vectorized over all M microbatches, and its parameters update
once per tick (synchronously): XLA's gather partitioner cannot partition embedding
lookups inside partial-manual regions (hard CHECK crash), and a full-mesh-sharded
embedding table is the better layout anyway. The in-region cross-entropy is
gather-free (one-hot dot). Documented in DESIGN.md §7.

Slot schedule (depth-first 1F1B): fwd of microbatch m at pod p in slot m+p; bwd in
slot m + 2(P-1) - p; each bwd applies an immediate local update, so the realized
weight delay is tau_p = 2(P-1-p) updates — the cross-pod analogue of Eq. 5.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import lm
from repro.models.layers import ModelCfg
from repro.optim import optimizers
from repro.parallel import ax
from repro.parallel import sharding as shd


# ---------------------------------------------------------------------------
# Parameter layout
# ---------------------------------------------------------------------------

STAGE0_KEYS = ("tok_embed", "prelude", "enc_scan", "enc_final_norm")
POD_EDGE_KEYS = ("final_norm", "lm_head", "shared")


def build_pp_params(params, cfg: ModelCfg, n_pods: int):
    """Monolithic -> {'stage0': pjit params, 'pod_edge': [n_pods, ...] copies,
    'blocks': [n_pods, pp, ...], 'flags': [n_pods, pp]}."""
    Pn = cfg.n_periods
    pp = math.ceil(Pn / n_pods)
    pad = n_pods * pp - Pn

    def pad_stack(a):
        if pad:
            a = jnp.concatenate([a, jnp.repeat(a[-1:], pad, axis=0)], axis=0)
        return a.reshape((n_pods, pp) + a.shape[1:])

    blocks = jax.tree.map(pad_stack, params["scan"])
    flags = jnp.concatenate(
        [jnp.ones((Pn,), jnp.float32), jnp.zeros((pad,), jnp.float32)]
    ).reshape(n_pods, pp)
    stage0 = {k: params[k] for k in STAGE0_KEYS if k in params}
    edge_one = {k: params[k] for k in POD_EDGE_KEYS if k in params}
    if cfg.tie_embeddings:
        # the head gets its own copy of the embedding (independent under async PP)
        edge_one["head_w"] = params["tok_embed"].T.copy()
    edge = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n_pods,) + x.shape).copy(), edge_one)
    return {"stage0": stage0, "pod_edge": edge, "blocks": blocks, "flags": flags}


# ---------------------------------------------------------------------------
# Phases
# ---------------------------------------------------------------------------


def stage0_apply(stage0, batch, cfg: ModelCfg):
    """Embed + prelude (+ whole encoder) for ONE microbatch -> wire dict."""
    carry = {"x": None, "enc": None, "aux": jnp.zeros((), jnp.float32)}
    ops = []
    if cfg.enc_periods:
        ops += [("frames_in",), ("enc_blocks", 0, cfg.enc_periods), ("enc_out",)]
    ops += [("embed",)] + [("prelude", i) for i in range(len(cfg.prelude))]
    carry, _ = lm.run_stage_ops(stage0, ops, carry, batch, cfg)
    wire = {"x": carry["x"], "aux": carry["aux"]}
    if cfg.enc_periods:
        wire["enc"] = carry["enc"]
    return wire


def _mid_blocks(blocks, flags, wire, cfg: ModelCfg, shared):
    """Scan local (possibly padded) periods; padded periods are identity."""
    x, enc, aux = wire["x"], wire.get("enc"), wire["aux"]
    B, S = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(c, xs):
        xx, a = c
        bp, flag = xs
        x_new, aux_new = xx, a
        for j, blk in enumerate(cfg.pattern):
            x_new, da, _ = lm.block_apply(bp[f"b{j}"], blk, x_new, cfg,
                                          positions=positions, enc_out=enc,
                                          shared=shared, iota_positions=True)
            aux_new = aux_new + da
        xx = xx + flag.astype(xx.dtype) * (x_new - xx)
        a = a + flag * (aux_new - a)
        return (xx, a), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    (x, aux), _ = jax.lax.scan(body, (x, aux), (blocks, flags), unroll=cfg.unroll)
    out = {"x": x, "aux": aux}
    if "enc" in wire:
        out["enc"] = enc
    return out


def _head_phase(edge, wire, labels, cfg: ModelCfg):
    sp = {"final_norm": edge["final_norm"]}
    if cfg.tie_embeddings:
        sp["lm_head"] = edge["head_w"]
        cfg = dataclasses.replace(cfg, tie_embeddings=False)
    else:
        sp["lm_head"] = edge["lm_head"]
    loss = lm._head_loss(sp, cfg, wire["x"], {"labels": labels})
    return loss + wire["aux"]


# ---------------------------------------------------------------------------
# The pipelined async train step
# ---------------------------------------------------------------------------


class PPState(NamedTuple):
    step: jnp.ndarray
    pp: Any  # build_pp_params output
    opt_s0: Any  # stage-0 optimizer state (sync, per tick)
    opt: Any  # per-pod optimizer state over {'pod_edge','blocks'}
    stash: Any  # per-pod weight stash ring [pod, ring, ...]


def _wire_zero(cfg: ModelCfg, b, S):
    w = {"x": jnp.zeros((b, S, cfg.d_model), cfg.dtype),
         "aux": jnp.zeros((), jnp.float32)}
    if cfg.enc_periods:
        w["enc"] = jnp.zeros((b, cfg.n_frames, cfg.d_model), cfg.dtype)
    return w


def make_pipeline_step(cfg: ModelCfg, mesh, *, n_microbatches: int, method: str = "ours",
                       lr: float = 3e-4, weight_stash: bool = True):
    """Returns (init_fn(params)->PPState, step_fn(state, batch)->(state, metrics)).

    batch: {'tokens': [M, b, S], 'labels': [M, b, S], ...}; M = n_microbatches.
    """
    cfg = dataclasses.replace(cfg, onehot_xent=True)
    n_pods = dict(zip(mesh.axis_names, mesh.devices.shape))["pod"]
    M = n_microbatches
    ring = 2 * n_pods
    opt_kind = {"ours": "nadam", "pipedream": "adamw"}.get(method, "nadam")
    kw = {"b1": 0.99} if opt_kind == "nadam" else {}
    opt = optimizers.make_optimizer(opt_kind, lr=lr, **kw)

    def init_fn(params):
        pp = build_pp_params(params, cfg, n_pods)
        wb = {"pod_edge": pp["pod_edge"], "blocks": pp["blocks"]}
        w_one = jax.tree.map(lambda x: x[0], wb)
        opt_one = opt.init(w_one)
        opt_state = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n_pods,) + x.shape).copy(), opt_one)
        stash = jax.tree.map(
            lambda x: jnp.broadcast_to(x[:, None], (n_pods, ring) + x.shape[1:]).copy(), wb)
        opt_s0 = opt.init(pp["stage0"])
        return PPState(jnp.zeros((), jnp.int32), pp, opt_s0, opt_state, stash)

    n_slots = M + 2 * (n_pods - 1)

    def step_fn(state: PPState, batch):
        # --- stage 0 forward for all microbatches (pjit, vectorized over M) ---
        def s0_all(stage0, b):
            return jax.vmap(lambda mb: stage0_apply(stage0, mb, cfg))(b)

        x0_all, s0_vjp = jax.vjp(lambda p: s0_all(p, batch), state.pp["stage0"])
        labels_all = batch["labels"]
        b, S = labels_all.shape[1], labels_all.shape[2]
        zero_wire = _wire_zero(cfg, b, S)
        pod_ids = jnp.arange(n_pods, dtype=jnp.int32)
        flags_all = state.pp["flags"]

        def idx_mb(tree, i):
            i = jnp.clip(i, 0, M - 1)
            return jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False), tree)

        def pod_fn(w, flags, wire_in, labels, is_last):
            out = _mid_blocks(w["blocks"], flags, wire_in, cfg,
                              w["pod_edge"].get("shared"))
            loss = jax.lax.cond(
                is_last,
                lambda: _head_phase(w["pod_edge"], out, labels, cfg),
                lambda: jnp.zeros((), jnp.float32))
            return out, loss

        def slot_pod(W, opt_s, stw, x_ring, x_wire, e_wire, dx0, loss_sum,
                     flags, pod_id, s):
            """One slot of ONE pod (vmapped over the pod axis by `slot`)."""
            is_first = pod_id == 0
            is_last = pod_id == n_pods - 1
            # ---------------- forward unit ----------------
            fwd_mb = s - pod_id
            fwd_valid = (fwd_mb >= 0) & (fwd_mb < M)
            x0 = idx_mb(x0_all, fwd_mb)
            wire_in = jax.tree.map(lambda f, r: jnp.where(is_first, f, r), x0, x_wire)
            wire_in = jax.tree.map(lambda a, z: jnp.where(fwd_valid, a, z),
                                   wire_in, zero_wire)

            def do_fwd():
                out, _ = pod_fn({"pod_edge": W["pod_edge"], "blocks": W["blocks"]},
                                flags, wire_in, idx_mb(labels_all, fwd_mb), is_last)
                return out

            send = jax.lax.cond(fwd_valid & (~is_last), do_fwd, lambda: zero_wire)
            slot_idx = jnp.mod(jnp.clip(fwd_mb, 0, M - 1), ring)
            upd_ring = lambda r, v: jnp.where(
                fwd_valid,
                jax.lax.dynamic_update_index_in_dim(r, v.astype(r.dtype), slot_idx, 0), r)
            x_ring = jax.tree.map(upd_ring, x_ring, wire_in)
            stw = jax.tree.map(upd_ring, stw, W)

            # ---------------- backward unit ----------------
            bwd_mb = s - (2 * (n_pods - 1) - pod_id)
            bwd_valid = (bwd_mb >= 0) & (bwd_mb < M)
            bslot = jnp.mod(jnp.clip(bwd_mb, 0, M - 1), ring)
            labels_b = idx_mb(labels_all, bwd_mb)
            take = lambda r: jax.lax.dynamic_index_in_dim(r, bslot, 0, keepdims=False)
            x_saved = jax.tree.map(take, x_ring)
            W_b = jax.tree.map(take, stw) if weight_stash else W
            W_b = jax.tree.map(lambda a, ref: a.astype(ref.dtype), W_b, W)

            def do_bwd():
                (out, loss), vjp = jax.vjp(
                    lambda w, xi: pod_fn(w, flags, xi, labels_b, is_last), W_b, x_saved)
                zero_ct = jax.tree.map(jnp.zeros_like, out)
                ct_wire = jax.tree.map(
                    lambda e, z: jnp.where(is_last, z, e.astype(z.dtype)), e_wire, zero_ct)
                gW, ge = vjp((ct_wire, jnp.ones((), jnp.float32)))
                return gW, ge, loss

            def no_bwd():
                gW = jax.tree.map(jnp.zeros_like, W)
                ge = jax.tree.map(jnp.zeros_like, zero_wire)
                return gW, ge, jnp.zeros((), jnp.float32)

            gW, ge, loss = jax.lax.cond(bwd_valid, do_bwd, no_bwd)
            newW, new_opt, _ = opt.update(W, gW, opt_s)
            W = jax.tree.map(lambda a, b_: jnp.where(bwd_valid, a, b_), newW, W)
            opt_s = jax.tree.map(lambda a, b_: jnp.where(bwd_valid, a, b_), new_opt, opt_s)
            loss_sum = loss_sum + jnp.where(bwd_valid & is_last, loss, 0.0)
            # first pod's input-cotangent = stage-0 output grads: collect per mb
            dx0 = jax.tree.map(
                lambda buf, g: jnp.where(
                    bwd_valid & is_first,
                    jax.lax.dynamic_update_index_in_dim(
                        buf, g.astype(buf.dtype), jnp.clip(bwd_mb, 0, M - 1), 0), buf),
                dx0, ge)
            return W, opt_s, stw, x_ring, dx0, loss_sum, send, ge

        def slot(carry, s):
            W, opt_s, stw, x_ring, x_wire, e_wire, dx0, loss_sum = carry
            W, opt_s, stw, x_ring, dx0, loss_sum, send, ge = jax.vmap(
                lambda *a: slot_pod(*a, s)
            )(W, opt_s, stw, x_ring, x_wire, e_wire, dx0, loss_sum, flags_all, pod_ids)
            # wires: cyclic shift over the pod axis (XLA lowers the sharded roll
            # to a collective-permute — the activation/error hop between pods)
            x_wire = jax.tree.map(lambda v: jnp.roll(v, 1, axis=0), send)
            e_wire = jax.tree.map(lambda v: jnp.roll(v, -1, axis=0), ge)
            return (W, opt_s, stw, x_ring, x_wire, e_wire, dx0, loss_sum), None

        W0 = {"pod_edge": state.pp["pod_edge"], "blocks": state.pp["blocks"]}
        pstack = lambda z, lead: jnp.zeros((n_pods,) + lead + z.shape, z.dtype)
        x_ring0 = jax.tree.map(lambda z: pstack(z, (ring,)), zero_wire)
        wire0 = jax.tree.map(lambda z: pstack(z, ()), zero_wire)
        dx0_0 = jax.tree.map(
            lambda z: jnp.zeros((n_pods, M) + z.shape, jnp.float32), zero_wire)
        carry0 = (W0, state.opt, state.stash, x_ring0, wire0,
                  jax.tree.map(jnp.zeros_like, wire0), dx0_0,
                  jnp.zeros((n_pods,), jnp.float32))
        # 'pod' is a batched axis here, not a constrainable one: keep ax.constrain
        # specs inside the per-pod trace to 'data'/'model' only
        with ax.manual_axes("pod"):
            carry, _ = jax.lax.scan(slot, carry0, jnp.arange(n_slots),
                                    unroll=cfg.unroll)
        W, opt_s, stw, _, _, _, dx0, loss_sum = carry
        loss = jnp.sum(jnp.where(pod_ids == n_pods - 1, loss_sum, 0.0)) / M

        # --- stage 0 backward + synchronous per-tick update ---
        dx0_first = jax.tree.map(lambda a: a[0], dx0)  # first pod's cotangents
        dx0_cast = jax.tree.map(lambda g, x: g.astype(x.dtype), dx0_first, x0_all)
        (g_s0,) = s0_vjp(dx0_cast)
        g_s0 = jax.tree.map(lambda g: g / M, g_s0)
        new_s0, new_opt_s0, _ = opt.update(state.pp["stage0"], g_s0, state.opt_s0)

        pp = dict(state.pp)
        pp["stage0"], pp["pod_edge"], pp["blocks"] = new_s0, W["pod_edge"], W["blocks"]
        return (PPState(state.step + 1, pp, new_opt_s0, opt_s, stw),
                {"loss": loss})

    return init_fn, step_fn


# ---------------------------------------------------------------------------
# Dry-run integration
# ---------------------------------------------------------------------------


def lower_pipeline_train(cfg: ModelCfg, cell, mesh, method: str = "ours"):
    init_fn, step_fn = make_pipeline_step(
        cfg, mesh, n_microbatches=cell.accum, method=method)
    params_sds = jax.eval_shape(lambda k: lm.init_lm(k, cfg), jax.random.PRNGKey(0))
    state_sds = jax.eval_shape(init_fn, params_sds)

    from repro.launch import specs as S
    from repro.launch.dryrun import _maybe
    from jax.tree_util import tree_flatten_with_path, keystr

    batch_sds = S.train_batch_specs(cfg, cell)

    def podded_spec(tree):
        def one(path, l):
            sp = list(shd.spec_for(path, l.shape, mesh))
            sp[0] = "pod"
            return P(*sp)

        leaves, treedef = tree_flatten_with_path(tree)
        return jax.tree.unflatten(treedef, [one(keystr(p), l) for p, l in leaves])

    state_spec = PPState(
        P(),
        {
            "stage0": shd.spec_for_tree(state_sds.pp["stage0"], mesh),
            "pod_edge": podded_spec(state_sds.pp["pod_edge"]),
            "blocks": podded_spec(state_sds.pp["blocks"]),
            "flags": P("pod", None),
        },
        shd.spec_for_tree(state_sds.opt_s0, mesh),
        podded_spec(state_sds.opt),
        podded_spec(state_sds.stash),
    )
    state_spec = _maybe(state_spec, state_sds, mesh)
    b_spec = _maybe(jax.tree.map(
        lambda x: shd.batch_spec(mesh, len(x.shape), leading_micro=True), batch_sds),
        batch_sds, mesh)

    with mesh:
        lowered = jax.jit(
            step_fn,
            in_shardings=(
                jax.tree.map(lambda s: NamedSharding(mesh, s), state_spec,
                             is_leaf=lambda x: isinstance(x, P)),
                jax.tree.map(lambda s: NamedSharding(mesh, s), b_spec,
                             is_leaf=lambda x: isinstance(x, P)),
            ),
        ).lower(state_sds, batch_sds)
    return lowered
