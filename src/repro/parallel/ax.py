"""Activation-sharding constraints that degrade to no-ops off-mesh.

`constrain(x, 'data', None, 'model', None)` applies with_sharding_constraint when an
ambient mesh is active (pjit tracing under `with mesh:`), keeping only the axes that
exist in the mesh AND divide the corresponding dim. On CPU tests with no mesh it is
an identity — model code can call it unconditionally.
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import PartitionSpec as P

from jax._src.mesh import thread_resources

_MANUAL = threading.local()


@contextlib.contextmanager
def manual_axes(*names):
    """Declare axes manual for the enclosed trace (shard_map bodies).

    Jax versions with `get_abstract_mesh` detect this automatically; on older
    jax the lowering-time check fires *after* `constrain` returns, so partial
    shard_map callers declare their manual axes explicitly.
    """
    prev = getattr(_MANUAL, "names", frozenset())
    _MANUAL.names = frozenset(prev) | frozenset(names)
    try:
        yield
    finally:
        _MANUAL.names = prev


def _ambient_mesh():
    m = thread_resources.env.physical_mesh
    return None if m.empty else m


def _usable_axes(mesh):
    """Axis name -> size, excluding axes that are Manual in the current trace
    (inside a shard_map region constraints may only name auto axes)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for name in getattr(_MANUAL, "names", ()):
        sizes.pop(name, None)
    try:
        am = jax.sharding.get_abstract_mesh()
        if am is not None and not am.empty:
            for name, ty in zip(am.axis_names, am.axis_types):
                if "Manual" in str(ty) and name in sizes:
                    del sizes[name]
    except Exception:
        pass
    return sizes


def constrain(x, *spec):
    if x is None:
        return None
    mesh = _ambient_mesh()
    if mesh is None:
        return x
    sizes = _usable_axes(mesh)
    fixed = []
    for d, names in enumerate(spec):
        if names is None:
            fixed.append(None)
            continue
        ns = names if isinstance(names, tuple) else (names,)
        ns = tuple(n for n in ns if n in sizes)
        if not ns:
            fixed.append(None)
            continue
        tot = 1
        for n in ns:
            tot *= sizes[n]
        if x.shape[d] % tot != 0:
            # try the first axis alone
            if x.shape[d] % sizes[ns[0]] == 0:
                ns = (ns[0],)
            else:
                fixed.append(None)
                continue
        fixed.append(ns if len(ns) > 1 else ns[0])
    if all(f is None for f in fixed):
        return x
    try:
        return jax.lax.with_sharding_constraint(x, P(*fixed))
    except Exception:
        # e.g. axis is manual inside a shard_map region — constraint not applicable
        return x


def batch_axes():
    """Logical batch mapping: ('pod','data') when a pod axis exists, else 'data'."""
    mesh = _ambient_mesh()
    if mesh is not None and "pod" in mesh.axis_names:
        return ("pod", "data")
    return "data"
