"""Tiny bundled real-text corpus + char-level tokenizer (offline WikiText stand-in
for sanity checks that the synthetic source could mask; see DESIGN.md §7)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# public-domain text (Austen, Pride & Prejudice, ch. 1 excerpt + Darwin, Origin,
# introduction excerpt) — enough for order-1k-step char-LM sanity runs.
_TEXT = """It is a truth universally acknowledged, that a single man in possession
of a good fortune, must be in want of a wife. However little known the feelings or
views of such a man may be on his first entering a neighbourhood, this truth is so
well fixed in the minds of the surrounding families, that he is considered as the
rightful property of some one or other of their daughters. My dear Mr. Bennet, said
his lady to him one day, have you heard that Netherfield Park is let at last? Mr.
Bennet replied that he had not. But it is, returned she; for Mrs. Long has just been
here, and she told me all about it. Mr. Bennet made no answer. Do not you want to
know who has taken it? cried his wife impatiently. You want to tell me, and I have
no objection to hearing it. This was invitation enough.
When on board H.M.S. Beagle, as naturalist, I was much struck with certain facts in
the distribution of the inhabitants of South America, and in the geological
relations of the present to the past inhabitants of that continent. These facts
seemed to me to throw some light on the origin of species, that mystery of
mysteries, as it has been called by one of our greatest philosophers. On my return
home, it occurred to me, in 1837, that something might perhaps be made out on this
question by patiently accumulating and reflecting on all sorts of facts which could
possibly have any bearing on it. After five years work I allowed myself to
speculate on the subject, and drew up some short notes; these I enlarged in 1844
into a sketch of the conclusions, which then seemed to me probable: from that
period to the present day I have steadily pursued the same object."""


class CharCorpus:
    """Char-level tokenized corpus with deterministic batch sampling."""

    def __init__(self, text: str = _TEXT, seed: int = 0):
        chars = sorted(set(text))
        self.vocab = {c: i for i, c in enumerate(chars)}
        self.inv = {i: c for c, i in self.vocab.items()}
        self.vocab_size = len(chars)
        self.data = np.asarray([self.vocab[c] for c in text], np.int32)
        self.seed = seed

    def batch(self, step: int, k_micro: int, batch: int, seq: int):
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        n = k_micro * batch
        starts = jax.random.randint(key, (n,), 0, len(self.data) - seq - 1)
        idx = np.asarray(starts)[:, None] + np.arange(seq + 1)[None, :]
        toks = jnp.asarray(self.data[idx]).reshape(k_micro, batch, seq + 1)
        return {"tokens": toks[..., :-1], "labels": toks[..., 1:]}

    def decode(self, ids) -> str:
        return "".join(self.inv[int(i)] for i in np.asarray(ids).reshape(-1))
