"""Deterministic synthetic LM data: a Zipf-unigram / permutation-bigram Markov source.

token_{t+1} = perm[token_t] with prob q, else ~ Zipf(alpha).  The bigram component is
learnable structure (a trained model approaches the analytic entropy floor), the Zipf
component keeps the unigram distribution realistic. Fully deterministic in
(seed, step, host shard) -> reproducible across restarts and elastic resharding.
WikiText/BC/OWT stand-in for this offline container (DESIGN.md §7).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np


class SyntheticLM:
    def __init__(self, vocab_size: int, *, alpha: float = 1.2, q: float = 0.7, seed: int = 0):
        self.vocab_size = vocab_size
        self.q = q
        self.seed = seed
        w = 1.0 / np.arange(1, vocab_size + 1, dtype=np.float64) ** alpha
        p = w / w.sum()
        self.cdf = jnp.asarray(np.cumsum(p), jnp.float32)
        self.perm = jax.random.permutation(jax.random.PRNGKey(seed + 7), vocab_size)
        # analytic floor: H = q*H(q-part) ... (reported by entropy_floor())
        self._p = p

    def entropy_floor(self) -> float:
        """Per-token conditional entropy of the source (nats) — loss lower bound."""
        q, p = self.q, self._p
        # next ~ q*delta_perm + (1-q)*zipf: H = -E[log(q*1[y=perm(x)] + (1-q) p_y)]
        # exact for the delta part; zipf part approximated by expectation over y~p
        h_hit = -(q + (1 - q) * p) * np.log(q + (1 - q) * p)  # y == perm[x]
        h_miss = -(1 - q) * p * np.log((1 - q) * p)
        return float(np.sum(h_hit * p / p.sum()) + (np.sum(h_miss) - np.sum(h_miss * p)))

    @functools.partial(jax.jit, static_argnums=(0, 2, 3, 4))
    def batch(self, step, k_micro: int, batch: int, seq: int):
        """[K, B, S] tokens + next-token labels, deterministic in `step`."""
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        k1, k2, k3 = jax.random.split(key, 3)
        n = k_micro * batch
        first = jnp.searchsorted(self.cdf, jax.random.uniform(k1, (n,)))
        use_perm = jax.random.uniform(k2, (n, seq + 1)) < self.q
        fresh = jnp.searchsorted(self.cdf, jax.random.uniform(k3, (n, seq + 1)))

        def gen(tok, inp):
            up, fr = inp
            nxt = jnp.where(up, self.perm[tok], fr)
            return nxt, nxt

        _, toks = jax.lax.scan(gen, first, (use_perm.T, fresh.T))
        toks = toks.T.reshape(k_micro, batch, seq + 1)
        return {"tokens": toks[..., :-1].astype(jnp.int32),
                "labels": toks[..., 1:].astype(jnp.int32)}


def make_batch_fn(cfg, k_micro: int, batch: int, seq: int, seed: int = 0):
    src = SyntheticLM(cfg.vocab_size, seed=seed)

    def fn(step: int):
        b = src.batch(step, k_micro, batch, seq)
        if cfg.enc_periods:
            key = jax.random.fold_in(jax.random.PRNGKey(seed + 13), step)
            b["frames"] = 0.02 * jax.random.normal(
                key, (k_micro, batch, cfg.n_frames, cfg.d_model), jnp.float32)
        if cfg.n_prefix_img:
            key = jax.random.fold_in(jax.random.PRNGKey(seed + 17), step)
            b["patches"] = 0.02 * jax.random.normal(
                key, (k_micro, batch, cfg.n_prefix_img, cfg.d_model), jnp.float32)
        return b

    return fn, src
