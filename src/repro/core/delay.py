"""Gradient-staleness model for 1F1B async pipeline parallelism (paper Eq. 5)."""
from __future__ import annotations

import math


def stage_delay(i: int, P: int, K: int = 1) -> int:
    """tau_i = floor((2(P-i)+1)/(2K)), i in 1..P. Earlier stages: larger delay."""
    assert 1 <= i <= P
    return int(math.floor((2 * (P - i) + 1) / (2 * K)))


def stage_delays(P: int, K: int = 1) -> tuple:
    return tuple(stage_delay(i, P, K) for i in range(1, P + 1))


def max_delay(P: int, K: int = 1) -> int:
    return stage_delay(1, P, K)


def validate_taus(taus, P: int) -> tuple:
    """Validate a per-stage delay vector (EngineCfg.straggler_delays — the
    static override of the event runtime's DelayModel; see core/events.py)."""
    taus = tuple(int(t) for t in taus)
    if len(taus) != P:
        raise ValueError(
            f"straggler_delays must have one entry per pipeline stage: "
            f"got {len(taus)} entries for P={P} stages")
    if any(t < 0 for t in taus):
        raise ValueError(f"stage delays must be >= 0, got {taus}")
    return taus
