"""Gradient-staleness model for 1F1B async pipeline parallelism (paper Eq. 5)."""
from __future__ import annotations

import math


def stage_delay(i: int, P: int, K: int = 1) -> int:
    """tau_i = floor((2(P-i)+1)/(2K)), i in 1..P. Earlier stages: larger delay."""
    assert 1 <= i <= P
    return int(math.floor((2 * (P - i) + 1) / (2 * K)))


def stage_delays(P: int, K: int = 1) -> tuple:
    return tuple(stage_delay(i, P, K) for i in range(1, P + 1))


def max_delay(P: int, K: int = 1) -> int:
    return stage_delay(1, P, K)


def validate_taus(taus, P: int) -> tuple:
    """Validate a per-stage delay vector (EngineCfg.straggler_delays — the
    static override of the event runtime's DelayModel; see core/events.py)."""
    taus = tuple(int(t) for t in taus)
    if len(taus) != P:
        raise ValueError(
            f"straggler_delays must have one entry per pipeline stage: "
            f"got {len(taus)} entries for P={P} stages")
    if any(t < 0 for t in taus):
        raise ValueError(f"stage delays must be >= 0, got {taus}")
    return taus


def validate_dynamic_taus(taus, P: int) -> list:
    """Validate a per-TICK delay vector for the engine's dynamic path
    (AsyncTrainer.step(..., taus=...)): a length-P sequence or [P] array,
    possibly traced, typically one row of `RuntimeResult.taus` — the event
    runtime's observed per-tick staleness fed back into the jit engine.
    Entries may be fractional (K>1 accumulation groups average the delays of
    their K microbatches). Returns the per-stage entries as a list; lengths
    are static even for traced arrays, so this check costs nothing in jit."""
    shape = getattr(taus, "shape", None)
    if shape is None and not hasattr(taus, "__len__"):
        raise ValueError(
            f"dynamic taus must be a length-{P} per-stage vector, got the "
            f"scalar {taus!r}")
    n = len(taus) if shape is None else (shape[0] if len(shape) == 1 else -1)
    if n != P:
        raise ValueError(
            f"dynamic taus must be a length-{P} per-stage vector (one entry "
            f"per pipeline stage), got "
            f"{'shape ' + str(tuple(shape)) if shape is not None else f'{n} entries'}")
    return [taus[i] for i in range(P)]
