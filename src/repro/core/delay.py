"""Gradient-staleness model for 1F1B async pipeline parallelism (paper Eq. 5)."""
from __future__ import annotations

import math


def stage_delay(i: int, P: int, K: int = 1) -> int:
    """tau_i = floor((2(P-i)+1)/(2K)), i in 1..P. Earlier stages: larger delay."""
    assert 1 <= i <= P
    return int(math.floor((2 * (P - i) + 1) / (2 * K)))


def stage_delays(P: int, K: int = 1) -> tuple:
    return tuple(stage_delay(i, P, K) for i in range(1, P + 1))


def stage_mb_delay(i: int, k: int, P: int, K: int = 1) -> int:
    """Per-MICROBATCH steady-state staleness under fixed-delay 1F1B:

        tau_{i,k} = max(ceil((P - i - k) / K), 0),   i in 1..P, k in 0..K-1

    (k indexes the microbatch within an accumulation group). Derivation: stage
    i forwards global microbatch g = tK + k at its live point, which has seen
    u_fwd = max(floor((g - (P - i)) / K), 0) updates, so the observed delay is
    t - u_fwd — the closed form above at steady state. Eq. 5's scalar is the
    LAST microbatch of the group (k = K-1): stage_delay(i, P, K) ==
    stage_mb_delay(i, K-1, P, K), while earlier microbatches in the group are
    staler (up to ceil((P-i)/K) at k = 0) — the per-update mean the runtime
    reports is fractional exactly because the group straddles these values.
    Verified against simulate_schedule's observed taus (tests/test_runtime.py,
    tests/test_delay_stash.py)."""
    assert 1 <= i <= P and 0 <= k < K
    return max(-((i + k - P) // K), 0)  # ceil((P-i-k)/K) via floor-div


def stage_mb_delays(P: int, K: int = 1) -> tuple:
    """[P][K] matrix of per-microbatch delays: rows ordered by stage (1..P),
    columns by microbatch position within the accumulation group. The static
    schedule the engine's per-microbatch stash replay defaults to at K > 1."""
    return tuple(tuple(stage_mb_delay(i, k, P, K) for k in range(K))
                 for i in range(1, P + 1))


def max_delay(P: int, K: int = 1) -> int:
    return stage_delay(1, P, K)


def max_mb_delay(P: int, K: int = 1) -> int:
    """Largest per-microbatch delay (stage 1, first microbatch of its group):
    ceil((P-1)/K) — EXCEEDS Eq. 5's floor((2(P-1)+1)/2K) whenever K does not
    divide P-1, which is why per-microbatch stash rings must be sized off this
    bound rather than the per-update scalar."""
    return stage_mb_delay(1, 0, P, K)


def validate_taus(taus, P: int) -> tuple:
    """Validate a per-stage delay vector (EngineCfg.straggler_delays — the
    static override of the event runtime's DelayModel; see core/events.py)."""
    taus = tuple(int(t) for t in taus)
    if len(taus) != P:
        raise ValueError(
            f"straggler_delays must have one entry per pipeline stage: "
            f"got {len(taus)} entries for P={P} stages")
    if any(t < 0 for t in taus):
        raise ValueError(f"stage delays must be >= 0, got {taus}")
    return taus


def validate_dynamic_taus(taus, P: int, K: int = None) -> list:
    """Validate a per-TICK delay input for the engine's dynamic path
    (AsyncTrainer.step(..., taus=...)). Two accepted forms:

    - length-P vector ([P] array or sequence, possibly traced): one delay per
      stage, applied to EVERY microbatch of the tick — the legacy idealized
      form (typically one row of `RuntimeResult.taus`; entries may be
      fractional at K>1, where they are the group mean).
    - [P, K] matrix (array or nested sequence, possibly traced): one delay per
      (stage, microbatch) — the lossless form (one row of
      `RuntimeResult.tau_groups`, or the static `stage_mb_delays` schedule)
      that the per-microbatch stash replay consumes.

    Returns the per-stage entries as a list: scalars for the vector form,
    length-K rows for the matrix form. Lengths/shapes are static even for
    traced arrays, so this check costs nothing in jit. K is only required to
    validate the matrix form's second axis."""
    shape = getattr(taus, "shape", None)
    if shape is None and not hasattr(taus, "__len__"):
        raise ValueError(
            f"dynamic taus must be a length-{P} per-stage vector or a "
            f"[{P}, K] per-microbatch matrix, got the scalar {taus!r}")
    n = len(taus) if shape is None else (shape[0] if shape else -1)
    if n != P:
        raise ValueError(
            f"dynamic taus must be a length-{P} per-stage vector (one entry "
            f"per pipeline stage) or a [{P}, K] per-microbatch matrix, got "
            f"{'shape ' + str(tuple(shape)) if shape is not None else f'{n} entries'}")
    rows = [taus[i] for i in range(P)]
    widths = []
    for r in rows:
        rs = getattr(r, "shape", None)
        if rs is not None:
            widths.append(rs[0] if len(rs) == 1 else (-1 if rs else None))
        elif hasattr(r, "__len__"):
            widths.append(len(r))
        else:
            widths.append(None)  # scalar entry: vector form
    if all(w is None for w in widths):
        return rows
    if any(w is None or w < 0 for w in widths) or len(set(widths)) != 1:
        raise ValueError(
            f"per-microbatch dynamic taus must be a rectangular [{P}, K] "
            f"matrix (every stage row the same length), got row widths "
            f"{widths}")
    if K is not None and widths[0] != K:
        raise ValueError(
            f"per-microbatch dynamic taus must have one column per "
            f"accumulation microbatch (K={K}), got {widths[0]} columns")
    return rows
