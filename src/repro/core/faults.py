"""Keyed, deterministic fault injection for the async pipeline stack.

The same treatment `DelayModel`/`ChurnModel` give latency and membership,
applied to *faults*: every injection decision is a counter-based PRNG draw on
`(seed, epoch, kind, stage, microbatch, attempt)`, never on generator state,
so a fault schedule is a pure function of the spec — independent of event
interleaving, replayable, and A/B-able against a fault-free run.

Fault kinds (spec grammar in docs/cli.md, `make_fault_model` below):

- `nan_grad=RATE`   — poison a stage's backward cotangent+grads (NaN/Inf)
- `nan_act=RATE`    — poison a stage's forward activations (at the last stage
                      this poisons the recorded loss)
- `drop=RATE`       — drop a fwd/bwd message at the Mailbox boundary; the
                      runtime recovers by retransmit-with-backoff, escalating
                      a repeatedly-unreachable stage into a synthesized
                      leave/join (PR 4's outage path) instead of deadlocking
- `dup=RATE`        — deliver a message twice (the Mailbox dedupes + counts)
- `crash=N@T`       — N workers crash mid-tick starting at simulated clock T
                      (mapped onto the churn leave/join machinery)
- `ckpt_trunc=RATE` — truncate a checkpoint file right after it is written
                      (exercises `checkpoint.restore_latest` fallback)

Contract: an **empty FaultModel is a bitwise no-op** — the runtime treats
`FaultModel()` exactly like `faults=None` (it never consults the model), so
every existing equivalence test is unchanged bit for bit
(tests/test_faults.py).

`DivergenceWatchdog` is the recovery half: an EMA loss-spike detector (plus
non-finite-loss and quarantine-budget trips) that `launch/train.py` uses to
roll a run back to the last *valid* checkpoint (DESIGN.md §11).
"""
from __future__ import annotations

import dataclasses
import math
import os
from typing import Optional, Sequence

import numpy as np

from . import events

# Distinct from events._OP_IDS: fault draws live in their own keyed stream.
_FAULT_IDS = {"nan_grad": 0, "nan_act": 1, "drop": 2, "dup": 3,
              "ckpt_trunc": 4, "crash": 5, "poison_mode": 6}

_RATE_KEYS = ("nan_grad", "nan_act", "drop", "dup", "ckpt_trunc")


@dataclasses.dataclass
class FaultModel:
    """Keyed Bernoulli fault sampler. All rates in [0, 1]; all-zero + no
    crashes == empty == never consulted by the runtime (bitwise no-op).

    `epoch` salts every draw and is bumped by the training loop on each
    watchdog rollback: injected faults are *transient* — the replayed ticks
    re-sample rather than deterministically re-hitting the identical fault,
    which would force an infinite rollback loop. Still fully deterministic
    given (seed, rollback history).
    """

    nan_grad: float = 0.0
    nan_act: float = 0.0
    drop: float = 0.0
    dup: float = 0.0
    ckpt_trunc: float = 0.0
    crashes: tuple = ()  # ((count, start), ...) simulated-clock crash plans
    crash_duration: float = 6.0
    seed: int = 0
    epoch: int = 0

    def __post_init__(self):
        for k in _RATE_KEYS:
            v = getattr(self, k)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"fault rate {k}={v} must be in [0, 1]")
        for cnt, start in self.crashes:
            if cnt < 1 or start < 0:
                raise ValueError(
                    f"crash plan must be COUNT>=1 @ START>=0, got {cnt}@{start}")
        if self.crash_duration <= 0:
            raise ValueError(
                f"crash_duration must be > 0, got {self.crash_duration}")

    # -- keyed sampling ------------------------------------------------------

    @property
    def is_empty(self) -> bool:
        return (all(getattr(self, k) == 0.0 for k in _RATE_KEYS)
                and not self.crashes)

    @property
    def affects_messages(self) -> bool:
        return self.drop > 0.0 or self.dup > 0.0

    def _uniform(self, kind: str, stage: int, mb: int, attempt: int = 0) -> float:
        word = ((_FAULT_IDS[kind] << 59) | ((self.epoch & 0x7FF) << 48)
                | ((attempt & 0xF) << 44) | ((stage & 0xFFF) << 32)
                | (mb & 0xFFFFFFFF))
        rng = np.random.Generator(np.random.Philox(
            key=np.array([self.seed & 0xFFFFFFFFFFFFFFFF, word],
                         dtype=np.uint64)))
        return float(rng.random())

    def hit(self, kind: str, stage: int, mb: int, attempt: int = 0) -> bool:
        rate = getattr(self, kind)
        return rate > 0.0 and self._uniform(kind, stage, mb, attempt) < rate

    def drop_hit(self, op: str, dst: int, mb: int, attempt: int) -> bool:
        """Message-drop draw for a fwd ("fwd") / bwd ("bwd") edge into `dst`.
        The op is folded into the mb word (bit 31 is unused by real microbatch
        indices at any plausible horizon) so fwd/bwd edges draw independently."""
        mb_key = (mb & 0x7FFFFFFF) | ((1 << 31) if op == "bwd" else 0)
        return self.drop > 0.0 and self._uniform(
            "drop", dst, mb_key, attempt) < self.drop

    def dup_hit(self, op: str, dst: int, mb: int) -> bool:
        mb_key = (mb & 0x7FFFFFFF) | ((1 << 31) if op == "bwd" else 0)
        return self.dup > 0.0 and self._uniform("dup", dst, mb_key) < self.dup

    def poison_value(self, stage: int, mb: int) -> float:
        """NaN or +Inf, keyed per (stage, mb) — both non-finite classes must
        flow through the quarantine path (jnp.isfinite catches either)."""
        return (math.nan if self._uniform("poison_mode", stage, mb) < 0.5
                else math.inf)

    # -- structural faults ---------------------------------------------------

    def crash_outages(self, P: int) -> tuple:
        """Materialize the crash plan as churn `Outage` windows: each crash
        picks a keyed stage in [0, P) and knocks it out for `crash_duration`
        simulated units starting at the plan's clock. Successive crashes in one
        plan are staggered so their windows cannot overlap on one stage (an
        overlapping double-leave is the hung-worker path, not a crash)."""
        outs = []
        for plan_i, (cnt, start) in enumerate(self.crashes):
            for j in range(cnt):
                u = self._uniform("crash", plan_i, j)
                stage = min(int(u * P), P - 1)
                t0 = start + j * 2.0 * self.crash_duration
                outs.append(events.Outage(stage, t0, self.crash_duration))
        return tuple(outs)

    def maybe_truncate_checkpoint(self, path: str, step: int) -> bool:
        """Chaos-inject a torn write: with prob `ckpt_trunc` (keyed per step),
        truncate the just-written checkpoint to half its size. Returns True if
        the file was truncated."""
        if not self.hit("ckpt_trunc", 0, step):
            return False
        size = os.path.getsize(path)
        with open(path, "r+b") as f:
            f.truncate(max(size // 2, 1))
        return True


def make_fault_model(spec, seed: int = 0) -> Optional[FaultModel]:
    """Parse a CLI-friendly fault spec (docs/cli.md):

      "nan_grad=0.01,drop=0.005,crash=2@40"   (optional leading "faults:" tag)

    Fields: `nan_grad= nan_act= drop= dup= ckpt_trunc=` take a rate in [0, 1];
    `crash=N@T` schedules N keyed-stage crashes from simulated clock T (may
    repeat for several plans); `crash_dur=SECONDS` sets the outage length.
    Unknown keys, malformed fields, or out-of-range rates raise ValueError.
    Returns None for None/"" (no fault model at all).
    """
    if spec is None or spec == "":
        return None
    if isinstance(spec, FaultModel):
        return spec
    name, sep, args = spec.partition(":")
    if sep and name != "faults":
        raise ValueError(f"unknown fault spec {spec!r}")
    body = args if sep else spec
    kw: dict = {}
    crashes = []
    for field in body.split(","):
        key, eq, val = field.partition("=")
        key, val = key.strip(), val.strip()
        if not eq or not key or not val:
            raise ValueError(f"fault spec field {field!r} must be KEY=VALUE")
        if key in _RATE_KEYS:
            if key in kw:
                raise ValueError(f"duplicate fault key {key!r} in {spec!r}")
            kw[key] = float(val)
        elif key == "crash":
            cnt_s, at, start_s = val.partition("@")
            if not at:
                raise ValueError(
                    f"crash plan {val!r} must be COUNT@START (e.g. crash=2@40)")
            crashes.append((int(cnt_s), float(start_s)))
        elif key == "crash_dur":
            if "crash_duration" in kw:
                raise ValueError(f"duplicate fault key {key!r} in {spec!r}")
            kw["crash_duration"] = float(val)
        else:
            raise ValueError(f"unknown fault key {key!r} in spec {spec!r}")
    return FaultModel(crashes=tuple(crashes), seed=seed, **kw)


# ---------------------------------------------------------------------------
# divergence watchdog
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class DivergenceWatchdog:
    """EMA loss-spike detector + quarantine budget: decides when a run has
    diverged badly enough to roll back to the last valid checkpoint.

    Trips (observe_chunk returns a reason string) on any of:
      - a non-finite loss in the chunk (poisoned activations reached the head);
      - after `warmup` finite observations, a loss exceeding
        `spike_factor * EMA + margin` (classic divergence);
      - `skip_limit` or more quarantined (non-finite-grad) updates since the
        last clean chunk — sustained corruption even when the loss trajectory
        still looks healthy, because skipped stages silently stop learning.

    `reset()` is called after a rollback: the EMA re-seeds from the restored
    trajectory rather than comparing it against the diverged one.
    """

    beta: float = 0.9
    spike_factor: float = 3.0
    margin: float = 1.0
    warmup: int = 5
    skip_limit: int = 3

    def __post_init__(self):
        if not 0.0 < self.beta < 1.0:
            raise ValueError(f"watchdog beta must be in (0, 1), got {self.beta}")
        if self.spike_factor <= 1.0:
            raise ValueError(
                f"watchdog factor must be > 1, got {self.spike_factor}")
        if self.warmup < 1 or self.skip_limit < 1:
            raise ValueError("watchdog warmup and skips must be >= 1")
        self.reset()

    def reset(self):
        self._ema: Optional[float] = None
        self._n = 0
        self._skips = 0

    def observe_chunk(self, losses: Sequence[float],
                      nonfinite_delta: int = 0) -> Optional[str]:
        """Feed one chunk of per-tick losses + the chunk's quarantined-update
        count. Returns a trip reason (roll back now, do NOT checkpoint this
        chunk) or None (chunk is healthy — safe to checkpoint)."""
        self._skips += int(nonfinite_delta)
        if self._skips >= self.skip_limit:
            reason = f"{self._skips} non-finite updates quarantined"
            self._skips = 0
            return reason
        for loss in losses:
            loss = float(loss)
            if not math.isfinite(loss):
                return f"non-finite loss {loss}"
            if (self._n >= self.warmup
                    and loss > self.spike_factor * self._ema + self.margin):
                return (f"loss spike {loss:.4g} > "
                        f"{self.spike_factor:g}*EMA({self._ema:.4g})"
                        f"+{self.margin:g}")
            self._ema = (loss if self._ema is None
                         else self.beta * self._ema + (1.0 - self.beta) * loss)
            self._n += 1
        if nonfinite_delta == 0:
            self._skips = 0  # clean chunk: the quarantine budget re-arms
        return None


def make_watchdog(spec) -> Optional[DivergenceWatchdog]:
    """Parse a watchdog spec: None/""/"off" -> None; "on"/"auto"/"default" ->
    defaults; else "beta=0.9,factor=3.0,margin=1.0,warmup=5,skips=3" (any
    subset). Unknown keys raise ValueError."""
    if spec is None or spec in ("", "off", "none"):
        return None
    if isinstance(spec, DivergenceWatchdog):
        return spec
    if spec in ("on", "auto", "default"):
        return DivergenceWatchdog()
    kw: dict = {}
    names = {"beta": ("beta", float), "factor": ("spike_factor", float),
             "margin": ("margin", float), "warmup": ("warmup", int),
             "skips": ("skip_limit", int)}
    for field in spec.split(","):
        key, eq, val = field.partition("=")
        key, val = key.strip(), val.strip()
        if not eq or key not in names or not val:
            raise ValueError(f"unknown watchdog field {field!r} in {spec!r}")
        dest, cast = names[key]
        if dest in kw:
            raise ValueError(f"duplicate watchdog key {key!r} in {spec!r}")
        kw[dest] = cast(val)
    return DivergenceWatchdog(**kw)
