"""Method registry: the paper's method, its variants, and every baseline it compares
against (Table 1, Figs. 2-8, 12).

A method is a declarative recipe the engine interprets:
  fwd_point  — what each stage stashes as the point its forward runs at
  bwd_point  — where each stage's VJP is linearized
  optimizer  — per-stage optimizer kind + hyperparams
  lr_discount / stage_momentum — Eq. 13 stage-dependent corrections
  grad_forecast — gradient forecasting transform applied to stale grads
  tau_source — which staleness VALUE the delay-dependent corrections consume:
               the live per-tick delay of the execution path ("observed") or
               the static closed-form Eq. 5 schedule ("stage_index")
  sync       — synchronous (no staleness; GPipe)
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class Method:
    name: str
    optimizer: str = "adamw"  # adamw | nadam | nadam_nodiscount | sgd_nag | ...
    opt_kw: tuple = ()  # extra optimizer kwargs as a tuple of (k, v)
    sync: bool = False
    fwd_point: str = "current"  # current | lookahead | xpipe_predict
    bwd_point: str = "stash"  # stash | current | pipemare_predict
    lr_discount: bool = False
    lr_discount_T: int = 6000
    stage_momentum: bool = False
    grad_forecast: Optional[str] = None  # None | second_order | polyfft
    forecast_hist: int = 8
    # Which tau the delay-dependent corrections (lr_discount, grad_forecast,
    # pipemare/xpipe prediction, delay-keyed momentum) consume:
    #   "observed"    — the live tau of the execution path: the event runtime's
    #                   measured per-tick staleness, or the engine's dynamic
    #                   vector when driven via step(..., taus=...). With
    #                   stage_momentum, the Eq. 13 coefficient is re-keyed off
    #                   that live delay (schedules.delay_momentum).
    #   "stage_index" — pin the static stage-index schedule (Eq. 5 /
    #                   EngineCfg.straggler_delays): corrections ignore what the
    #                   runtime actually measured, and stage_momentum keeps the
    #                   paper's literal gamma_i = f(stage index) form.
    # Under FixedDelay at K=1 the two sources agree at steady state (observed
    # tau == Eq. 5 and delay_momentum(tau_i) == stage_momentum(i)); they split
    # during warmup and under stragglers/jitter/churn (DESIGN.md §10).
    tau_source: str = "observed"  # observed | stage_index
    # How the K per-microbatch delays of an accumulation group collapse to the
    # ONE tau value the per-update correction math consumes (K>1 only; at K=1
    # the group is a single delay and every policy is the identity):
    #   "mean" — the group average (fractional at K>1). This is what the event
    #            runtime has always fed back per update, so it is the default
    #            for every registered method — but now an explicit contract
    #            instead of a float inherited by accident from np.mean.
    #   "max"  — the stalest microbatch of the group: conservative corrections
    #            (discount/smooth for the worst delay the update saw).
    #   "last" — the group's final microbatch (k = K-1), i.e. Eq. 5's literal
    #            scalar at steady state (stage_mb_delay(i, K-1) == Eq. 5).
    # Stash replay is NOT affected: each microbatch always replays at its own
    # per-microbatch delay; tau_reduce only keys the update-level corrections
    # (lr_discount, delay_momentum, forecasting, pipemare/xpipe prediction).
    tau_reduce: str = "mean"  # mean | max | last
    # memory class as reported in Table 1 (P = stages, N = params)
    memory: str = "O(PN)"

    def __post_init__(self):
        if self.tau_source not in ("observed", "stage_index"):
            raise ValueError(
                f"tau_source must be 'observed' or 'stage_index', "
                f"got {self.tau_source!r}")
        if self.tau_reduce not in ("mean", "max", "last"):
            raise ValueError(
                f"tau_reduce must be 'mean', 'max', or 'last', "
                f"got {self.tau_reduce!r}")

    def opt_kwargs(self):
        return dict(self.opt_kw)

    @property
    def uses_tau_value(self) -> bool:
        """True when the update math consumes a delay VALUE at all (not just
        the stash selection), from whichever source tau_source selects."""
        return bool(self.lr_discount or self.grad_forecast
                    or self.bwd_point == "pipemare_predict"
                    or self.fwd_point == "xpipe_predict"
                    or self.stage_momentum)

    @property
    def tau_consuming(self) -> bool:
        """True when the update math consumes the LIVE delay value: these
        methods react to the event runtime's observed per-tick staleness, so
        their event-driven trajectories diverge from the fixed-schedule jit
        engine during warmup/stragglers unless the engine is driven with the
        same dynamic tau vector (step(..., taus=...)). A method with
        tau_source="stage_index" pins the static schedule instead and is NOT
        tau-consuming even when it applies delay corrections."""
        return self.uses_tau_value and self.tau_source == "observed"


METHODS = {}


def _reg(m: Method):
    METHODS[m.name] = m
    return m


# --- synchronous baseline ---------------------------------------------------
_reg(Method("gpipe", optimizer="adamw", sync=True, memory="O(N)"))

# --- async baselines ----------------------------------------------------------
_reg(Method("pipedream", optimizer="adamw", fwd_point="current", bwd_point="stash"))
_reg(Method("pipemare", optimizer="adamw", fwd_point="current", bwd_point="pipemare_predict",
            lr_discount=True, memory="O(N)"))
_reg(Method("pipedream_lr", optimizer="adamw", lr_discount=True))
_reg(Method("lr_second_order", optimizer="adamw", lr_discount=True, grad_forecast="second_order"))
_reg(Method("polyfft", optimizer="adamw", grad_forecast="polyfft"))
_reg(Method("xpipe", optimizer="adamw", fwd_point="xpipe_predict", bwd_point="stash"))

# --- ours --------------------------------------------------------------------
_reg(Method("ours", optimizer="nadam", opt_kw=(("b1", 0.99),)))
_reg(Method("ours_theory", optimizer="sgd_nag", fwd_point="lookahead"))
# the paper's published O(N) form: Eq. 13 corrections in their literal
# stage-keyed/schedule-keyed form — pinned to "stage_index" so the published
# numerics never drift with measured delays (the observed-keyed counterpart
# of this recipe is the ours_delay_adaptive direction below)
_reg(Method("ours_nows", optimizer="nadam", bwd_point="current", lr_discount=True,
            stage_momentum=True, tau_source="stage_index", memory="O(N)"))
# ablations
_reg(Method("nag_base", optimizer="nadam_nodiscount", opt_kw=(("b1", 0.99),)))
# the paper's literal Eq. 13 adaptive momentum: gamma_i keyed off the STAGE
# INDEX, blind to what the runtime actually measures
_reg(Method("ours_adaptive_mom", optimizer="nadam", stage_momentum=True,
            tau_source="stage_index"))
# beyond-paper: delay-adaptive momentum as straggler mitigation (see ft/) —
# gamma keyed off the LIVE observed staleness (schedules.delay_momentum), so
# the momentum reacts to warmup, stragglers, jitter, and churn instead of
# assuming the closed-form schedule. Identical to ours_adaptive_mom under
# FixedDelay steady state; diverges exactly when delays move (DESIGN.md §10).
_reg(Method("ours_delay_adaptive", optimizer="nadam", opt_kw=(("b1", 0.99),),
            stage_momentum=True, tau_source="observed"))
# composition checks (Fig. 4: NAG + other corrections)
_reg(Method("ours_lr", optimizer="nadam", opt_kw=(("b1", 0.99),), lr_discount=True))
_reg(Method("ours_second_order", optimizer="nadam", opt_kw=(("b1", 0.99),),
            grad_forecast="second_order"))
_reg(Method("ours_polyfft", optimizer="nadam", opt_kw=(("b1", 0.99),), grad_forecast="polyfft"))


def get_method(name: str) -> Method:
    if name not in METHODS:
        raise ValueError(f"unknown method {name!r}; have {sorted(METHODS)}")
    return METHODS[name]
