"""Method registry: the paper's method, its variants, and every baseline it compares
against (Table 1, Figs. 2-8, 12).

A method is a declarative recipe the engine interprets:
  fwd_point  — what each stage stashes as the point its forward runs at
  bwd_point  — where each stage's VJP is linearized
  optimizer  — per-stage optimizer kind + hyperparams
  lr_discount / stage_momentum — Eq. 13 stage-dependent corrections
  grad_forecast — gradient forecasting transform applied to stale grads
  sync       — synchronous (no staleness; GPipe)
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class Method:
    name: str
    optimizer: str = "adamw"  # adamw | nadam | nadam_nodiscount | sgd_nag | ...
    opt_kw: tuple = ()  # extra optimizer kwargs as a tuple of (k, v)
    sync: bool = False
    fwd_point: str = "current"  # current | lookahead | xpipe_predict
    bwd_point: str = "stash"  # stash | current | pipemare_predict
    lr_discount: bool = False
    lr_discount_T: int = 6000
    stage_momentum: bool = False
    grad_forecast: Optional[str] = None  # None | second_order | polyfft
    forecast_hist: int = 8
    # memory class as reported in Table 1 (P = stages, N = params)
    memory: str = "O(PN)"

    def opt_kwargs(self):
        return dict(self.opt_kw)

    @property
    def tau_consuming(self) -> bool:
        """True when the update math consumes the delay VALUE itself (not just
        the stash selection): these methods react to the event runtime's
        observed per-tick staleness, so their event-driven trajectories diverge
        from the fixed-schedule jit engine during warmup/stragglers unless the
        engine is driven with the same dynamic tau vector (step(..., taus=...))."""
        return bool(self.lr_discount or self.grad_forecast
                    or self.bwd_point == "pipemare_predict"
                    or self.fwd_point == "xpipe_predict")


METHODS = {}


def _reg(m: Method):
    METHODS[m.name] = m
    return m


# --- synchronous baseline ---------------------------------------------------
_reg(Method("gpipe", optimizer="adamw", sync=True, memory="O(N)"))

# --- async baselines ----------------------------------------------------------
_reg(Method("pipedream", optimizer="adamw", fwd_point="current", bwd_point="stash"))
_reg(Method("pipemare", optimizer="adamw", fwd_point="current", bwd_point="pipemare_predict",
            lr_discount=True, memory="O(N)"))
_reg(Method("pipedream_lr", optimizer="adamw", lr_discount=True))
_reg(Method("lr_second_order", optimizer="adamw", lr_discount=True, grad_forecast="second_order"))
_reg(Method("polyfft", optimizer="adamw", grad_forecast="polyfft"))
_reg(Method("xpipe", optimizer="adamw", fwd_point="xpipe_predict", bwd_point="stash"))

# --- ours --------------------------------------------------------------------
_reg(Method("ours", optimizer="nadam", opt_kw=(("b1", 0.99),)))
_reg(Method("ours_theory", optimizer="sgd_nag", fwd_point="lookahead"))
_reg(Method("ours_nows", optimizer="nadam", bwd_point="current", lr_discount=True,
            stage_momentum=True, memory="O(N)"))
# ablations
_reg(Method("nag_base", optimizer="nadam_nodiscount", opt_kw=(("b1", 0.99),)))
_reg(Method("ours_adaptive_mom", optimizer="nadam", stage_momentum=True))
# beyond-paper: delay-adaptive momentum as straggler mitigation (see ft/)
_reg(Method("ours_delay_adaptive", optimizer="nadam", opt_kw=(("b1", 0.99),),
            stage_momentum=True))
# composition checks (Fig. 4: NAG + other corrections)
_reg(Method("ours_lr", optimizer="nadam", opt_kw=(("b1", 0.99),), lr_discount=True))
_reg(Method("ours_second_order", optimizer="nadam", opt_kw=(("b1", 0.99),),
            grad_forecast="second_order"))
_reg(Method("ours_polyfft", optimizer="nadam", opt_kw=(("b1", 0.99),), grad_forecast="polyfft"))


def get_method(name: str) -> Method:
    if name not in METHODS:
        raise ValueError(f"unknown method {name!r}; have {sorted(METHODS)}")
    return METHODS[name]
