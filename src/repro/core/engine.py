"""Async pipeline-parallel training engine.

One `tick` == one 1F1B steady-state update interval (paper Sec. 2.2): the microbatch
completing its backward now forwarded through *staggered stale weights*
f_P^t . f_{P-1}^{t-1} ... f_1^{t-P+1} (Eq. 7); every stage updates with its own
staleness tau_i (Eq. 5/6). The stash ring buffers replay exactly those weights, so
the single jit-compiled program is per-iteration faithful to asynchronous execution.

Engine state is a pure pytree -> pjit-shardable, checkpointable, and scan-able.
"""
from __future__ import annotations

import dataclasses
import functools
import numbers
from typing import Any, Callable, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import delay as delay_mod
from repro.core import staged, stash
from repro.core.methods import Method, get_method
from repro.kernels import dispatch as kdispatch
from repro.models import lm
from repro.models.layers import ModelCfg
from repro.optim import forecast, optimizers, schedules


def _where_tau(tau, if_stale, if_fresh):
    """Select between two pytrees on tau > 0. Static tau folds at trace time
    (preserving the fixed-schedule engine's exact program); traced tau lowers
    to a per-leaf jnp.where (the dynamic/observed-delay path)."""
    if isinstance(tau, (int, float)):
        return if_stale if tau > 0 else if_fresh
    return jax.tree.map(lambda a, b: jnp.where(tau > 0, a, b), if_stale, if_fresh)


class AsyncState(NamedTuple):
    step: jnp.ndarray  # int32 scalar: tick counter t
    params: tuple  # per-stage current weights w_i^t
    stashes: tuple  # per-stage ring buffers of forward points (depth tau_i+1)
    opt: tuple  # per-stage optimizer states
    extra: tuple  # per-stage method-specific state (forecast history, ...)


@dataclasses.dataclass
class EngineCfg:
    n_stages: int = 4
    update_interval: int = 1  # K in Eq. 5 (microbatches accumulated per update)
    lr: float = 3e-4
    weight_decay: float = 0.01
    warmup_steps: int = 0
    total_steps: int = 10000
    constant_lr: bool = False
    collect_metrics: bool = True
    stash_dtype: Any = None  # e.g. jnp.bfloat16 to halve stash memory
    # Static per-stage override of the Eq. 5 schedule (straggler injection).
    # Must have exactly one entry per pipeline stage (length == P after the
    # model-unit clamp). This is the *static* counterpart of the event
    # runtime's DelayModel (core/events.py): the DelayModel samples latencies
    # and the runtime feeds the *observed* tau back per tick, while this field
    # pins a fixed tau vector into the single-jit engine.
    straggler_delays: Optional[tuple] = None
    # Upper bound on per-tick dynamic delays for step(..., taus=...): stash
    # ring depth becomes max_dynamic_delay + 1 on every stage so any observed
    # tau <= max_dynamic_delay replays exactly. None = static schedule depth.
    max_dynamic_delay: Optional[int] = None
    # kernel routing: backend for the fused optimizer tick (env var
    # REPRO_KERNEL_BACKEND overrides; see kernels/dispatch.py). None = platform.
    kernel_backend: Optional[str] = None
    # None = auto: fuse when the backend is pallas/interpret and the method's
    # optimizer has a fused flat-buffer implementation (nadam family).
    fused_optimizer: Optional[bool] = None


class AsyncTrainer:
    """Builds init/step for (model cfg, method). Step is jit-compatible and pjit-able."""

    def __init__(self, model_cfg: ModelCfg, ecfg: EngineCfg, method: str | Method):
        self.model_cfg = model_cfg
        self.ecfg = ecfg
        self.method = get_method(method) if isinstance(method, str) else method
        # a stage must own >= 1 block unit: clamp P to the model's block count
        n_units = len(model_cfg.prelude) + model_cfg.n_periods + model_cfg.enc_periods
        P = min(ecfg.n_stages, max(n_units, 1))
        self.P = P
        K = ecfg.update_interval
        if self.method.sync:
            self.taus = tuple(0 for _ in range(P))
            self.taus_mb = tuple((0,) * K for _ in range(P))
        elif ecfg.straggler_delays is not None:
            self.taus = delay_mod.validate_taus(ecfg.straggler_delays, P)
            # a pinned override fixes the stage's delay for every microbatch
            self.taus_mb = tuple((t,) * K for t in self.taus)
        else:
            self.taus = delay_mod.stage_delays(P, K)
            # the static per-MICROBATCH schedule (delay.stage_mb_delays): at
            # K > 1 the engine replays each microbatch of an update at its own
            # staggered point instead of idealizing the whole group at Eq. 5's
            # scalar — the K>1 event/engine equivalence contract (DESIGN.md §10)
            self.taus_mb = delay_mod.stage_mb_delays(P, K)
        kw = dict(self.method.opt_kwargs())
        kw.setdefault("wd", ecfg.weight_decay)
        # kernel routing: with a pallas/interpret backend, the per-stage optimizer
        # tick runs as ONE fused nag_update pass over contiguous flat buffers
        self.kernel_backend = kdispatch.resolve_backend(ecfg.kernel_backend)
        fused = ecfg.fused_optimizer
        if fused is None:
            fused = (self.kernel_backend != "ref"
                     and self.method.optimizer in optimizers.FUSABLE)
        self.opt = optimizers.make_optimizer(
            self.method.optimizer, lr=1.0, fused=fused,
            kernel_backend=self.kernel_backend, **kw)
        # lr folded via lr_scale so schedules stay outside the optimizer
        if ecfg.constant_lr:
            self.lr_sched = schedules.constant(ecfg.lr)
        else:
            self.lr_sched = schedules.warmup_cosine(ecfg.lr, ecfg.warmup_steps, ecfg.total_steps)
        self._stage_ops = None

    # -- setup ---------------------------------------------------------------

    def init(self, key) -> AsyncState:
        params = lm.init_lm(key, self.model_cfg)
        return self.init_from_params(params)

    def init_from_params(self, params) -> AsyncState:
        stages_p, stage_ops = lm.split_stages(params, self.model_cfg, self.P)
        # Under PP, params shared across stages (tied embeddings, zamba2 shared
        # blocks) become independent per-stage copies — an async pipeline cannot
        # sync them without reintroducing a barrier (see DESIGN.md §7). Dedupe
        # buffers so each stage owns its copy (also required for jit donation).
        seen: set = set()

        def dedupe(x):
            nonlocal seen
            key = id(x)
            if key in seen:
                return jnp.array(x)
            seen.add(key)
            return x

        stages_p = [jax.tree.map(dedupe, sp) for sp in stages_p]
        self._stage_ops = stage_ops
        self.stage_fns = staged.make_stage_fns(self.model_cfg, stage_ops)
        stashes = tuple(
            stash.init_stash(sp, self._stash_depth(i), dtype=self.ecfg.stash_dtype)
            for i, sp in enumerate(stages_p)
        )
        opt_states = tuple(self.opt.init(sp) for sp in stages_p)
        extras = tuple(self._init_extra(sp) for sp in stages_p)
        return AsyncState(jnp.zeros((), jnp.int32), tuple(stages_p), stashes, opt_states, extras)

    def _init_extra(self, sp):
        # non-finite quarantine counter (DESIGN.md §11): updates skipped
        # because their gradients were NaN/Inf — maintained by _stage_update
        # for every method, surfaced per run in RuntimeResult.nonfinite_skipped
        e = {"nonfinite_skipped": jnp.zeros((), jnp.int32)}
        if self.method.grad_forecast == "polyfft":
            e["hist"] = forecast.init_history(sp, self.method.forecast_hist)
        if self.method.bwd_point == "pipemare_predict":
            e["velocity"] = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), sp)
        return e

    def _stash_depth(self, i: int) -> int:
        if self.ecfg.max_dynamic_delay is not None:
            return stash.depth_for(self.ecfg.max_dynamic_delay)
        # per-microbatch replay: the FIRST microbatch of a group is staler
        # than Eq. 5's scalar (stage_mb_delay(i, 0) = ceil((P-i)/K) vs
        # floor((2(P-i)+1)/2K)), so the ring must cover the group maximum
        return max(max(self.taus_mb[i]), self.taus[i]) + 1

    # -- per-stage method semantics (shared by the jit engine and the event
    #    runtime, so both execution paths apply bit-identical update math) -----

    def _method_tau(self, i: int, tau):
        """The tau the method's delay corrections consume (Method.tau_source):
        "observed" passes the execution path's live value through (the event
        runtime's measured staleness, or step(..., taus=...)); "stage_index"
        pins the static Eq. 5 / straggler-override schedule so corrections stay
        blind to measured delays. Stash selection always uses the live tau —
        only the correction math is re-sourced. The live value may be a
        per-microbatch GROUP (length-K tuple or [K] array) at K > 1; callers
        collapse it via _reduce_tau (Method.tau_reduce)."""
        return tau if self.method.tau_source == "observed" else self.taus[i]

    def _reduce_tau(self, tau):
        """Collapse a per-microbatch delay group (length-K tuple or [K] array)
        to the single per-update value the correction math consumes — the
        method's explicit Method.tau_reduce policy ("mean" | "max" | "last").
        Scalars pass through unchanged, so the K = 1 and legacy vector paths
        are byte-identical to the pre-group engine. Static (python-number)
        groups fold at trace time; traced groups lower to one jnp reduction."""
        red = self.method.tau_reduce
        if isinstance(tau, (tuple, list)):
            if len(tau) == 1:
                return tau[0]
            if all(isinstance(x, (int, float)) for x in tau):
                if red == "mean":
                    return sum(tau) / len(tau)
                return max(tau) if red == "max" else tau[-1]
            tau = jnp.asarray(tau)
        if getattr(tau, "ndim", 0) >= 1:
            if tau.shape[0] == 1:
                return tau[0]
            if red == "mean":
                return jnp.mean(jnp.asarray(tau, jnp.float32))
            return jnp.max(tau) if red == "max" else tau[-1]
        return tau

    def _bwd_weights(self, i: int, params, extra, W_stale, tau):
        """Where stage i's VJP is linearized. tau: static int or traced/observed
        (per-microbatch callers pass each microbatch's own delay, so e.g.
        PipeMare's prediction is linearized per microbatch — matching the event
        runtime, which computes Wb at every backward's own tau_g)."""
        m = self.method
        tau = self._reduce_tau(self._method_tau(i, tau))
        if m.bwd_point == "stash":
            return W_stale
        if m.bwd_point == "current":
            return params
        if m.bwd_point == "pipemare_predict":
            # PipeMare: estimate the weights the forward used via update velocity:
            # w_hat_i = w_t - tau_i * velocity_i  (identity at tau == 0)
            v = extra.get("velocity") if extra else None
            if v is None:
                return params
            tau_f = jnp.asarray(tau, jnp.float32)
            return jax.tree.map(
                lambda w, vv: (w.astype(jnp.float32) - tau_f * vv).astype(w.dtype),
                params, v)
        raise ValueError(m.bwd_point)

    def _stage_update(self, i: int, params, grads, opt_state, extra, tau, t, *,
                      W_stale=None, lr_t=None):
        """One stage's method-interpreted update at (possibly dynamic) delay tau.

        Returns (new_params, new_opt, new_extra, fwd_point, aux). tau may be a
        python number (static Eq. 5 schedule — branches fold at trace time), a
        traced scalar (live observed delay from the event runtime), or a
        per-microbatch GROUP (length-K tuple / [K] array) which collapses to
        one per-update value via the method's explicit Method.tau_reduce.
        """
        m = self.method
        if lr_t is None:
            lr_t = self.lr_sched(t)
        # corrections consume the method-selected tau source; the raw `tau`
        # argument stays the execution path's live value (stash selection)
        tau_m = self._reduce_tau(self._method_tau(i, tau))
        new_extra = dict(extra)
        # gradient forecasting corrections (baselines of Sec. 5.4)
        if m.grad_forecast == "second_order":
            corrected = forecast.second_order_correct(grads, params, W_stale)
            grads = _where_tau(tau_m, corrected, grads)
        elif m.grad_forecast == "polyfft":
            h = m.forecast_hist
            new_extra["hist"] = forecast.push_history(extra["hist"], grads, h)
            predicted = forecast.polyfft_predict(new_extra["hist"], h, tau_m)
            grads = _where_tau(tau_m, predicted, grads)
        # Eq. 13 stage schedules (delay-keyed momentum when tau is observed)
        lr_scale = lr_t
        if m.lr_discount:
            lr_scale = lr_scale * schedules.lr_discount_factor(tau_m, t, m.lr_discount_T)
        if not m.stage_momentum:
            mom = None
        elif m.tau_source == "observed":
            mom = schedules.delay_momentum(tau_m, self.P, self.ecfg.update_interval)
        else:
            mom = schedules.stage_momentum(i + 1, self.P)
        new_params, new_opt, aux = self.opt.update(params, grads, opt_state,
                                                   lr_scale=lr_scale, mom=mom, t=t)
        if m.bwd_point == "pipemare_predict":
            beta = 0.9
            new_extra["velocity"] = jax.tree.map(
                lambda v, s: beta * v + (1 - beta) * s,
                extra["velocity"], aux["step_dir"])
        # the point the *next* forward runs at
        if m.fwd_point == "current":
            fp = new_params
        elif m.fwd_point == "lookahead":
            fp = aux["lookahead"]
        elif m.fwd_point == "xpipe_predict":
            # XPipe: predict weights tau updates ahead along the optimizer step
            tau_f = jnp.asarray(tau_m, jnp.float32)
            fp = jax.tree.map(
                lambda w, s: (w.astype(jnp.float32) + tau_f * s).astype(w.dtype),
                new_params, aux["step_dir"])
        else:
            raise ValueError(m.fwd_point)
        # Non-finite quarantine (DESIGN.md §11): a poisoned or overflowed
        # gradient must never reach the weights, the optimizer moments, or the
        # method state (a NaN momentum entry would re-poison every later
        # update). Skip-and-count: one all-leaves isfinite flag selects every
        # candidate against its pre-update value; the forward point falls back
        # to the current params (a zero update — sane under every fwd_point
        # mode). The guard is always on: with finite grads the select is the
        # identity, so the fault-free path computes the same update.
        leaves = jax.tree.leaves(grads)
        ok = (jnp.all(jnp.stack([jnp.all(jnp.isfinite(g)) for g in leaves]))
              if leaves else jnp.asarray(True))
        skipped = extra.get("nonfinite_skipped", jnp.zeros((), jnp.int32))

        def _sel(a, b):
            return jnp.where(ok, a, b)

        new_params = jax.tree.map(_sel, new_params, params)
        new_opt = jax.tree.map(_sel, new_opt, opt_state)
        fp = jax.tree.map(_sel, fp, params)
        quar_extra = {}
        for k, v in new_extra.items():
            if k == "nonfinite_skipped":
                continue
            old = extra.get(k)
            quar_extra[k] = jax.tree.map(_sel, v, old) if old is not None else v
        quar_extra["nonfinite_skipped"] = skipped + (1 - ok.astype(jnp.int32))
        return new_params, new_opt, quar_extra, fp, aux

    # -- one tick -------------------------------------------------------------

    def _host_check_taus(self, rows):
        """Host-side depth-bound validation for CONCRETE dynamic taus (python /
        numpy numbers, committed jax arrays): an oversized delay raises here
        instead of reading a saturated ring slot. Traced entries are skipped —
        they clamp inside stash.get/get_group (documented saturation)."""
        for i, r in enumerate(rows):
            if isinstance(r, numbers.Real):
                vals = [float(r)]
            elif isinstance(r, (tuple, list)):
                if not all(isinstance(x, numbers.Real) for x in r):
                    continue
                vals = [float(x) for x in r]
            elif isinstance(r, (np.ndarray, jax.Array)):
                try:
                    vals = np.asarray(r).reshape(-1).tolist()
                except (jax.errors.ConcretizationTypeError,
                        jax.errors.TracerArrayConversionError):
                    continue  # traced: clamps in stash._check_tau instead
            else:
                continue
            hi = self._stash_depth(i) - 1
            bad = [v for v in vals if v < 0 or v > hi]
            if bad:
                raise ValueError(
                    f"dynamic tau(s) {bad} for stage {i} exceed its stash ring "
                    f"depth {hi + 1} (valid delays 0..{hi}): raise "
                    f"EngineCfg.max_dynamic_delay to replay them exactly")

    def _grouped_loss_and_grads(self, state: AsyncState, rows, batch, t):
        """Per-microbatch stash replay: microbatch k of the tick forwards (and
        backwards, for weight-stashing methods) through each stage's tick
        (t - rows[i][k]) point — K staggered points per stage per tick instead
        of one, Eq. 7 applied per microbatch. Accumulation mirrors
        staged.grad_accum exactly (f32 cast, in-order sum via scan, 1/K scale)
        so the K = 1 numerics are unchanged and the event runtime's in-order
        per-microbatch accumulation is reproduced term for term.

        Returns (loss, grads, Wfwd_g) with Wfwd_g the per-stage stacked [K]
        forward points (leading microbatch axis, via stash.get_group)."""
        m = self.method
        P, K = self.P, self.ecfg.update_interval
        Wfwd_g = [stash.get_group(state.stashes[i], t, rows[i],
                                  like=state.params[i]) for i in range(P)]
        # [K, P] per-microbatch delay columns (static rows fold to constants)
        tau_cols = jnp.transpose(jnp.stack([jnp.asarray(r) for r in rows]))

        def one_mb(Wf_k, b_k, tau_col):
            Wb_k = (Wf_k if m.bwd_point == "stash" else
                    [self._bwd_weights(i, state.params[i], state.extra[i],
                                       Wf_k[i], tau_col[i]) for i in range(P)])
            return staged.staged_loss_and_grads(self.stage_fns, Wf_k, Wb_k, b_k)

        def at0(tree_):
            return jax.tree.map(lambda x: x[0], tree_)

        loss0, grads0 = one_mb([at0(g) for g in Wfwd_g], at0(batch), tau_cols[0])
        if K == 1:
            return loss0, grads0, Wfwd_g
        grads0 = jax.tree.map(lambda g: g.astype(jnp.float32), grads0)

        def body(acc, xs):
            Wf_rest, b_k, tau_col = xs
            Wf_k = list(Wf_rest)
            loss_k, grads_k = one_mb(Wf_k, b_k, tau_col)
            acc_loss, acc_grads = acc
            acc_grads = jax.tree.map(lambda a, g: a + g.astype(a.dtype),
                                     acc_grads, grads_k)
            return (acc_loss + loss_k, acc_grads), None

        rest = (tuple(jax.tree.map(lambda x: x[1:], g) for g in Wfwd_g),
                jax.tree.map(lambda x: x[1:], batch), tau_cols[1:])
        (loss, grads), _ = jax.lax.scan(body, (loss0, grads0), rest,
                                        unroll=self.model_cfg.unroll)
        scale = 1.0 / K
        return loss * scale, jax.tree.map(lambda g: g * scale, grads), Wfwd_g

    def step(self, state: AsyncState, batch, taus=None) -> tuple:
        """batch: pytree with leading microbatch axis [K, ...] (K = update_interval).

        taus: optional per-tick delay override of the static schedule — the
        dynamic-tau path driven by the event runtime's observed staleness.
        Two accepted shapes (delay.validate_dynamic_taus):

        - [P] vector (length-P sequence or int32 array, possibly traced): one
          delay per stage applied to every microbatch of the tick — the legacy
          idealized form (one row of `RuntimeResult.taus`).
        - [P, K] matrix: one delay per (stage, microbatch) — the lossless form
          (one row of `RuntimeResult.tau_groups`) replayed per microbatch
          through stash.get_group.

        With taus=None at K > 1, async methods default to the static
        per-microbatch schedule `delay.stage_mb_delays(P, K)` (whose k = K-1
        column is exactly Eq. 5), closing the K>1 event/engine gap; at K = 1
        the two schedules coincide and the legacy single-point program is
        emitted unchanged. Concrete entries are depth-validated host-side;
        traced entries saturate at the ring depth (stash._check_tau). Whether
        the method's correction math ALSO consumes the live value is its
        `tau_source` axis, and how a K-group collapses for that math is its
        `tau_reduce` policy (DESIGN.md §10).
        """
        m = self.method
        t = state.step
        P = self.P
        K = self.ecfg.update_interval
        if taus is None:
            if m.sync or K == 1:
                rows = list(self.taus)
            else:
                rows = list(self.taus_mb)
        else:
            rows = delay_mod.validate_dynamic_taus(taus, P, K)
        grouped = any(isinstance(r, (tuple, list)) or getattr(r, "ndim", 0) >= 1
                      for r in rows)
        if grouped and K == 1:  # [P, 1] matrix == [P] vector: legacy program
            rows = [r[0] for r in rows]
            grouped = False
        self._host_check_taus(rows)
        grouped = grouped and not m.sync  # sync ignores stashes entirely

        if grouped:
            # 1+2) K staggered points per stage; per-microbatch fwd/bwd + accum
            loss, grads, Wfwd_g = self._grouped_loss_and_grads(state, rows, batch, t)
            # the update-level stale point is the group's FINAL microbatch's
            # (k = K-1) — the microbatch whose backward completes the update,
            # matching the event runtime's W_used at the accumulation boundary
            W_stale = [jax.tree.map(lambda x: x[-1], g) for g in Wfwd_g]
            # metrics report the stalest point of the group (k = 0)
            W_gap = [jax.tree.map(lambda x: x[0], g) for g in Wfwd_g]
            taus_t = rows
        else:
            # 1) forward/backward points per stage (one staggered point each)
            taus_t = rows
            Wfwd = []
            for i in range(P):
                if m.sync:
                    Wfwd.append(state.params[i])
                else:
                    Wfwd.append(stash.get(state.stashes[i], t, taus_t[i],
                                          like=state.params[i]))
            Wbwd = ([self._bwd_weights(i, state.params[i], state.extra[i],
                                       Wfwd[i], taus_t[i]) for i in range(P)]
                    if m.bwd_point != "stash" else Wfwd)

            # 2) staggered-stale forward + per-stage VJP backward (+ accum)
            def lg(Wf, Wb, b):
                return staged.staged_loss_and_grads(self.stage_fns, Wf, Wb, b)

            loss, grads = staged.grad_accum(lg, Wfwd, Wbwd, batch,
                                            unroll=self.model_cfg.unroll)
            W_stale = Wfwd
            W_gap = Wfwd

        # 3-5) per-stage method update (Sec. 5.4 corrections + Eq. 13 schedules),
        # then stash the next tick's forward point
        lr_t = self.lr_sched(t)
        new_params, new_opts, new_stashes, new_extras = [], [], [], []
        aux_by_stage = []
        for i in range(P):
            np_i, no_i, ne_i, fp_i, aux = self._stage_update(
                i, state.params[i], grads[i], state.opt[i], state.extra[i],
                taus_t[i], t, W_stale=W_stale[i], lr_t=lr_t)
            new_params.append(np_i)
            new_opts.append(no_i)
            new_extras.append(ne_i)
            aux_by_stage.append(aux)
            new_stashes.append(stash.push(state.stashes[i], fp_i, t + 1))

        metrics = {"loss": loss, "lr": lr_t}
        if self.ecfg.collect_metrics and not m.sync:
            # weight discrepancy Delta_t at stage 1 (largest delay) — Fig. 4 'gap'
            d = jax.tree.map(
                lambda w, wb: w.astype(jnp.float32) - wb.astype(jnp.float32),
                state.params[0], W_gap[0])
            sq = sum(jnp.vdot(x, x) for x in jax.tree.leaves(d))
            n = sum(x.size for x in jax.tree.leaves(d))
            metrics["stage1_gap_rmse"] = jnp.sqrt(sq / n)
            # cos(Delta_t, d_bar_t): alignment of delay with the stale step (Prop. 1)
            dbar = aux_by_stage[0]["last_step"]
            num = sum(jnp.vdot(a, b) for a, b in zip(jax.tree.leaves(d), jax.tree.leaves(dbar)))
            den = jnp.sqrt(sq) * jnp.sqrt(sum(jnp.vdot(x, x) for x in jax.tree.leaves(dbar)))
            metrics["stage1_align_cos"] = num / (den + 1e-20)

        new_state = AsyncState(t + 1, tuple(new_params), tuple(new_stashes),
                               tuple(new_opts), tuple(dict(e) for e in new_extras))
        return new_state, metrics

    # -- convenience ----------------------------------------------------------

    def jit_step(self, donate=True):
        return jax.jit(self.step, donate_argnums=(0,) if donate else ())

    def merge_params(self, state: AsyncState):
        """Re-assemble the monolithic param pytree (for eval/serve/checkpoints)."""
        merged: dict = {}
        for sp in state.params:
            for k, v in sp.items():
                if k in ("scan", "enc_scan") and k in merged:
                    merged[k] = jax.tree.map(lambda a, b: jnp.concatenate([a, b], 0), merged[k], v)
                elif k == "prelude" and k in merged:
                    merged[k] = {**merged[k], **v}
                elif k not in merged:
                    merged[k] = v
        return merged
