"""Async pipeline-parallel training engine.

One `tick` == one 1F1B steady-state update interval (paper Sec. 2.2): the microbatch
completing its backward now forwarded through *staggered stale weights*
f_P^t . f_{P-1}^{t-1} ... f_1^{t-P+1} (Eq. 7); every stage updates with its own
staleness tau_i (Eq. 5/6). The stash ring buffers replay exactly those weights, so
the single jit-compiled program is per-iteration faithful to asynchronous execution.

Engine state is a pure pytree -> pjit-shardable, checkpointable, and scan-able.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import delay as delay_mod
from repro.core import staged, stash
from repro.core.methods import Method, get_method
from repro.kernels import dispatch as kdispatch
from repro.models import lm
from repro.models.layers import ModelCfg
from repro.optim import forecast, optimizers, schedules


def _where_tau(tau, if_stale, if_fresh):
    """Select between two pytrees on tau > 0. Static tau folds at trace time
    (preserving the fixed-schedule engine's exact program); traced tau lowers
    to a per-leaf jnp.where (the dynamic/observed-delay path)."""
    if isinstance(tau, (int, float)):
        return if_stale if tau > 0 else if_fresh
    return jax.tree.map(lambda a, b: jnp.where(tau > 0, a, b), if_stale, if_fresh)


class AsyncState(NamedTuple):
    step: jnp.ndarray  # int32 scalar: tick counter t
    params: tuple  # per-stage current weights w_i^t
    stashes: tuple  # per-stage ring buffers of forward points (depth tau_i+1)
    opt: tuple  # per-stage optimizer states
    extra: tuple  # per-stage method-specific state (forecast history, ...)


@dataclasses.dataclass
class EngineCfg:
    n_stages: int = 4
    update_interval: int = 1  # K in Eq. 5 (microbatches accumulated per update)
    lr: float = 3e-4
    weight_decay: float = 0.01
    warmup_steps: int = 0
    total_steps: int = 10000
    constant_lr: bool = False
    collect_metrics: bool = True
    stash_dtype: Any = None  # e.g. jnp.bfloat16 to halve stash memory
    # Static per-stage override of the Eq. 5 schedule (straggler injection).
    # Must have exactly one entry per pipeline stage (length == P after the
    # model-unit clamp). This is the *static* counterpart of the event
    # runtime's DelayModel (core/events.py): the DelayModel samples latencies
    # and the runtime feeds the *observed* tau back per tick, while this field
    # pins a fixed tau vector into the single-jit engine.
    straggler_delays: Optional[tuple] = None
    # Upper bound on per-tick dynamic delays for step(..., taus=...): stash
    # ring depth becomes max_dynamic_delay + 1 on every stage so any observed
    # tau <= max_dynamic_delay replays exactly. None = static schedule depth.
    max_dynamic_delay: Optional[int] = None
    # kernel routing: backend for the fused optimizer tick (env var
    # REPRO_KERNEL_BACKEND overrides; see kernels/dispatch.py). None = platform.
    kernel_backend: Optional[str] = None
    # None = auto: fuse when the backend is pallas/interpret and the method's
    # optimizer has a fused flat-buffer implementation (nadam family).
    fused_optimizer: Optional[bool] = None


class AsyncTrainer:
    """Builds init/step for (model cfg, method). Step is jit-compatible and pjit-able."""

    def __init__(self, model_cfg: ModelCfg, ecfg: EngineCfg, method: str | Method):
        self.model_cfg = model_cfg
        self.ecfg = ecfg
        self.method = get_method(method) if isinstance(method, str) else method
        # a stage must own >= 1 block unit: clamp P to the model's block count
        n_units = len(model_cfg.prelude) + model_cfg.n_periods + model_cfg.enc_periods
        P = min(ecfg.n_stages, max(n_units, 1))
        self.P = P
        if self.method.sync:
            self.taus = tuple(0 for _ in range(P))
        elif ecfg.straggler_delays is not None:
            self.taus = delay_mod.validate_taus(ecfg.straggler_delays, P)
        else:
            self.taus = delay_mod.stage_delays(P, ecfg.update_interval)
        kw = dict(self.method.opt_kwargs())
        kw.setdefault("wd", ecfg.weight_decay)
        # kernel routing: with a pallas/interpret backend, the per-stage optimizer
        # tick runs as ONE fused nag_update pass over contiguous flat buffers
        self.kernel_backend = kdispatch.resolve_backend(ecfg.kernel_backend)
        fused = ecfg.fused_optimizer
        if fused is None:
            fused = (self.kernel_backend != "ref"
                     and self.method.optimizer in optimizers.FUSABLE)
        self.opt = optimizers.make_optimizer(
            self.method.optimizer, lr=1.0, fused=fused,
            kernel_backend=self.kernel_backend, **kw)
        # lr folded via lr_scale so schedules stay outside the optimizer
        if ecfg.constant_lr:
            self.lr_sched = schedules.constant(ecfg.lr)
        else:
            self.lr_sched = schedules.warmup_cosine(ecfg.lr, ecfg.warmup_steps, ecfg.total_steps)
        self._stage_ops = None

    # -- setup ---------------------------------------------------------------

    def init(self, key) -> AsyncState:
        params = lm.init_lm(key, self.model_cfg)
        return self.init_from_params(params)

    def init_from_params(self, params) -> AsyncState:
        stages_p, stage_ops = lm.split_stages(params, self.model_cfg, self.P)
        # Under PP, params shared across stages (tied embeddings, zamba2 shared
        # blocks) become independent per-stage copies — an async pipeline cannot
        # sync them without reintroducing a barrier (see DESIGN.md §7). Dedupe
        # buffers so each stage owns its copy (also required for jit donation).
        seen: set = set()

        def dedupe(x):
            nonlocal seen
            key = id(x)
            if key in seen:
                return jnp.array(x)
            seen.add(key)
            return x

        stages_p = [jax.tree.map(dedupe, sp) for sp in stages_p]
        self._stage_ops = stage_ops
        self.stage_fns = staged.make_stage_fns(self.model_cfg, stage_ops)
        stashes = tuple(
            stash.init_stash(sp, self._stash_depth(i), dtype=self.ecfg.stash_dtype)
            for i, sp in enumerate(stages_p)
        )
        opt_states = tuple(self.opt.init(sp) for sp in stages_p)
        extras = tuple(self._init_extra(sp) for sp in stages_p)
        return AsyncState(jnp.zeros((), jnp.int32), tuple(stages_p), stashes, opt_states, extras)

    def _init_extra(self, sp):
        e = {}
        if self.method.grad_forecast == "polyfft":
            e["hist"] = forecast.init_history(sp, self.method.forecast_hist)
        if self.method.bwd_point == "pipemare_predict":
            e["velocity"] = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), sp)
        return e

    def _stash_depth(self, i: int) -> int:
        if self.ecfg.max_dynamic_delay is not None:
            return stash.depth_for(self.ecfg.max_dynamic_delay)
        return self.taus[i] + 1

    # -- per-stage method semantics (shared by the jit engine and the event
    #    runtime, so both execution paths apply bit-identical update math) -----

    def _method_tau(self, i: int, tau):
        """The tau the method's delay corrections consume (Method.tau_source):
        "observed" passes the execution path's live value through (the event
        runtime's measured staleness, or step(..., taus=...)); "stage_index"
        pins the static Eq. 5 / straggler-override schedule so corrections stay
        blind to measured delays. Stash selection always uses the live tau —
        only the correction math is re-sourced."""
        return tau if self.method.tau_source == "observed" else self.taus[i]

    def _bwd_weights(self, i: int, params, extra, W_stale, tau):
        """Where stage i's VJP is linearized. tau: static int or traced/observed."""
        m = self.method
        tau = self._method_tau(i, tau)
        if m.bwd_point == "stash":
            return W_stale
        if m.bwd_point == "current":
            return params
        if m.bwd_point == "pipemare_predict":
            # PipeMare: estimate the weights the forward used via update velocity:
            # w_hat_i = w_t - tau_i * velocity_i  (identity at tau == 0)
            v = extra.get("velocity") if extra else None
            if v is None:
                return params
            tau_f = jnp.asarray(tau, jnp.float32)
            return jax.tree.map(
                lambda w, vv: (w.astype(jnp.float32) - tau_f * vv).astype(w.dtype),
                params, v)
        raise ValueError(m.bwd_point)

    def _stage_update(self, i: int, params, grads, opt_state, extra, tau, t, *,
                      W_stale=None, lr_t=None):
        """One stage's method-interpreted update at (possibly dynamic) delay tau.

        Returns (new_params, new_opt, new_extra, fwd_point, aux). tau may be a
        python number (static Eq. 5 schedule — branches fold at trace time) or
        a traced scalar (live observed delay from the event runtime).
        """
        m = self.method
        if lr_t is None:
            lr_t = self.lr_sched(t)
        # corrections consume the method-selected tau source; the raw `tau`
        # argument stays the execution path's live value (stash selection)
        tau_m = self._method_tau(i, tau)
        new_extra = dict(extra)
        # gradient forecasting corrections (baselines of Sec. 5.4)
        if m.grad_forecast == "second_order":
            corrected = forecast.second_order_correct(grads, params, W_stale)
            grads = _where_tau(tau_m, corrected, grads)
        elif m.grad_forecast == "polyfft":
            h = m.forecast_hist
            new_extra["hist"] = forecast.push_history(extra["hist"], grads, h)
            predicted = forecast.polyfft_predict(new_extra["hist"], h, tau_m)
            grads = _where_tau(tau_m, predicted, grads)
        # Eq. 13 stage schedules (delay-keyed momentum when tau is observed)
        lr_scale = lr_t
        if m.lr_discount:
            lr_scale = lr_scale * schedules.lr_discount_factor(tau_m, t, m.lr_discount_T)
        if not m.stage_momentum:
            mom = None
        elif m.tau_source == "observed":
            mom = schedules.delay_momentum(tau_m, self.P, self.ecfg.update_interval)
        else:
            mom = schedules.stage_momentum(i + 1, self.P)
        new_params, new_opt, aux = self.opt.update(params, grads, opt_state,
                                                   lr_scale=lr_scale, mom=mom, t=t)
        if m.bwd_point == "pipemare_predict":
            beta = 0.9
            new_extra["velocity"] = jax.tree.map(
                lambda v, s: beta * v + (1 - beta) * s,
                extra["velocity"], aux["step_dir"])
        # the point the *next* forward runs at
        if m.fwd_point == "current":
            fp = new_params
        elif m.fwd_point == "lookahead":
            fp = aux["lookahead"]
        elif m.fwd_point == "xpipe_predict":
            # XPipe: predict weights tau updates ahead along the optimizer step
            tau_f = jnp.asarray(tau_m, jnp.float32)
            fp = jax.tree.map(
                lambda w, s: (w.astype(jnp.float32) + tau_f * s).astype(w.dtype),
                new_params, aux["step_dir"])
        else:
            raise ValueError(m.fwd_point)
        return new_params, new_opt, new_extra, fp, aux

    # -- one tick -------------------------------------------------------------

    def step(self, state: AsyncState, batch, taus=None) -> tuple:
        """batch: pytree with leading microbatch axis [K, ...] (K = update_interval).

        taus: optional per-tick delay vector (length-P sequence or int32 [P]
        array, possibly traced) overriding the static schedule — the dynamic-tau
        path driven by the event runtime's observed staleness (one row of
        `RuntimeResult.taus`). Every entry must be <= the stash depth bound
        (EngineCfg.max_dynamic_delay). The vector drives the stash replay for
        every method; whether the method's correction math ALSO consumes it is
        its `tau_source` axis (DESIGN.md §10).
        """
        m = self.method
        t = state.step
        P = self.P
        if taus is None:
            taus_t = list(self.taus)
        else:
            taus_t = delay_mod.validate_dynamic_taus(taus, P)

        # 1) forward/backward points per stage
        Wfwd = []
        for i in range(P):
            if m.sync:
                Wfwd.append(state.params[i])
            else:
                Wfwd.append(stash.get(state.stashes[i], t, taus_t[i], like=state.params[i]))
        Wbwd = ([self._bwd_weights(i, state.params[i], state.extra[i], Wfwd[i], taus_t[i])
                 for i in range(P)]
                if m.bwd_point != "stash" else Wfwd)

        # 2) staggered-stale forward + per-stage VJP backward (+ grad accumulation)
        def lg(Wf, Wb, b):
            return staged.staged_loss_and_grads(self.stage_fns, Wf, Wb, b)

        loss, grads = staged.grad_accum(lg, Wfwd, Wbwd, batch,
                                        unroll=self.model_cfg.unroll)

        # 3-5) per-stage method update (Sec. 5.4 corrections + Eq. 13 schedules),
        # then stash the next tick's forward point
        lr_t = self.lr_sched(t)
        new_params, new_opts, new_stashes, new_extras = [], [], [], []
        aux_by_stage = []
        for i in range(P):
            np_i, no_i, ne_i, fp_i, aux = self._stage_update(
                i, state.params[i], grads[i], state.opt[i], state.extra[i],
                taus_t[i], t, W_stale=Wfwd[i], lr_t=lr_t)
            new_params.append(np_i)
            new_opts.append(no_i)
            new_extras.append(ne_i)
            aux_by_stage.append(aux)
            new_stashes.append(stash.push(state.stashes[i], fp_i, t + 1))

        metrics = {"loss": loss, "lr": lr_t}
        if self.ecfg.collect_metrics and not m.sync:
            # weight discrepancy Delta_t at stage 1 (largest delay) — Fig. 4 'gap'
            d = jax.tree.map(
                lambda w, wb: w.astype(jnp.float32) - wb.astype(jnp.float32),
                state.params[0], Wfwd[0])
            sq = sum(jnp.vdot(x, x) for x in jax.tree.leaves(d))
            n = sum(x.size for x in jax.tree.leaves(d))
            metrics["stage1_gap_rmse"] = jnp.sqrt(sq / n)
            # cos(Delta_t, d_bar_t): alignment of delay with the stale step (Prop. 1)
            dbar = aux_by_stage[0]["last_step"]
            num = sum(jnp.vdot(a, b) for a, b in zip(jax.tree.leaves(d), jax.tree.leaves(dbar)))
            den = jnp.sqrt(sq) * jnp.sqrt(sum(jnp.vdot(x, x) for x in jax.tree.leaves(dbar)))
            metrics["stage1_align_cos"] = num / (den + 1e-20)

        new_state = AsyncState(t + 1, tuple(new_params), tuple(new_stashes),
                               tuple(new_opts), tuple(dict(e) for e in new_extras))
        return new_state, metrics

    # -- convenience ----------------------------------------------------------

    def jit_step(self, donate=True):
        return jax.jit(self.step, donate_argnums=(0,) if donate else ())

    def merge_params(self, state: AsyncState):
        """Re-assemble the monolithic param pytree (for eval/serve/checkpoints)."""
        merged: dict = {}
        for sp in state.params:
            for k, v in sp.items():
                if k in ("scan", "enc_scan") and k in merged:
                    merged[k] = jax.tree.map(lambda a, b: jnp.concatenate([a, b], 0), merged[k], v)
                elif k == "prelude" and k in merged:
                    merged[k] = {**merged[k], **v}
                elif k not in merged:
                    merged[k] = v
        return merged
