"""Staged forward/backward with per-stage weight substitution.

The backward pass is the manual stage-chain rule: stage i's VJP is linearized at
(Wbwd_i, carry_i) where carry_i is the activation produced by the *forward* weights.
- Wbwd == Wfwd        -> exact backprop through the (stale) forward weights
                         == PipeDream weight stashing (paper Eq. 6).
- Wbwd == current     -> the no-weight-stash idealization (paper Eq. 12).
- Wbwd == predicted   -> PipeMare backward weight prediction.

Each stage is recomputed inside its VJP, i.e. activation checkpointing at stage
boundaries comes for free.
"""
from __future__ import annotations

from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models.layers import ModelCfg


def make_stage_fns(cfg: ModelCfg, stage_ops: Sequence[list]):
    """stage_fn(i): (stage_params, carry, batch) -> carry."""

    def mk(ops):
        def f(sp, carry, batch):
            out, _ = lm.run_stage_ops(sp, ops, carry, batch, cfg)
            return out

        return f

    return [mk(ops) for ops in stage_ops]


def init_carry():
    return {"x": None, "enc": None, "aux": jnp.zeros((), jnp.float32)}


def staged_forward(stage_fns, Ws, batch):
    """Returns (loss, carries): carries[i] = input carry of stage i."""
    carry = init_carry()
    carries = []
    for f, w in zip(stage_fns, Ws):
        carries.append(carry)
        carry = f(w, carry, batch)
    return carry["loss"], carries


def _loss_seed(carry_out):
    """Cotangent seeding d(loss)=1 for a stage-output carry."""
    ct = jax.tree.map(lambda x: jnp.zeros_like(x), carry_out)
    ct["loss"] = jnp.ones_like(carry_out["loss"])
    return ct


def staged_loss_and_grads(stage_fns, Wfwd, Wbwd, batch):
    """Manual per-stage chain rule. Returns (loss, grads_list).

    Two regimes:
    - Wbwd is Wfwd (weight-stashing methods: correct backprop at the stale
      weights): ONE-PASS — the vjp-forward itself produces the carries, so the
      whole step costs fwd + bwd instead of 2x fwd + bwd. All stages' residuals
      are live simultaneously, but per-block remat inside the layer scans keeps
      that to one boundary activation per layer (§Perf H1).
    - Wbwd != Wfwd (no-stash / PipeMare-predicted backward): forward through
      Wfwd storing stage-boundary carries, then per-stage VJPs linearized at
      (Wbwd_i, carry_i) — paper Eq. 12 semantics.
    """
    P = len(stage_fns)
    if Wbwd is Wfwd:
        vjps = []
        carry = init_carry()
        for i in range(P):
            f = stage_fns[i]
            carry, vjp_fn = jax.vjp(lambda w, c, f=f: f(w, c, batch), Wfwd[i], carry)
            vjps.append(vjp_fn)
        loss = carry["loss"]
        ct = _loss_seed(carry)
        grads = [None] * P
        for i in reversed(range(P)):
            gW, ct = vjps[i](ct)
            grads[i] = gW
        return loss, grads

    loss, carries = staged_forward(stage_fns, Wfwd, batch)
    grads = [None] * P
    ct = None
    for i in reversed(range(P)):
        f = stage_fns[i]
        out_i, vjp_fn = jax.vjp(lambda w, c: f(w, c, batch), Wbwd[i], carries[i])
        if ct is None:
            ct = _loss_seed(out_i)
        gW, ct = vjp_fn(ct)
        grads[i] = gW
    return loss, grads


def grad_accum(loss_and_grads_fn, Wfwd, Wbwd, batches, unroll=False):
    """Accumulate over the leading microbatch axis of `batches` via scan."""
    K = jax.tree.leaves(batches)[0].shape[0]
    if K == 1:
        b0 = jax.tree.map(lambda x: x[0], batches)
        return loss_and_grads_fn(Wfwd, Wbwd, b0)

    def body(acc, b):
        loss, grads = loss_and_grads_fn(Wfwd, Wbwd, b)
        acc_loss, acc_grads = acc
        acc_grads = jax.tree.map(lambda a, g: a + g.astype(a.dtype), acc_grads, grads)
        return (acc_loss + loss, acc_grads), None

    b0 = jax.tree.map(lambda x: x[0], batches)
    loss0, grads0 = loss_and_grads_fn(Wfwd, Wbwd, b0)
    grads0 = jax.tree.map(lambda g: g.astype(jnp.float32), grads0)
    rest = jax.tree.map(lambda x: x[1:], batches)
    (loss, grads), _ = jax.lax.scan(body, (loss0, grads0), rest, unroll=unroll)
    scale = 1.0 / K
    return loss * scale, jax.tree.map(lambda g: g * scale, grads)
