"""Weight-stash ring buffers: pytrees with a leading time axis, mod-indexed.

PipeDream-style weight stashing made functional: `push` writes slot (t mod depth),
`get` reads slot ((t - tau) mod depth). No rolls — O(1) writes under jit, and the
buffers shard like the params they stash (leading axis unsharded).

Dynamic delays: `t` and `tau` may both be traced scalars, so one compiled
program serves any per-tick tau_t <= depth - 1 — the jit engine's dynamic-tau
path (`AsyncTrainer.step(..., taus=...)`) indexes the same ring with a live
delay vector. Size the ring with `depth_for(max_tau)`.

Depth bound: a tau beyond depth - 1 used to silently alias a NEWER slot
(mod-index wraparound), corrupting the replay with fresher weights than asked
for. `get`/`get_group` now enforce the bound: a concrete out-of-range tau
raises at trace time, and a traced one SATURATES to depth - 1 (the oldest
entry the ring still holds — the conservative direction: never fresher than
requested). Callers that need exact replay of larger delays must size the
ring up front (EngineCfg.max_dynamic_delay).
"""
from __future__ import annotations

import numbers

import jax
import jax.numpy as jnp


def init_stash(tree, depth: int, dtype=None):
    """Stash filled with `depth` copies of `tree` (warmup base case, Thm. 1)."""

    def mk(x):
        x = x.astype(dtype) if dtype is not None else x
        return jnp.broadcast_to(x[None], (depth,) + x.shape).copy()

    return jax.tree.map(mk, tree)


def stash_depth(stash) -> int:
    return jax.tree.leaves(stash)[0].shape[0]


def depth_for(max_tau: int) -> int:
    """Ring depth covering every delay in 0..max_tau (= max observed delay)."""
    return int(max_tau) + 1


def push(stash, tree, t):
    """Write `tree` at slot t mod depth. t: traced int32 scalar."""
    depth = stash_depth(stash)
    slot = jnp.mod(t, depth)

    def upd(buf, x):
        return jax.lax.dynamic_update_index_in_dim(buf, x.astype(buf.dtype), slot, 0)

    return jax.tree.map(upd, stash, tree)


def _check_tau(tau, depth: int):
    """Enforce the ring-depth bound. Concrete taus (python/numpy numbers, or
    concrete 0-d arrays) are validated host-side — an out-of-range value
    raises instead of aliasing a newer slot. Traced taus are clamped to
    [0, depth - 1]: the read saturates at the oldest entry the ring holds
    (documented degradation, never a silently FRESHER point)."""
    if isinstance(tau, numbers.Real):
        if not 0 <= tau <= depth - 1:
            raise ValueError(
                f"stash tau {tau} outside ring depth {depth} (valid delays "
                f"0..{depth - 1}): a larger ring is required to replay this "
                f"delay exactly (stash.depth_for / EngineCfg.max_dynamic_delay)")
        return tau
    return jnp.clip(tau, 0, depth - 1)


def get(stash, t, tau: int, like=None):
    """Read the entry written at tick (t - tau). If like is given, cast to its
    dtypes. tau must lie in [0, depth - 1] (see _check_tau)."""
    depth = stash_depth(stash)
    slot = jnp.mod(t - _check_tau(tau, depth), depth)
    out = jax.tree.map(lambda buf: jax.lax.dynamic_index_in_dim(buf, slot, 0, keepdims=False), stash)
    if like is not None:
        out = jax.tree.map(lambda o, l: o.astype(l.dtype), out, like)
    return out


def get_group(stash, t, taus, like=None):
    """Vectorized per-microbatch read: `taus` is a length-K delay vector (one
    entry per microbatch of an accumulation group — a row of the engine's
    [P, K] tau matrix). Returns the stashed entries with a leading [K] axis,
    entry k being the tick (t - taus[k]) forward point — the K staggered
    points the per-microbatch stash replay forwards through (Eq. 7 applied
    per microbatch). Concrete entries are bound-checked like `get`; traced
    entries saturate at the ring depth."""
    depth = stash_depth(stash)
    if isinstance(taus, (tuple, list)):
        taus = [_check_tau(x, depth) for x in taus]
    taus_k = jnp.asarray(taus)
    if taus_k.ndim != 1:
        raise ValueError(f"get_group taus must be a length-K vector, got "
                         f"shape {tuple(taus_k.shape)}")
    slots = jnp.mod(t - _check_tau(taus_k, depth), depth)
    out = jax.tree.map(lambda buf: jnp.take(buf, slots, axis=0), stash)
    if like is not None:
        out = jax.tree.map(lambda o, l: o.astype(l.dtype), out, like)
    return out
