"""Weight-stash ring buffers: pytrees with a leading time axis, mod-indexed.

PipeDream-style weight stashing made functional: `push` writes slot (t mod depth),
`get` reads slot ((t - tau) mod depth). No rolls — O(1) writes under jit, and the
buffers shard like the params they stash (leading axis unsharded).

Dynamic delays: `t` and `tau` may both be traced scalars, so one compiled
program serves any per-tick tau_t <= depth - 1 — the jit engine's dynamic-tau
path (`AsyncTrainer.step(..., taus=...)`) indexes the same ring with a live
delay vector. Size the ring with `depth_for(max_tau)`; a tau larger than
depth - 1 silently aliases a newer slot, so the depth bound is the caller's
contract (EngineCfg.max_dynamic_delay).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_stash(tree, depth: int, dtype=None):
    """Stash filled with `depth` copies of `tree` (warmup base case, Thm. 1)."""

    def mk(x):
        x = x.astype(dtype) if dtype is not None else x
        return jnp.broadcast_to(x[None], (depth,) + x.shape).copy()

    return jax.tree.map(mk, tree)


def stash_depth(stash) -> int:
    return jax.tree.leaves(stash)[0].shape[0]


def depth_for(max_tau: int) -> int:
    """Ring depth covering every delay in 0..max_tau (= max observed delay)."""
    return int(max_tau) + 1


def push(stash, tree, t):
    """Write `tree` at slot t mod depth. t: traced int32 scalar."""
    depth = stash_depth(stash)
    slot = jnp.mod(t, depth)

    def upd(buf, x):
        return jax.lax.dynamic_update_index_in_dim(buf, x.astype(buf.dtype), slot, 0)

    return jax.tree.map(upd, stash, tree)


def get(stash, t, tau: int, like=None):
    """Read the entry written at tick (t - tau). If like is given, cast to its dtypes."""
    depth = stash_depth(stash)
    slot = jnp.mod(t - tau, depth)
    out = jax.tree.map(lambda buf: jax.lax.dynamic_index_in_dim(buf, slot, 0, keepdims=False), stash)
    if like is not None:
        out = jax.tree.map(lambda o, l: o.astype(l.dtype), out, like)
    return out
