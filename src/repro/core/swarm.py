"""SWARM-style stage-wise data parallelism (paper Sec. 5.7, Fig. 8).

Each pipeline stage has R worker replicas; workers take async local update steps on
their own microbatches and periodically synchronize within the stage (all-reduce
mean), exactly SWARM's gradient-accumulation-free async variant. Three modes:

  swarm        — synchronous: per-tick stage-wise mean-gradient (all-reduce) update
  swarm_async  — async local updates + periodic stage-wise weight averaging
  swarm_ours   — swarm_async with the paper's no-weight-stash Nesterov method

Replicas are a leading axis on every stage-param leaf (vmap over the engine's
optimizer update); cross-replica sync is a mean over that axis — on a real mesh
that axis maps to `data` and the mean lowers to an all-reduce. Optional int8 +
error-feedback compression models the low-bandwidth decentralized links.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import staged
from repro.core.engine import AsyncTrainer, EngineCfg
from repro.models import lm


@dataclasses.dataclass
class SwarmCfg:
    replicas: int = 2
    sync_every: int = 8  # stage-wise weight sync period (async modes)
    compress: bool = False  # int8 + error feedback on sync deltas


class SwarmState(NamedTuple):
    inner: object  # AsyncState with replica-leading-axis params/opt/stash
    # error-feedback residuals per stage with a leading [R] axis (or empty
    # dicts when compression is off): each replica quantizes its OWN delta and
    # carries its OWN residual — the EF telescope is per-replica bookkeeping
    err: tuple


def _quantize_int8_ef(delta, err):
    """int8 quantize (per-leaf scale) with error feedback. Returns (deq, new_err)."""

    def q(d, e):
        d = d + e
        scale = jnp.maximum(jnp.max(jnp.abs(d)), 1e-12) / 127.0
        qv = jnp.clip(jnp.round(d / scale), -127, 127)
        deq = qv * scale
        return deq, d - deq

    flat_d, treedef = jax.tree.flatten(delta)
    flat_e = jax.tree.leaves(err)
    out = [q(d, e) for d, e in zip(flat_d, flat_e)]
    deq = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_err = jax.tree.unflatten(treedef, [o[1] for o in out])
    return deq, new_err


class SwarmTrainer:
    """Wraps AsyncTrainer with a replica axis per stage."""

    def __init__(self, model_cfg, ecfg: EngineCfg, method: str, scfg: SwarmCfg):
        self.inner = AsyncTrainer(model_cfg, ecfg, method)
        self.scfg = scfg

    def init(self, key) -> SwarmState:
        base = self.inner.init(key)
        R = self.scfg.replicas

        def rep(tree):
            return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (R,) + x.shape).copy(), tree)

        inner = base._replace(
            params=tuple(rep(p) for p in base.params),
            stashes=tuple(rep(s) for s in base.stashes),
            opt=tuple(rep(o) for o in base.opt),
            extra=tuple(rep(e) for e in base.extra),
        )
        err = tuple(jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), p)
                    for p in inner.params) if self.scfg.compress else tuple({} for _ in inner.params)
        return SwarmState(inner, err)

    def step(self, state: SwarmState, batch):
        """batch leaves: [R, K, ...] — each replica its own microbatch stream."""
        R = self.scfg.replicas
        inner = state.inner

        def one_replica(params, stashes, opt, extra, b):
            st = inner._replace(params=params, stashes=stashes, opt=opt, extra=extra)
            new_st, m = self.inner.step(st, b)
            return new_st.params, new_st.stashes, new_st.opt, new_st.extra, m

        # vmap over the replica axis of every stage tree + the batch
        new_p, new_s, new_o, new_e, metrics = jax.vmap(
            one_replica, in_axes=(0, 0, 0, 0, 0))(
            inner.params, inner.stashes, inner.opt, inner.extra, batch)

        t = inner.step + 1
        do_sync = jnp.equal(jnp.mod(t, self.scfg.sync_every), 0)

        def sync_stage(p, e):
            mean = jax.tree.map(lambda x: jnp.mean(x.astype(jnp.float32), axis=0), p)
            if self.scfg.compress:
                delta = jax.tree.map(
                    lambda mn, x: mn[None] - x.astype(jnp.float32), mean, p)
                # each replica quantizes ITS OWN delta toward the mean and
                # carries ITS OWN residual (leading [R] axis on e). Averaging
                # residuals across replicas breaks the EF telescope — opposite
                # per-replica errors cancel in the mean, so the carried
                # correction vanishes and quantization error accumulates
                # instead of being re-injected (tests/test_swarm.py).
                dq, new_err = jax.vmap(_quantize_int8_ef)(delta, e)
                newp = jax.tree.map(
                    lambda x, d: (x.astype(jnp.float32) + d).astype(x.dtype), p, dq)
                return newp, new_err
            newp = jax.tree.map(
                lambda x, mn: jnp.broadcast_to(mn[None], x.shape).astype(x.dtype), p, mean)
            return newp, e

        synced, errs = [], []
        for i in range(len(new_p)):
            sp, se = sync_stage(new_p[i], state.err[i])
            # only apply on sync ticks
            sp = jax.tree.map(lambda a, b: jnp.where(do_sync, a, b), sp, new_p[i])
            if self.scfg.compress:
                se = jax.tree.map(lambda a, b: jnp.where(do_sync, a, b), se, state.err[i])
            synced.append(sp)
            errs.append(se)

        new_inner = inner._replace(step=t, params=tuple(synced), stashes=new_s,
                                   opt=new_o, extra=new_e)
        out_metrics = {k: jnp.mean(v) for k, v in metrics.items()}
        return SwarmState(new_inner, tuple(errs)), out_metrics

    def jit_step(self):
        return jax.jit(self.step, donate_argnums=(0,))

    # -- event-driven async mode ----------------------------------------------

    def run_event(self, batch_fns, n_ticks: int, *, key=None, delay_models=None,
                  rcfg=None, in_flight=None, churn=None):
        """Route the async SWARM modes through the event-driven runtime
        (core/runtime.py): each replica is its own EventRuntime — its own
        DelayModel, its own observed staleness — and every `sync_every` updates
        the replica pipelines drain and stage weights average across replicas
        (int8+error-feedback compressed when scfg.compress). This is the
        deployment-shaped counterpart of the vmap jit path: heterogeneous
        workers, real stragglers, periodic decentralized sync.

        batch_fns: one batch_fn(t) -> [K, ...] per replica.
        delay_models: optional per-replica DelayModel / spec string.
        churn: optional events.ChurnModel / spec mapping the runtime's churn
          events onto replica membership: Outage.stage is the REPLICA index and
          start/duration are in update (tick) units, quantized to sync rounds.
          A replica whose outage intersects a round drops out of it — no
          compute, no averaging contribution (the remaining replicas keep
          syncing; at least one must stay alive). On rejoin the replica
          re-syncs: it adopts the last synced stage means as its live params
          (full state fetch, uncompressed) and, when compressing, resets its
          error-feedback residuals — its local deltas no longer describe the
          adopted weights. Its update counter resumes where it left off, so
          its loss stream is simply shorter by the dropped rounds.
        Returns {"losses": [R][<=n_ticks], "taus": [R] per-tick tuples,
                 "n_syncs", "dropped": per-replica rounds skipped,
                 "runtimes": the live EventRuntime objects}.
        """
        from repro.core import events as events_mod
        from repro.core import runtime as rt_mod

        R = self.scfg.replicas
        if len(batch_fns) != R:
            raise ValueError(f"need {R} batch fns, got {len(batch_fns)}")
        cm = events_mod.make_churn_model(churn).validate(R) if churn is not None else None
        if key is None:
            raise ValueError(
                "run_event: pass key= — a hardcoded PRNGKey(0) fallback "
                "would decouple the swarm init from --seed")
        base = self.inner.init(key)
        rts = []
        for r in range(R):
            if rcfg is not None:
                # rcfg carries the shared knobs; per-replica delay model/seed
                # still apply on top so heterogeneous workers stay heterogeneous
                cfg_r = dataclasses.replace(rcfg, seed=r)
                if delay_models is not None:
                    cfg_r = dataclasses.replace(
                        cfg_r, delay_model=events_mod.make_delay_model(
                            delay_models[r], seed=r))
            else:
                cfg_r = rt_mod.RuntimeCfg(
                    delay_model=events_mod.make_delay_model(
                        delay_models[r] if delay_models else None, seed=r),
                    in_flight=in_flight, seed=r)
            rts.append(rt_mod.EventRuntime(self.inner, cfg_r).init_from_state(base))

        def zero_err():
            return (tuple(jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), p)
                          for p in base.params) if self.scfg.compress
                    else tuple({} for _ in base.params))

        err = [zero_err() for _ in range(R)]
        losses = [[] for _ in range(R)]
        taus = [[] for _ in range(R)]
        last_mean = None  # per-stage means of the most recent sync
        was_out = [False] * R
        dropped = [0] * R
        n_syncs = 0
        done = 0
        while done < n_ticks:
            chunk = min(self.scfg.sync_every, n_ticks - done)
            # a zero-duration window is an empty interval: it intersects no
            # round (the documented Outage no-op contract holds here too)
            out = [cm is not None and any(
                o.stage == r and o.duration > 0 and o.start < done + chunk
                and o.start + o.duration > done
                for o in cm.outages) for r in range(R)]
            if all(out):
                raise RuntimeError(
                    f"all {R} replicas in outage over ticks [{done}, {done + chunk})")
            for r in range(R):
                if out[r]:
                    dropped[r] += 1
                    continue
                if was_out[r]:
                    # re-sync on rejoin: adopt the last synced means wholesale
                    # (a rejoin is a full state fetch, not a compressed delta)
                    # and drop stale EF residuals — they describe deltas of
                    # weights this replica no longer holds
                    if last_mean is not None:
                        for i in range(self.inner.P):
                            newp = jax.tree.map(
                                lambda mn, x: mn.astype(x.dtype),
                                last_mean[i], rts[r]._stages[i].params)
                            rts[r]._stages[i].params = newp
                            rts[r]._stages[i].fwd_point = newp
                    err[r] = zero_err()
                res = rts[r].run(batch_fns[r], chunk)
                losses[r].extend(res.losses)
                taus[r].extend(res.taus)
            done += chunk
            # stage-wise weight averaging across the (drained) alive replicas
            alive = [r for r in range(R) if not out[r]]
            last_mean = []
            for i in range(self.inner.P):
                stage_params = [rts[r]._stages[i].params for r in alive]
                mean = jax.tree.map(
                    lambda *xs: sum(x.astype(jnp.float32) for x in xs) / len(alive),
                    *stage_params)
                last_mean.append(mean)
                for r in alive:
                    if self.scfg.compress:
                        d_r = jax.tree.map(
                            lambda mn, x: mn - x.astype(jnp.float32),
                            mean, rts[r]._stages[i].params)
                        dq, err_r = _quantize_int8_ef(d_r, err[r][i])
                        newp = jax.tree.map(
                            lambda x, d: (x.astype(jnp.float32) + d).astype(x.dtype),
                            rts[r]._stages[i].params, dq)
                        err[r] = err[r][:i] + (err_r,) + err[r][i + 1:]
                    else:
                        newp = jax.tree.map(
                            lambda x, mn: mn.astype(x.dtype),
                            rts[r]._stages[i].params, mean)
                    rts[r]._stages[i].params = newp
                    # the drained stash re-warms from the synced weights
                    rts[r]._stages[i].fwd_point = newp
            was_out = out
            n_syncs += 1
        return {"losses": losses, "taus": taus, "n_syncs": n_syncs,
                "dropped": dropped, "runtimes": rts, "err": err}

    def eval_loss(self, state: SwarmState, batch):
        """Loss of replica-0 weights (post-sync evaluation)."""
        params0 = tuple(jax.tree.map(lambda x: x[0], p) for p in state.inner.params)
        loss, _ = staged.staged_forward(self.inner.stage_fns, params0,
                                        jax.tree.map(lambda x: x[0][0], batch))
        return loss


# ---------------------------------------------------------------------------
# Fully-async 2D mesh: gossip stage-averaging as runtime events (no barrier)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class MeshCfg:
    """Config for the fully-async gossip mesh (DESIGN.md §13).

    replicas x period play the role of SwarmCfg.replicas x sync_every, but the
    sync itself is event-driven: no replica ever waits for another. fanout
    bounds how many keyed partners each replica pushes to per round (None =
    all others); max_stale_rounds bounds absorption staleness exactly like
    stash depth bounds weight staleness. opt_shard enables the ZeRO-1
    owner-shard optimizer (each replica persists 1/R of the flat p/m/v);
    compress keeps the barrier path's int8+EF per-replica discipline and is
    mutually exclusive with opt_shard (a quantized average would corrupt the
    owner-authoritative shard segments).
    """

    replicas: int = 2
    period: int = 8
    fanout: object = None  # Optional[int]
    compress: bool = False
    opt_shard: bool = False
    max_stale_rounds: int = 1
    sync_delay: object = None  # spec str | events.SyncDelayModel | None
    seed: int = 0

    def __post_init__(self):
        if self.replicas < 1:
            raise ValueError(f"need >= 1 replicas, got {self.replicas}")
        if self.period < 1:
            raise ValueError(f"mesh period must be >= 1, got {self.period}")
        if self.max_stale_rounds < 0:
            raise ValueError(
                f"max_stale_rounds must be >= 0, got {self.max_stale_rounds}")
        if self.compress and self.opt_shard:
            raise ValueError("compress and opt_shard are mutually exclusive: "
                             "quantized averaging would corrupt the "
                             "owner-authoritative ZeRO-1 param segments")


class MeshTrainer:
    """Per-replica EventRuntimes stitched by gossip SyncEvents (events.drive_mesh).

    Degenerate-case contract (tests/test_mesh.py): with identical per-replica
    delay models, zero sync delay, full fanout and no compression, every
    replica's absorption sees exactly the other replicas' same-round weights,
    and the absorbed mean is computed with the SAME expression and summation
    order as SwarmTrainer.run_event's barrier `sync_stage` — so the two paths
    are bitwise identical. With opt_shard, absorption instead adopts each
    partner's owner-authoritative ZeRO-1 param segment (the event-driven
    all-gather half of the sharded optimizer step).
    """

    def __init__(self, model_cfg, ecfg: EngineCfg, method: str, mcfg: MeshCfg):
        from repro.optim import optimizers as opt_mod

        self.mcfg = mcfg
        self.inner = AsyncTrainer(model_cfg, ecfg, method)
        R = mcfg.replicas
        if mcfg.opt_shard:
            if self.inner.method.optimizer not in ("nadam", "nadam_nodiscount"):
                raise ValueError(
                    "opt_shard requires a nadam-family optimizer (the ZeRO-1 "
                    f"shard update is fused nag_update), got "
                    f"{self.inner.method.optimizer!r}")
            # one trainer per replica, its optimizer swapped for the rank's
            # owner-shard variant. Mirrors engine.py's construction: lr=1.0
            # (folded via the lr_scale schedule), method opt_kw on top of the
            # EngineCfg weight-decay default.
            self.replica_trainers = []
            for r in range(R):
                tr = AsyncTrainer(model_cfg, ecfg, method)
                kw = dict(tr.method.opt_kwargs())
                kw.setdefault("wd", ecfg.weight_decay)
                tr.opt = opt_mod.nadam_flat_shard(
                    rank=r, world=R, lr=1.0,
                    discount=(tr.method.optimizer != "nadam_nodiscount"),
                    backend=tr.kernel_backend, **kw)
                self.replica_trainers.append(tr)
        else:
            self.replica_trainers = [self.inner] * R

    @property
    def P(self):
        return self.inner.P

    def run_gossip(self, batch_fns, n_ticks: int, *, key=None,
                   delay_models=None, rcfg=None, in_flight=None):
        """Run R replica pipelines for n_ticks local updates each, gossiping
        stage weights every `period` ticks through events.drive_mesh — the
        event-driven counterpart of SwarmTrainer.run_event with the barrier
        removed. No churn support here: membership churn composes with the
        per-replica runtimes (RuntimeCfg.churn), not with the mesh layer.

        Returns the run_event-shaped dict plus the mesh telemetry: "events"
        (the payload-free drive_mesh log, == the simulate_mesh_schedule twin),
        "absorbed"/"stale_dropped"/"superseded"/"unabsorbed", "makespan",
        "inbox_high_water", and the ZeRO-1 memory claim numbers
        "opt_bytes_per_replica" / "opt_bytes_replicated".
        """
        from repro.core import events as events_mod
        from repro.core import runtime as rt_mod
        from repro.optim import optimizers as opt_mod

        m = self.mcfg
        R = m.replicas
        P = self.inner.P
        if len(batch_fns) != R:
            raise ValueError(f"need {R} batch fns, got {len(batch_fns)}")
        if key is None:
            raise ValueError(
                "run_gossip: pass key= — a hardcoded PRNGKey(0) fallback "
                "would decouple the mesh init from --seed")
        # key consumed once: every replica starts from the same model init
        # (the run_event discipline); under opt_shard each replica re-derives
        # its own rank's opt layout from the shared full param tree
        # (init_from_params is deterministic — no further key draws).
        if m.opt_shard:
            full = lm.init_lm(key, self.inner.model_cfg)
            states = [tr.init_from_params(full) for tr in self.replica_trainers]
        else:
            states = [self.inner.init(key)] * R
        rts = []
        for r in range(R):
            # identical per-replica runtime construction to run_event — part
            # of the degenerate-case bitwise contract
            if rcfg is not None:
                cfg_r = dataclasses.replace(rcfg, seed=r)
                if delay_models is not None:
                    cfg_r = dataclasses.replace(
                        cfg_r, delay_model=events_mod.make_delay_model(
                            delay_models[r], seed=r))
            else:
                cfg_r = rt_mod.RuntimeCfg(
                    delay_model=events_mod.make_delay_model(
                        delay_models[r] if delay_models else None, seed=r),
                    in_flight=in_flight, seed=r)
            tr = self.replica_trainers[r]
            rts.append(rt_mod.EventRuntime(tr, cfg_r).init_from_state(states[r]))

        def zero_err(r):
            base_p = [rts[r]._stages[i].params for i in range(P)]
            return (tuple(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), p) for p in base_p)
                if m.compress else tuple({} for _ in base_p))

        err = [zero_err(r) for r in range(R)]
        losses = [[] for _ in range(R)]
        taus = [[] for _ in range(R)]
        n_rounds = -(-n_ticks // m.period)

        def chunk(rnd):
            return min(m.period, n_ticks - rnd * m.period)

        def run_round(r, rnd):
            res = rts[r].run(batch_fns[r], chunk(rnd))
            losses[r].extend(res.losses)
            taus[r].extend(res.taus)
            return res.makespan

        def snapshot(r, rnd):
            return [rts[r]._stages[i].params for i in range(P)]

        def absorb(r, rnd, by_stage, now):
            for i, contribs in sorted(by_stage.items()):
                own = rts[r]._stages[i].params
                if m.opt_shard:
                    # event-driven all-gather: adopt each partner's
                    # owner-authoritative ZeRO-1 segment, keep our own
                    pf = opt_mod.flatten_tree(own)
                    n = pf.shape[0]
                    S = opt_mod.zero1_shard_size(n, R)
                    for src, _src_rnd, data in contribs:
                        lo, hi = src * S, min(src * S + S, n)
                        if lo >= hi:
                            continue
                        seg = opt_mod.zero1_shard(
                            opt_mod.flatten_tree(data), src, R)
                        pf = jnp.concatenate([pf[:lo], seg[:hi - lo], pf[hi:]])
                    newp = opt_mod.unflatten_like(pf, own)
                else:
                    # barrier sync_stage math, verbatim: contributions plus our
                    # own weights, summed in replica-index order
                    entries = {src: data for src, _src_rnd, data in contribs}
                    entries[r] = own
                    xs_list = [entries[k] for k in sorted(entries)]
                    mean = jax.tree.map(
                        lambda *xs: sum(x.astype(jnp.float32) for x in xs) / len(xs_list),
                        *xs_list)
                    if m.compress:
                        d_r = jax.tree.map(
                            lambda mn, x: mn - x.astype(jnp.float32), mean, own)
                        dq, err_r = _quantize_int8_ef(d_r, err[r][i])
                        newp = jax.tree.map(
                            lambda x, d: (x.astype(jnp.float32) + d).astype(x.dtype),
                            own, dq)
                        err[r] = err[r][:i] + (err_r,) + err[r][i + 1:]
                    else:
                        newp = jax.tree.map(
                            lambda x, mn: mn.astype(x.dtype), own, mean)
                rts[r]._stages[i].params = newp
                # the drained stash re-warms from the absorbed weights
                rts[r]._stages[i].fwd_point = newp

        mesh = events_mod.drive_mesh(
            R, n_rounds, n_stages=P, fanout=m.fanout, seed=m.seed,
            sync_delay=m.sync_delay, max_stale_rounds=m.max_stale_rounds,
            run_round=run_round, snapshot=snapshot, absorb=absorb)

        opt_bytes = sum(opt_mod.optimizer_memory_bytes(rts[0]._stages[i].opt)
                        for i in range(P))
        if m.opt_shard:
            n_total = sum(
                sum(int(jnp.size(l)) for l in
                    jax.tree.leaves(rts[0]._stages[i].params))
                for i in range(P))
            repl_bytes = 3 * 4 * n_total  # replicated flat fp32 p/m/v
        else:
            repl_bytes = opt_bytes
        return {"losses": losses, "taus": taus, "runtimes": rts, "err": err,
                "n_rounds": n_rounds, "events": mesh["events"],
                "absorbed": mesh["absorbed"],
                "stale_dropped": mesh["stale_dropped"],
                "superseded": mesh["superseded"],
                "unabsorbed": mesh["unabsorbed"],
                "makespan": mesh["makespan"],
                "inbox_high_water": mesh["inbox_high_water"],
                "opt_bytes_per_replica": opt_bytes,
                "opt_bytes_replicated": repl_bytes}
