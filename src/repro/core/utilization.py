"""Pipeline utilization / bubble analytics (Fig. 5 runtime model, formalized).

Time units are per-stage microbatch-times; `c` is a per-stage-boundary overhead
(activation transfer on the slow link) relative to a single layer's compute.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class PipelineTiming:
    iter_time: float  # one optimizer-step wall time (arbitrary units)
    bubble_frac: float  # idle fraction of stage-time
    utilization: float  # 1 - bubble


def gpipe_timing(P: int, M: int, L: int, *, t_layer: float = 1.0, c: float = 0.15):
    """GPipe: fill/drain bubble of (P-1) stage-slots per flush."""
    t_stage = t_layer * L / P + c
    total = (M + P - 1) * t_stage
    useful = M * t_stage
    return PipelineTiming(total, (total - useful) / total, useful / total)


def onef_oneb_sync_timing(P: int, M: int, L: int, *, t_layer: float = 1.0, c: float = 0.15):
    """Synchronous 1F1B (PipeDream-flush): same bubble, lower activation memory."""
    return gpipe_timing(P, M, L, t_layer=t_layer, c=c)


def async_timing(P: int, M: int, L: int, *, t_layer: float = 1.0, c: float = 0.15):
    """Asynchronous 1F1B (the paper): no flush, 100% utilization at steady state."""
    t_stage = t_layer * L / P + c
    return PipelineTiming(M * t_stage, 0.0, 1.0)


def relative_slowdown(P: int, base_P: int, M: int, L: int, kind: str, **kw) -> float:
    """Iteration-time ratio vs the base_P-stage run (paper Fig. 5's x-axis)."""
    f = {"gpipe": gpipe_timing, "sync1f1b": onef_oneb_sync_timing,
         "async": async_timing}[kind]
    return f(P, M, L, **kw).iter_time / f(base_P, M, L, **kw).iter_time


def straggler_effective_delay(taus: tuple, slow_stage: int, slow_factor: float) -> tuple:
    """A stage running slow_factor x slower in async PP does not stall peers — it
    *adds delay*: microbatches queue, so its own tau (and its upstreams') grow by
    roughly the extra in-flight count. Returns adjusted taus (straggler model used
    by EngineCfg.straggler_delays + ft.loop.adaptive_gamma)."""
    extra = max(0, int(round((slow_factor - 1.0) * (len(taus) - slow_stage))))
    return tuple(t + extra if i <= slow_stage else t for i, t in enumerate(taus))
