"""Event-driven asynchronous pipeline runtime (discrete-event execution).

The jit engine (`core/engine.py`) replays the paper's *fixed* 1F1B staleness
schedule tau_i = floor((2(P-i)+1)/2K) inside one compiled program. This module
executes the pipeline the way a real deployment runs it:

- per-stage workers with activation/cotangent mailboxes (`core/events.Mailbox`)
  driven by a wall-clock event queue,
- compute/communication latencies sampled from a `DelayModel`
  (fixed | jitter | straggler | trace-replay),
- in-order 1F1B scheduling with backward priority and per-stage in-flight
  capacity P - s (microbatch units),
- per-microbatch weight stashing (a dict keyed by microbatch id — the
  real-system analogue of the engine's ring buffer; its peak size IS the
  max observed delay + 1),
- first-class membership churn: `RuntimeCfg.churn` schedules leave/join
  windows (`events.ChurnModel`); a dead stage stops dispatching while its
  mailboxes keep buffering, upstream caps turn elastic so the pipe keeps
  forwarding, and the rejoined worker replays its backlog from its own live
  params — the outage is paid in stash/mailbox memory and observed tau, not
  in a drain barrier (DESIGN.md §9), and
- the *observed* staleness of every update fed back into the method
  (`AsyncTrainer._stage_update` with a live tau), so lr discounting, PipeMare
  prediction, gradient forecasting, and delay-keyed momentum react to
  stragglers and jitter instead of assuming the closed-form schedule —
  whether a method consumes that live value or pins the static Eq. 5 schedule
  is its `tau_source` axis (core/methods.py, DESIGN.md §10), and
- optional latency calibration (`RuntimeCfg.record_trace`): host wall-clock
  timing around every stage's jitted fwd/bwd dispatch collected into an
  `events.TraceRecorder`, exported as TraceDelay JSON so later simulations
  replay measured rather than synthetic distributions (DESIGN.md §10).

Under a uniform `FixedDelay` model and K=1 the discipline reproduces the
closed-form schedule exactly, so the runtime matches `AsyncTrainer`
tick-for-tick (tests/test_runtime.py) — every paper result transfers to the
event-driven execution path. `simulate_schedule` is the compute-free twin used
for schedule dry-runs (launch/dryrun.py --sim-schedule) and benchmarks.

Checkpointing: `export_state()` packs the runtime into an engine-compatible
`AsyncState` (stashes re-warmed from the live forward point, runtime counters
under a per-stage `extra["rt"]` dict), so `checkpoint.save/restore` round-trips
and a run can resume under either execution path (staleness history resets on
the switch, like `checkpoint.restage`).
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import sanitize
from repro.core import events, staged
from repro.core import faults as faults_mod
from repro.core import stash as stash_mod
from repro.core.engine import AsyncState, AsyncTrainer


@dataclasses.dataclass
class RuntimeCfg:
    # None -> events.FixedDelay(); or any events.DelayModel / spec string
    delay_model: Optional[object] = None
    # per-stage in-flight microbatch capacity: None -> 1F1B (P - s; 1 for sync
    # methods). An int or tuple raises the buffer bound — elastic mailboxes let
    # observed delays GROW behind a straggler instead of stalling the pipe.
    in_flight: Optional[object] = None
    # None -> always-alive stages; or an events.ChurnModel / spec string
    # scheduling leave/join windows on the simulated clock (DESIGN.md §9).
    churn: Optional[object] = None
    record_timeline: bool = False
    # Measure real per-op latencies: host wall-clock around every stage's
    # jitted fwd/bwd dispatch (block_until_ready'd), collected into an
    # events.TraceRecorder for TraceDelay JSON export — the calibration hook
    # behind `launch/train.py --record-trace` (docs/cli.md, DESIGN.md §10).
    # Each timed op forces a device sync, so leave off unless calibrating.
    record_trace: bool = False
    seed: int = 0  # forwarded to spec-string delay models and fault models
    # None -> no fault injection; or a faults.FaultModel / spec string
    # ("nan_grad=0.01,drop=0.005,crash=2@40", docs/cli.md). An empty model is
    # treated exactly like None — the bitwise no-op contract (DESIGN.md §11).
    faults: Optional[object] = None
    # Message-drop recovery (only consulted when `faults` injects drops):
    # retransmit after retry_timeout * 2^attempt simulated units; at
    # escalate_after consecutive drops the destination is presumed hung and a
    # leave/join outage is synthesized (PR 4's degradation path); a message
    # dropped more than max_retries times raises instead of spinning forever.
    retry_timeout: float = 4.0
    escalate_after: int = 3
    max_retries: int = 16


class _TauGroup:
    """K-group accumulator for per-microbatch observed delays — the ONE shared
    helper behind the full runtime's and simulate_schedule's update boundaries
    (they used to hand-roll this separately). `add` records one backward's
    observed tau; when the K-th lands, `take` emits the completed group as a
    tuple (microbatch order) for lossless feedback/reporting — the per-update
    mean is derived from it, not the other way around."""

    __slots__ = ("K", "cur")

    def __init__(self, K: int):
        self.K = K
        self.cur = []

    def add(self, tau) -> bool:
        """Record one observed tau; True when the group is complete."""
        self.cur.append(float(tau))
        return len(self.cur) == self.K

    def take(self) -> tuple:
        group = tuple(self.cur)
        self.cur = []
        return group

    def __len__(self):
        return len(self.cur)


@dataclasses.dataclass
class RuntimeResult:
    losses: list  # per tick (mean over the K microbatches of the update)
    metrics: list  # per tick: {"loss", "lr", "tau_obs", "tau_group"}
    taus: list  # per tick: tuple of per-stage observed delays (update units;
    #             the K-group MEAN at K > 1 — fractional, legacy reporting)
    tau_groups: list  # per tick: tuple of per-stage length-K tuples — every
    #             microbatch's observed delay, lossless. Feed a row (as an
    #             int32 [P, K] array) to AsyncTrainer.step(..., taus=...) to
    #             replay this tick's staleness per microbatch.
    makespan: float  # simulated wall-clock of this run() call
    utilization: tuple  # per-stage busy fraction of the makespan
    max_stash: tuple  # per-stage peak stash entries (== max observed tau + 1)
    max_tau_obs: tuple  # per-stage peak observed delay
    # per-stage simulated time spent left (churn outages) during this run()
    outage_time: tuple = ()
    # per-stage (fwd, bwd) peak buffered microbatches since init — mailbox
    # memory pressure; bounded by the in-flight caps of the neighbour stages
    # (stage 0's fwd box is the preloaded data source, not a transport buffer)
    mailbox_high_water: tuple = ()
    # fault-recovery observability (all zero on a fault-free run):
    # per-stage updates skipped by the non-finite quarantine during this run()
    nonfinite_skipped: tuple = ()
    retransmits: int = 0  # messages re-sent after an injected drop
    duplicates: int = 0  # injected duplicate deliveries absorbed by Mailboxes
    escalations: int = 0  # hung-stage leave/join outages synthesized
    timeline: Optional[list] = None  # (stage, op, mb, start, end) if recorded


_SEED_CT = object()  # last stage's backward seeds its own cotangent


def _poison_tree(tree, value: float):
    """Overwrite every inexact leaf with `value` (NaN/Inf payload corruption);
    integer leaves (token ids, counters) pass through untouched."""
    return jax.tree.map(
        lambda x: (jnp.full_like(x, value)
                   if jnp.issubdtype(jnp.asarray(x).dtype, jnp.inexact) else x),
        tree)


class _StageWorker:
    def __init__(self, idx, params, opt_state, extra, fwd_point, n_updates, K=1,
                 dedupe=False):
        self.idx = idx
        self.params = params
        self.opt = opt_state
        self.extra = extra
        self.fwd_point = fwd_point  # latest stashed forward point
        self.stash = {}  # mb -> (W_used, tau_obs): PipeDream stash, dict form
        self.carries = {}  # mb -> input carry (VJP linearization point)
        self.fwd_box = events.Mailbox(dedupe=dedupe)
        self.bwd_box = events.Mailbox(dedupe=dedupe)
        self.next_fwd = 0  # overwritten by the runtime (global mb index)
        self.next_bwd = 0
        self.n_updates = n_updates  # global update count (== engine tick)
        self.acc = None  # gradient accumulator (K > 1)
        self.acc_n = 0
        self.acc_tau = _TauGroup(K)  # per-microbatch observed delays of the group
        self.last_tau_group = (0.0,) * K  # most recent completed group
        self.busy_until = 0.0
        self.busy_time = 0.0
        self.max_stash = 0
        self.max_tau = 0.0
        # membership lifecycle (churn): a dead worker stops dispatching but its
        # mailboxes keep buffering; params/stash/carries persist across the
        # outage — nothing restages, the backlog replays on join
        self.alive = True
        self.left_at = 0.0
        self.outage_time = 0.0

    @property
    def in_flight(self):
        return self.next_fwd - self.next_bwd


class EventRuntime:
    """Drives an AsyncTrainer's stages through the discrete-event loop.

    The trainer supplies the math (stage fns, optimizer, method semantics via
    `_stage_update`/`_bwd_weights`); the runtime supplies the execution order.
    """

    def __init__(self, trainer: AsyncTrainer, rcfg: Optional[RuntimeCfg] = None):
        self.trainer = trainer
        self.rcfg = rcfg or RuntimeCfg()
        self.dm = events.make_delay_model(self.rcfg.delay_model, seed=self.rcfg.seed)
        self.P = trainer.P
        self.K = trainer.ecfg.update_interval
        self.caps = self._resolve_caps()
        self.recorder = (events.TraceRecorder(self.P, self.K)
                         if self.rcfg.record_trace else None)
        self.churn = (events.make_churn_model(self.rcfg.churn).validate(self.P)
                      if self.rcfg.churn is not None else None)
        # fault model: an empty model is normalized to None so the fault-free
        # path never consults it — the bitwise no-op contract (DESIGN.md §11)
        fm = faults_mod.make_fault_model(self.rcfg.faults, seed=self.rcfg.seed)
        self.fm = fm if fm is not None and not fm.is_empty else None
        if self.fm is not None and self.fm.crashes:
            # mid-tick worker crashes ride the churn leave/join machinery:
            # materialize the keyed crash plan as extra outage windows
            crash = self.fm.crash_outages(self.P)
            self.churn = (events.ChurnModel(crash) if self.churn is None else
                          dataclasses.replace(
                              self.churn,
                              outages=self.churn.outages + crash)).validate(self.P)
        self._dead = set()  # stages currently left (membership view)
        self._churn_fired = set()  # outage indices already scheduled
        self._quarantined = set()  # stages under a synthesized hang outage
        self._retransmits = 0
        self._escalations = 0
        self._stages = None
        self._clock = 0.0
        self._u_done = 0

    def _cap(self, s: int) -> float:
        """Effective in-flight capacity of stage s. While any stage downstream
        of s is dead, s's cap is raised by the churn slack (None = unbounded):
        upstream keeps forwarding through the outage, paying it in stash and
        mailbox memory — and observed tau — instead of a barrier."""
        if self._dead and any(j > s for j in self._dead):
            # no churn model (a faults-escalation synthesized this leave) ==
            # unbounded slack: nothing configured a memory bound for the outage
            if self.churn is None or self.churn.slack is None:
                return float("inf")
            return self.caps[s] + self.churn.slack
        return self.caps[s]

    def _resolve_caps(self) -> tuple:
        P = self.P
        if self.rcfg.in_flight is not None:
            c = self.rcfg.in_flight
            caps = tuple(int(x) for x in (c if isinstance(c, (tuple, list)) else (c,) * P))
            if len(caps) != P or any(x < 1 for x in caps):
                raise ValueError(f"in_flight must be {P} positive entries, got {caps}")
            return caps
        if self.trainer.method.sync:
            return (1,) * P  # global barrier: one microbatch in the pipe
        return tuple(P - s for s in range(P))  # 1F1B steady-state buffers

    # -- state ----------------------------------------------------------------

    def init(self, key) -> "EventRuntime":
        return self.init_from_state(self.trainer.init(key))

    def init_from_params(self, params) -> "EventRuntime":
        return self.init_from_state(self.trainer.init_from_params(params))

    def init_from_state(self, state: AsyncState) -> "EventRuntime":
        """Adopt a drained AsyncState (fresh init or restored checkpoint)."""
        if not hasattr(self.trainer, "stage_fns"):
            raise RuntimeError(
                "trainer has no stage fns; build the state via runtime.init / "
                "init_from_params, or call trainer.init first when restoring")
        t = int(state.step)
        self._u_done = t
        self._stages = []
        # update-boundary aggregation state persists ACROSS run() calls: a
        # partial K-group rolled past a drain boundary (e.g. by a churn outage)
        # is carried here and emitted by the run() that completes it, instead
        # of KeyError-ing the drain-boundary loss grouping
        self._losses = {}
        self._taus_by_u = {}
        self._tau_groups_by_u = {}
        for i in range(self.P):
            extra = dict(state.extra[i])
            rt = extra.pop("rt", None)
            if rt is not None:
                self._clock = float(rt["clock"])
            # the engine pushes the tick-t forward point at slot t: that is the
            # newest stash entry, i.e. the live forward point of this worker
            fp = stash_mod.get(state.stashes[i], jnp.asarray(t, jnp.int32), 0,
                               like=state.params[i])
            st = _StageWorker(i, state.params[i], state.opt[i], extra, fp, t,
                              K=self.K,
                              dedupe=self.fm is not None and self.fm.dup > 0)
            if rt is not None and "last_tau_group" in rt:
                st.last_tau_group = tuple(
                    float(x) for x in np.asarray(rt["last_tau_group"]).reshape(-1))
            st.next_fwd = st.next_bwd = t * self.K
            self._stages.append(st)
        self._build_jits()
        return self

    def reset_recorder(self) -> events.TraceRecorder:
        """Discard everything recorded so far as compile warmup (record_trace
        mode only). Call after a one-tick warmup chunk so compile-inflated
        first-dispatch samples never reach a saved trace — the calibration
        invariant every recording caller (launch/train.py,
        benchmarks/runtime_bench.py) relies on (§10). Microbatch-aware: the
        recorder keeps its identity and ignores any late sample for a
        pre-boundary microbatch by INDEX (whole K-groups), so at K > 1 a
        warmup group's straggling backward cannot leak into the trace
        (events.TraceRecorder.discard_warmup)."""
        if self.recorder is None:
            raise RuntimeError("reset_recorder requires RuntimeCfg.record_trace")
        self.recorder.discard_warmup()
        return self.recorder

    def export_state(self, include_runtime: bool = True) -> AsyncState:
        """Engine-compatible AsyncState (pipeline must be drained). Stashes are
        re-warmed from the live forward point — staleness history resets, the
        same documented behaviour as checkpoint.restage on elastic events."""
        for st in self._stages:
            if (st.in_flight or st.stash or st.carries or st.acc_n
                    or len(st.fwd_box) or len(st.bwd_box) or not st.alive):
                raise RuntimeError("export_state requires a drained pipeline")
        params, stashes, opts, extras = [], [], [], []
        for i, st in enumerate(self._stages):
            params.append(st.params)
            buf = stash_mod.init_stash(st.fwd_point, self.trainer._stash_depth(i),
                                       dtype=self.trainer.ecfg.stash_dtype)
            stashes.append(buf)
            opts.append(st.opt)
            e = dict(st.extra)
            if include_runtime:
                e["rt"] = {"n_updates": jnp.asarray(st.n_updates, jnp.int32),
                           "max_tau_obs": jnp.asarray(st.max_tau, jnp.float32),
                           "clock": jnp.asarray(self._clock, jnp.float32),
                           # the last update's K per-microbatch observed delays
                           # (lossless provenance for the [P, K] dynamic path)
                           "last_tau_group": jnp.asarray(st.last_tau_group,
                                                         jnp.float32)}
                if "nonfinite_skipped" in st.extra:
                    # quarantine provenance rides along with the runtime
                    # counters (the live counter itself lives in extra proper,
                    # where the engine's _stage_update maintains it)
                    e["rt"]["nonfinite_skipped"] = jnp.asarray(
                        st.extra["nonfinite_skipped"], jnp.int32)
            extras.append(e)
        return AsyncState(jnp.asarray(self._u_done, jnp.int32), tuple(params),
                          tuple(stashes), tuple(opts), tuple(extras))

    # -- jitted per-stage kernels ---------------------------------------------

    def _build_jits(self):
        fns = self.trainer.stage_fns
        tr = self.trainer

        def mk_fwd(f):
            return jax.jit(lambda w, c, b: f(w, c, b))

        def mk_bwd_mid(f):
            def bwd(w, c, b, ct):
                _, vjp = jax.vjp(lambda w_, c_: f(w_, c_, b), w, c)
                gW, ct_in = vjp(ct)
                return gW, ct_in

            return jax.jit(bwd)

        def mk_bwd_last(f):
            def bwd(w, c, b):
                out, vjp = jax.vjp(lambda w_, c_: f(w_, c_, b), w, c)
                gW, ct_in = vjp(staged._loss_seed(out))
                return gW, ct_in

            return jax.jit(bwd)

        def mk_upd(s):
            def upd(params, grads, opt_state, extra, tau, t, W_stale):
                return tr._stage_update(s, params, grads, opt_state, extra,
                                        tau, t, W_stale=W_stale)

            return jax.jit(upd)

        self._fwd = [mk_fwd(f) for f in fns]
        self._bwd_mid = [mk_bwd_mid(f) for f in fns]
        self._bwd_last = mk_bwd_last(fns[-1])
        self._upd = [mk_upd(s) for s in range(self.P)]

    # -- microbatch plumbing ---------------------------------------------------

    def _mb_batch(self, g: int):
        u = g // self.K
        ent = self._tick_batches.get(u)
        if ent is None:
            b = self._batch_fn(u)
            slices = [jax.tree.map(lambda x: x[k], b) for k in range(self.K)]
            ent = self._tick_batches[u] = [slices, self.K]
        return ent[0][g - u * self.K]

    def _release(self, g: int):
        u = g // self.K
        ent = self._tick_batches.get(u)
        if ent is not None:
            ent[1] -= 1
            if ent[1] <= 0:
                del self._tick_batches[u]

    # -- fault-aware transport -------------------------------------------------

    def _nonfinite_host(self) -> tuple:
        """Per-stage quarantine counters (host ints). Zero for states restored
        from pre-quarantine checkpoints that lack the counter."""
        vals = [st.extra.get("nonfinite_skipped") for st in self._stages]
        if any(v is None for v in vals):
            return (0,) * self.P
        return tuple(int(x) for x in jax.device_get(vals))

    def _send(self, q, t, kind, dst, g, payload, attempt=0):
        """Cross-stage message hand-off through the fault model. With no fault
        model (or none touching messages) this is exactly `q.push` — the
        fault-free event order is untouched. An injected drop never loses the
        message: it is retransmitted after an exponential backoff ("retry"
        event), keeping simulated time flowing so the loop cannot deadlock;
        at `escalate_after` consecutive drops the destination is presumed hung
        and a leave/join outage is synthesized around the retransmit horizon —
        the bounded-wait escalation that degrades a dead transport into PR 4's
        churn path (DESIGN.md §11)."""
        fm = self.fm
        if fm is None or not fm.affects_messages:
            q.push(t, kind, dst, g, payload)
            return
        op = "bwd" if kind == "bwd_arrive" else "fwd"
        if fm.drop_hit(op, dst, g, attempt):
            nxt = attempt + 1
            if nxt > self.rcfg.max_retries:
                raise RuntimeError(
                    f"message {op}:{g} -> stage {dst} dropped {nxt} times "
                    f"(max_retries={self.rcfg.max_retries})")
            backoff = self.rcfg.retry_timeout * (2.0 ** attempt)
            q.push(t + backoff, "retry", dst, g, payload=(kind, payload, nxt))
            self._retransmits += 1
            if (nxt == self.rcfg.escalate_after
                    and dst not in self._quarantined
                    and self._stages[dst].alive):
                self._escalations += 1
                self._quarantined.add(dst)
                q.push(t, "leave", dst)
                q.push(t + backoff, "join", dst)
            return
        q.push(t, kind, dst, g, payload)
        if fm.dup_hit(op, dst, g):
            q.push(t, kind, dst, g, payload)  # Mailbox dedupes + counts

    # -- the event loop --------------------------------------------------------

    def run(self, batch_fn: Callable[[int], dict], n_ticks: int) -> RuntimeResult:
        """Process n_ticks update intervals (n_ticks * K microbatches) through
        completion. batch_fn(t) returns the engine-shaped per-tick batch with a
        leading [K, ...] microbatch axis, so the two execution paths share data
        pipelines. The pipeline drains before returning."""
        if self._stages is None:
            raise RuntimeError("call init/init_from_params/init_from_state first")
        # REPRO_SANITIZE=1: debug_nans/enable_checks plus fail-fast on
        # quarantined updates at the end of this run (docs/lint.md)
        sanitize.apply()
        P, K = self.P, self.K
        self._batch_fn = batch_fn
        self._tick_batches = {}
        # NOTE: _losses/_taus_by_u/_tau_groups_by_u are NOT reset here — they
        # carry partial K-groups across run() calls (init_from_state owns them)
        self._timeline = [] if self.rcfg.record_timeline else None
        u0 = self._u_done
        g_end = (u0 + n_ticks) * K
        t_start = self._clock
        busy0 = [st.busy_time for st in self._stages]
        out0 = [st.outage_time for st in self._stages]
        nf0 = self._nonfinite_host()
        ret0, esc0 = self._retransmits, self._escalations
        dup0 = sum(st.fwd_box.duplicates + st.bwd_box.duplicates
                   for st in self._stages)

        q = events.EventQueue()
        src = self._stages[0]
        for g in range(u0 * K, g_end):
            src.fwd_box.put(g, None)  # stage-0 input carry is synthesized fresh
        # schedule churn windows that have not yet elapsed on the simulated
        # clock; a window straddling this run's natural end simply delays the
        # drain until its join fires (joins are always scheduled — see Outage)
        pushed_outages, fired_leaves = {}, set()
        if self.churn is not None:
            for idx, o in enumerate(self.churn.outages):
                if idx in self._churn_fired:
                    continue
                end = o.start + o.duration
                if end < self._clock:  # already over before this run started
                    self._churn_fired.add(idx)
                    continue
                q.push(max(o.start, self._clock), "leave", o.stage, payload=idx)
                q.push(end, "join", o.stage)
                self._churn_fired.add(idx)
                pushed_outages[idx] = o
        q.push(self._clock, "free", 0)

        def drained_alive():
            return all(st.n_updates == u0 + n_ticks and not st.in_flight
                       and not st.acc_n and st.alive for st in self._stages)

        while q:
            # outage windows beyond this run's work belong to the NEXT run()
            # chunk: once the pipe is drained (and everyone is back), un-fire
            # the outages whose leave never happened and stop
            if pushed_outages and q.only_membership() and drained_alive():
                for idx in set(pushed_outages) - fired_leaves:
                    self._churn_fired.discard(idx)
                break
            batch_evs = q.pop_batch()
            now = batch_evs[0].time
            touched = set()
            for ev in batch_evs:
                st = self._stages[ev.stage]
                if ev.kind == "fwd_arrive":
                    st.fwd_box.put(ev.mb, ev.payload)
                elif ev.kind == "bwd_arrive":
                    st.bwd_box.put(ev.mb, ev.payload)
                elif ev.kind == "retry":
                    # retransmit a dropped message (fault injection): re-route
                    # through _send so a repeat drop backs off / escalates
                    kind2, payload2, attempt = ev.payload
                    self._send(q, now, kind2, ev.stage, ev.mb, payload2,
                               attempt=attempt)
                elif ev.kind == "leave":
                    if ev.payload is not None:
                        fired_leaves.add(ev.payload)
                    # guard: a synthesized hang-escalation leave may race a
                    # churn window on the same stage — a dead worker stays dead
                    if st.alive:
                        st.alive = False
                        st.left_at = now
                        self._dead.add(ev.stage)
                        # upstream caps just turned elastic: stages idling at
                        # their old capacity get no further events (no
                        # cotangents flow through a dead stage), so
                        # re-dispatch them here
                        touched.update(range(ev.stage))
                        if self._timeline is not None:
                            self._timeline.append(
                                (ev.stage, "leave", -1, now, now))
                elif ev.kind == "join":
                    # re-adopt the live params: the worker resumes from its own
                    # weights — nothing restages, the buffered backlog replays
                    # and the inflated observed tau flows through _stage_update
                    self._quarantined.discard(ev.stage)
                    if not st.alive:
                        st.alive = True
                        st.outage_time += now - st.left_at
                        st.busy_until = max(st.busy_until, now)
                        self._dead.discard(ev.stage)
                        if self._timeline is not None:
                            self._timeline.append(
                                (ev.stage, "join", -1, now, now))
                touched.add(ev.stage)
            for s in sorted(touched):
                self._dispatch(s, now, q, g_end)
        self._clock = max(self._clock, max(st.busy_until for st in self._stages))

        for st in self._stages:
            if (st.n_updates != u0 + n_ticks or st.in_flight or st.acc_n
                    or st.stash or st.carries or len(st.fwd_box)
                    or len(st.bwd_box) or not st.alive):
                raise RuntimeError(
                    f"stage {st.idx} ended at update {st.n_updates} with "
                    f"{st.in_flight} in flight, {len(st.stash)} stashed, "
                    f"{len(st.carries)} carries, {len(st.fwd_box)}/"
                    f"{len(st.bwd_box)} boxed, alive={st.alive} "
                    f"(expected {u0 + n_ticks}, all empty): "
                    "event loop did not drain")
        self._u_done = u0 + n_ticks

        # one host transfer for the whole run: losses stayed on device inside
        # the event loop (a per-event float() would serialize the loop on D2H)
        loss_host = {g: float(v) for g, v in
                     zip(self._losses, jax.device_get(list(self._losses.values())))}
        lr_host = np.broadcast_to(np.asarray(jax.device_get(
            self.trainer.lr_sched(jnp.arange(u0, u0 + n_ticks))), np.float32),
            (n_ticks,))  # constant() returns a scalar for any t
        losses, metrics, taus, tau_groups = [], [], [], []
        for u in range(u0, u0 + n_ticks):
            # pop-on-emit: anything this run() did not complete (a partial
            # K-group carried past the drain) stays held for the next chunk
            group = [loss_host[g] for g in range(u * K, (u + 1) * K)]
            for g in range(u * K, (u + 1) * K):
                self._losses.pop(g, None)
            loss_u = float(np.mean(group))
            tau_u = tuple(self._taus_by_u.pop(u))
            tau_grp = tuple(self._tau_groups_by_u.pop(u))
            losses.append(loss_u)
            taus.append(tau_u)
            tau_groups.append(tau_grp)
            metrics.append({"loss": loss_u, "lr": float(lr_host[u - u0]),
                            "tau_obs": tau_u, "tau_group": tau_grp})
        span = self._clock - t_start
        util = tuple((st.busy_time - b0) / span if span > 0 else 0.0
                     for st, b0 in zip(self._stages, busy0))
        nonfinite_delta = tuple(
            a - b for a, b in zip(self._nonfinite_host(), nf0))
        if sanitize.enabled():
            # sanitizer contract (DESIGN.md §12): the engine's non-finite
            # quarantine may keep a chaos run alive, but it may NOT be silent
            # under sanitize — a poisoned gradient is an error, not a counter.
            if any(nonfinite_delta):
                raise FloatingPointError(
                    f"sanitize: {sum(nonfinite_delta)} non-finite update(s) "
                    f"quarantined (per-stage {nonfinite_delta}) — injected or "
                    "real NaN/Inf gradients are hard errors under "
                    f"{sanitize.ENV_VAR}=1")
            bad = [(u, v) for u, v in zip(range(u0, u0 + n_ticks), losses)
                   if not math.isfinite(v)]
            if bad:
                raise FloatingPointError(
                    f"sanitize: non-finite loss(es) at update(s) {bad}")
        return RuntimeResult(
            losses=losses, metrics=metrics, taus=taus, tau_groups=tau_groups,
            makespan=span,
            utilization=util,
            max_stash=tuple(st.max_stash for st in self._stages),
            max_tau_obs=tuple(st.max_tau for st in self._stages),
            outage_time=tuple(st.outage_time - o0
                              for st, o0 in zip(self._stages, out0)),
            mailbox_high_water=tuple(
                (st.fwd_box.high_water, st.bwd_box.high_water)
                for st in self._stages),
            nonfinite_skipped=nonfinite_delta,
            retransmits=self._retransmits - ret0,
            duplicates=sum(st.fwd_box.duplicates + st.bwd_box.duplicates
                           for st in self._stages) - dup0,
            escalations=self._escalations - esc0,
            timeline=self._timeline)

    def _dispatch(self, s: int, now: float, q: events.EventQueue, g_end: int):
        st = self._stages[s]
        if not st.alive or st.busy_until > now:
            return
        tr = self.trainer
        # 1) backward priority, strictly in microbatch order
        g = st.next_bwd
        if st.bwd_box.ready(g):
            ct = st.bwd_box.take(g)
            W_used, tau_g = st.stash.pop(g)
            carry_in = st.carries.pop(g)
            b = self._mb_batch(g)
            Wb = (W_used if tr.method.bwd_point == "stash"
                  else tr._bwd_weights(s, st.params, st.extra, W_used, float(tau_g)))
            t_host = time.perf_counter() if self.recorder is not None else 0.0
            if s == self.P - 1:
                gW, ct_in = self._bwd_last(Wb, carry_in, b)
            else:
                gW, ct_in = self._bwd_mid[s](Wb, carry_in, b, ct)
            if self.recorder is not None:
                jax.block_until_ready((gW, ct_in))
                self.recorder.add(s, "bwd", g, time.perf_counter() - t_host)
            if self.fm is not None and self.fm.hit("nan_grad", s, g):
                # payload corruption: this stage's grads AND the outgoing
                # cotangent go non-finite — every stage the poison reaches
                # quarantines its update (engine._stage_update isfinite guard)
                bad = self.fm.poison_value(s, g)
                gW = _poison_tree(gW, bad)
                ct_in = _poison_tree(ct_in, bad)
            st.next_bwd += 1
            # accumulate exactly like staged.grad_accum: K == 1 passes grads
            # through untouched; K > 1 casts to f32, sums in order, scales 1/K
            if self.K == 1:
                grads, ready = gW, True
            else:
                if st.acc is None:
                    st.acc = jax.tree.map(lambda x: x.astype(jnp.float32), gW)
                else:
                    st.acc = jax.tree.map(lambda a, x: a + x.astype(a.dtype),
                                          st.acc, gW)
                st.acc_n += 1
                ready = st.acc_n == self.K
                grads = (jax.tree.map(lambda a: a * (1.0 / self.K), st.acc)
                         if ready else None)
            st.acc_tau.add(tau_g)
            if ready:
                u = st.n_updates
                group = st.acc_tau.take()  # the K per-microbatch observed taus
                st.last_tau_group = group
                tau_u = float(np.mean(group))
                # K > 1 feeds the method the WHOLE group ([K] f32): the update
                # collapses it via its explicit Method.tau_reduce — the same
                # reduction the engine applies to a [P, K] matrix row, so the
                # two paths' correction math agrees bit-for-bit. K == 1 keeps
                # the scalar signature (identical pre-group compiled program).
                tau_arg = (jnp.asarray(group, jnp.float32) if self.K > 1
                           else jnp.asarray(tau_u, jnp.float32))
                np_, no_, ne_, fp_, _aux = self._upd[s](
                    st.params, grads, st.opt, st.extra,
                    tau_arg, jnp.asarray(u, jnp.int32),
                    W_used)
                st.params, st.opt, st.extra, st.fwd_point = np_, no_, dict(ne_), fp_
                st.n_updates = u + 1
                st.acc, st.acc_n = None, 0
                self._taus_by_u.setdefault(u, [0.0] * self.P)[s] = tau_u
                self._tau_groups_by_u.setdefault(
                    u, [(0.0,) * self.K] * self.P)[s] = group
            lat = self.dm.latency(s, "bwd", g)
            done = now + lat
            st.busy_until = done
            st.busy_time += lat
            q.push(done, "free", s)
            if s > 0:
                self._send(q, done + self.dm.latency(s, "comm_bwd", g),
                           "bwd_arrive", s - 1, g, ct_in)
            else:
                self._release(g)
            if self._timeline is not None:
                self._timeline.append((s, "bwd", g, now, done))
            return
        # 2) forward: next expected microbatch, gated by in-flight capacity
        # (elastic during an outage downstream — see _cap)
        g = st.next_fwd
        if g < g_end and st.fwd_box.ready(g) and st.in_flight < self._cap(s):
            item = st.fwd_box.take(g)
            carry_in = staged.init_carry() if s == 0 else item
            b = self._mb_batch(g)
            W = st.params if tr.method.sync else st.fwd_point
            tau_g = g // self.K - st.n_updates  # observed staleness, update units
            t_host = time.perf_counter() if self.recorder is not None else 0.0
            carry_out = self._fwd[s](W, carry_in, b)
            if self.recorder is not None:
                jax.block_until_ready(carry_out)
                self.recorder.add(s, "fwd", g, time.perf_counter() - t_host)
            if self.fm is not None and self.fm.hit("nan_act", s, g):
                # activation corruption: downstream forwards (and the loss, if
                # this is the last stage) go non-finite; the backward from the
                # poisoned carry produces non-finite grads -> quarantined
                carry_out = _poison_tree(carry_out, self.fm.poison_value(s, g))
            st.stash[g] = (W, tau_g)
            st.carries[g] = carry_in
            st.max_stash = max(st.max_stash, len(st.stash))
            st.max_tau = max(st.max_tau, float(tau_g))
            st.next_fwd += 1
            lat = self.dm.latency(s, "fwd", g)
            done = now + lat
            st.busy_until = done
            st.busy_time += lat
            q.push(done, "free", s)
            if s < self.P - 1:
                self._send(q, done + self.dm.latency(s, "comm_fwd", g),
                           "fwd_arrive", s + 1, g, carry_out)
            else:
                # keep the loss on device — run() gathers them all in ONE
                # device_get at the drain boundary (a float() here would block
                # the event loop on a host transfer every last-stage forward)
                self._losses[g] = carry_out["loss"]
                q.push(done, "bwd_arrive", s, g, _SEED_CT)
            if self._timeline is not None:
                self._timeline.append((s, "fwd", g, now, done))


# ---------------------------------------------------------------------------
# compute-free schedule simulation (dryrun / capacity planning)
# ---------------------------------------------------------------------------


def simulate_schedule(P: int, K: int = 1, n_ticks: int = 50, delay_model=None,
                      in_flight=None, sync: bool = False, seed: int = 0,
                      churn=None, faults=None, retry_timeout: float = 4.0,
                      escalate_after: int = 3, max_retries: int = 16) -> dict:
    """Run the runtime's 1F1B event discipline with no tensor math: returns
    {"makespan", "utilization", "taus" (per-update per-stage observed means),
    "tau_groups" (per-update per-stage length-K per-microbatch groups),
    "max_tau_obs", "max_stash", "outage_time", "mailbox_high_water"}. Same
    capacity, priority, and membership (churn) rules as EventRuntime, so its
    fixed-delay taus equal core/delay.stage_delays and its churn schedules
    match the full runtime event for event (asserted in tests/test_runtime.py);
    used by `launch/dryrun.py --sim-schedule` to estimate straggler / jitter /
    outage throughput without compiling a model. `faults` mirrors the schedule-
    affecting half of `RuntimeCfg.faults` — message drops (retransmit/backoff/
    hang escalation, same keyed draws as the full runtime, so the two schedules
    match event for event) and crashes (merged into churn); nan/dup rates do
    not move the schedule, so the twin stays valid under them too. Adds
    {"retransmits", "escalations"} to the returned dict."""
    dm = events.make_delay_model(delay_model, seed=seed)
    cm = events.make_churn_model(churn).validate(P) if churn is not None else None
    fm = faults_mod.make_fault_model(faults, seed=seed)
    fm = fm if fm is not None and not fm.is_empty else None
    if fm is not None and fm.crashes:
        crash = fm.crash_outages(P)
        cm = (events.ChurnModel(crash) if cm is None else
              dataclasses.replace(cm, outages=cm.outages + crash)).validate(P)
    if in_flight is not None:
        caps = tuple(int(x) for x in (in_flight if isinstance(in_flight, (tuple, list))
                                      else (in_flight,) * P))
    else:
        caps = (1,) * P if sync else tuple(P - s for s in range(P))
    g_end = n_ticks * K
    dead = set()

    def eff_cap(s):
        if dead and any(j > s for j in dead):
            return (float("inf") if cm is None or cm.slack is None
                    else caps[s] + cm.slack)
        return caps[s]

    class _S:
        __slots__ = ("next_fwd", "next_bwd", "n_updates", "busy_until",
                     "busy_time", "fwd_box", "bwd_box", "stash", "acc_tau",
                     "max_stash", "max_tau", "alive", "left_at", "outage_time")

        def __init__(self):
            self.next_fwd = self.next_bwd = self.n_updates = 0
            self.busy_until = self.busy_time = 0.0
            dd = fm is not None and fm.dup > 0
            self.fwd_box, self.bwd_box = (events.Mailbox(dedupe=dd),
                                          events.Mailbox(dedupe=dd))
            self.stash = set()
            self.acc_tau = _TauGroup(K)  # same K-group helper as EventRuntime
            self.max_stash, self.max_tau = 0, 0.0
            self.alive, self.left_at, self.outage_time = True, 0.0, 0.0

    stages = [_S() for _ in range(P)]
    taus_by_u = {}
    tau_groups_by_u = {}
    q = events.EventQueue()
    tau_of = {}  # (stage, mb) -> observed tau at forward
    for g in range(g_end):
        stages[0].fwd_box.put(g, None)
    if cm is not None:
        for o in cm.outages:
            q.push(o.start, "leave", o.stage)
            q.push(o.start + o.duration, "join", o.stage)
    q.push(0.0, "free", 0)
    counters = {"retransmits": 0, "escalations": 0}
    quarantined = set()

    def send(t, kind, dst, g, attempt=0):
        # same drop/retry/escalation discipline (and keyed draws) as
        # EventRuntime._send, so injected-drop schedules match event for event
        if fm is None or not fm.affects_messages:
            q.push(t, kind, dst, g)
            return
        op = "bwd" if kind == "bwd_arrive" else "fwd"
        if fm.drop_hit(op, dst, g, attempt):
            nxt = attempt + 1
            if nxt > max_retries:
                raise RuntimeError(
                    f"message {op}:{g} -> stage {dst} dropped {nxt} times "
                    f"(max_retries={max_retries})")
            backoff = retry_timeout * (2.0 ** attempt)
            q.push(t + backoff, "retry", dst, g, payload=(kind, nxt))
            counters["retransmits"] += 1
            if (nxt == escalate_after and dst not in quarantined
                    and stages[dst].alive):
                counters["escalations"] += 1
                quarantined.add(dst)
                q.push(t, "leave", dst)
                q.push(t + backoff, "join", dst)
            return
        q.push(t, kind, dst, g)
        if fm.dup_hit(op, dst, g):
            q.push(t, kind, dst, g)

    def dispatch(s, now):
        st = stages[s]
        if not st.alive or st.busy_until > now:
            return
        g = st.next_bwd
        if st.bwd_box.ready(g):
            st.bwd_box.take(g)
            st.stash.discard(g)
            st.next_bwd += 1
            if st.acc_tau.add(tau_of.pop((s, g))):
                group = st.acc_tau.take()
                taus_by_u.setdefault(st.n_updates, [0.0] * P)[s] = float(
                    np.mean(group))
                tau_groups_by_u.setdefault(
                    st.n_updates, [(0.0,) * K] * P)[s] = group
                st.n_updates += 1
            lat = dm.latency(s, "bwd", g)
            st.busy_until = now + lat
            st.busy_time += lat
            q.push(st.busy_until, "free", s)
            if s > 0:
                send(st.busy_until + dm.latency(s, "comm_bwd", g),
                     "bwd_arrive", s - 1, g)
            return
        g = st.next_fwd
        if g < g_end and st.fwd_box.ready(g) and st.next_fwd - st.next_bwd < eff_cap(s):
            st.fwd_box.take(g)
            tau = g // K - st.n_updates
            tau_of[(s, g)] = tau
            st.stash.add(g)
            st.max_stash = max(st.max_stash, len(st.stash))
            st.max_tau = max(st.max_tau, float(tau))
            st.next_fwd += 1
            lat = dm.latency(s, "fwd", g)
            st.busy_until = now + lat
            st.busy_time += lat
            q.push(st.busy_until, "free", s)
            if s < P - 1:
                send(st.busy_until + dm.latency(s, "comm_fwd", g),
                     "fwd_arrive", s + 1, g)
            else:
                q.push(st.busy_until, "bwd_arrive", s, g)

    while q:
        # mirror EventRuntime.run: outages past the drained makespan fire in a
        # later chunk there, so they must not accrue outage time here either
        if q.only_membership() and all(
                st.n_updates == n_ticks and st.next_fwd == st.next_bwd
                and st.alive for st in stages):
            break
        evs = q.pop_batch()
        now = evs[0].time
        touched = set()
        for ev in evs:
            st = stages[ev.stage]
            if ev.kind == "fwd_arrive":
                st.fwd_box.put(ev.mb, None)
            elif ev.kind == "bwd_arrive":
                st.bwd_box.put(ev.mb, None)
            elif ev.kind == "retry":
                kind2, attempt = ev.payload
                send(now, kind2, ev.stage, ev.mb, attempt)
            elif ev.kind == "leave":
                if st.alive:
                    st.alive, st.left_at = False, now
                    dead.add(ev.stage)
                    touched.update(range(ev.stage))  # caps turned elastic
            elif ev.kind == "join":
                quarantined.discard(ev.stage)
                if not st.alive:
                    st.alive = True
                    st.outage_time += now - st.left_at
                    st.busy_until = max(st.busy_until, now)
                    dead.discard(ev.stage)
            touched.add(ev.stage)
        for s in sorted(touched):
            dispatch(s, now)

    makespan = max(st.busy_until for st in stages)
    return {
        "makespan": makespan,
        "utilization": tuple(st.busy_time / makespan if makespan else 0.0
                             for st in stages),
        "taus": [tuple(taus_by_u[u]) for u in range(n_ticks)],
        "tau_groups": [tuple(tau_groups_by_u[u]) for u in range(n_ticks)],
        "max_tau_obs": tuple(st.max_tau for st in stages),
        "max_stash": tuple(st.max_stash for st in stages),
        "outage_time": tuple(st.outage_time for st in stages),
        "mailbox_high_water": tuple(
            (st.fwd_box.high_water, st.bwd_box.high_water) for st in stages),
        "retransmits": counters["retransmits"],
        "escalations": counters["escalations"],
    }


def simulate_mesh_schedule(R: int, P: int, K: int = 1, n_ticks: int = 50, *,
                           period: int = 8, fanout=None, sync_delay=None,
                           delay_models=None, seed: int = 0, in_flight=None,
                           max_stale_rounds: int = 1) -> dict:
    """Compute-free twin of swarm.MeshTrainer.run_gossip: R per-replica
    simulate_schedule chunks stitched by the SAME events.drive_mesh loop, so
    the payload-free mesh event log ("events") matches the full training
    runtime's event for event under identical (delay_models, sync_delay, seed)
    — a pinned contract (tests/test_mesh.py contract c).

    Caveat: each gossip round simulates as a fresh drained chunk, so per-chunk
    microbatch indices restart at 0 here while the full runtime's keep
    counting. The twin is therefore exact for microbatch-independent compute
    delay models (fixed, permanent straggler); mb-windowed models (outage,
    period stragglers, traces) diverge across round boundaries.

    Per-replica delay models follow run_gossip's convention: `delay_models`
    is None (FixedDelay everywhere) or a length-R list of specs/models, each
    seeded with its replica index. Returns the drive_mesh telemetry dict plus
    {"spans": [R][n_rounds] per-round makespans, "utilization": [R] mean
    per-stage utilization of the last round}.
    """
    dms = [events.make_delay_model(
        delay_models[r] if delay_models else None, seed=r) for r in range(R)]
    n_rounds = -(-n_ticks // period)
    spans = [[] for _ in range(R)]
    util = [0.0] * R

    def run_round(r, rnd):
        chunk = min(period, n_ticks - rnd * period)
        sim = simulate_schedule(P, K, chunk, delay_model=dms[r],
                                in_flight=in_flight, seed=r)
        spans[r].append(sim["makespan"])
        util[r] = float(np.mean(sim["utilization"]))
        return sim["makespan"]

    out = events.drive_mesh(R, n_rounds, n_stages=P, fanout=fanout, seed=seed,
                            sync_delay=sync_delay,
                            max_stale_rounds=max_stale_rounds,
                            run_round=run_round)
    out["spans"] = spans
    out["utilization"] = util
    out["n_rounds"] = n_rounds
    return out


def simulate_serve_schedule(requests, *, n_slots: int = 4, page_size: int = 8,
                            n_pages: int = 64, prefill_tok_s: float = 4096.0,
                            decode_step_s: float = 0.02) -> dict:
    """Compute-free twin of launch/serve.ServeEngine: dry-run a traffic trace.

    Prefill and decode are modelled as two disaggregated pipeline roles on one
    event clock — requests (events.Request) enter as microbatch events, the
    prefill worker serves FIFO one prompt at a time (latency prompt_len /
    prefill_tok_s), and the decode worker advances all admitted sequences one
    token per decode_step_s. Admission is the serving in-flight cap: a request
    starts prefill only when a decode slot AND enough free KV pages exist;
    pages return to the pool at retirement. Same discipline as the real engine
    (slots, page reservation, FIFO), so relative numbers — queueing delay,
    page high-water, role utilization — transfer without compiling a model.

    Returns {"makespan", "tok_s", "ttft" (sorted per-request seconds), "tpot"
    (per-request s/token), "utilization" {prefill, decode}, "peak_pages",
    "queue_high_water", "n_requests"}.
    """
    reqs = sorted(requests, key=lambda r: (r.arrival, r.rid))

    def pages_for(r):
        return -(-(r.prompt_len + r.gen_len) // page_size)

    for r in reqs:
        if r.prompt_len < 1 or r.gen_len < 1:
            raise ValueError(f"request {r.rid}: prompt_len/gen_len must be >= 1")
        if pages_for(r) > n_pages:
            raise ValueError(f"request {r.rid} needs {pages_for(r)} pages "
                             f"> pool n_pages={n_pages}")

    q = events.EventQueue()
    for r in reqs:
        q.push(r.arrival, "arrive", 0, r.rid, payload=r)
    waiting: list = []
    active: dict = {}      # rid -> Request
    emitted: dict = {}     # rid -> tokens produced so far
    held_pages: dict = {}  # rid -> pages reserved
    free_slots, free_pages = n_slots, n_pages
    peak_pages = queue_high_water = 0
    prefill_free_t = prefill_busy = decode_busy = 0.0
    step_scheduled = False
    ttft, t_first, done_t = {}, {}, {}
    now = 0.0

    def retire(rid, t):
        nonlocal free_slots, free_pages
        free_slots += 1
        free_pages += held_pages.pop(rid)
        done_t[rid] = t

    while q:
        evs = q.pop_batch()
        now = evs[0].time
        for ev in evs:
            if ev.kind == "arrive":
                waiting.append(ev.payload)
            elif ev.kind == "prefill_done":
                r = ev.payload
                ttft[r.rid] = now - r.arrival
                t_first[r.rid] = now
                emitted[r.rid] = 1  # first token comes out of prefill logits
                if r.gen_len <= 1:
                    retire(r.rid, now)
                else:
                    active[r.rid] = r
            elif ev.kind == "step":
                step_scheduled = False
                if active:
                    decode_busy += decode_step_s
                    for rid in list(active):
                        emitted[rid] += 1
                        if emitted[rid] >= active[rid].gen_len:
                            del active[rid]
                            retire(rid, now)
        queue_high_water = max(queue_high_water, len(waiting))
        while waiting and free_slots > 0 and free_pages >= pages_for(waiting[0]):
            r = waiting.pop(0)
            free_slots -= 1
            free_pages -= pages_for(r)
            held_pages[r.rid] = pages_for(r)
            peak_pages = max(peak_pages, n_pages - free_pages)
            start = max(now, prefill_free_t)
            lat = max(r.prompt_len / prefill_tok_s, events.MIN_LATENCY)
            prefill_free_t = start + lat
            prefill_busy += lat
            q.push(prefill_free_t, "prefill_done", 0, r.rid, payload=r)
        if active and not step_scheduled:
            q.push(now + decode_step_s, "step", 1)
            step_scheduled = True

    makespan = max(done_t.values(), default=0.0)
    total_tokens = sum(emitted.values())
    tpot = {r.rid: (done_t[r.rid] - t_first[r.rid]) / max(r.gen_len - 1, 1)
            for r in reqs}
    return {
        "makespan": makespan,
        "tok_s": total_tokens / makespan if makespan > 0 else 0.0,
        "ttft": sorted(ttft.values()),
        "tpot": [tpot[r.rid] for r in reqs],
        "utilization": {
            "prefill": prefill_busy / makespan if makespan else 0.0,
            "decode": decode_busy / makespan if makespan else 0.0,
        },
        "peak_pages": peak_pages,
        "queue_high_water": queue_high_water,
        "n_requests": len(reqs),
    }
