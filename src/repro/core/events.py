"""Discrete-event primitives for the asynchronous pipeline runtime.

Three pieces, deliberately free of any jax dependency so the same machinery can
drive both the full training runtime (`core/runtime.py`) and compute-free
schedule simulations (`runtime.simulate_schedule`, used by the dryrun launcher):

- `EventQueue` — a wall-clock priority queue with deterministic FIFO
  tie-breaking at equal timestamps (insertion order), so a given (delay model,
  seed) always replays the identical execution order.
- `Mailbox`   — an in-order microbatch mailbox. Links may reorder deliveries
  (jittery comm latencies), but 1F1B consumes microbatches strictly in order;
  the mailbox buffers early arrivals until the expected index shows up.
- `DelayModel` — per-(stage, op, microbatch) latency sampler. Sampling is
  *keyed* (counter-based PRNG on (seed, stage, op, mb)), not sequential, so a
  latency does not depend on the order the simulator happens to ask for it.

The closed-form schedule tau_i = floor((2(P-i)+1)/2K) in `core/delay.py` is the
fixed-delay special case of this model; `EngineCfg.straggler_delays` remains the
static override for the jit engine (see `core/engine.py`).
"""
from __future__ import annotations

import dataclasses
import heapq
import json
from typing import Any, Optional, Sequence

import numpy as np

# Minimum latency for any compute op: the event loop advances time only through
# op completions, so a zero compute latency could livelock the simulation.
MIN_LATENCY = 1e-6

_OP_IDS = {"fwd": 0, "bwd": 1, "comm_fwd": 2, "comm_bwd": 3, "update": 4}


# ---------------------------------------------------------------------------
# event queue
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Event:
    time: float
    kind: str  # "fwd_arrive" | "bwd_arrive" | "free" | "leave" | "join" | "retry"
    stage: int
    mb: int = -1
    payload: Any = None


class EventQueue:
    """Priority queue over (time, seq). seq = insertion order -> deterministic."""

    def __init__(self):
        self._heap: list = []
        self._seq = 0

    def push(self, time: float, kind: str, stage: int, mb: int = -1, payload=None):
        heapq.heappush(self._heap, (time, self._seq, Event(time, kind, stage, mb, payload)))
        self._seq += 1

    def pop_batch(self) -> list:
        """Pop ALL events sharing the earliest timestamp (arrivals must be fully
        ingested before any scheduling decision at that instant — otherwise a
        same-time cotangent could lose its backward-priority to a forward)."""
        if not self._heap:
            return []
        t0 = self._heap[0][0]
        out = []
        while self._heap and self._heap[0][0] == t0:
            out.append(heapq.heappop(self._heap)[2])
        return out

    def next_time(self) -> Optional[float]:
        """Earliest queued timestamp, or None when empty (serving admission
        uses this to fast-forward an idle engine to the next arrival)."""
        return self._heap[0][0] if self._heap else None

    def pop_until(self, t: float) -> list:
        """Pop every event with time <= t, in (time, insertion) order — the
        wall-clock-driven form of pop_batch used by the serving engine, which
        advances on real time rather than on simulated op completions."""
        out = []
        while self._heap and self._heap[0][0] <= t:
            out.append(heapq.heappop(self._heap)[2])
        return out

    def only_membership(self) -> bool:
        """True when every queued event is a leave/join — no work left for the
        churn to affect. The runtime uses this to stop a drained run instead of
        letting future outage windows fire pointlessly past the makespan (they
        belong to the next run() chunk)."""
        return all(t[2].kind in ("leave", "join") for t in self._heap)

    def __len__(self):
        return len(self._heap)

    def __bool__(self):
        return bool(self._heap)


# ---------------------------------------------------------------------------
# in-order mailbox
# ---------------------------------------------------------------------------


class Mailbox:
    """Buffers (mb -> item) deliveries; `take(mb)` only yields the asked index.

    Contract (DESIGN.md §9): deliveries may arrive out of order; consumption is
    strictly in microbatch order; an item is delivered exactly once. `high_water`
    tracks the peak number of buffered items (mailbox memory pressure).

    A duplicate delivery is a transport bug in the default (strict) mode and
    raises. Under fault injection (`core/faults.py` `dup=RATE`) the runtime
    opts into `dedupe=True`: a redelivery of any microbatch ever put — buffered
    OR already consumed — is dropped and counted in `duplicates` (at-least-once
    transport with receiver-side dedup).
    """

    def __init__(self, dedupe: bool = False):
        self._items: dict = {}
        self.high_water = 0
        self.dedupe = dedupe
        self.duplicates = 0
        self._seen: set = set()

    def put(self, mb: int, item):
        if mb in self._items or (self.dedupe and mb in self._seen):
            if self.dedupe:
                self.duplicates += 1
                return
            raise RuntimeError(f"duplicate delivery for microbatch {mb}")
        if self.dedupe:
            self._seen.add(mb)
        self._items[mb] = item
        self.high_water = max(self.high_water, len(self._items))

    def ready(self, mb: int) -> bool:
        return mb in self._items

    def take(self, mb: int):
        return self._items.pop(mb)

    def pending(self) -> list:
        """Buffered keys in sorted order — a deterministic snapshot for
        consumers that drain by scanning (the mesh gossip inbox) rather than
        by asking for one expected index (1F1B's strict in-order take)."""
        return sorted(self._items)

    def __len__(self):
        return len(self._items)


# ---------------------------------------------------------------------------
# delay models
# ---------------------------------------------------------------------------


class DelayModel:
    """latency(stage, op, mb) -> float seconds (arbitrary units).

    op in {"fwd", "bwd", "comm_fwd", "comm_bwd"}; comm ops are sampled at the
    *sending* stage. Subclasses override `_latency`; the base class clamps
    compute ops to MIN_LATENCY (comm may be exactly 0 = on-chip neighbour).
    """

    def latency(self, stage: int, op: str, mb: int) -> float:
        lat = float(self._latency(stage, op, mb))
        if op in ("fwd", "bwd"):
            return max(lat, MIN_LATENCY)
        return max(lat, 0.0)

    def _latency(self, stage: int, op: str, mb: int) -> float:
        raise NotImplementedError

    def _rng(self, seed: int, stage: int, op: str, mb: int) -> np.random.Generator:
        """Counter-based keyed PRNG: the draw for (stage, op, mb) is independent
        of simulation order, so runs with the same seed are exactly repeatable
        even when the event interleaving changes."""
        word = (stage << 40) | (_OP_IDS[op] << 36) | (mb & 0xFFFFFFFF)
        return np.random.Generator(np.random.Philox(
            key=np.array([seed & 0xFFFFFFFFFFFFFFFF, word], dtype=np.uint64)))


@dataclasses.dataclass
class FixedDelay(DelayModel):
    """Uniform deterministic latencies — the regime of paper Eq. 5. Under this
    model the event runtime's 1F1B discipline reproduces the closed-form
    tau_i = floor((2(P-i)+1)/2K) exactly (tests/test_runtime.py)."""

    fwd: float = 1.0
    bwd: float = 2.0
    comm: float = 0.0

    def _latency(self, stage, op, mb):
        if op == "fwd":
            return self.fwd
        if op == "bwd":
            return self.bwd
        return self.comm


@dataclasses.dataclass
class JitterDelay(DelayModel):
    """Log-normal multiplicative jitter on every op: base * exp(N(0, sigma)).

    Models jittery links / noisy neighbours; sigma ~ 0.2-0.5 is mild-to-rough.
    """

    sigma: float = 0.25
    fwd: float = 1.0
    bwd: float = 2.0
    comm: float = 0.1
    seed: int = 0

    def _latency(self, stage, op, mb):
        base = {"fwd": self.fwd, "bwd": self.bwd}.get(op, self.comm)
        z = self._rng(self.seed, stage, op, mb).normal(0.0, self.sigma)
        return base * float(np.exp(z))


@dataclasses.dataclass
class StragglerDelay(DelayModel):
    """One stage runs `factor`x slower — permanently, or in on/off windows of
    `period` microbatches (an elastic worker degrading and recovering)."""

    slow_stage: int = 0
    factor: float = 4.0
    period: Optional[int] = None  # None = always slow; else alternate windows
    fwd: float = 1.0
    bwd: float = 2.0
    comm: float = 0.0

    def _latency(self, stage, op, mb):
        base = {"fwd": self.fwd, "bwd": self.bwd}.get(op, self.comm)
        if stage != self.slow_stage or op not in ("fwd", "bwd"):
            return base
        slow = self.period is None or (mb // self.period) % 2 == 0
        return base * self.factor if slow else base


@dataclasses.dataclass
class OutageDelay(DelayModel):
    """Outage-aware StragglerDelay analogue: one stage degrades `factor`x inside
    a [mb_start, mb_end) microbatch window — a worker limping before it drops
    out, or re-warming caches after a rejoin. Unlike a `ChurnModel` outage the
    worker never stops dispatching; the slowdown is paid purely in latency.
    Compose with a ChurnModel (leave/join around the window) to model the full
    degrade -> drop -> rejoin -> recover arc."""

    stage: int = 0
    mb_start: int = 0
    mb_end: int = 0
    factor: float = 10.0
    fwd: float = 1.0
    bwd: float = 2.0
    comm: float = 0.0

    def _latency(self, stage, op, mb):
        base = {"fwd": self.fwd, "bwd": self.bwd}.get(op, self.comm)
        if (stage == self.stage and op in ("fwd", "bwd")
                and self.mb_start <= mb < self.mb_end):
            return base * self.factor
        return base


# ---------------------------------------------------------------------------
# churn (membership) model
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Outage:
    """One scheduled leave/join window: stage `stage` leaves at simulated-clock
    `start` and rejoins at `start + duration`. `duration` == 0 is the documented
    no-op (leave and join collapse to the same instant; the runtime result is
    bitwise identical to a churn-free run — asserted in tests/test_runtime.py).
    A join is always scheduled: a leave without a finite rejoin would deadlock
    the drain, which is exactly the barrier semantics this model replaces."""

    stage: int
    start: float
    duration: float


@dataclasses.dataclass
class ChurnModel:
    """Schedules worker outages for the event runtime (`RuntimeCfg.churn`).

    `slack` is the elastic in-flight allowance granted to every stage UPSTREAM
    of a currently-dead stage: None lifts their caps entirely for the outage
    (the pipe keeps forwarding, paying the outage in stash/mailbox memory and
    observed tau); an int bounds the extra buffered microbatches per stage.
    """

    outages: tuple = ()
    slack: Optional[int] = None

    def __post_init__(self):
        for o in self.outages:
            if o.duration < 0 or o.start < 0:
                raise ValueError(f"outage windows must be non-negative, got {o}")
        if self.slack is not None and self.slack < 0:
            raise ValueError(f"churn slack must be >= 0, got {self.slack}")

    def validate(self, P: int):
        for o in self.outages:
            if not 0 <= o.stage < P:
                raise ValueError(f"outage stage {o.stage} out of range for P={P}")
        return self


def make_churn_model(spec, slack: Optional[int] = None) -> ChurnModel:
    """Parse a CLI-friendly churn spec:

      "STAGE,START,DURATION" — one outage window, or several joined with "/":
      "1,10,5/2,30,4" (an optional leading "churn:" tag is accepted). Each
      window must have exactly three fields; excess or malformed fields raise.
    """
    if isinstance(spec, ChurnModel):
        return spec if slack is None else dataclasses.replace(spec, slack=slack)
    name, sep, args = spec.partition(":")
    if sep and name != "churn":
        raise ValueError(f"unknown churn spec {spec!r}")
    body = args if sep else spec
    outages = []
    for win in body.split("/"):
        parts = [p for p in win.split(",") if p.strip() != ""]
        if len(parts) != 3:
            raise ValueError(
                f"churn window {win!r} must be STAGE,START,DURATION (got "
                f"{len(parts)} fields)")
        outages.append(Outage(int(parts[0]), float(parts[1]), float(parts[2])))
    return ChurnModel(tuple(outages), slack=slack)


class TraceDelay(DelayModel):
    """Replay measured latencies: traces[op][stage] is a list cycled over mb.

    The JSON schema (docs/cli.md) is the calibration interchange format:

        {"version": 1, "P": 4, "K": 1, "unit": "seconds",
         "fwd":  [[...per-mb latencies...], ...one row per stage...],
         "bwd":  [[...], ...],
         "comm": [[...], ...]}

    Only "fwd"/"bwd"/"comm" drive replay (a missing op falls back to 1.0 for
    compute, 0.0 for comm); the remaining keys are provenance. Replay is fully
    deterministic — the same trace file always reproduces the same schedule.
    `from_json(path)` loads the file; `save(path)` writes it back unchanged
    (roundtrip contract, tests/test_runtime.py). Traces are recorded from a
    real run by `TraceRecorder` (launch/train.py --record-trace).
    """

    def __init__(self, traces: dict):
        self.traces = traces

    @classmethod
    def from_json(cls, path: str) -> "TraceDelay":
        with open(path) as f:
            return cls(json.load(f))

    def save(self, path: str):
        with open(path, "w") as f:
            json.dump(self.traces, f)

    def _latency(self, stage, op, mb):
        key = "comm" if op.startswith("comm") else op
        per_stage = self.traces.get(key)
        if not per_stage:
            return 0.0 if key == "comm" else 1.0
        row = per_stage[stage % len(per_stage)]
        return float(row[mb % len(row)])


class TraceRecorder:
    """Collects measured per-(stage, op, microbatch) latencies from a real run
    into the TraceDelay JSON schema — the calibration half of the trace loop:

        train --runtime event --record-trace out.json   (timing hooks in
        core/runtime.py around each stage's jitted fwd/bwd dispatch)
        -> out.json -> --delay-model trace:out.json | dryrun --sim-schedule
           --sim-models trace:out.json | benchmarks/runtime_bench.py

    so simulations and benchmarks replay MEASURED rather than synthetic
    latency distributions. Comm latency is not separable in a single-process
    runtime (activations hand over in memory), so comm rows record 0.0 —
    on-chip-neighbour semantics; multi-host transports can fill them in.
    """

    def __init__(self, P: int, K: int = 1):
        self.P = P
        self.K = K
        self.warmup_mb = 0  # samples below this GLOBAL mb index are warmup
        self._lat = {"fwd": [dict() for _ in range(P)],
                     "bwd": [dict() for _ in range(P)]}

    def add(self, stage: int, op: str, mb: int, seconds: float):
        if mb < self.warmup_mb:
            return  # compile-inflated warmup dispatch: never reaches a trace
        self._lat[op][stage][mb] = float(seconds)

    def __len__(self):
        return sum(len(row) for rows in self._lat.values() for row in rows)

    def discard_warmup(self) -> int:
        """Mark everything recorded so far as compile warmup and drop it.

        Microbatch-aware: the boundary is (max recorded mb + 1) rounded UP to
        a K multiple — a whole number of accumulation groups — so at K > 1 no
        compile-inflated dispatch of a partially-recorded group survives, and
        any straggling `add` for a pre-boundary microbatch (a warmup backward
        landing after the reset) is ignored by INDEX rather than by when the
        recorder object happened to be swapped. Keeps per-group microbatch
        alignment for TraceDelay's `row[mb % len(row)]` replay. Returns the
        new boundary."""
        seen = [mb for rows in self._lat.values() for row in rows for mb in row]
        if seen:
            hi = max(self.warmup_mb, max(seen) + 1)
            self.warmup_mb = -(-hi // self.K) * self.K
        for rows in self._lat.values():
            for row in rows:
                row.clear()
        return self.warmup_mb

    def traces(self) -> dict:
        """Emit the TraceDelay schema dict; per-stage rows are ordered by
        microbatch index (dense from the first recorded mb), so replay of the
        same horizon reuses each microbatch's measured latency exactly. The
        `warmup_mb` key records how many leading microbatches were discarded
        as compile warmup (provenance only — replay ignores unknown keys)."""
        out = {"version": 1, "P": self.P, "K": self.K, "unit": "seconds",
               "warmup_mb": self.warmup_mb}
        for op in ("fwd", "bwd"):
            out[op] = [[row[mb] for mb in sorted(row)] or [MIN_LATENCY]
                       for row in self._lat[op]]
        out["comm"] = [[0.0] for _ in range(self.P)]
        return out

    def to_delay(self) -> TraceDelay:
        return TraceDelay(self.traces())

    def save(self, path: str):
        self.to_delay().save(path)


def _spec_fields(name: str, args: str, lo: int, hi: int) -> list:
    """Split a spec's comma arg list, enforcing arity — excess or empty fields
    raise instead of being silently dropped (the pre-ISSUE-4 parser ate them)."""
    parts = args.split(",") if args else []
    if any(p.strip() == "" for p in parts):
        raise ValueError(f"empty field in {name!r} spec args {args!r}")
    if not lo <= len(parts) <= hi:
        raise ValueError(
            f"{name!r} spec takes {lo}..{hi} args, got {len(parts)}: {args!r}")
    return parts


def make_delay_model(spec: str | DelayModel | None, seed: int = 0) -> DelayModel:
    """Parse a CLI-friendly spec:

      "fixed" | "fixed:FWD[,BWD[,COMM]]"
      | "jitter:SIGMA[,FWD,BWD,COMM]"
      | "straggler:STAGE[,FACTOR[,PERIOD]]"
      | "outage:STAGE,MB_START,MB_END[,FACTOR]"
      | "trace:/path/to/traces.json"

    `seed` keys the stochastic models (jitter); the deterministic models have
    no randomness to seed. Unknown names, excess args, or malformed fields
    raise ValueError (spec-roundtrip contract, tests/test_runtime.py).
    """
    if spec is None:
        return FixedDelay()
    if isinstance(spec, DelayModel):
        return spec
    name, _, args = spec.partition(":")
    if name == "fixed":
        vals = [float(x) for x in _spec_fields(name, args, 0, 3)]
        return FixedDelay(*vals)
    if name == "jitter":
        parts = _spec_fields(name, args, 0, 4)
        if len(parts) in (2, 3):
            raise ValueError(
                f"'jitter' spec is SIGMA or SIGMA,FWD,BWD,COMM, got {args!r}")
        kw = {"sigma": float(parts[0])} if parts else {}
        if len(parts) == 4:
            kw.update(fwd=float(parts[1]), bwd=float(parts[2]), comm=float(parts[3]))
        return JitterDelay(seed=seed, **kw)
    if name == "straggler":
        parts = _spec_fields(name, args, 0, 3)
        kw = {}
        if len(parts) > 0:
            kw["slow_stage"] = int(parts[0])
        if len(parts) > 1:
            kw["factor"] = float(parts[1])
        if len(parts) > 2:
            kw["period"] = int(parts[2])
        return StragglerDelay(**kw)
    if name == "outage":
        parts = _spec_fields(name, args, 3, 4)
        kw = {"stage": int(parts[0]), "mb_start": int(parts[1]),
              "mb_end": int(parts[2])}
        if len(parts) > 3:
            kw["factor"] = float(parts[3])
        return OutageDelay(**kw)
    if name == "trace":
        return TraceDelay.from_json(args)
    raise ValueError(f"unknown delay model spec {spec!r}")


# ---------------------------------------------------------------------------
# Serving traffic: requests as microbatch events (launch/serve.py)
# ---------------------------------------------------------------------------
#
# A serving request is the inference-side analogue of a training microbatch:
# it enters the pipeline as an event, is admitted under the same in-flight-cap
# discipline 1F1B uses for microbatches (the decode-slot count is the cap), and
# its KV pages are the stash-ring memory it occupies while in flight. The trace
# generator below is keyed per request id — like DelayModel._rng, draws are
# independent of simulation order, so a (seed, rate, dists) tuple always yields
# the identical trace (tests/test_serve.py).


@dataclasses.dataclass(frozen=True)
class Request:
    """One inference request in a traffic trace (times in seconds)."""

    rid: int
    arrival: float
    prompt_len: int
    gen_len: int

    @property
    def total_len(self) -> int:
        return self.prompt_len + self.gen_len


def _serve_rng(seed: int, rid: int, field: int) -> np.random.Generator:
    word = (1 << 48) | (field << 40) | (rid & 0xFFFFFFFF)
    return np.random.Generator(np.random.Philox(
        key=np.array([seed & 0xFFFFFFFFFFFFFFFF, word], dtype=np.uint64)))


def poisson_trace(n_requests: int, *, rate: float = 1.0, seed: int = 0,
                  prompt_lens: Sequence[int] = (4, 16),
                  gen_lens: Sequence[int] = (2, 8)) -> tuple:
    """Poisson-arrival traffic: n requests, exp(rate) inter-arrival gaps,
    prompt/gen lengths uniform over [lo, hi] inclusive.

    Deterministic under (seed, rate, dists): every draw is keyed by request id,
    never by generator state, so traces are reproducible and order-independent.
    """
    if n_requests < 0:
        raise ValueError(f"n_requests must be >= 0, got {n_requests}")
    if rate <= 0:
        raise ValueError(f"rate must be > 0, got {rate}")
    for name, (lo, hi) in (("prompt_lens", tuple(prompt_lens)),
                           ("gen_lens", tuple(gen_lens))):
        if lo < 1 or hi < lo:
            raise ValueError(f"{name} must satisfy 1 <= lo <= hi, got {(lo, hi)}")
    reqs, t = [], 0.0
    for rid in range(n_requests):
        t += float(_serve_rng(seed, rid, 0).exponential(1.0 / rate))
        pl = int(_serve_rng(seed, rid, 1).integers(prompt_lens[0], prompt_lens[1] + 1))
        gl = int(_serve_rng(seed, rid, 2).integers(gen_lens[0], gen_lens[1] + 1))
        reqs.append(Request(rid=rid, arrival=t, prompt_len=pl, gen_len=gl))
    return tuple(reqs)


# ---------------------------------------------------------------------------
# Cross-replica sync: gossip mesh events (core/swarm.py MeshTrainer)
# ---------------------------------------------------------------------------
#
# The barrier SwarmTrainer round-trips every replica through a global drain
# before averaging — reintroducing the sync stall the async pipeline removes.
# The mesh promotes cross-replica sync to a first-class event kind: a
# `SyncEvent` carries (replica, stage, round) through the same deterministic
# EventQueue/Mailbox discipline as fwd/bwd, with its own keyed delay model
# (`SyncDelayModel`) and keyed partner selection (`gossip_partners`). The
# driver (`drive_mesh`) is compute-free: callbacks supply the per-round local
# compute span and the absorption math, so the full training runtime
# (swarm.MeshTrainer) and the schedule twin (runtime.simulate_mesh_schedule)
# replay the IDENTICAL event stream — that equality is a pinned contract
# (tests/test_mesh.py).

# Keyed-draw namespaces, disjoint from the training words ((stage<<40)|...,
# _OP_IDS << 36) and the serving words ((1<<48)|...): sync latencies draw at
# bit 61, partner selection at bits 61|60.
_SYNC_NS = 2 << 60
_PARTNER_NS = 3 << 60


@dataclasses.dataclass(frozen=True)
class SyncEvent:
    """One cross-replica partner exchange in flight: replica `src`'s stage
    `stage` weights, published at the end of gossip round `round`, addressed
    to replica `dst`."""

    src: int
    dst: int
    stage: int
    round: int


class SyncDelayModel:
    """latency(src, dst, stage, rnd) -> float >= 0 for one SyncEvent hop.

    The sync analogue of DelayModel: draws are keyed by the full event
    coordinate, never by sampler state, so a mesh run replays exactly under
    the same seed regardless of event interleaving. Zero latency is legal —
    a same-instant delivery is the degenerate case that reduces gossip to
    the barrier sync (DESIGN.md §13)."""

    def latency(self, src: int, dst: int, stage: int, rnd: int) -> float:
        return max(float(self._latency(src, dst, stage, rnd)), 0.0)

    def _latency(self, src, dst, stage, rnd):
        raise NotImplementedError

    def _rng(self, seed: int, src: int, dst: int, stage: int, rnd: int):
        word = (_SYNC_NS | ((src & 0xFF) << 52) | ((dst & 0xFF) << 44)
                | ((stage & 0xFF) << 36) | (rnd & 0xFFFFFFFFF))
        return np.random.Generator(np.random.Philox(
            key=np.array([seed & 0xFFFFFFFFFFFFFFFF, word], dtype=np.uint64)))


@dataclasses.dataclass
class FixedSyncDelay(SyncDelayModel):
    """Uniform deterministic sync-hop latency (0.0 = the barrier-equivalent
    degenerate case)."""

    lat: float = 0.0

    def _latency(self, src, dst, stage, rnd):
        return self.lat


@dataclasses.dataclass
class JitterSyncDelay(SyncDelayModel):
    """Log-normal multiplicative jitter per hop: base * exp(N(0, sigma))."""

    base: float = 1.0
    sigma: float = 0.25
    seed: int = 0

    def _latency(self, src, dst, stage, rnd):
        z = self._rng(self.seed, src, dst, stage, rnd).normal(0.0, self.sigma)
        return self.base * float(np.exp(z))


def make_sync_delay_model(spec, seed: int = 0) -> SyncDelayModel:
    """Parse a CLI-friendly sync-delay spec:

      "fixed" | "fixed:LAT" | "jitter:BASE,SIGMA"

    None means zero-latency FixedSyncDelay (the degenerate/barrier case).
    Same arity discipline as make_delay_model: malformed fields raise.
    """
    if spec is None:
        return FixedSyncDelay(0.0)
    if isinstance(spec, SyncDelayModel):
        return spec
    name, _, args = spec.partition(":")
    if name == "fixed":
        vals = [float(x) for x in _spec_fields(name, args, 0, 1)]
        return FixedSyncDelay(*vals)
    if name == "jitter":
        parts = _spec_fields(name, args, 2, 2)
        return JitterSyncDelay(base=float(parts[0]), sigma=float(parts[1]),
                               seed=seed)
    raise ValueError(f"unknown sync delay spec {spec!r}")


def gossip_partners(seed: int, rnd: int, r: int, R: int,
                    fanout: Optional[int] = None) -> tuple:
    """Partner set replica `r` pushes its weights to at gossip round `rnd`.

    A pure keyed function of (seed, round, replica) — no sequential RNG state,
    so any participant (or a replayer) recomputes the identical mesh topology
    for any round without observing the others (tests/test_mesh.py contract d).
    fanout None (or >= R-1) selects every other replica — full fanout, the
    all-to-all degenerate case; otherwise a keyed-uniform subset of that size.
    Returned sorted ascending.
    """
    if R < 1:
        raise ValueError(f"need R >= 1 replicas, got {R}")
    if not 0 <= r < R:
        raise ValueError(f"replica {r} out of range for R={R}")
    others = [x for x in range(R) if x != r]
    if fanout is None or fanout >= len(others):
        return tuple(others)
    if fanout < 1:
        raise ValueError(f"fanout must be >= 1, got {fanout}")
    word = _PARTNER_NS | ((rnd & 0xFFFFFFFF) << 20) | (r & 0xFFFFF)
    rng = np.random.Generator(np.random.Philox(
        key=np.array([seed & 0xFFFFFFFFFFFFFFFF, word], dtype=np.uint64)))
    pick = rng.permutation(len(others))[:fanout]
    return tuple(sorted(others[int(i)] for i in pick))


@dataclasses.dataclass(frozen=True)
class MeshSpec:
    """Parsed --mesh spec: cross-replica sync topology and cadence."""

    mode: str  # "gossip" | "barrier"
    period: int = 8  # local update ticks per gossip round / barrier sync
    fanout: Optional[int] = None  # gossip partners per round (None = all)

    def __post_init__(self):
        if self.mode not in ("gossip", "barrier"):
            raise ValueError(f"mesh mode must be gossip|barrier, got {self.mode!r}")
        if self.period < 1:
            raise ValueError(f"mesh period must be >= 1, got {self.period}")
        if self.fanout is not None and self.fanout < 1:
            raise ValueError(f"mesh fanout must be >= 1, got {self.fanout}")
        if self.mode == "barrier" and self.fanout is not None:
            raise ValueError("barrier mesh takes no fanout (it is all-to-all)")


def make_mesh_spec(spec) -> MeshSpec:
    """Parse a CLI-friendly mesh spec (docs/cli.md):

      "gossip:PERIOD[,FANOUT]" — fully-async gossip averaging every PERIOD
          ticks, pushing to FANOUT keyed partners (default: all others)
      "barrier:PERIOD"         — the legacy round-barrier SwarmTrainer sync

    Same arity discipline as make_delay_model: excess/empty fields raise.
    """
    if isinstance(spec, MeshSpec):
        return spec
    name, _, args = spec.partition(":")
    if name == "gossip":
        parts = _spec_fields(name, args, 1, 2)
        kw = {"period": int(parts[0])}
        if len(parts) > 1:
            kw["fanout"] = int(parts[1])
        return MeshSpec("gossip", **kw)
    if name == "barrier":
        parts = _spec_fields(name, args, 1, 1)
        return MeshSpec("barrier", period=int(parts[0]))
    raise ValueError(f"unknown mesh spec {spec!r}")


def drive_mesh(R: int, n_rounds: int, *, n_stages: int = 1,
               fanout: Optional[int] = None, seed: int = 0, sync_delay=None,
               max_stale_rounds: int = 1, run_round=None, snapshot=None,
               absorb=None) -> dict:
    """The fully-async gossip event loop, shared by the training runtime
    (swarm.MeshTrainer.run_gossip) and its compute-free twin
    (runtime.simulate_mesh_schedule).

    Per replica lifecycle, all through one deterministic EventQueue:

      mesh_boundary(r, n) — replica r finished local round n: snapshot its
          stage weights, push one SyncEvent per (partner, stage) with a keyed
          latency from `sync_delay`, then schedule mesh_start(r, n) at now.
      mesh_sync(dst, ...) — a SyncEvent arrives: ingest into dst's inbox
          Mailbox under the strict exactly-once discipline.
      mesh_start(r, n)    — absorb: scan the inbox, drop contributions staler
          than `max_stale_rounds` rounds (bounded like stash depth), keep the
          newest per (stage, src), hand them to `absorb`, then start round
          n+1 (span from `run_round`). There is NO barrier: a replica never
          waits for partners; late weights land in a later absorption or age
          out.

    Same-instant ordering: a batch of equal-time events processes arrivals
    first, then boundaries, then starts — and when a batch holds both
    boundaries and starts, the starts are re-queued at the same timestamp so
    any zero-latency contributions published by those boundaries are ingested
    before anyone absorbs. This is what makes the zero-delay/full-fanout
    degenerate case reduce to the barrier sync bitwise (tests/test_mesh.py).

    Callbacks (all optional except run_round):
      run_round(r, rnd) -> float      simulated span of replica r's round rnd
      snapshot(r, rnd) -> list        per-stage payloads published at a
                                      boundary (None -> payload-free twin)
      absorb(r, rnd, by_stage, now)   by_stage: {stage: [(src, src_rnd,
                                      payload), ...] sorted by src}

    Returns {"events", "absorbed", "stale_dropped", "superseded",
             "unabsorbed", "makespan", "inbox_high_water"}; `events` is a
    payload-free list of tuples — directly comparable across runtime/twin:
      ("round_start", t, r, rnd)
      ("round_end",   t, r, rnd)
      ("sync_send",   t, src, dst, stage, rnd)
      ("sync_arrive", t, src, dst, stage, rnd)
      ("absorb",      t, r, rnd, n_absorbed, n_stale)
    """
    if R < 1:
        raise ValueError(f"need R >= 1 replicas, got {R}")
    if n_rounds < 1:
        raise ValueError(f"need n_rounds >= 1, got {n_rounds}")
    if max_stale_rounds < 0:
        raise ValueError(f"max_stale_rounds must be >= 0, got {max_stale_rounds}")
    if run_round is None:
        raise ValueError("drive_mesh requires a run_round callback")
    sdm = (sync_delay if isinstance(sync_delay, SyncDelayModel)
           else make_sync_delay_model(sync_delay, seed=seed))
    q = EventQueue()
    inbox = [Mailbox() for _ in range(R)]
    log: list = []
    absorbed = stale_dropped = superseded = 0

    def key_of(src, rnd, stage):
        return (rnd * R + src) * n_stages + stage

    def decode(k):
        stage = k % n_stages
        sr = k // n_stages
        return sr % R, sr // R, stage  # (src, rnd, stage)

    for r in range(R):
        log.append(("round_start", 0.0, r, 0))
        q.push(run_round(r, 0), "mesh_boundary", r, 0)

    while q:
        batch = q.pop_batch()
        now = batch[0].time
        arrivals = [e for e in batch if e.kind == "mesh_sync"]
        bounds = [e for e in batch if e.kind == "mesh_boundary"]
        starts = [e for e in batch if e.kind == "mesh_start"]
        for e in arrivals:
            se, data = e.payload
            log.append(("sync_arrive", now, se.src, se.dst, se.stage, se.round))
            inbox[se.dst].put(key_of(se.src, se.round, se.stage), (se, data))
        for e in bounds:
            r, rnd = e.stage, e.mb
            log.append(("round_end", now, r, rnd))
            payload = snapshot(r, rnd) if snapshot is not None else None
            for dst in gossip_partners(seed, rnd, r, R, fanout):
                for i in range(n_stages):
                    log.append(("sync_send", now, r, dst, i, rnd))
                    se = SyncEvent(src=r, dst=dst, stage=i, round=rnd)
                    q.push(now + sdm.latency(r, dst, i, rnd), "mesh_sync", dst,
                           i, payload=(se, None if payload is None else payload[i]))
            q.push(now, "mesh_start", r, rnd)
        if bounds and starts:
            # defer: those boundaries may have published zero-latency
            # contributions at `now` that must be ingested before absorbing
            for e in starts:
                q.push(now, "mesh_start", e.stage, e.mb)
            continue
        for e in starts:
            r, rnd = e.stage, e.mb
            newest: dict = {}  # (stage, src) -> (src_rnd, key)
            n_stale_here = 0
            for k in inbox[r].pending():
                src, src_rnd, stage = decode(k)
                if src_rnd < rnd - max_stale_rounds:
                    inbox[r].take(k)
                    stale_dropped += 1
                    n_stale_here += 1
                    continue
                prev = newest.get((stage, src))
                if prev is None or src_rnd > prev[0]:
                    if prev is not None:
                        inbox[r].take(prev[1])
                        superseded += 1
                    newest[(stage, src)] = (src_rnd, k)
                else:
                    inbox[r].take(k)
                    superseded += 1
            by_stage: dict = {}
            for (stage, src), (src_rnd, k) in sorted(newest.items()):
                _, data = inbox[r].take(k)
                by_stage.setdefault(stage, []).append((src, src_rnd, data))
                absorbed += 1
            log.append(("absorb", now, r, rnd,
                        sum(len(v) for v in by_stage.values()), n_stale_here))
            if absorb is not None and by_stage:
                absorb(r, rnd, by_stage, now)
            if rnd + 1 < n_rounds:
                log.append(("round_start", now, r, rnd + 1))
                q.push(now + run_round(r, rnd + 1), "mesh_boundary", r, rnd + 1)

    return {"events": log, "absorbed": absorbed, "stale_dropped": stale_dropped,
            "superseded": superseded,
            "unabsorbed": sum(len(mb) for mb in inbox),
            "makespan": max((e[1] for e in log), default=0.0),
            "inbox_high_water": [mb.high_water for mb in inbox]}
