"""The paper's contribution: async-PP engine (engine.py), stage-delay model
(delay.py), weight-stash rings (stash.py), staged VJP (staged.py), method registry
(methods.py), SWARM stage-DP (swarm.py), utilization analytics (utilization.py)."""
