"""The paper's contribution: async-PP engine (engine.py), stage-delay model
(delay.py), weight-stash rings (stash.py), staged VJP (staged.py), method registry
(methods.py), SWARM stage-DP (swarm.py), utilization analytics (utilization.py),
and the event-driven async runtime (runtime.py + events.py: discrete-event 1F1B
with sampled delays and observed-staleness feedback — DESIGN.md §9)."""
