"""Model-zoo layer library: norms, RoPE, attention variants (GQA / local / softcap /
QK-norm / cross / MLA), MLPs, MoE (sort-based capacity dispatch), Mamba2 SSD.

Pure-functional: each layer has ``init_*(key, cfg, blk) -> params`` and
``*_apply(params, x, ...) -> y``. Params are plain dicts of jnp arrays so they stack
cleanly for scan-over-layers and shard under pjit.

Naming convention for sharding rules (see parallel/sharding.py): param key names are
stable and matched by regex — 'wq','wk','wv','wo','w_gate','w_up','w_down','router',
'moe_*','tok_embed','lm_head','in_proj','out_proj', etc.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.kernels import dispatch as kdis
from repro.parallel import ax

Params = Any


def kernel_backend(cfg) -> str:
    """The resolved kernel backend for this model config (static at trace time)."""
    return kdis.resolve_backend(cfg.kernel_backend)


# ---------------------------------------------------------------------------
# Block / model configs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BlockDef:
    """One transformer-ish block: a sequence mixer + a channel mixer."""

    mixer: str = "attn"  # attn | ssm | shared_attn | none
    mlp: str = "swiglu"  # swiglu | geglu | gelu | moe | none
    window: Optional[int] = None  # sliding-window size (local attention)
    cross_attn: bool = False  # adds cross-attention (whisper decoder)
    causal: bool = True  # False for encoder blocks
    rope_theta: float = 1e4


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int = 8
    top_k: int = 2
    d_ff_expert: int = 0
    n_shared: int = 0
    d_ff_shared: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001


@dataclasses.dataclass(frozen=True)
class MLACfg:
    kv_lora: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 128


@dataclasses.dataclass(frozen=True)
class ModelCfg:
    name: str = "model"
    family: str = "dense"  # dense | moe | ssm | hybrid | vlm | audio
    d_model: int = 512
    n_heads: int = 8
    n_kv_heads: int = 8
    head_dim: int = 64
    d_ff: int = 2048
    vocab_size: int = 32000
    norm_eps: float = 1e-6
    qkv_bias: bool = False
    qk_norm: bool = False
    use_post_norm: bool = False  # gemma2/3 style post-sublayer norms
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    tie_embeddings: bool = True
    # layer program: `prelude` blocks run once, then `pattern` repeats n_periods times.
    prelude: tuple = ()
    pattern: tuple = (BlockDef(),)
    n_periods: int = 4
    # encoder (whisper): encoder blocks prepended, using precomputed frame embeddings
    enc_pattern: tuple = ()
    enc_periods: int = 0
    n_frames: int = 0  # encoder sequence length (stub frontend output)
    # vlm (paligemma): first `n_prefix_img` positions are precomputed patch embeddings
    n_prefix_img: int = 0
    prefix_lm: bool = False
    moe: Optional[MoECfg] = None
    mla: Optional[MLACfg] = None
    ssm: Optional[SSMCfg] = None
    dtype: Any = jnp.bfloat16
    # training-shape knobs
    xent_chunk: int = 0  # 0 = unchunked loss
    attn_q_chunk: int = 0  # 0 = dense attention; else scan over q chunks
    mlp_s_chunk: int = 0  # 0 = full-seq channel mix; else scan over seq chunks
    remat: bool = True
    # full-unroll of scans for dry-run cost analysis: XLA cost_analysis counts a
    # while-loop body ONCE, so rolled scans hide n_periods x the FLOPs/bytes.
    unroll: bool = False
    # gather-free cross-entropy (one-hot dot): required inside partial-manual
    # shard_map regions where XLA's gather partitioner is fragile.
    onehot_xent: bool = False
    # store attention scores/probs in bf16 (f32 softmax statistics): halves the
    # dominant HBM stream of long-seq training (§Perf H2). Off = paper-faithful
    # f32 scores.
    attn_scores_bf16: bool = False
    # kernel routing: 'pallas' | 'interpret' | 'ref' | None (= platform default).
    # Resolved via kernels/dispatch.py; the REPRO_KERNEL_BACKEND env var wins.
    # Non-'ref' backends route attention, the mid-block rmsnorm+residual, and the
    # Mamba-2 SSD scan through the fused Pallas kernels, forward AND backward
    # (dedicated dq/dk/dv, SSD reverse-scan, and rmsnorm backward kernels).
    kernel_backend: Optional[str] = None

    @property
    def n_layers(self) -> int:
        return len(self.prelude) + len(self.pattern) * self.n_periods

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS roofline term)."""
        import numpy as np

        # cheap: init with eval_shape to avoid allocation; the key literal is
        # shape-only (eval_shape never executes) so it cannot bias results
        from . import lm  # local import to avoid cycle

        shapes = jax.eval_shape(lambda k: lm.init_lm(k, self),
                                jax.random.PRNGKey(0))
        return int(sum(np.prod(x.shape) for x in jax.tree.leaves(shapes)))


# ---------------------------------------------------------------------------
# Small pieces
# ---------------------------------------------------------------------------


def _dense_init(key, shape, scale=None):
    fan_in = shape[0] if len(shape) > 1 else 1
    scale = scale if scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(jnp.float32)


def init_rmsnorm(d):
    return {"scale": jnp.zeros((d,), jnp.float32)}


def rmsnorm_apply(params, x, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + params["scale"])).astype(dt)


def fused_rmsnorm_residual(params, x, h, cfg, *, backend=None):
    """Kernel-fused `r = x + h; y = rmsnorm(r) * (1 + scale)` in one HBM pass.

    Returns (r, y) — the residual stream and the normed input of the next
    sublayer. Call sites fall back to the unfused pair when the backend is 'ref'.
    """
    be = backend if backend is not None else kernel_backend(cfg)
    return kdis.dispatch_grad("rmsnorm_residual", x, h, params["scale"],
                              backend=be, eps=cfg.norm_eps)


def rope_frequencies(head_dim, positions, theta):
    """positions [*, S] -> (cos, sin) each [*, S, head_dim/2], fp32."""
    half = head_dim // 2
    inv = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv  # [*, S, half]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x [..., S, H, D]; cos/sin [..., S, D/2] (broadcast over head axis)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]  # broadcast over heads: [..., S, 1, half]
    s = sin[..., None, :]
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return out.astype(x.dtype)


def softcap(x, cap):
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# Attention (GQA family)
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ModelCfg, blk: BlockDef):
    ks = jax.random.split(key, 8)
    D, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "wq": _dense_init(ks[0], (D, H, hd)),
        "wk": _dense_init(ks[1], (D, Hkv, hd)),
        "wv": _dense_init(ks[2], (D, Hkv, hd)),
        "wo": _dense_init(ks[3], (H, hd, D), scale=1.0 / math.sqrt(H * hd)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), jnp.float32)
        p["bk"] = jnp.zeros((Hkv, hd), jnp.float32)
        p["bv"] = jnp.zeros((Hkv, hd), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(hd)
        p["k_norm"] = init_rmsnorm(hd)
    if blk.cross_attn:
        p["c_wq"] = _dense_init(ks[4], (D, H, hd))
        p["c_wk"] = _dense_init(ks[5], (D, Hkv, hd))
        p["c_wv"] = _dense_init(ks[6], (D, Hkv, hd))
        p["c_wo"] = _dense_init(ks[7], (H, hd, D), scale=1.0 / math.sqrt(H * hd))
    return p


def _mask_bias(q_pos, k_pos, *, causal, window, prefix_len, dtype=jnp.float32):
    """Additive mask bias [*, Sq, Sk]. q_pos [*, Sq], k_pos [*, Sk] int32."""
    ok = jnp.ones(q_pos.shape[:-1] + (q_pos.shape[-1], k_pos.shape[-1]), bool)
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :]
    if causal:
        allow = kp <= qp
        if prefix_len is not None:  # prefix-LM: bidirectional over the prefix
            allow = allow | (kp < prefix_len)
        ok = ok & allow
    if window is not None:
        ok = ok & (kp > qp - window)
    return jnp.where(ok, 0.0, -1e30).astype(dtype)


def _attend(q, k, v, bias, cap, scale, scores_bf16=False):
    """Grouped attention core.

    q: [B, Sq, Hkv, G, hd]; k,v: [B, Sk, Hkv, hd]; bias: [B, Sq, Sk] additive.
    Returns [B, Sq, Hkv, G, hd]. Softmax statistics in fp32; with scores_bf16 the
    stored score/prob tensors are bf16 (halves the dominant HBM stream).
    """
    if scores_bf16 and q.dtype == jnp.bfloat16:
        # bf16-resident scores/probs; only the row statistics are f32
        s = jnp.einsum("bqngd,bknd->bngqk", q, k) * jnp.asarray(scale, q.dtype)
        if cap is not None:
            s = softcap(s, jnp.asarray(cap, s.dtype))
        s = s + bias[:, None, None, :, :].astype(s.dtype)
        m = jax.lax.stop_gradient(jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m)  # bf16, values in [0, 1]
        denom = jnp.sum(p.astype(jnp.float32), axis=-1, keepdims=True)
        w = p * (1.0 / denom).astype(p.dtype)
    else:
        scores = jnp.einsum("bqngd,bknd->bngqk", q, k)
        scores = scores.astype(jnp.float32) * scale
        if cap is not None:
            scores = softcap(scores, cap)
        scores = scores + bias[:, None, None, :, :]
        w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bngqk,bknd->bqngd", w.astype(v.dtype), v)
    return out


def _attend_qchunk(q, k, v, q_pos, k_pos, mask_kw, cap, scale, q_chunk, unroll=False, scores_bf16=False):
    """Memory-bounded attention: scan over q chunks, building the mask bias
    per chunk ([B, q_chunk, Sk] instead of [B, Sq, Sk])."""
    B, Sq, Hkv, G, hd = q.shape
    n = Sq // q_chunk
    qr = q.reshape(B, n, q_chunk, Hkv, G, hd).swapaxes(0, 1)  # [n,B,qc,Hkv,G,d]
    pr = q_pos.reshape(B, n, q_chunk).swapaxes(0, 1)  # [n,B,qc]

    def body(_, qb):
        qi, pi = qb
        bi = _mask_bias(pi, k_pos, **mask_kw)
        return None, _attend(qi, k, v, bi, cap, scale, scores_bf16)

    _, outs = jax.lax.scan(body, None, (qr, pr), unroll=unroll)
    return outs.swapaxes(0, 1).reshape(B, Sq, Hkv, G, hd)


def attention_apply(
    p,
    x,
    cfg: ModelCfg,
    blk: BlockDef,
    *,
    positions,
    prefix_len=None,
    cache=None,
    enc_out=None,
    iota_positions=False,
    paging=None,
):
    """Self-attention (+ optional cross-attention block for whisper decoder).

    cache: None (train/prefill full-seq), dict(k,v,pos) for dense one-token
    decode, or dict(k_pages,v_pages) for paged decode (serving) — the paged
    branch additionally needs `paging` (page_table/write_page/write_off/
    read_len, shared across layers; see lm.serve_decode_paged).
    Returns (y, new_cache).
    """
    B, S, D = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    G = H // Hkv
    cdt = cfg.dtype

    bt = ax.batch_axes()
    q = ax.constrain(jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cdt)), bt, None, "model", None)
    k = ax.constrain(jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(cdt)), bt, None, "model", None)
    v = ax.constrain(jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(cdt)), bt, None, "model", None)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(cdt)
        k = k + p["bk"].astype(cdt)
        v = v + p["bv"].astype(cdt)
    if cfg.qk_norm:
        q = rmsnorm_apply(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm_apply(p["k_norm"], k, cfg.norm_eps)

    cos, sin = rope_frequencies(hd, positions, blk.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    new_cache = None
    scale = 1.0 / math.sqrt(hd)
    mask_kw = dict(causal=blk.causal, window=blk.window, prefix_len=prefix_len)
    if cache is not None and "k_pages" in cache:
        # paged decode (serving): k/v live in a shared fixed-size page pool;
        # per-slot routing (page table, write slot, live length) is computed
        # once by lm.serve_decode_paged and shared by every layer. Inactive
        # lanes carry write_page == n_pages: the scatter drops them, so
        # retired slots never touch the pool (their pages may already be
        # owned by a new sequence).
        if paging is None:
            raise ValueError("paged attention cache needs batch['paging'] routing")
        if S != 1:
            raise ValueError("paged attention cache is decode-only (S == 1)")
        ck = cache["k_pages"].at[paging["write_page"], paging["write_off"]].set(
            k[:, 0], mode="drop")
        cv = cache["v_pages"].at[paging["write_page"], paging["write_off"]].set(
            v[:, 0], mode="drop")
        out = kdis.dispatch(
            "paged_attn_decode", q[:, 0], ck, cv,
            paging["page_table"], paging["read_len"],
            backend=kernel_backend(cfg), window=blk.window,
            softcap=cfg.attn_softcap, scale=scale)
        out = out.reshape(B, 1, Hkv, G, hd)
        new_cache = {"k_pages": ck, "v_pages": cv}
    elif cache is not None:
        # prefill (S>1) or one-token decode; cache k/v [B, Smax, Hkv, hd]
        idx = cache["pos"]  # scalar int32 current length
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, idx, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, idx, axis=1)
        new_cache = {"k": ck, "v": cv, "pos": idx + S}
        k_pos = jnp.broadcast_to(
            jnp.arange(ck.shape[1], dtype=jnp.int32)[None, :], (B, ck.shape[1]))
        qq = q.reshape(B, S, Hkv, G, hd)
        if cfg.attn_q_chunk and S % cfg.attn_q_chunk == 0 and S > cfg.attn_q_chunk:
            out = _attend_qchunk(qq, ck, cv, positions, k_pos, mask_kw,
                                 cfg.attn_softcap, scale, cfg.attn_q_chunk,
                                 unroll=cfg.unroll, scores_bf16=cfg.attn_scores_bf16)
        else:
            bias = _mask_bias(positions, k_pos, **mask_kw)
            out = _attend(qq, ck, cv, bias, cfg.attn_softcap, scale, cfg.attn_scores_bf16)
    elif kernel_backend(cfg) != "ref" and prefix_len is None and iota_positions:
        # fused flash-attention kernel. Gated on iota_positions (a static flag
        # from the caller: True only when positions were generated as arange, not
        # supplied by the batch) because the kernel masks by block index — custom
        # positions (packed sequences, resets) must take the bias path below.
        # attn_q_chunk configs also land here: the dedicated dq/dk/dv backward
        # kernels stream over kv tiles from (o, lse) residuals, so the q-chunked
        # scan's [B, q_chunk, Sk] working-set bound holds on BOTH passes — the
        # chunked path below remains only for the masked/positions cases.
        out = kdis.dispatch_grad(
            "flash_attention", q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2),
            backend=kernel_backend(cfg), causal=blk.causal, window=blk.window,
            softcap=cfg.attn_softcap, scale=scale)
        out = out.swapaxes(1, 2).reshape(B, S, Hkv, G, hd)
    else:
        k_pos = positions
        qq = q.reshape(B, S, Hkv, G, hd)
        if cfg.attn_q_chunk and S % cfg.attn_q_chunk == 0 and S > cfg.attn_q_chunk:
            out = _attend_qchunk(qq, k, v, positions, k_pos, mask_kw,
                                 cfg.attn_softcap, scale, cfg.attn_q_chunk,
                                 unroll=cfg.unroll, scores_bf16=cfg.attn_scores_bf16)
        else:
            bias = _mask_bias(positions, k_pos, **mask_kw)
            out = _attend(qq, k, v, bias, cfg.attn_softcap, scale, cfg.attn_scores_bf16)

    out = ax.constrain(out.reshape(B, S, H, hd), bt, None, "model", None)
    y = ax.constrain(jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cdt)), bt, None, None)

    if blk.cross_attn:
        assert enc_out is not None, "cross-attn block needs encoder output"
        cq = jnp.einsum("bsd,dhk->bshk", x, p["c_wq"].astype(cdt)).reshape(B, S, Hkv, G, hd)
        ck = jnp.einsum("bsd,dhk->bshk", enc_out, p["c_wk"].astype(cdt))
        cv = jnp.einsum("bsd,dhk->bshk", enc_out, p["c_wv"].astype(cdt))
        zero = jnp.zeros((B, S, ck.shape[1]), jnp.float32)
        cout = _attend(cq, ck, cv, zero, None, scale).reshape(B, S, H, hd)
        y = y + jnp.einsum("bshk,hkd->bsd", cout, p["c_wo"].astype(cdt))
    return y, new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# ---------------------------------------------------------------------------


def init_mla(key, cfg: ModelCfg, blk: BlockDef):
    m = cfg.mla
    ks = jax.random.split(key, 6)
    D, H = cfg.d_model, cfg.n_heads
    qd = m.qk_nope_dim + m.qk_rope_dim
    return {
        "wq": _dense_init(ks[0], (D, H, qd)),
        "w_dkv": _dense_init(ks[1], (D, m.kv_lora + m.qk_rope_dim)),
        "kv_norm": init_rmsnorm(m.kv_lora),
        "w_uk": _dense_init(ks[2], (m.kv_lora, H, m.qk_nope_dim)),
        "w_uv": _dense_init(ks[3], (m.kv_lora, H, m.v_head_dim)),
        "wo": _dense_init(ks[4], (H, m.v_head_dim, D), scale=1.0 / math.sqrt(H * m.v_head_dim)),
    }


def mla_apply(p, x, cfg: ModelCfg, blk: BlockDef, *, positions, cache=None, **_):
    m = cfg.mla
    B, S, D = x.shape
    H = cfg.n_heads
    cdt = cfg.dtype
    bt = ax.batch_axes()
    q = ax.constrain(jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cdt)), bt, None, "model", None)
    q_nope, q_rope = q[..., : m.qk_nope_dim], q[..., m.qk_nope_dim :]
    ckv = jnp.einsum("bsd,dk->bsk", x, p["w_dkv"].astype(cdt))
    c_kv, k_rope = ckv[..., : m.kv_lora], ckv[..., m.kv_lora :]
    c_kv = rmsnorm_apply(p["kv_norm"], c_kv, cfg.norm_eps)

    cos, sin = rope_frequencies(m.qk_rope_dim, positions, blk.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)[:, :, 0, :]  # shared across heads

    scale = 1.0 / math.sqrt(m.qk_nope_dim + m.qk_rope_dim)
    # Absorbed form: score = q_nope·(W_uk c) + q_rope·k_rope  — works for both
    # train (full-seq latents) and decode (latent cache), and is the MLA memory win.
    q_eff = jnp.einsum("bshn,khn->bshk", q_nope, p["w_uk"].astype(cdt))
    new_cache = None
    if cache is not None:
        idx = cache["pos"]
        cc = jax.lax.dynamic_update_slice_in_dim(cache["c_kv"], c_kv, idx, axis=1)
        cr = jax.lax.dynamic_update_slice_in_dim(cache["k_rope"], k_rope, idx, axis=1)
        new_cache = {"c_kv": cc, "k_rope": cr, "pos": idx + S}
        c_all, r_all = cc, cr
        k_pos = jnp.broadcast_to(
            jnp.arange(cc.shape[1], dtype=jnp.int32)[None, :], (B, cc.shape[1]))
        bias = _mask_bias(positions, k_pos, causal=True, window=None, prefix_len=None)
    else:
        c_all, r_all = c_kv, k_rope
        bias = _mask_bias(positions, positions, causal=True, window=None, prefix_len=None)

    def _mla_attend(q_eff_c, q_rope_c, bias_c):
        s_nope = jnp.einsum("bshk,btk->bhst", q_eff_c, c_all)
        s_rope = jnp.einsum("bshr,btr->bhst", q_rope_c, r_all)
        sc = (s_nope + s_rope).astype(jnp.float32) * scale
        sc = sc + bias_c[:, None, :, :]  # bias [B,Sq,Sk] or [B,1,Sk]
        w = jax.nn.softmax(sc, axis=-1).astype(cdt)
        return jnp.einsum("bhst,btk->bshk", w, c_all)  # attend over latents

    qc = cfg.attn_q_chunk
    if qc and S > qc and S % qc == 0:
        # bound the [B,H,Sq,Sk] score working set: scan over q chunks
        n = S // qc
        k_pos_full = jnp.broadcast_to(
            jnp.arange(c_all.shape[1], dtype=jnp.int32)[None, :], (B, c_all.shape[1]))

        def body(_, xs):
            qe, qr, pos_c = xs
            b_c = _mask_bias(pos_c, k_pos_full, causal=True, window=None, prefix_len=None)
            return None, _mla_attend(qe, qr, b_c)

        qe_s = q_eff.reshape(B, n, qc, H, -1).swapaxes(0, 1)
        qr_s = q_rope.reshape(B, n, qc, H, -1).swapaxes(0, 1)
        pos_s = positions.reshape(B, n, qc).swapaxes(0, 1)
        _, ctxs = jax.lax.scan(body, None, (qe_s, qr_s, pos_s), unroll=cfg.unroll)
        ctx = ctxs.swapaxes(0, 1).reshape(B, S, H, -1)
    else:
        ctx = _mla_attend(q_eff, q_rope, bias)
    out = ax.constrain(jnp.einsum("bshk,khv->bshv", ctx, p["w_uv"].astype(cdt)), bt, None, "model", None)
    y = ax.constrain(jnp.einsum("bshv,hvd->bsd", out, p["wo"].astype(cdt)), bt, None, None)
    return y, new_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def init_mlp(key, d_model, d_ff, kind):
    ks = jax.random.split(key, 3)
    if kind in ("swiglu", "geglu"):
        return {
            "w_gate": _dense_init(ks[0], (d_model, d_ff)),
            "w_up": _dense_init(ks[1], (d_model, d_ff)),
            "w_down": _dense_init(ks[2], (d_ff, d_model)),
        }
    return {
        "w_up": _dense_init(ks[0], (d_model, d_ff)),
        "w_down": _dense_init(ks[1], (d_ff, d_model)),
    }


def mlp_apply(p, x, kind, dtype):
    bt = ax.batch_axes()
    if kind in ("swiglu", "geglu"):
        act = jax.nn.silu if kind == "swiglu" else lambda z: jax.nn.gelu(z, approximate=True)
        g = act(ax.constrain(jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(dtype)), bt, None, "model"))
        u = ax.constrain(jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(dtype)), bt, None, "model")
        return ax.constrain(jnp.einsum("bsf,fd->bsd", g * u, p["w_down"].astype(dtype)), bt, None, None)
    h = jax.nn.gelu(ax.constrain(jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(dtype)), bt, None, "model"), approximate=True)
    return ax.constrain(jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(dtype)), bt, None, None)


# ---------------------------------------------------------------------------
# MoE — sort-based capacity dispatch (MegaBlocks-style, dense-shape friendly)
# ---------------------------------------------------------------------------


def init_moe(key, cfg: ModelCfg):
    mc = cfg.moe
    ks = jax.random.split(key, 5)
    D, E, F = cfg.d_model, mc.n_experts, mc.d_ff_expert
    p = {
        "router": _dense_init(ks[0], (D, E)),
        "moe_gate": _dense_init(ks[1], (E, D, F)),
        "moe_up": _dense_init(ks[2], (E, D, F)),
        "moe_down": _dense_init(ks[3], (E, F, D)),
    }
    if mc.n_shared:
        p["shared"] = init_mlp(ks[4], D, mc.d_ff_shared, "swiglu")
    return p


def moe_apply(p, x, cfg: ModelCfg):
    """Top-k token-choice MoE with capacity; sort-based dispatch (no [T,E,C] one-hot).

    Returns (y, aux_loss).
    """
    mc = cfg.moe
    B, S, D = x.shape
    T = B * S
    E, K = mc.n_experts, mc.top_k
    cdt = cfg.dtype
    bt = ax.batch_axes()
    xf = ax.constrain(x.reshape(T, D), bt, None)

    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, K)  # [T,K]
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)

    # load-balance aux loss (Switch-style)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(idx[:, 0], E, dtype=jnp.float32), axis=0)
    aux = mc.router_aux_weight * E * jnp.sum(me * ce)

    C = int(max(8, math.ceil(mc.capacity_factor * K * T / E)))
    C = min(C, T)
    flat_e = idx.reshape(-1)  # [T*K]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=E)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    pos_in_e = jnp.arange(T * K, dtype=jnp.int32) - starts[sorted_e].astype(jnp.int32)
    keep = pos_in_e < C
    slot = jnp.where(keep, sorted_e * C + pos_in_e, E * C)  # sentinel = E*C
    token_of = order // K

    # slot -> source-token map (1D int scatter; row values never enter the scatter,
    # so XLA does not materialize [rows, D] index maps)
    s2src = jnp.full((E * C + 1,), T, jnp.int32).at[slot].set(
        jnp.where(keep, token_of.astype(jnp.int32), T), mode="drop")
    xf_pad = jnp.concatenate([xf.astype(cdt), jnp.zeros((1, D), cdt)], axis=0)
    h_in = ax.constrain(xf_pad[s2src[: E * C]].reshape(E, C, D), "model", None, None)
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", h_in, p["moe_gate"].astype(cdt)))
    u = jnp.einsum("ecd,edf->ecf", h_in, p["moe_up"].astype(cdt))
    h_out = ax.constrain(jnp.einsum("ecf,efd->ecd", g * u, p["moe_down"].astype(cdt)),
                         "model", None, None)
    out_all = jnp.concatenate([h_out.reshape(E * C, D), jnp.zeros((1, D), cdt)], axis=0)

    # invert the sort: slot for each (t, k); row gather back to tokens
    slot_unsorted = jnp.zeros((T * K,), jnp.int32).at[order].set(slot)
    gathered = ax.constrain(out_all[slot_unsorted].reshape(T, K, D), bt, None, None)
    y = ax.constrain(
        jnp.sum(gathered.astype(jnp.float32) * gates[:, :, None], axis=1), bt, None
    ).astype(cdt)
    y = y.reshape(B, S, D)
    if mc.n_shared:
        y = y + mlp_apply(p["shared"], x, "swiglu", cdt)
    return y, aux


# ---------------------------------------------------------------------------
# Mamba2 (SSD) block — chunked state-space duality algorithm, pure jnp
# ---------------------------------------------------------------------------


def ssm_dims(cfg: ModelCfg):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    conv_ch = d_inner + 2 * s.n_groups * s.d_state
    return d_inner, n_heads, conv_ch


def init_ssm(key, cfg: ModelCfg):
    s = cfg.ssm
    d_inner, n_heads, conv_ch = ssm_dims(cfg)
    ks = jax.random.split(key, 4)
    return {
        "in_proj": _dense_init(ks[0], (cfg.d_model, 2 * d_inner + 2 * s.n_groups * s.d_state + n_heads)),
        "conv_w": _dense_init(ks[1], (s.d_conv, conv_ch), scale=1.0 / math.sqrt(s.d_conv)),
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)).astype(jnp.float32),
        "ssm_D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.exp(jax.random.uniform(ks[2], (n_heads,), jnp.float32, math.log(1e-3), math.log(1e-1))))),
        "gate_norm": init_rmsnorm(d_inner),
        "out_proj": _dense_init(ks[3], (d_inner, cfg.d_model)),
    }


def _ssd_chunked(xbc_x, B_, C_, dt, A, chunk, h0=None, unroll=False):
    """Chunked SSD core. x [b,S,H,P]; B_,C_ [b,S,G,N]; dt [b,S,H]; A [H] (negative).

    Returns (y [b,S,H,P], h_final [b,H,N,P]). fp32 state math (Mamba-2 SSD alg;
    matmul-dominant so MXU-friendly). h0: optional initial state.
    """
    b, S, H, Pdim = xbc_x.shape
    G = B_.shape[2]
    N = B_.shape[3]
    nc = S // chunk
    x = xbc_x.reshape(b, nc, chunk, H, Pdim).astype(jnp.float32)
    Bc = B_.reshape(b, nc, chunk, G, N).astype(jnp.float32)
    Cc = C_.reshape(b, nc, chunk, G, N).astype(jnp.float32)
    dtc = dt.reshape(b, nc, chunk, H).astype(jnp.float32)
    rep = H // G

    da = dtc * A[None, None, None, :]  # [b,nc,c,H]  (negative)
    cum = jnp.cumsum(da, axis=2)  # within-chunk cumulative
    # intra-chunk: L[i,j] = exp(cum_i - cum_j) * (i>=j)
    Li = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # [b,nc,ci,cj,H]
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    Lmat = jnp.where(tri[None, None, :, :, None], jnp.exp(Li), 0.0)
    # scores S_ij = C_i · B_j  (per head via group map)
    Bh = jnp.repeat(Bc, rep, axis=3) if G != H else Bc  # [b,nc,c,H,N]
    Ch = jnp.repeat(Cc, rep, axis=3) if G != H else Cc
    scores = jnp.einsum("bnihs,bnjhs->bnijh", Ch, Bh)  # [b,nc,ci,cj,H]
    y_intra = jnp.einsum("bnijh,bnjh,bnjhp->bnihp", scores * Lmat, dtc, x)

    # chunk summary states: S_n = sum_j exp(cum_end - cum_j) dt_j B_j x_j^T
    seg_end = cum[:, :, -1:, :]  # [b,nc,1,H]
    w_end = jnp.exp(seg_end - cum)  # [b,nc,c,H]
    states = jnp.einsum("bnch,bnchs,bnchp->bnhsp", w_end * dtc, Bh, x)
    # inter-chunk recurrence over nc: H_{n+1} = exp(seg_end_n) H_n + S_n
    decay = jnp.exp(seg_end[:, :, 0, :])  # [b,nc,H]

    def scan_body(h, inp):
        st, dc = inp  # st [b,H,N,P], dc [b,H]
        h_new = h * dc[:, :, None, None] + st
        return h_new, h

    if h0 is None:
        h0 = jnp.zeros((b, H, N, Pdim), jnp.float32)
    h_last, h_prev = jax.lax.scan(
        scan_body, h0, (states.swapaxes(0, 1), decay.swapaxes(0, 1)), unroll=unroll
    )  # h_prev [nc,b,H,N,P] = state entering each chunk
    h_prev = h_prev.swapaxes(0, 1)  # [b,nc,H,N,P]
    w_in = jnp.exp(cum)  # decay from chunk start to position i
    y_inter = jnp.einsum("bnch,bnchs,bnhsp->bnchp", w_in, Ch, h_prev)
    y = (y_intra + y_inter).reshape(b, S, H, Pdim)
    return y, h_last


def ssm_apply(p, x, cfg: ModelCfg, *, cache=None, **_):
    """Mamba2 block. cache: None (full seq) or dict(conv [b,d_conv-1,ch], state [b,H,N,P], pos)."""
    s = cfg.ssm
    d_inner, n_heads, conv_ch = ssm_dims(cfg)
    B_, S, D = x.shape
    cdt = cfg.dtype
    bt = ax.batch_axes()
    zxbcdt = ax.constrain(jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(cdt)), bt, None, None)
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner : d_inner + conv_ch]
    dt_raw = zxbcdt[..., d_inner + conv_ch :]  # [b,S,H]

    new_cache = None
    if cache is None:
        pad = jnp.zeros((B_, s.d_conv - 1, conv_ch), xbc.dtype)
        xpad = jnp.concatenate([pad, xbc], axis=1)
        conv = sum(
            xpad[:, i : i + S, :] * p["conv_w"][i].astype(cdt) for i in range(s.d_conv)
        ) + p["conv_b"].astype(cdt)
    else:
        xpad = jnp.concatenate([cache["conv"].astype(xbc.dtype), xbc], axis=1)
        conv = sum(
            xpad[:, i : i + S, :] * p["conv_w"][i].astype(cdt) for i in range(s.d_conv)
        ) + p["conv_b"].astype(cdt)
        new_conv = xpad[:, S:, :]
    xbc = jax.nn.silu(conv)
    xs = xbc[..., :d_inner].reshape(B_, S, n_heads, s.head_dim)
    Bmat = xbc[..., d_inner : d_inner + s.n_groups * s.d_state].reshape(B_, S, s.n_groups, s.d_state)
    Cmat = xbc[..., d_inner + s.n_groups * s.d_state :].reshape(B_, S, s.n_groups, s.d_state)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # [b,S,H]
    A = -jnp.exp(p["A_log"])  # [H]

    if cache is None or S > 1:
        chunk = min(s.chunk, S)
        if S % chunk != 0:
            chunk = S  # smoke-test sizes
        h0 = None if cache is None else cache["state"]
        if h0 is None and kernel_backend(cfg) != "ref":
            # fused SSD scan kernel (train path: zero initial state); VMEM-resident
            # inter-chunk state instead of XLA-materialized per-chunk tensors.
            # Backward is the reverse-scan kernel from saved chunk-boundary states.
            y, new_state = kdis.dispatch_grad(
                "ssd_scan", xs, dt, A, Bmat, Cmat,
                backend=kernel_backend(cfg), chunk=chunk)
            y = y.astype(jnp.float32)
        else:
            y, new_state = _ssd_chunked(xs, Bmat, Cmat, dt, A, chunk, h0=h0,
                                        unroll=cfg.unroll)
    else:
        # single-step recurrence: h' = exp(dt A) h + dt B x
        rep = n_heads // s.n_groups
        Bh = jnp.repeat(Bmat[:, 0], rep, axis=1) if s.n_groups != n_heads else Bmat[:, 0]
        Ch = jnp.repeat(Cmat[:, 0], rep, axis=1) if s.n_groups != n_heads else Cmat[:, 0]
        da = jnp.exp(dt[:, 0] * A[None, :])  # [b,H]
        h = cache["state"] * da[:, :, None, None] + jnp.einsum(
            "bh,bhn,bhp->bhnp", dt[:, 0], Bh.astype(jnp.float32), xs[:, 0].astype(jnp.float32)
        )
        y = jnp.einsum("bhn,bhnp->bhp", Ch.astype(jnp.float32), h)[:, None]
        new_state = h
    y = y + xs.astype(jnp.float32) * p["ssm_D"][None, None, :, None]
    y = ax.constrain(y.reshape(B_, S, d_inner).astype(cdt), bt, None, "model")
    y = rmsnorm_apply(p["gate_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = ax.constrain(jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(cdt)), bt, None, None)
    if cache is not None:
        new_cache = {"conv": new_conv, "state": new_state, "pos": cache["pos"] + S}
    return out, new_cache
