"""LM assembly: layer-program execution (prelude + scanned pattern + encoder),
staged splitting for pipeline parallelism, loss, and serving (prefill/decode).

The canonical parameter layout is *monolithic*; `split_stages` cuts it into P
contiguous stage pytrees for the async-PP engine. Stage functions are built from a
static "op list" so dense/moe/ssm/enc-dec/vlm archs all flow through one code path.
"""
from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from . import layers as L
from .layers import BlockDef, ModelCfg
from repro.parallel import ax

# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_block(key, cfg: ModelCfg, blk: BlockDef):
    ks = jax.random.split(key, 4)
    p: dict = {"pre_norm": L.init_rmsnorm(cfg.d_model)}
    if blk.mixer == "attn":
        p["mixer"] = L.init_mla(ks[0], cfg, blk) if cfg.mla else L.init_attention(ks[0], cfg, blk)
    elif blk.mixer == "ssm":
        p["mixer"] = L.init_ssm(ks[0], cfg)
    elif blk.mixer == "shared_attn":
        # params live in the model-level 'shared' slot; per-occurrence output proj
        p["shared_out_proj"] = L._dense_init(ks[0], (cfg.d_model, cfg.d_model))
    if cfg.use_post_norm and blk.mixer != "none":
        p["post_mixer_norm"] = L.init_rmsnorm(cfg.d_model)
    if blk.mlp == "moe":
        p["mlp_norm"] = L.init_rmsnorm(cfg.d_model)
        p["moe"] = L.init_moe(ks[1], cfg)
    elif blk.mlp != "none":
        p["mlp_norm"] = L.init_rmsnorm(cfg.d_model)
        p["mlp"] = L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, blk.mlp)
    if cfg.use_post_norm and blk.mlp != "none":
        p["post_mlp_norm"] = L.init_rmsnorm(cfg.d_model)
    return p


def _has_shared(cfg: ModelCfg) -> bool:
    return any(b.mixer == "shared_attn" for b in cfg.pattern + cfg.prelude)


def init_lm(key, cfg: ModelCfg):
    ks = iter(jax.random.split(key, 16 + len(cfg.prelude)))
    D, V = cfg.d_model, cfg.vocab_size
    # embed ~ N(0, 1/D): inputs get x*sqrt(D) scaling (unit variance) and tied
    # logits h @ E^T stay O(1).
    params: dict = {"tok_embed": L._dense_init(next(ks), (V, D), scale=1.0 / math.sqrt(D))}
    if not cfg.tie_embeddings:
        params["lm_head"] = L._dense_init(next(ks), (D, V))
    params["final_norm"] = L.init_rmsnorm(D)

    if cfg.enc_periods:
        kk = next(ks)
        def enc_one(k):
            kb = jax.random.split(k, len(cfg.enc_pattern))
            return {f"b{j}": init_block(kb[j], cfg, blk) for j, blk in enumerate(cfg.enc_pattern)}
        params["enc_scan"] = jax.vmap(enc_one)(jax.random.split(kk, cfg.enc_periods))
        params["enc_final_norm"] = L.init_rmsnorm(D)

    params["prelude"] = {
        f"p{i}": init_block(next(ks), cfg, blk) for i, blk in enumerate(cfg.prelude)
    }

    kk = next(ks)
    def one(k):
        kb = jax.random.split(k, len(cfg.pattern))
        return {f"b{j}": init_block(kb[j], cfg, blk) for j, blk in enumerate(cfg.pattern)}
    params["scan"] = jax.vmap(one)(jax.random.split(kk, cfg.n_periods))

    if _has_shared(cfg):
        shared_blk = BlockDef(mixer="attn", mlp="swiglu")
        params["shared"] = init_block(next(ks), cfg, shared_blk)
    return params


# ---------------------------------------------------------------------------
# Block application
# ---------------------------------------------------------------------------


def block_apply(bp, blk: BlockDef, x, cfg: ModelCfg, *, positions, prefix_len=None,
                enc_out=None, cache=None, shared=None, iota_positions=False,
                paging=None):
    """Returns (x, aux, new_cache). iota_positions: static flag — True when
    `positions` is a generated arange (enables position-free fused attention)."""
    x = ax.constrain(x, ax.batch_axes(), None, None)
    aux = jnp.zeros((), jnp.float32)
    new_cache = cache
    if blk.mixer == "shared_attn":
        sblk = BlockDef(mixer="attn", mlp="swiglu", rope_theta=blk.rope_theta)
        h = L.rmsnorm_apply(shared["pre_norm"], x, cfg.norm_eps)
        h, new_mix_cache = L.attention_apply(
            shared["mixer"], h, cfg, sblk, positions=positions, prefix_len=prefix_len,
            cache=None if cache is None else cache.get("mixer"),
            iota_positions=iota_positions)
        h = h + L.mlp_apply(shared["mlp"], L.rmsnorm_apply(shared["mlp_norm"], h, cfg.norm_eps),
                            "swiglu", cfg.dtype)
        h = jnp.einsum("bsd,de->bse", h, bp["shared_out_proj"].astype(cfg.dtype))
        x = x + h
        if cache is not None:
            new_cache = {"mixer": new_mix_cache}
        return x, aux, new_cache

    mix_h = None  # mixer output, residual-add deferred so it can fuse with mlp_norm
    if blk.mixer != "none":
        h = L.rmsnorm_apply(bp["pre_norm"], x, cfg.norm_eps)
        if blk.mixer == "attn":
            fn = L.mla_apply if cfg.mla else L.attention_apply
            h, new_mix_cache = fn(bp["mixer"], h, cfg, blk, positions=positions,
                                  prefix_len=prefix_len, enc_out=enc_out,
                                  cache=None if cache is None else cache.get("mixer"),
                                  iota_positions=iota_positions, paging=paging)
        elif blk.mixer == "ssm":
            h, new_mix_cache = L.ssm_apply(bp["mixer"], h, cfg,
                                           cache=None if cache is None else cache.get("mixer"))
        if cfg.use_post_norm:
            h = L.rmsnorm_apply(bp["post_mixer_norm"], h, cfg.norm_eps)
        mix_h = h
    else:
        new_mix_cache = None

    if blk.mlp != "none":
        S = x.shape[1]
        ck = cfg.mlp_s_chunk
        chunked = ck and S > ck and S % ck == 0
        # mid-block boundary `x += mix_h; h = rmsnorm(x)` as ONE fused kernel pass
        fuse = (mix_h is not None and not cfg.use_post_norm and not chunked
                and blk.mlp != "moe" and L.kernel_backend(cfg) != "ref")
        if mix_h is not None and not fuse:
            x = x + mix_h

        def channel_mix(xc):
            h = L.rmsnorm_apply(bp["mlp_norm"], xc, cfg.norm_eps)
            if blk.mlp == "moe":
                h, a = L.moe_apply(bp["moe"], h, cfg)
            else:
                h, a = L.mlp_apply(bp["mlp"], h, blk.mlp, cfg.dtype), jnp.zeros((), jnp.float32)
            if cfg.use_post_norm:
                h = L.rmsnorm_apply(bp["post_mlp_norm"], h, cfg.norm_eps)
            return h, a

        if fuse:
            x, hn = L.fused_rmsnorm_residual(bp["mlp_norm"], x, mix_h, cfg)
            h = L.mlp_apply(bp["mlp"], hn, blk.mlp, cfg.dtype)
            a = jnp.zeros((), jnp.float32)
        elif chunked:
            # bound the channel-mix working set (MoE dispatch buffers scale with
            # tokens): scan over sequence chunks; capacity becomes per-chunk.
            xs = x.reshape(x.shape[0], S // ck, ck, -1).swapaxes(0, 1)
            _, (hs, auxs) = jax.lax.scan(
                lambda _, xc: (None, channel_mix(xc)), None, xs, unroll=cfg.unroll)
            h = hs.swapaxes(0, 1).reshape(x.shape)
            a = jnp.sum(auxs)
        else:
            h, a = channel_mix(x)
        aux = aux + a
        x = x + h
    elif mix_h is not None:
        x = x + mix_h

    if cache is not None:
        new_cache = {"mixer": new_mix_cache}
    return x, aux, new_cache


def _scan_blocks(scan_params, pattern, x, cfg, *, positions, prefix_len=None,
                 enc_out=None, caches=None, shared=None, j0=0, j1=None,
                 iota_positions=False, paging=None):
    """Run periods [j0, j1) of the scanned pattern. caches: stacked pytree or None."""
    n = (j1 if j1 is not None else jax.tree.leaves(scan_params)[0].shape[0]) - j0
    if n <= 0:
        return x, jnp.zeros((), jnp.float32), caches
    sl = jax.tree.map(lambda a: jax.lax.slice_in_dim(a, j0, j0 + n, axis=0), scan_params)
    csl = None if caches is None else jax.tree.map(
        lambda a: jax.lax.slice_in_dim(a, j0, j0 + n, axis=0), caches)

    def body(carry, xs):
        xx, aux = carry
        bp, cc = xs
        new_cc = {} if cc is not None else None
        for j, blk in enumerate(pattern):
            xx, a, nc = block_apply(bp[f"b{j}"], blk, xx, cfg, positions=positions,
                                    prefix_len=prefix_len, enc_out=enc_out,
                                    cache=None if cc is None else cc[f"b{j}"],
                                    shared=shared, iota_positions=iota_positions,
                                    paging=paging)
            aux = aux + a
            if new_cc is not None:
                new_cc[f"b{j}"] = nc
        return (xx, aux), new_cc

    if cfg.remat and caches is None:
        body = jax.checkpoint(body, prevent_cse=False)
    (x, aux), new_caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), (sl, csl),
                                        unroll=cfg.unroll)
    if caches is not None:
        caches = jax.tree.map(
            lambda full, new: jax.lax.dynamic_update_slice_in_dim(full, new, j0, axis=0),
            caches, new_caches)
    return x, aux, caches


# ---------------------------------------------------------------------------
# Stage program (op lists)
# ---------------------------------------------------------------------------


def build_ops(cfg: ModelCfg):
    """Full ordered op list for the layer program."""
    ops = []
    if cfg.enc_periods:
        ops.append(("frames_in",))
        for j in range(cfg.enc_periods):
            ops.append(("enc_blocks", j, j + 1))
        ops.append(("enc_out",))
    ops.append(("embed",))
    for i in range(len(cfg.prelude)):
        ops.append(("prelude", i))
    for j in range(cfg.n_periods):
        ops.append(("blocks", j, j + 1))
    ops.append(("head",))
    return ops


def split_ops(cfg: ModelCfg, n_stages: int):
    """Split op list into n_stages contiguous chunks, weighting block ops only."""
    ops = build_ops(cfg)
    weights = [1 if o[0] in ("enc_blocks", "prelude", "blocks") else 0 for o in ops]
    total = sum(weights)
    per = total / n_stages
    chunks, cur, acc, done = [], [], 0.0, 0
    for o, w in zip(ops, weights):
        cur.append(o)
        acc += w
        if w and acc >= per * (done + 1) - 1e-9 and done < n_stages - 1:
            chunks.append(cur)
            cur = []
            done += 1
    chunks.append(cur)
    while len(chunks) < n_stages:  # degenerate tiny models
        chunks.append([])
    # merge consecutive block ranges for fewer scans
    merged = []
    for ch in chunks:
        m = []
        for o in ch:
            if m and o[0] == m[-1][0] and o[0] in ("blocks", "enc_blocks") and m[-1][2] == o[1]:
                m[-1] = (o[0], m[-1][1], o[2])
            else:
                m.append(list(o) if o[0] in ("blocks", "enc_blocks") else o)
        merged.append([tuple(o) if isinstance(o, list) else o for o in m])
    return merged


def stage_param_names(cfg: ModelCfg, ops):
    names = set()
    for o in ops:
        if o[0] == "enc_blocks":
            names.add("enc_scan")
        elif o[0] == "enc_out":
            names.add("enc_final_norm")
        elif o[0] == "embed":
            names.add("tok_embed")
        elif o[0] == "prelude":
            names.add("prelude")
        elif o[0] == "blocks":
            names.add("scan")
            if _has_shared(cfg):
                names.add("shared")
        elif o[0] == "head":
            names.add("final_norm")
            if cfg.tie_embeddings:
                names.add("tok_embed")
            else:
                names.add("lm_head")
    return names


def split_stages(params, cfg: ModelCfg, n_stages: int):
    """Cut monolithic params into per-stage pytrees (scan leaves sliced by period).

    Returns (stage_params_list, stage_ops_list). Block-op period indices in the
    returned ops are *local* to each stage's sliced scan stack, so the op lists are
    pure static metadata and the stage params stay clean jnp pytrees.
    """
    op_chunks = split_ops(cfg, n_stages)
    stages, local_ops = [], []
    for ops in op_chunks:
        sp: dict = {}
        names = stage_param_names(cfg, ops)
        rebased = []
        offsets = {}
        for nm in names:
            if nm in ("scan", "enc_scan"):
                kind = "blocks" if nm == "scan" else "enc_blocks"
                ranges = [(o[1], o[2]) for o in ops if o[0] == kind]
                j0, j1 = ranges[0][0], ranges[-1][1]
                sp[nm] = jax.tree.map(lambda a: a[j0:j1], params[nm])
                offsets[kind] = j0
            elif nm == "prelude":
                idxs = [o[1] for o in ops if o[0] == "prelude"]
                sp["prelude"] = {f"p{i}": params["prelude"][f"p{i}"] for i in idxs}
            else:
                sp[nm] = params[nm]
        for o in ops:
            if o[0] in ("blocks", "enc_blocks"):
                rebased.append((o[0], o[1] - offsets[o[0]], o[2] - offsets[o[0]]))
            else:
                rebased.append(o)
        stages.append(sp)
        local_ops.append(rebased)
    return stages, local_ops


def _embed(params, cfg: ModelCfg, batch):
    x = params["tok_embed"].astype(cfg.dtype)[batch["tokens"]] * math.sqrt(cfg.d_model)
    if cfg.n_prefix_img and "patches" in batch:
        n = cfg.n_prefix_img
        x = jnp.concatenate([batch["patches"].astype(cfg.dtype), x[:, n:, :]], axis=1)
    return ax.constrain(x, ax.batch_axes(), None, None)


def _head_logits(sp, cfg: ModelCfg, h):
    w = (sp["tok_embed"].T if cfg.tie_embeddings else sp["lm_head"]).astype(cfg.dtype)
    logits = ax.constrain(jnp.einsum("bsd,dv->bsv", h, w), ax.batch_axes(), None, "model")
    if cfg.final_softcap:
        logits = L.softcap(logits.astype(jnp.float32), cfg.final_softcap)
    return logits


def _xent(logits, labels, onehot=False):
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    if onehot:  # gather-free (partial-manual shard_map safe)
        oh = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32)
        tgt = jnp.sum(logits * oh, axis=-1)
    else:
        tgt = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.sum(lse - tgt)


def _head_loss(sp, cfg: ModelCfg, h, batch):
    labels = batch["labels"]
    B, S = labels.shape
    h = L.rmsnorm_apply(sp["final_norm"], h, cfg.norm_eps)
    if cfg.xent_chunk and S % cfg.xent_chunk == 0 and S > cfg.xent_chunk:
        n = S // cfg.xent_chunk
        hs = h.reshape(B, n, cfg.xent_chunk, -1).swapaxes(0, 1)
        ls = labels.reshape(B, n, cfg.xent_chunk).swapaxes(0, 1)

        def body(tot, xs):
            hh, ll = xs
            return tot + _xent(_head_logits(sp, cfg, hh), ll, cfg.onehot_xent), None

        body = jax.checkpoint(body, prevent_cse=False) if cfg.remat else body
        tot, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hs, ls), unroll=cfg.unroll)
    else:
        tot = _xent(_head_logits(sp, cfg, h), labels, cfg.onehot_xent)
    return tot / (B * S)


def run_stage_ops(sp, ops, carry, batch, cfg: ModelCfg, *, caches=None):
    """Interpret one stage's op list. carry: dict(x, enc, aux) -> updated carry.

    If the stage contains 'head', carry gains 'loss'.
    """
    x, enc, aux = carry.get("x"), carry.get("enc"), carry["aux"]
    if caches is not None:
        caches = dict(caches)  # avoid mutating caller's top-level dict
    for o in ops:
        if o[0] == "frames_in":
            x = batch["frames"].astype(cfg.dtype)
        elif o[0] == "enc_blocks":
            B, S = x.shape[0], x.shape[1]
            pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
            x, a, _ = _scan_blocks(sp["enc_scan"], cfg.enc_pattern, x, cfg,
                                   positions=pos, j0=o[1], j1=o[2],
                                   iota_positions=True)
            aux = aux + a
        elif o[0] == "enc_out":
            enc = L.rmsnorm_apply(sp["enc_final_norm"], x, cfg.norm_eps)
            x = None
        elif o[0] == "embed":
            x = _embed(sp, cfg, batch)
        elif o[0] in ("prelude", "blocks"):
            B, S = x.shape[0], x.shape[1]
            positions = batch.get("positions")
            iota = positions is None  # static: batch-supplied positions may be
            # packed/reset sequences, which the fused attention path must not see
            if positions is None:
                positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
            prefix_len = batch.get("prefix_len")
            paging = batch.get("paging")
            if o[0] == "prelude":
                blk = cfg.prelude[o[1]]
                cc = None if caches is None else caches["prelude"][f"p{o[1]}"]
                x, a, nc = block_apply(sp["prelude"][f"p{o[1]}"], blk, x, cfg,
                                       positions=positions, prefix_len=prefix_len,
                                       enc_out=enc, cache=cc, shared=sp.get("shared"),
                                       iota_positions=iota, paging=paging)
                if caches is not None:
                    caches["prelude"] = dict(caches["prelude"])
                    caches["prelude"][f"p{o[1]}"] = nc
            else:
                cs = None if caches is None else caches["scan"]
                x, a, cs = _scan_blocks(sp["scan"], cfg.pattern, x, cfg,
                                        positions=positions, prefix_len=prefix_len,
                                        enc_out=enc, caches=cs, shared=sp.get("shared"),
                                        j0=o[1], j1=o[2], iota_positions=iota,
                                        paging=paging)
                if caches is not None:
                    caches["scan"] = cs
            aux = aux + a
        elif o[0] == "head":
            loss = _head_loss(sp, cfg, x, batch)
            return {"x": None, "enc": None, "aux": aux, "loss": loss + aux}, caches
    return {"x": x, "enc": enc, "aux": aux}, caches


# ---------------------------------------------------------------------------
# Monolithic convenience API
# ---------------------------------------------------------------------------


def lm_loss(params, batch, cfg: ModelCfg):
    """Full-model loss (single-stage path)."""
    stages, op_chunks = split_stages(params, cfg, 1)
    carry = {"x": None, "enc": None, "aux": jnp.zeros((), jnp.float32)}
    carry, _ = run_stage_ops(stages[0], op_chunks[0], carry, batch, cfg)
    return carry["loss"]


def forward_hidden(params, batch, cfg: ModelCfg, *, caches=None):
    """Run everything except the head; returns (h, caches)."""
    stages, op_chunks = split_stages(params, cfg, 1)
    ops = [o for o in op_chunks[0] if o[0] != "head"]
    carry = {"x": None, "enc": None, "aux": jnp.zeros((), jnp.float32)}
    carry, caches = run_stage_ops(stages[0], ops, carry, batch, cfg, caches=caches)
    return carry["x"], carry.get("enc"), caches


# ---------------------------------------------------------------------------
# Serving: cache init, prefill, decode
# ---------------------------------------------------------------------------


def _init_block_cache(cfg: ModelCfg, blk: BlockDef, batch_size, max_len):
    if blk.mixer == "attn" and cfg.mla:
        m = cfg.mla
        return {"mixer": {
            "c_kv": jnp.zeros((batch_size, max_len, m.kv_lora), cfg.dtype),
            "k_rope": jnp.zeros((batch_size, max_len, m.qk_rope_dim), cfg.dtype),
            "pos": jnp.zeros((), jnp.int32)}}
    if blk.mixer in ("attn", "shared_attn"):
        eff_len = max_len if blk.window is None else min(max_len, blk.window)
        # NOTE: we do not ring-buffer windows in the baseline; window layers still
        # allocate full cache (hillclimb target), except obvious wins could trim.
        return {"mixer": {
            "k": jnp.zeros((batch_size, max_len, cfg.n_kv_heads, cfg.head_dim), cfg.dtype),
            "v": jnp.zeros((batch_size, max_len, cfg.n_kv_heads, cfg.head_dim), cfg.dtype),
            "pos": jnp.zeros((), jnp.int32)}}
    if blk.mixer == "ssm":
        d_inner, n_heads, conv_ch = L.ssm_dims(cfg)
        s = cfg.ssm
        return {"mixer": {
            "conv": jnp.zeros((batch_size, s.d_conv - 1, conv_ch), cfg.dtype),
            "state": jnp.zeros((batch_size, n_heads, s.d_state, s.head_dim), jnp.float32),
            "pos": jnp.zeros((), jnp.int32)}}
    return {"mixer": None}


def init_caches(cfg: ModelCfg, batch_size, max_len):
    def stack(n, mk):
        one = mk()
        return jax.tree.map(lambda a: jnp.broadcast_to(a[None], (n,) + a.shape).copy() if a is not None else None, one)

    caches = {
        "prelude": {f"p{i}": _init_block_cache(cfg, blk, batch_size, max_len)
                    for i, blk in enumerate(cfg.prelude)},
        "scan": stack(cfg.n_periods, lambda: {
            f"b{j}": _init_block_cache(cfg, blk, batch_size, max_len)
            for j, blk in enumerate(cfg.pattern)}),
    }
    return caches


def serve_prefill(params, batch, cfg: ModelCfg, max_len=None, last_pos=None):
    """Process the full prompt, fill caches, return (last_logits, caches).

    last_pos: optional [B] int32 of each row's true last prompt position —
    ragged prompts right-padded to a common S read their logits there instead
    of at S-1 (padding never leaks backwards under the causal mask).
    """
    B, S = batch["tokens"].shape
    max_len = max_len or S
    caches = init_caches(cfg, B, max_len)
    h, enc, caches = forward_hidden(params, batch, cfg, caches=caches)
    if last_pos is None:
        h_last = h[:, -1:, :]
    else:
        h_last = jnp.take_along_axis(h, last_pos[:, None, None].astype(jnp.int32), axis=1)
    h_last = L.rmsnorm_apply(params["final_norm"], h_last, cfg.norm_eps)
    logits = _head_logits(params, cfg, h_last)
    if cfg.enc_periods:
        caches["enc_out"] = enc
    return logits, caches


def serve_decode(params, caches, tokens, cfg: ModelCfg, pos):
    """One-token decode. tokens [B,1]; pos scalar int32 (current length)."""
    B = tokens.shape[0]
    batch = {"tokens": tokens, "positions": jnp.broadcast_to(pos[None, None], (B, 1)).astype(jnp.int32)}
    if cfg.n_prefix_img:
        batch = dict(batch)  # patches only matter at prefill
    stages, op_chunks = split_stages(params, cfg, 1)
    ops = [o for o in op_chunks[0] if o[0] not in ("head", "frames_in", "enc_blocks", "enc_out")]
    carry = {"x": None, "enc": caches.get("enc_out"), "aux": jnp.zeros((), jnp.float32)}
    carry, caches2 = run_stage_ops(stages[0], ops, carry, batch, cfg, caches=caches)
    h = L.rmsnorm_apply(params["final_norm"], carry["x"], cfg.norm_eps)
    logits = _head_logits(params, cfg, h)
    if cfg.enc_periods:
        caches2["enc_out"] = caches.get("enc_out")
    return logits, caches2


# ---------------------------------------------------------------------------
# Paged serving: shared KV page pools + per-slot SSD state (continuous batching)
# ---------------------------------------------------------------------------
#
# Layout: attention layers cache into ONE pool per layer of fixed-size pages
# [n_pages, page_size, Hkv, hd]; a sequence owns a chain of page ids (its page
# table row) and pages return to the allocator at retirement — the stash.py
# mod-indexed ring discipline applied to serving memory (write slot is
# `length // page_size` into the table, `length % page_size` into the page).
# SSD (mamba2) layers keep their O(1)-per-sequence recurrent state per decode
# SLOT, not per page. MLA latent caches and shared-attn blocks are not paged.


def _init_block_paged(cfg: ModelCfg, blk: BlockDef, n_slots, n_pages, page_size):
    if blk.mixer == "attn" and cfg.mla:
        raise NotImplementedError("paged serving: MLA latent caches not supported")
    if blk.mixer == "shared_attn":
        raise NotImplementedError("paged serving: shared-attn blocks not supported")
    if blk.mixer == "attn":
        return {"mixer": {
            "k_pages": jnp.zeros((n_pages, page_size, cfg.n_kv_heads, cfg.head_dim), cfg.dtype),
            "v_pages": jnp.zeros((n_pages, page_size, cfg.n_kv_heads, cfg.head_dim), cfg.dtype)}}
    if blk.mixer == "ssm":
        d_inner, n_heads, conv_ch = L.ssm_dims(cfg)
        s = cfg.ssm
        return {"mixer": {
            "conv": jnp.zeros((n_slots, s.d_conv - 1, conv_ch), cfg.dtype),
            "state": jnp.zeros((n_slots, n_heads, s.d_state, s.head_dim), jnp.float32),
            "pos": jnp.zeros((), jnp.int32)}}
    return {"mixer": None}


def init_paged_caches(cfg: ModelCfg, n_slots, n_pages, page_size):
    """Paged decode caches: n_slots concurrent sequences over n_pages shared pages."""
    if cfg.enc_periods:
        raise NotImplementedError("paged serving: encoder-decoder archs not supported")

    def stack(n, mk):
        one = mk()
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n,) + a.shape).copy() if a is not None else None,
            one)

    return {
        "prelude": {f"p{i}": _init_block_paged(cfg, blk, n_slots, n_pages, page_size)
                    for i, blk in enumerate(cfg.prelude)},
        "scan": stack(cfg.n_periods, lambda: {
            f"b{j}": _init_block_paged(cfg, blk, n_slots, n_pages, page_size)
            for j, blk in enumerate(cfg.pattern)}),
    }


def _tree_pool_dims(paged):
    """(n_pages, page_size) from the first attention pool; (None, None) if pure-SSM."""
    for leaf_name in ("k_pages",):
        found = []

        def visit(d):
            if isinstance(d, dict):
                if leaf_name in d:
                    found.append(d[leaf_name])
                for v in d.values():
                    visit(v)

        visit(paged)
        if found:
            shp = found[0].shape  # [..., n_pages, PS, Hkv, hd]
            return shp[-4], shp[-3]
    return None, None


def _map_mixers(paged, dense_or_new, fn):
    """Apply fn(paged_mixer, other_mixer, stacked) over the block-cache tree."""
    out = {"prelude": {}, "scan": {}}
    for k, blkc in paged["prelude"].items():
        out["prelude"][k] = {"mixer": fn(blkc["mixer"], dense_or_new["prelude"][k]["mixer"], False)}
    for k, blkc in paged["scan"].items():
        out["scan"][k] = {"mixer": fn(blkc["mixer"], dense_or_new["scan"][k]["mixer"], True)}
    return out


def write_prefill_pages(paged, dense, page_ids, slot, page_size):
    """Scatter one request's dense prefill caches (batch==1) into the pools.

    page_ids: [ceil(S/page_size)] int32 allocated page ids (chain order).
    slot: scalar int32 decode slot for per-slot (SSD) state. Jit-friendly:
    page_ids/slot may be traced; shapes are static per prefill bucket.
    """

    def copy(pg, dn, stacked):
        if pg is None:
            return pg
        if "k_pages" in pg:
            def put(pool, kv):
                S = kv.shape[-3]
                npg = page_ids.shape[0]
                pad = npg * page_size - S
                if pad:
                    widths = [(0, 0)] * kv.ndim
                    widths[-3] = (0, pad)
                    kv = jnp.pad(kv, widths)
                pages = kv.reshape(kv.shape[:-4] + (npg, page_size) + kv.shape[-2:])
                return pool.at[:, page_ids].set(pages) if stacked else pool.at[page_ids].set(pages)
            return {"k_pages": put(pg["k_pages"], dn["k"]),
                    "v_pages": put(pg["v_pages"], dn["v"])}
        if "state" in pg:
            def put(pool, st):
                return pool.at[:, slot].set(st[:, 0]) if stacked else pool.at[slot].set(st[0])
            return {**pg, "conv": put(pg["conv"], dn["conv"]),
                    "state": put(pg["state"], dn["state"])}
        return pg

    return _map_mixers(paged, dense, copy)


def _freeze_inactive(old, new, active):
    """Keep per-slot recurrent state (SSD conv/state) frozen on inactive lanes.

    Page pools need no masking — inactive lanes' writes were dropped — but the
    SSD recurrence always advances its whole [n_slots] batch."""

    def merge(o, n, stacked):
        if o is None or n is None or "state" not in o:
            return n
        axis = 1 if stacked else 0

        def mrg(a, b):
            shp = [1] * b.ndim
            shp[axis] = -1
            return jnp.where(active.reshape(shp), b, a)

        return {**n, "conv": mrg(o["conv"], n["conv"]), "state": mrg(o["state"], n["state"])}

    return _map_mixers(old, new, merge)


def serve_decode_paged(params, caches, tokens, cfg: ModelCfg, page_table, lengths, active):
    """One continuous-batching decode step over the n_slots decode lanes.

    tokens [B,1] current tokens; page_table [B, max_pages] int32 (unused entries
    must hold any in-range page id); lengths [B] int32 tokens already cached per
    slot (== the position of this step's token); active [B] bool. Inactive
    lanes compute garbage but write nothing: pool writes are dropped and SSD
    state is re-frozen. Returns (logits [B, vocab], new_caches).
    """
    B = tokens.shape[0]
    n_pages, page_size = _tree_pool_dims(caches)
    paging = None
    if n_pages is not None:
        wp = jnp.where(active, page_table[jnp.arange(B), lengths // page_size], n_pages)
        paging = {
            "page_table": page_table.astype(jnp.int32),
            "write_page": wp.astype(jnp.int32),
            "write_off": (lengths % page_size).astype(jnp.int32),
            "read_len": (lengths + active.astype(jnp.int32)).astype(jnp.int32),
        }
    batch = {"tokens": tokens, "positions": lengths[:, None].astype(jnp.int32)}
    if paging is not None:
        batch["paging"] = paging
    stages, op_chunks = split_stages(params, cfg, 1)
    ops = [o for o in op_chunks[0] if o[0] not in ("head", "frames_in", "enc_blocks", "enc_out")]
    carry = {"x": None, "enc": None, "aux": jnp.zeros((), jnp.float32)}
    carry, caches2 = run_stage_ops(stages[0], ops, carry, batch, cfg, caches=caches)
    caches2 = _freeze_inactive(caches, caches2, active)
    h = L.rmsnorm_apply(params["final_norm"], carry["x"], cfg.norm_eps)
    logits = _head_logits(params, cfg, h)
    return logits[:, -1], caches2
