"""Optimizers for async-PP training.

Uniform interface (per-stage application by the async engine):

    opt = make_optimizer(kind, lr=..., b1=..., ...)
    state = opt.init(params)
    new_params, new_state, aux = opt.update(params, grads, state, lr_scale=..., mom=..., t=...)

`lr_scale` and `mom` are traced per-stage scalars (Eq. 13 stage-dependent schedules);
`mom` overrides the momentum coefficient when not None. `aux` carries method hooks:
  - 'lookahead': the point the *next* forward should be evaluated at (Eq. 10), or None
  - 'step_dir':  the (undamped) per-step direction estimate, used by XPipe / PipeMare
  - 'last_step': w_{t+1} - w_t (for Prop.-1 alignment metrics)

`nadam_flat` is the kernel-fused variant of `nadam` (same math, same interface):
per-stage params/m/v live in contiguous fp32 flat buffers built once at `init`,
and the whole update is ONE dispatched `nag_update` kernel pass per stage per
tick instead of a tree-map of per-leaf XLA kernels — the optimizer tick is pure
HBM bandwidth at scale, so pass count is the cost model (DESIGN.md §8).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels import dispatch as kdispatch

PyTree = Any


class Optimizer(NamedTuple):
    init: Callable[[PyTree], PyTree]
    update: Callable[..., tuple]
    kind: str


def _tmap(f, *trees):
    return jax.tree.map(f, *trees)


def _zeros_like_f32(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


# ---------------------------------------------------------------------------
# AdamW (baselines: GPipe, PipeDream, PipeMare, LR variants)
# ---------------------------------------------------------------------------


def adamw(lr, b1=0.9, b2=0.95, eps=1e-8, wd=0.01):
    def init(params):
        return {"m": _zeros_like_f32(params), "v": _zeros_like_f32(params),
                "count": jnp.zeros((), jnp.int32)}

    def update(params, grads, state, *, lr_scale=1.0, mom=None, t=None):
        c = state["count"] + 1
        beta1 = b1 if mom is None else mom
        m = _tmap(lambda m_, g: beta1 * m_ + (1 - beta1) * g.astype(jnp.float32), state["m"], grads)
        v = _tmap(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)), state["v"], grads)
        bc1 = 1 - beta1 ** c.astype(jnp.float32)
        bc2 = 1 - b2 ** c.astype(jnp.float32)
        eta = lr * lr_scale

        def step(p, m_, v_):
            upd = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            return (p.astype(jnp.float32) * (1 - eta * wd) - eta * upd).astype(p.dtype)

        new_params = _tmap(step, params, m, v)
        step_dir = _tmap(lambda np_, p: np_.astype(jnp.float32) - p.astype(jnp.float32), new_params, params)
        aux = {"lookahead": None, "step_dir": step_dir, "last_step": step_dir}
        return new_params, {"m": m, "v": v, "count": c}, aux

    return Optimizer(init, update, "adamw")


# ---------------------------------------------------------------------------
# NAdam — THE paper's practical method ("Ours"): NAdam with beta1=0.99, decoupled wd.
# PyTorch-faithful momentum warmup mu_t = b1 * (1 - 0.5 * 0.96^(t*psi)).
# ---------------------------------------------------------------------------


def nadam(lr, b1=0.99, b2=0.95, eps=1e-8, wd=0.01, psi=0.004, discount=True):
    """discount=False gives PipeDream-NAG-Base (Fig. 7 ablation: no (1-mu) factor)."""

    def _mu(c, base):
        return base * (1.0 - 0.5 * 0.96 ** (c.astype(jnp.float32) * psi))

    def init(params):
        return {"m": _zeros_like_f32(params), "v": _zeros_like_f32(params),
                "count": jnp.zeros((), jnp.int32),
                "mu_prod": jnp.ones((), jnp.float32)}

    def update(params, grads, state, *, lr_scale=1.0, mom=None, t=None):
        c = state["count"] + 1
        base = b1 if mom is None else mom
        mu_t = _mu(c, base)
        mu_next = _mu(c + 1, base)
        mu_prod = state["mu_prod"] * mu_t
        mu_prod_next = mu_prod * mu_next
        beta1 = base
        m = _tmap(lambda m_, g: beta1 * m_ + (1 - beta1) * g.astype(jnp.float32), state["m"], grads)
        v = _tmap(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)), state["v"], grads)
        bc2 = 1 - b2 ** c.astype(jnp.float32)
        eta = lr * lr_scale

        def step(p, m_, v_, g):
            g = g.astype(jnp.float32)
            denom = jnp.sqrt(v_ / bc2) + eps
            if discount:
                mhat = mu_next * m_ / (1 - mu_prod_next) + (1 - mu_t) * g / (1 - mu_prod)
            else:
                # ablation: remove the (1-mu) gradient discounting -> staleness blows up
                mhat = mu_next * m_ / (1 - mu_prod_next) + g
            return (p.astype(jnp.float32) * (1 - eta * wd) - eta * mhat / denom).astype(p.dtype)

        new_params = _tmap(step, params, m, v, grads)
        step_dir = _tmap(lambda np_, p: np_.astype(jnp.float32) - p.astype(jnp.float32), new_params, params)
        aux = {"lookahead": None, "step_dir": step_dir, "last_step": step_dir}
        return new_params, {"m": m, "v": v, "count": c, "mu_prod": mu_prod}, aux

    return Optimizer(init, update, "nadam")


# ---------------------------------------------------------------------------
# Flat-buffer fused NAdam: contiguous fp32 p/m/v + one nag_update kernel pass.
# ---------------------------------------------------------------------------


def flatten_tree(tree) -> jnp.ndarray:
    """Concatenate all leaves into one contiguous fp32 vector (fixed leaf order)."""
    leaves = jax.tree.leaves(tree)
    if not leaves:
        return jnp.zeros((0,), jnp.float32)
    return jnp.concatenate([jnp.ravel(l).astype(jnp.float32) for l in leaves])


def unflatten_like(flat, like):
    """Slice a flat vector back into the shapes/dtypes of `like` (layout inverse).

    `like` leaves only need .shape/.dtype (arrays or ShapeDtypeStructs).
    """
    leaves, treedef = jax.tree.flatten(like)
    out, off = [], 0
    for l in leaves:
        n = 1
        for d in l.shape:
            n *= int(d)
        out.append(flat[off:off + n].reshape(l.shape).astype(l.dtype))
        off += n
    return jax.tree.unflatten(treedef, out)


def nadam_flat(lr, b1=0.99, b2=0.95, eps=1e-8, wd=0.01, psi=0.004, discount=True,
               backend="pallas", block=1024):
    """Kernel-fused `nadam`: identical math, flat fp32 buffers, one pass per tick.

    State = {'flat': {'p','m','v'}, 'count', 'mu_prod'}. The flat 'p' is the
    master copy (params are fp32 in this repo, so it's bit-identical to the tree
    params); `update` flattens only the incoming grads, runs the dispatched
    `nag_update` kernel once over the stage's whole parameter vector, and
    unflattens the result back into the caller's pytree layout.
    """

    def _mu(c, base):
        return base * (1.0 - 0.5 * 0.96 ** (c.astype(jnp.float32) * psi))

    def init(params):
        flat = flatten_tree(params)
        return {"flat": {"p": flat, "m": jnp.zeros_like(flat), "v": jnp.zeros_like(flat)},
                "count": jnp.zeros((), jnp.int32),
                "mu_prod": jnp.ones((), jnp.float32)}

    def update(params, grads, state, *, lr_scale=1.0, mom=None, t=None):
        c = state["count"] + 1
        base = b1 if mom is None else mom
        mu_t = _mu(c, base)
        mu_next = _mu(c + 1, base)
        mu_prod = state["mu_prod"] * mu_t
        mu_prod_next = mu_prod * mu_next
        bc2 = 1 - b2 ** c.astype(jnp.float32)
        eta = lr * lr_scale
        pf, mf, vf = state["flat"]["p"], state["flat"]["m"], state["flat"]["v"]
        if pf.size == 0:  # degenerate empty stage
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            aux = {"lookahead": None, "step_dir": zeros, "last_step": zeros}
            return params, {"flat": dict(state["flat"]), "count": c, "mu_prod": mu_prod}, aux
        gf = flatten_tree(grads)
        p2, m2, v2 = kdispatch.dispatch(
            "nag_update", pf, mf, vf, gf, backend=backend,
            lr=eta, b1=base, b2=b2, eps=eps, wd=wd, mu_t=mu_t, mu_next=mu_next,
            mu_prod=mu_prod, mu_prod_next=mu_prod_next, bc2=bc2,
            discount=discount, block=block)
        new_params = unflatten_like(p2, params)
        step_dir = unflatten_like(p2 - pf, jax.tree.map(
            lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params))
        aux = {"lookahead": None, "step_dir": step_dir, "last_step": step_dir}
        return new_params, {"flat": {"p": p2, "m": m2, "v": v2},
                            "count": c, "mu_prod": mu_prod}, aux

    return Optimizer(init, update, "nadam_flat")


# ---------------------------------------------------------------------------
# SGD-NAG, exact Eq. (10) form — used for the convergence-theory tests and the
# 'ours_theory' engine mode (gradients evaluated at the *stashed look-ahead*).
# ---------------------------------------------------------------------------


def sgd_nag(lr, gamma=None, discount=True, wd=0.0):
    """gamma=None -> theory schedule gamma_t=(t-2)/t (clipped at 0); else constant.

    update:  d_t = gamma_t (w_t - w_{t-1})
             w_{t+1} = w_t + d_t - lr * (1-gamma_t) * g      (discount=True, Eq. 10)
             w_{t+1} = w_t + d_t - lr * g                    (discount=False, NAG-Base)
    aux['lookahead'] = w_{t+1} + gamma_{t+1} (w_{t+1} - w_t)
    """

    def _gamma(c):
        cf = c.astype(jnp.float32)
        return jnp.maximum((cf - 2.0) / jnp.maximum(cf, 1.0), 0.0) if gamma is None else jnp.asarray(gamma, jnp.float32)

    def init(params):
        # jnp.array copies, so 'prev' never aliases the live params buffer
        return {"prev": jax.tree.map(lambda p: jnp.array(p, jnp.float32), params),
                "count": jnp.zeros((), jnp.int32)}

    def update(params, grads, state, *, lr_scale=1.0, mom=None, t=None):
        c = state["count"] + 1
        g_t = _gamma(c) if mom is None else mom
        g_next = _gamma(c + 1) if mom is None else mom
        eta = lr * lr_scale
        coef = (1 - g_t) if discount else 1.0

        def step(p, pv, g):
            p32 = p.astype(jnp.float32)
            d = g_t * (p32 - pv)
            return (p32 * (1 - eta * wd) + d - eta * coef * g.astype(jnp.float32)).astype(p.dtype)

        new_params = _tmap(step, params, state["prev"], grads)
        prev = jax.tree.map(lambda p: p.astype(jnp.float32), params)
        look = _tmap(
            lambda np_, p: (np_.astype(jnp.float32) + g_next * (np_.astype(jnp.float32) - p.astype(jnp.float32))).astype(np_.dtype),
            new_params, params)
        step_dir = _tmap(lambda np_, p: np_.astype(jnp.float32) - p.astype(jnp.float32), new_params, params)
        aux = {"lookahead": look, "step_dir": step_dir, "last_step": step_dir}
        return new_params, {"prev": prev, "count": c}, aux

    return Optimizer(init, update, "sgd_nag")


FUSABLE = {"nadam": nadam_flat,
           "nadam_nodiscount": lambda **kw: nadam_flat(discount=False, **kw)}


def make_optimizer(kind: str, *, fused: bool = False, kernel_backend: str = "pallas",
                   **kw) -> Optimizer:
    """`fused=True` routes fusable kinds through the flat-buffer kernel path
    (backend per `kernel_backend`); non-fusable kinds ignore the flag."""
    if fused and kind in FUSABLE:
        return FUSABLE[kind](backend=kernel_backend, **kw)
    if kind == "adamw":
        return adamw(**kw)
    if kind == "nadam":
        return nadam(**kw)
    if kind == "nadam_nodiscount":
        return nadam(discount=False, **kw)
    if kind == "sgd_nag":
        return sgd_nag(**kw)
    if kind == "sgd_nag_nodiscount":
        return sgd_nag(discount=False, **kw)
    raise ValueError(f"unknown optimizer {kind}")
