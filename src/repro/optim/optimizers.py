"""Optimizers for async-PP training.

Uniform interface (per-stage application by the async engine):

    opt = make_optimizer(kind, lr=..., b1=..., ...)
    state = opt.init(params)
    new_params, new_state, aux = opt.update(params, grads, state, lr_scale=..., mom=..., t=...)

`lr_scale` and `mom` are traced per-stage scalars (Eq. 13 stage-dependent schedules);
`mom` overrides the momentum coefficient when not None. `aux` carries method hooks:
  - 'lookahead': the point the *next* forward should be evaluated at (Eq. 10), or None
  - 'step_dir':  the (undamped) per-step direction estimate, used by XPipe / PipeMare
  - 'last_step': w_{t+1} - w_t (for Prop.-1 alignment metrics)

`nadam_flat` is the kernel-fused variant of `nadam` (same math, same interface):
per-stage params/m/v live in contiguous fp32 flat buffers built once at `init`,
and the whole update is ONE dispatched `nag_update` kernel pass per stage per
tick instead of a tree-map of per-leaf XLA kernels — the optimizer tick is pure
HBM bandwidth at scale, so pass count is the cost model (DESIGN.md §8).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels import dispatch as kdispatch

PyTree = Any


class Optimizer(NamedTuple):
    init: Callable[[PyTree], PyTree]
    update: Callable[..., tuple]
    kind: str


def _tmap(f, *trees):
    return jax.tree.map(f, *trees)


def _zeros_like_f32(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


# ---------------------------------------------------------------------------
# AdamW (baselines: GPipe, PipeDream, PipeMare, LR variants)
# ---------------------------------------------------------------------------


def adamw(lr, b1=0.9, b2=0.95, eps=1e-8, wd=0.01):
    def init(params):
        return {"m": _zeros_like_f32(params), "v": _zeros_like_f32(params),
                "count": jnp.zeros((), jnp.int32)}

    def update(params, grads, state, *, lr_scale=1.0, mom=None, t=None):
        c = state["count"] + 1
        beta1 = b1 if mom is None else mom
        m = _tmap(lambda m_, g: beta1 * m_ + (1 - beta1) * g.astype(jnp.float32), state["m"], grads)
        v = _tmap(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)), state["v"], grads)
        bc1 = 1 - beta1 ** c.astype(jnp.float32)
        bc2 = 1 - b2 ** c.astype(jnp.float32)
        eta = lr * lr_scale

        def step(p, m_, v_):
            upd = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            return (p.astype(jnp.float32) * (1 - eta * wd) - eta * upd).astype(p.dtype)

        new_params = _tmap(step, params, m, v)
        step_dir = _tmap(lambda np_, p: np_.astype(jnp.float32) - p.astype(jnp.float32), new_params, params)
        aux = {"lookahead": None, "step_dir": step_dir, "last_step": step_dir}
        return new_params, {"m": m, "v": v, "count": c}, aux

    return Optimizer(init, update, "adamw")


# ---------------------------------------------------------------------------
# NAdam — THE paper's practical method ("Ours"): NAdam with beta1=0.99, decoupled wd.
# PyTorch-faithful momentum warmup mu_t = b1 * (1 - 0.5 * 0.96^(t*psi)).
# ---------------------------------------------------------------------------


def nadam(lr, b1=0.99, b2=0.95, eps=1e-8, wd=0.01, psi=0.004, discount=True):
    """discount=False gives PipeDream-NAG-Base (Fig. 7 ablation: no (1-mu) factor)."""

    def _mu(c, base):
        return base * (1.0 - 0.5 * 0.96 ** (c.astype(jnp.float32) * psi))

    def init(params):
        return {"m": _zeros_like_f32(params), "v": _zeros_like_f32(params),
                "count": jnp.zeros((), jnp.int32),
                "mu_prod": jnp.ones((), jnp.float32)}

    def update(params, grads, state, *, lr_scale=1.0, mom=None, t=None):
        c = state["count"] + 1
        base = b1 if mom is None else mom
        mu_t = _mu(c, base)
        mu_next = _mu(c + 1, base)
        mu_prod = state["mu_prod"] * mu_t
        mu_prod_next = mu_prod * mu_next
        beta1 = base
        m = _tmap(lambda m_, g: beta1 * m_ + (1 - beta1) * g.astype(jnp.float32), state["m"], grads)
        v = _tmap(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)), state["v"], grads)
        bc2 = 1 - b2 ** c.astype(jnp.float32)
        eta = lr * lr_scale

        def step(p, m_, v_, g):
            g = g.astype(jnp.float32)
            denom = jnp.sqrt(v_ / bc2) + eps
            if discount:
                mhat = mu_next * m_ / (1 - mu_prod_next) + (1 - mu_t) * g / (1 - mu_prod)
            else:
                # ablation: remove the (1-mu) gradient discounting -> staleness blows up
                mhat = mu_next * m_ / (1 - mu_prod_next) + g
            return (p.astype(jnp.float32) * (1 - eta * wd) - eta * mhat / denom).astype(p.dtype)

        new_params = _tmap(step, params, m, v, grads)
        step_dir = _tmap(lambda np_, p: np_.astype(jnp.float32) - p.astype(jnp.float32), new_params, params)
        aux = {"lookahead": None, "step_dir": step_dir, "last_step": step_dir}
        return new_params, {"m": m, "v": v, "count": c, "mu_prod": mu_prod}, aux

    return Optimizer(init, update, "nadam")


# ---------------------------------------------------------------------------
# Flat-buffer fused NAdam: contiguous fp32 p/m/v + one nag_update kernel pass.
# ---------------------------------------------------------------------------


def flatten_tree(tree) -> jnp.ndarray:
    """Concatenate all leaves into one contiguous fp32 vector (fixed leaf order)."""
    leaves = jax.tree.leaves(tree)
    if not leaves:
        return jnp.zeros((0,), jnp.float32)
    return jnp.concatenate([jnp.ravel(l).astype(jnp.float32) for l in leaves])


def unflatten_like(flat, like):
    """Slice a flat vector back into the shapes/dtypes of `like` (layout inverse).

    `like` leaves only need .shape/.dtype (arrays or ShapeDtypeStructs).
    """
    leaves, treedef = jax.tree.flatten(like)
    out, off = [], 0
    for l in leaves:
        n = 1
        for d in l.shape:
            n *= int(d)
        out.append(flat[off:off + n].reshape(l.shape).astype(l.dtype))
        off += n
    return jax.tree.unflatten(treedef, out)


def nadam_flat(lr, b1=0.99, b2=0.95, eps=1e-8, wd=0.01, psi=0.004, discount=True,
               backend="pallas", block=1024):
    """Kernel-fused `nadam`: identical math, flat fp32 buffers, one pass per tick.

    State = {'flat': {'p','m','v'}, 'count', 'mu_prod'}. The flat 'p' is the
    master copy (params are fp32 in this repo, so it's bit-identical to the tree
    params); `update` flattens only the incoming grads, runs the dispatched
    `nag_update` kernel once over the stage's whole parameter vector, and
    unflattens the result back into the caller's pytree layout.
    """

    def _mu(c, base):
        return base * (1.0 - 0.5 * 0.96 ** (c.astype(jnp.float32) * psi))

    def init(params):
        flat = flatten_tree(params)
        return {"flat": {"p": flat, "m": jnp.zeros_like(flat), "v": jnp.zeros_like(flat)},
                "count": jnp.zeros((), jnp.int32),
                "mu_prod": jnp.ones((), jnp.float32)}

    def update(params, grads, state, *, lr_scale=1.0, mom=None, t=None):
        c = state["count"] + 1
        base = b1 if mom is None else mom
        mu_t = _mu(c, base)
        mu_next = _mu(c + 1, base)
        mu_prod = state["mu_prod"] * mu_t
        mu_prod_next = mu_prod * mu_next
        bc2 = 1 - b2 ** c.astype(jnp.float32)
        eta = lr * lr_scale
        pf, mf, vf = state["flat"]["p"], state["flat"]["m"], state["flat"]["v"]
        if pf.size == 0:  # degenerate empty stage
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            aux = {"lookahead": None, "step_dir": zeros, "last_step": zeros}
            return params, {"flat": dict(state["flat"]), "count": c, "mu_prod": mu_prod}, aux
        gf = flatten_tree(grads)
        p2, m2, v2 = kdispatch.dispatch(
            "nag_update", pf, mf, vf, gf, backend=backend,
            lr=eta, b1=base, b2=b2, eps=eps, wd=wd, mu_t=mu_t, mu_next=mu_next,
            mu_prod=mu_prod, mu_prod_next=mu_prod_next, bc2=bc2,
            discount=discount, block=block)
        new_params = unflatten_like(p2, params)
        step_dir = unflatten_like(p2 - pf, jax.tree.map(
            lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params))
        aux = {"lookahead": None, "step_dir": step_dir, "last_step": step_dir}
        return new_params, {"flat": {"p": p2, "m": m2, "v": v2},
                            "count": c, "mu_prod": mu_prod}, aux

    return Optimizer(init, update, "nadam_flat")


# ---------------------------------------------------------------------------
# SGD-NAG, exact Eq. (10) form — used for the convergence-theory tests and the
# 'ours_theory' engine mode (gradients evaluated at the *stashed look-ahead*).
# ---------------------------------------------------------------------------


def sgd_nag(lr, gamma=None, discount=True, wd=0.0):
    """gamma=None -> theory schedule gamma_t=(t-2)/t (clipped at 0); else constant.

    update:  d_t = gamma_t (w_t - w_{t-1})
             w_{t+1} = w_t + d_t - lr * (1-gamma_t) * g      (discount=True, Eq. 10)
             w_{t+1} = w_t + d_t - lr * g                    (discount=False, NAG-Base)
    aux['lookahead'] = w_{t+1} + gamma_{t+1} (w_{t+1} - w_t)
    """

    def _gamma(c):
        cf = c.astype(jnp.float32)
        return jnp.maximum((cf - 2.0) / jnp.maximum(cf, 1.0), 0.0) if gamma is None else jnp.asarray(gamma, jnp.float32)

    def init(params):
        # jnp.array copies, so 'prev' never aliases the live params buffer
        return {"prev": jax.tree.map(lambda p: jnp.array(p, jnp.float32), params),
                "count": jnp.zeros((), jnp.int32)}

    def update(params, grads, state, *, lr_scale=1.0, mom=None, t=None):
        c = state["count"] + 1
        g_t = _gamma(c) if mom is None else mom
        g_next = _gamma(c + 1) if mom is None else mom
        eta = lr * lr_scale
        coef = (1 - g_t) if discount else 1.0

        def step(p, pv, g):
            p32 = p.astype(jnp.float32)
            d = g_t * (p32 - pv)
            return (p32 * (1 - eta * wd) + d - eta * coef * g.astype(jnp.float32)).astype(p.dtype)

        new_params = _tmap(step, params, state["prev"], grads)
        prev = jax.tree.map(lambda p: p.astype(jnp.float32), params)
        look = _tmap(
            lambda np_, p: (np_.astype(jnp.float32) + g_next * (np_.astype(jnp.float32) - p.astype(jnp.float32))).astype(np_.dtype),
            new_params, params)
        step_dir = _tmap(lambda np_, p: np_.astype(jnp.float32) - p.astype(jnp.float32), new_params, params)
        aux = {"lookahead": look, "step_dir": step_dir, "last_step": step_dir}
        return new_params, {"prev": prev, "count": c}, aux

    return Optimizer(init, update, "sgd_nag")


FUSABLE = {"nadam": nadam_flat,
           "nadam_nodiscount": lambda **kw: nadam_flat(discount=False, **kw)}


def make_optimizer(kind: str, *, fused: bool = False, kernel_backend: str = "pallas",
                   **kw) -> Optimizer:
    """`fused=True` routes fusable kinds through the flat-buffer kernel path
    (backend per `kernel_backend`); non-fusable kinds ignore the flag."""
    if fused and kind in FUSABLE:
        return FUSABLE[kind](backend=kernel_backend, **kw)
    if kind == "adamw":
        return adamw(**kw)
    if kind == "nadam":
        return nadam(**kw)
    if kind == "nadam_nodiscount":
        return nadam(discount=False, **kw)
    if kind == "sgd_nag":
        return sgd_nag(**kw)
    if kind == "sgd_nag_nodiscount":
        return sgd_nag(discount=False, **kw)
    raise ValueError(f"unknown optimizer {kind}")


# ---------------------------------------------------------------------------
# ZeRO-1 sharded NAdam: the flat fp32 p/m/v buffers are partitioned across R
# replicas — reduce-scatter the mean grad onto each rank's 1/R shard, run the
# SAME fused nag_update kernel on the shard, all-gather the params. The update
# math is identical to nadam_flat (the kernel is elementwise with shared
# scalars), only placement changes — so sharded and replicated trajectories
# are BITWISE equal, a pinned contract (tests/test_mesh.py contract a).
# ---------------------------------------------------------------------------


def zero1_shard_size(n: int, world: int) -> int:
    """Padded shard length S = ceil(n / world); every rank holds exactly S
    elements (the last rank zero-padded), so shard shapes are uniform and the
    all-gather is a plain concatenate + trim."""
    if world < 1:
        raise ValueError(f"world must be >= 1, got {world}")
    return -(-n // world) if n else 0


def zero1_shard(flat, rank: int, world: int):
    """Rank's shard of a flat vector, zero-padded to the uniform length S.

    The zero padding is inert through nag_update (m=v=g=0 keeps p=0), so the
    trim in `zero1_unshard` always recovers the exact unsharded vector.
    """
    n = flat.shape[0]
    S = zero1_shard_size(n, world)
    if not 0 <= rank < world:
        raise ValueError(f"rank {rank} out of range for world={world}")
    if S == 0:
        return flat[:0]
    seg = flat[min(rank * S, n):min(rank * S + S, n)]
    if seg.shape[0] == S:
        return seg
    return jnp.concatenate([seg, jnp.zeros((S - seg.shape[0],), flat.dtype)])


def zero1_unshard(shards, n: int):
    """All-gather inverse of zero1_shard: concatenate rank shards, trim padding."""
    if not shards:
        return jnp.zeros((0,), jnp.float32)
    return jnp.concatenate(list(shards))[:n]


def nadam_flat_sharded(lr, b1=0.99, b2=0.95, eps=1e-8, wd=0.01, psi=0.004,
                       discount=True, backend="pallas", block=1024, world=2):
    """ZeRO-1 collective form of nadam_flat: one state holds all `world` rank
    shards ({'shards': (rank0 {'p','m','v'}, ...), 'count', 'mu_prod'}), and
    `update` performs the full reduce-scatter -> shard-update -> all-gather
    round. `grads` may be a list/tuple of `world` per-replica grad trees
    (mean-reduced here, in replica-index order) or a single already-reduced
    tree. Single-process stand-in for the real collective: per-replica memory
    is one shard (3*S fp32), reported by `optimizer_memory_bytes`.
    """

    def _mu(c, base):
        return base * (1.0 - 0.5 * 0.96 ** (c.astype(jnp.float32) * psi))

    def init(params):
        flat = flatten_tree(params)
        shards = tuple(
            {"p": zero1_shard(flat, r, world),
             "m": jnp.zeros_like(zero1_shard(flat, r, world)),
             "v": jnp.zeros_like(zero1_shard(flat, r, world))}
            for r in range(world))
        return {"shards": shards, "count": jnp.zeros((), jnp.int32),
                "mu_prod": jnp.ones((), jnp.float32)}

    def update(params, grads, state, *, lr_scale=1.0, mom=None, t=None):
        c = state["count"] + 1
        base = b1 if mom is None else mom
        mu_t = _mu(c, base)
        mu_next = _mu(c + 1, base)
        mu_prod = state["mu_prod"] * mu_t
        mu_prod_next = mu_prod * mu_next
        bc2 = 1 - b2 ** c.astype(jnp.float32)
        eta = lr * lr_scale
        n = sum(int(jnp.size(l)) for l in jax.tree.leaves(params))
        if n == 0:
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            aux = {"lookahead": None, "step_dir": zeros, "last_step": zeros}
            return params, {"shards": state["shards"], "count": c,
                            "mu_prod": mu_prod}, aux
        if isinstance(grads, (list, tuple)):
            # reduce-scatter's reduce: per-element mean in replica-index order
            gf = sum(flatten_tree(g) for g in grads) / len(grads)
        else:
            gf = flatten_tree(grads)
        old_pf = zero1_unshard([s["p"] for s in state["shards"]], n)
        new_shards = []
        for r in range(world):
            s = state["shards"][r]
            g_r = zero1_shard(gf, r, world)
            p2, m2, v2 = kdispatch.dispatch(
                "nag_update", s["p"], s["m"], s["v"], g_r, backend=backend,
                lr=eta, b1=base, b2=b2, eps=eps, wd=wd, mu_t=mu_t,
                mu_next=mu_next, mu_prod=mu_prod, mu_prod_next=mu_prod_next,
                bc2=bc2, discount=discount, block=block)
            new_shards.append({"p": p2, "m": m2, "v": v2})
        pf = zero1_unshard([s["p"] for s in new_shards], n)
        new_params = unflatten_like(pf, params)
        step_dir = unflatten_like(pf - old_pf, jax.tree.map(
            lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params))
        aux = {"lookahead": None, "step_dir": step_dir, "last_step": step_dir}
        return new_params, {"shards": tuple(new_shards), "count": c,
                            "mu_prod": mu_prod}, aux

    return Optimizer(init, update, "nadam_flat_sharded")


def nadam_flat_shard(rank: int, world: int, lr=1.0, b1=0.99, b2=0.95, eps=1e-8,
                     wd=0.01, psi=0.004, discount=True, backend="pallas",
                     block=1024):
    """Owner-shard nadam_flat for one mesh replica: this rank persists ONLY its
    1/R shard of m/v plus the fp32 master copy of its own param segment
    ({'shard': {'p','m','v'}, 'count', 'mu_prod', 'rank', 'world'} — true 1/R
    optimizer memory, `optimizer_memory_bytes`). `update` steps the owned
    segment with the fused nag_update kernel and leaves non-owned coordinates
    untouched — between gossip absorptions they move only when partners'
    owned segments arrive (swarm.MeshTrainer opt_shard absorption). At
    zero-delay/full-fanout/every-round gossip this composes to exactly the
    collective `nadam_flat_sharded` step.
    """

    def _mu(c, base):
        return base * (1.0 - 0.5 * 0.96 ** (c.astype(jnp.float32) * psi))

    def init(params):
        flat = flatten_tree(params)
        shard = zero1_shard(flat, rank, world)
        return {"shard": {"p": shard, "m": jnp.zeros_like(shard),
                          "v": jnp.zeros_like(shard)},
                "count": jnp.zeros((), jnp.int32),
                "mu_prod": jnp.ones((), jnp.float32),
                "rank": jnp.asarray(rank, jnp.int32),
                "world": jnp.asarray(world, jnp.int32)}

    def update(params, grads, state, *, lr_scale=1.0, mom=None, t=None):
        c = state["count"] + 1
        base = b1 if mom is None else mom
        mu_t = _mu(c, base)
        mu_next = _mu(c + 1, base)
        mu_prod = state["mu_prod"] * mu_t
        mu_prod_next = mu_prod * mu_next
        bc2 = 1 - b2 ** c.astype(jnp.float32)
        eta = lr * lr_scale
        new_state = {"shard": dict(state["shard"]), "count": c,
                     "mu_prod": mu_prod, "rank": state["rank"],
                     "world": state["world"]}
        pf = flatten_tree(params)
        n = pf.shape[0]
        S = zero1_shard_size(n, world)
        lo, hi = rank * S, min(rank * S + S, n)
        if S == 0 or lo >= n:
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            aux = {"lookahead": None, "step_dir": zeros, "last_step": zeros}
            return params, new_state, aux
        s = state["shard"]
        g_r = zero1_shard(flatten_tree(grads), rank, world)
        p2, m2, v2 = kdispatch.dispatch(
            "nag_update", s["p"], s["m"], s["v"], g_r, backend=backend,
            lr=eta, b1=base, b2=b2, eps=eps, wd=wd, mu_t=mu_t, mu_next=mu_next,
            mu_prod=mu_prod, mu_prod_next=mu_prod_next, bc2=bc2,
            discount=discount, block=block)
        new_state["shard"] = {"p": p2, "m": m2, "v": v2}
        new_flat = jnp.concatenate([pf[:lo], p2[:hi - lo], pf[hi:]])
        new_params = unflatten_like(new_flat, params)
        seg_dir = p2[:hi - lo] - pf[lo:hi]
        dir_flat = jnp.concatenate([jnp.zeros((lo,), jnp.float32), seg_dir,
                                    jnp.zeros((n - hi,), jnp.float32)])
        step_dir = unflatten_like(dir_flat, jax.tree.map(
            lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params))
        aux = {"lookahead": None, "step_dir": step_dir, "last_step": step_dir}
        return new_params, new_state, aux

    return Optimizer(init, update, "nadam_flat_shard")


def optimizer_memory_bytes(state) -> int:
    """Persistent PER-REPLICA fp32 bytes of one stage's optimizer state
    (moment/master buffers only — scalar counters excluded). The number the
    ZeRO-1 memory claim is about: 'shard' and 'shards' layouts cost one rank's
    3*S floats; replicated flat costs 3*n (DESIGN.md §13 memory math).
    """
    if "shard" in state:
        return 4 * sum(int(x.size) for x in state["shard"].values())
    if "shards" in state:
        return 4 * max((sum(int(x.size) for x in s.values())
                        for s in state["shards"]), default=0)
    if "flat" in state:
        return 4 * sum(int(x.size) for x in state["flat"].values())
    if "m" in state:
        return 4 * sum(int(x.size) for x in
                       jax.tree.leaves(state["m"]) + jax.tree.leaves(state["v"]))
    if "prev" in state:
        return 4 * sum(int(x.size) for x in jax.tree.leaves(state["prev"]))
    return 0
