"""Gradient-forecasting delay corrections (paper Sec. 5.4 baselines).

- second_order: Taylor/delay compensation (Zheng et al. 2017):
      g_hat = g + lam * g (.) g (.) (w_t - w_bar)
  with the diagonal-Fisher Hessian approximation H ~ diag(g*g).

- polyfft: time-series forecasting of the gradient: 2nd-order polynomial trend over
  the last `hist` gradients + FFT phase-advance of the residual (Bloomfield 2004),
  predicting the gradient tau steps ahead.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp


def second_order_correct(grads, params_now, params_stale, lam=1.0):
    return jax.tree.map(
        lambda g, w, wb: (g.astype(jnp.float32)
                          + lam * g.astype(jnp.float32) ** 2 * (w.astype(jnp.float32) - wb.astype(jnp.float32))),
        grads, params_now, params_stale)


# ----- polynomial + FFT -----------------------------------------------------


def init_history(params, hist: int):
    return {
        "buf": jax.tree.map(lambda p: jnp.zeros((hist,) + p.shape, jnp.float32), params),
        "count": jnp.zeros((), jnp.int32),
    }


def push_history(state, grads, hist: int):
    t = state["count"]
    buf = jax.tree.map(
        lambda b, g: jax.lax.dynamic_update_index_in_dim(b, g.astype(jnp.float32), t % hist, 0),
        state["buf"], grads)
    return {"buf": buf, "count": t + 1}


def _poly_design(hist: int, tau):
    """Least-squares quadratic fit over t=0..hist-1, evaluated at t=hist-1+tau.

    Returns the weight vector w (length hist): prediction = w @ history.
    tau may be a static number (design folded into a constant at trace time,
    float64 numpy path — unchanged numerics) or a traced scalar (dynamic
    per-tick delay: the evaluation point moves with tau inside the program).
    The traced path is how observed staleness reaches the forecast: the event
    runtime / step(..., taus=...) feed per-stage entries of the measured tau
    vector (`RuntimeResult.taus`) here when the method's tau_source is
    "observed" (core/methods.py, DESIGN.md §10).
    """
    t = np.arange(hist, dtype=np.float64)
    X = np.stack([np.ones_like(t), t, t * t], axis=1)  # [hist, 3]
    pinv = np.linalg.pinv(X)  # [3, hist]
    if isinstance(tau, (int, float)):
        tq = hist - 1 + float(tau)
        q = np.array([1.0, tq, tq * tq])  # [3]
        return jnp.asarray(q @ pinv, jnp.float32)  # [hist]
    tq = jnp.asarray(tau, jnp.float32) + (hist - 1)
    q = jnp.stack([jnp.ones_like(tq), tq, tq * tq])  # [3]
    return q @ jnp.asarray(pinv, jnp.float32)  # [hist]


def polyfft_predict(state, hist: int, tau: float, fft_weight=0.5):
    """Forecast grad tau steps ahead from the ring buffer (ordered oldest->newest).

    tau may be static or traced, and fractional: at K > 1 it is the update's
    Method.tau_reduce collapse of the K per-microbatch observed delays (the
    "mean" default is fractional by construction) — the design matrix and FFT
    phase advance are continuous in tau, so no rounding is involved."""
    t = state["count"]
    w_poly = _poly_design(hist, tau)

    # FFT phase advance: x(t+tau)_k = X_k * exp(i 2 pi k tau / hist)
    k = jnp.arange(hist // 2 + 1, dtype=jnp.float32)
    phase = jnp.exp(1j * 2 * jnp.pi * k * (tau / hist))

    def pred(buf):
        # roll so that index 0 = oldest
        idx = (t + jnp.arange(hist)) % hist
        ordered = buf[idx]
        hb = ordered.reshape(hist, -1)
        poly = jnp.einsum("h,hn->n", w_poly, hb)
        trend = jnp.einsum("h,hn->n", w_poly * 0 + 1.0 / hist, hb)  # mean
        resid = hb - trend[None]
        F = jnp.fft.rfft(resid, axis=0)
        fwd = jnp.fft.irfft(F * phase[:, None], n=hist, axis=0)[-1]
        out = poly + fft_weight * fwd
        return out.reshape(buf.shape[1:])

    predicted = jax.tree.map(pred, state["buf"])
    # fall back to raw newest gradient until the buffer is warm
    def blend(p, b):
        newest = b[(t - 1) % hist]
        return jnp.where(t >= hist, p, newest)

    return jax.tree.map(blend, predicted, state["buf"])
