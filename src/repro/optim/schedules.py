"""LR schedules + the paper's stage-dependent corrections (Eq. 13)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(base_lr, warmup_steps, total_steps, init_lr=1e-7, final_lr=None):
    """Paper Sec 5.1: linear warmup from 1e-7, cosine decay to base_lr/10."""
    final_lr = base_lr / 10 if final_lr is None else final_lr

    def sched(t):
        t = t.astype(jnp.float32) if hasattr(t, "astype") else jnp.asarray(t, jnp.float32)
        warm = init_lr + (base_lr - init_lr) * jnp.minimum(t / max(warmup_steps, 1), 1.0)
        frac = jnp.clip((t - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = final_lr + 0.5 * (base_lr - final_lr) * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(t < warmup_steps, warm, cos)

    return sched


def constant(base_lr):
    return lambda t: jnp.asarray(base_lr, jnp.float32)


def lr_discount_factor(tau_i, t, T: int):
    """Eq. 13: eta_i^t = eta / tau_i^rho_t, rho_t = 1 - min(t/T, 1).

    Returns the multiplicative factor (<=1) for stage i with delay tau_i; the
    correction anneals away over the first T steps (PipeMare / Yang et al. 2021).
    tau_i may be a static int (fixed Eq. 5 schedule), a traced scalar (the
    per-tick observed delay fed back by the event runtime / the engine's
    step(..., taus=...) path), or a traced per-stage vector sourced from
    `RuntimeResult.taus` — the factor broadcasts elementwise. tau_i <= 1 is a
    no-op factor of 1 either way. Which source feeds it is the method's
    `tau_source` axis; at K > 1 the per-update value is the Method.tau_reduce
    collapse of the K per-microbatch delays (fractional under "mean") — both
    execution paths reduce the SAME group, so the factor agrees bit-for-bit
    (core/methods.py, DESIGN.md §10).
    """
    tau = jnp.maximum(jnp.asarray(tau_i, jnp.float32), 1.0)
    tf = t.astype(jnp.float32) if hasattr(t, "astype") else jnp.asarray(t, jnp.float32)
    rho = 1.0 - jnp.minimum(tf / max(T, 1), 1.0)
    return tau ** (-rho)


def stage_momentum(i: int, P: int, lo=0.9, hi=0.99):
    """Eq. 13: gamma_i = lo + (hi-lo) * (P - i) / P  for stage i in 1..P."""
    return lo + (hi - lo) * (P - i) / P


def delay_momentum(tau, P: int, K: int = 1, lo=0.9, hi=0.99):
    """Observed-staleness re-keying of Eq. 13's momentum (tau_source="observed"):

        gamma(tau) = lo + (hi - lo) * clip(K * tau / P, 0, 1)

    Under the fixed 1F1B schedule at K=1, Eq. 5 gives tau_i = P - i, so
    gamma(tau_i) == stage_momentum(i, P) EXACTLY — the paper's stage-keyed
    coefficient is the steady-state special case. Keying off the measured delay
    instead makes the coefficient track reality: it ramps 0 -> gamma_i with the
    warmup staleness, and grows (saturating at `hi`) when a straggler or churn
    outage inflates the observed tau — more smoothing exactly when gradients
    are more stale. `tau` may be a python number (folds at trace time), a
    traced scalar (live runtime feedback), or a traced per-stage vector
    (step(..., taus=...)); the result broadcasts accordingly. At K > 1 the
    scalar fed here is the Method.tau_reduce collapse of the update's K
    per-microbatch observed delays (core/methods.py).
    """
    frac = jnp.clip(jnp.asarray(tau, jnp.float32) * (K / P), 0.0, 1.0)
    return lo + (hi - lo) * frac
