"""LR schedules + the paper's stage-dependent corrections (Eq. 13)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(base_lr, warmup_steps, total_steps, init_lr=1e-7, final_lr=None):
    """Paper Sec 5.1: linear warmup from 1e-7, cosine decay to base_lr/10."""
    final_lr = base_lr / 10 if final_lr is None else final_lr

    def sched(t):
        t = t.astype(jnp.float32) if hasattr(t, "astype") else jnp.asarray(t, jnp.float32)
        warm = init_lr + (base_lr - init_lr) * jnp.minimum(t / max(warmup_steps, 1), 1.0)
        frac = jnp.clip((t - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = final_lr + 0.5 * (base_lr - final_lr) * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(t < warmup_steps, warm, cos)

    return sched


def constant(base_lr):
    return lambda t: jnp.asarray(base_lr, jnp.float32)


def lr_discount_factor(tau_i, t, T: int):
    """Eq. 13: eta_i^t = eta / tau_i^rho_t, rho_t = 1 - min(t/T, 1).

    Returns the multiplicative factor (<=1) for stage i with delay tau_i; the
    correction anneals away over the first T steps (PipeMare / Yang et al. 2021).
    tau_i may be a static int (fixed Eq. 5 schedule) or a traced scalar (the
    per-tick observed delay fed back by the event runtime); tau_i <= 1 is a
    no-op factor of 1 either way.
    """
    tau = jnp.maximum(jnp.asarray(tau_i, jnp.float32), 1.0)
    tf = t.astype(jnp.float32) if hasattr(t, "astype") else jnp.asarray(t, jnp.float32)
    rho = 1.0 - jnp.minimum(tf / max(T, 1), 1.0)
    return tau ** (-rho)


def stage_momentum(i: int, P: int, lo=0.9, hi=0.99):
    """Eq. 13: gamma_i = lo + (hi-lo) * (P - i) / P  for stage i in 1..P."""
    return lo + (hi - lo) * (P - i) / P
