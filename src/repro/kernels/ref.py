"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal=True, window=None, softcap=None, scale=None,
                  return_lse=False):
    """q [B,H,Sq,d]; k,v [B,Hkv,Sk,d]. Dense attention, fp32 softmax.

    ``return_lse=True`` also returns the row logsumexp [B,H,Sq] (f32) — the
    oracle for the flash-attention forward's saved backward residual."""
    B, H, Sq, d = q.shape
    Hkv = k.shape[1]
    G = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    kf = jnp.repeat(k, G, axis=1)
    vf = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kf.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(k.shape[2])[None, :]
    ok = jnp.ones((Sq, k.shape[2]), bool)
    if causal:
        ok &= kpos <= qpos
    if window is not None:
        ok &= kpos > qpos - window
    s = jnp.where(ok[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vf.astype(jnp.float32)).astype(q.dtype)
    if return_lse:
        return out, jax.nn.logsumexp(s, axis=-1)
    return out


def ssd_ref(x, dt, A, B_, C_, *, h0=None):
    """Sequential (exact) SSD recurrence. x [b,S,H,P]; dt [b,S,H]; A [H];
    B_,C_ [b,S,G,N]. Returns (y [b,S,H,P], h_final [b,H,N,P])."""
    b, S, H, Pd = x.shape
    G, N = B_.shape[2], B_.shape[3]
    rep = H // G
    Bh = jnp.repeat(B_, rep, axis=2).astype(jnp.float32)
    Ch = jnp.repeat(C_, rep, axis=2).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)

    def step(h, inp):
        xt, dtt, Bt, Ct = inp  # [b,H,P], [b,H], [b,H,N], [b,H,N]
        da = jnp.exp(dtt * A[None, :])  # [b,H]
        h = h * da[:, :, None, None] + jnp.einsum("bh,bhn,bhp->bhnp", dtt, Bt, xt)
        y = jnp.einsum("bhn,bhnp->bhp", Ct, h)
        return h, y

    h = jnp.zeros((b, H, N, Pd), jnp.float32) if h0 is None else h0
    h, ys = jax.lax.scan(step, h, (xf.swapaxes(0, 1), dtf.swapaxes(0, 1),
                                   Bh.swapaxes(0, 1), Ch.swapaxes(0, 1)))
    return ys.swapaxes(0, 1), h


def nag_update_ref(p, m, v, g, *, lr, b1, b2, eps, wd, mu_t, mu_next, mu_prod,
                   mu_prod_next, bc2, discount=True):
    """Delay-corrected NAdam (paper Eq. 10 practical form), elementwise."""
    g = g.astype(jnp.float32)
    m_new = b1 * m + (1 - b1) * g
    v_new = b2 * v + (1 - b2) * g * g
    denom = jnp.sqrt(v_new / bc2) + eps
    if discount:
        mhat = mu_next * m_new / (1 - mu_prod_next) + (1 - mu_t) * g / (1 - mu_prod)
    else:
        mhat = mu_next * m_new / (1 - mu_prod_next) + g
    p_new = p * (1 - lr * wd) - lr * mhat / denom
    return p_new, m_new, v_new
