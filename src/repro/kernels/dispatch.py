"""Kernel dispatch: one registry routing each hot-path op to a backend.

Every fused Pallas kernel in this package is registered here next to its pure-XLA
oracle (kernels/ref.py), and model/optimizer code calls ``dispatch(op, ...)``
instead of hard-wiring an implementation. Backends:

  - ``pallas``:    compiled Pallas kernel (TPU)
  - ``interpret``: the same kernel under the Pallas interpreter (CPU-correct;
                   used by CI and the differential parity harness)
  - ``ref``:       the pure-jnp oracle (unfused XLA; the numerics ground truth)

Selection precedence (first hit wins):
  1. the ``REPRO_KERNEL_BACKEND`` environment variable
  2. the ``kernel_backend`` field on ``ModelCfg`` / ``EngineCfg`` (passed in as
     ``cfg_backend``)
  3. platform default: ``pallas`` on TPU, ``ref`` everywhere else

Resolution is plain Python (env + static config), so the chosen branch is fixed
at trace time and jit caches per backend.

Autodiff: training call sites use ``dispatch_grad``. Ops that register a
dedicated backward (``fwd_res`` + ``bwd``: flash-attention dq/dk/dv, the SSD
reverse scan, the fused rmsnorm-residual backward) run forward AND backward
through Pallas: the forward saves compact kernel residuals (e.g. (o, lse)
instead of the S x S score matrix) and the backward is its own kernel pass.
Ops without a registered backward fall back to the VJP of the *reference*
implementation linearized at the same inputs (exact because the kernels are
numerically faithful re-implementations of the refs; remat of the ref forward
inside the backward is the cost — the pre-backward-kernel behavior). The
``custom_vjp`` wrapper for each (op, backend, static-kwargs) triple is built
once and memoized (``_VJP_CACHE``) so every call site traces the same callable
and jit caches are shared. See DESIGN.md §8 for the residual policy per op.

Each registry entry also carries parity cases — input builders spanning
tile-aligned, ragged, and multi-dtype shapes — which tests/test_kernel_parity.py
auto-discovers, so adding a kernel here buys its differential forward AND
gradient test for free.
"""
from __future__ import annotations

import dataclasses
import functools
import os
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.flash_attention import (flash_attention as _flash_attention,
                                           flash_attention_bwd as _flash_attention_bwd)
from repro.kernels.nag_update import nag_update as _nag_update
from repro.kernels.paged_attention import (paged_attn_decode as _paged_attn_decode,
                                           paged_attn_decode_ref as _paged_attn_decode_ref)
from repro.kernels.rmsnorm_residual import (rmsnorm_residual as _rmsnorm_residual,
                                            rmsnorm_residual_bwd as _rmsnorm_residual_bwd)
from repro.kernels.ssd_scan import ssd_scan as _ssd_scan, ssd_scan_bwd as _ssd_scan_bwd

ENV_VAR = "REPRO_KERNEL_BACKEND"
BACKENDS = ("pallas", "interpret", "ref")


@dataclasses.dataclass(frozen=True)
class ParityCase:
    """One (inputs, kwargs) builder for the differential parity harness.

    ``make(key, dtype)`` returns ``(args, kwargs)``; ``dtype`` is applied to the
    op's activation/gradient-like inputs (state stays fp32, as in training).
    ``tol_*`` bound the forward outputs; ``grad_tol_*`` bound the gradients
    (defaulting to the forward tolerances when unset) — gradient comparisons are
    scale-normalized by the harness, so these are relative-class tolerances.
    """

    label: str
    make: Callable[[jax.Array, Any], Tuple[tuple, dict]]
    tol_f32: float = 2e-5
    tol_bf16: float = 2e-2
    grad_tol_f32: Optional[float] = None
    grad_tol_bf16: Optional[float] = None

    def tol(self, dtype) -> float:
        return self.tol_bf16 if dtype == jnp.bfloat16 else self.tol_f32

    def grad_tol(self, dtype) -> float:
        if dtype == jnp.bfloat16:
            return self.grad_tol_bf16 if self.grad_tol_bf16 is not None else self.tol_bf16
        return self.grad_tol_f32 if self.grad_tol_f32 is not None else self.tol_f32


@dataclasses.dataclass(frozen=True)
class OpImpl:
    """Registry entry. ``fwd_res``/``bwd`` (both or neither) give the op a
    dedicated kernel backward:

      fwd_res(*args, interpret=..., **kw) -> (out, residuals)
      bwd(residuals, out_cotangent, interpret=..., **kw) -> per-arg cotangents

    ``residuals`` is an op-chosen pytree (typically the primal inputs plus the
    compact kernel state the backward recurrence needs). Ops without a ``bwd``
    differentiate via the ref-VJP fallback in ``dispatch_grad``.
    """

    name: str
    pallas: Callable  # must accept interpret= kwarg
    ref: Callable  # same signature minus interpret/blocking kwargs
    cases: Tuple[ParityCase, ...] = ()
    fwd_res: Optional[Callable] = None
    bwd: Optional[Callable] = None


_REGISTRY: Dict[str, OpImpl] = {}


def register(name: str, *, pallas: Callable, ref: Callable,
             cases: Tuple[ParityCase, ...] = (), fwd_res: Optional[Callable] = None,
             bwd: Optional[Callable] = None) -> None:
    if name in _REGISTRY:
        raise ValueError(f"kernel op {name!r} already registered")
    if (fwd_res is None) != (bwd is None):
        raise ValueError(f"kernel op {name!r}: fwd_res and bwd must be registered together")
    _REGISTRY[name] = OpImpl(name, pallas, ref, cases, fwd_res, bwd)


def registered_ops():
    return tuple(sorted(_REGISTRY))


def get_op(name: str) -> OpImpl:
    if name not in _REGISTRY:
        raise KeyError(f"unknown kernel op {name!r}; have {registered_ops()}")
    return _REGISTRY[name]


def parity_cases(name: str) -> Tuple[ParityCase, ...]:
    return get_op(name).cases


def _validate(backend: str, source: str) -> str:
    if backend not in BACKENDS:
        raise ValueError(
            f"invalid kernel backend {backend!r} (from {source}); expected one of {BACKENDS}")
    return backend


def resolve_backend(cfg_backend: Optional[str] = None) -> str:
    """env var > cfg field > platform default (pallas on TPU, ref elsewhere)."""
    env = os.environ.get(ENV_VAR)
    if env:
        return _validate(env, f"${ENV_VAR}")
    if cfg_backend is not None:
        return _validate(cfg_backend, "cfg.kernel_backend")
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def dispatch(name: str, *args, backend: Optional[str] = None, **kwargs):
    """Run op `name` on the selected backend (no autodiff support for pallas)."""
    op = get_op(name)
    be = resolve_backend() if backend is None else _validate(backend, "backend=")
    if be == "ref":
        return op.ref(*args, **kwargs)
    return op.pallas(*args, interpret=(be == "interpret"), **kwargs)


# ---------------------------------------------------------------------------
# Differentiable dispatch: memoized custom_vjp per (op, backend, static kwargs)
# ---------------------------------------------------------------------------

# The wrapper for a given (name, backend, frozen kwargs) is built ONCE: a fresh
# custom_vjp closure per call would be a new callable identity every time, so
# every jit trace through a call site would re-trace it (and AD caches would
# never hit). Kwargs must be static/hashable — they select the kernel variant.
_VJP_CACHE: Dict[Tuple[str, str, tuple], Callable] = {}
vjp_cache_stats = {"hits": 0, "misses": 0}


def _build_vjp(op: OpImpl, backend: str, kwargs: dict) -> Callable:
    interp = backend == "interpret"
    fwd_fn = functools.partial(op.pallas, interpret=interp, **kwargs)
    if op.bwd is not None:
        fwd_res_fn = functools.partial(op.fwd_res, interpret=interp, **kwargs)
        bwd_fn = functools.partial(op.bwd, interpret=interp, **kwargs)

        @jax.custom_vjp
        def f(*xs):
            return fwd_fn(*xs)

        def f_fwd(*xs):
            return fwd_res_fn(*xs)

        def f_bwd(res, ct):
            return tuple(bwd_fn(res, ct))

        f.defvjp(f_fwd, f_bwd)
        return f

    # ref-VJP fallback: backward through the reference implementation
    # linearized at the same inputs (remat of the unfused ref forward).
    ref_fn = functools.partial(op.ref, **kwargs)

    @jax.custom_vjp
    def f(*xs):
        return fwd_fn(*xs)

    def f_fwd(*xs):
        return fwd_fn(*xs), xs

    def f_bwd(xs, ct):
        _, vjp = jax.vjp(lambda *ys: ref_fn(*ys), *xs)
        return vjp(ct)

    f.defvjp(f_fwd, f_bwd)
    return f


def dispatch_grad(name: str, *args, backend: Optional[str] = None, **kwargs):
    """Differentiable dispatch.

    Backend 'ref' is just the reference op (native autodiff). Otherwise the
    forward runs the selected kernel backend and the backward runs the op's
    registered backward kernels (ref-VJP fallback when it has none). The kwargs
    must be static (they select the kernel variant, not traced values).
    """
    op = get_op(name)
    be = resolve_backend() if backend is None else _validate(backend, "backend=")
    if be == "ref":
        return op.ref(*args, **kwargs)
    key = (name, be, tuple(sorted(kwargs.items())))
    f = _VJP_CACHE.get(key)
    if f is None:
        vjp_cache_stats["misses"] += 1
        f = _VJP_CACHE[key] = _build_vjp(op, be, kwargs)
    else:
        vjp_cache_stats["hits"] += 1
    return f(*args)


# ---------------------------------------------------------------------------
# Registrations (ref wrappers normalize signatures/dtypes to the kernel's;
# fwd_res/bwd wrappers adapt the kernel backward entry points to the
# (residuals, cotangent) -> per-arg-cotangents contract)
# ---------------------------------------------------------------------------


def _attention_ref(q, k, v, *, causal=True, window=None, softcap=None, scale=None,
                   block_q=128, block_k=128):
    del block_q, block_k  # tiling knobs are kernel-only
    return _ref.attention_ref(q, k, v, causal=causal, window=window,
                              softcap=softcap, scale=scale)


def _attention_fwd_res(q, k, v, *, interpret=False, **kw):
    out, lse = _flash_attention(q, k, v, interpret=interpret,
                                return_residuals=True, **kw)
    return out, (q, k, v, out, lse)


def _attention_bwd(res, do, *, interpret=False, **kw):
    q, k, v, o, lse = res
    return _flash_attention_bwd(q, k, v, o, lse, do, interpret=interpret, **kw)


def _ssd_ref(x, dt, A, B_, C_, *, chunk=128):
    # The chunked-parallel jnp form, not the sequential ssd_ref recurrence: this
    # function is the BACKWARD comparator of the fused path (grad parity), and a
    # per-timestep lax.scan VJP would serialize over all S steps. The chunked
    # form is itself validated against the sequential oracle in
    # tests/test_kernels.py. Late import: layers imports this module.
    from repro.models.layers import _ssd_chunked

    y, h = _ssd_chunked(x, B_, C_, dt, A, min(chunk, x.shape[1]))
    return y.astype(x.dtype), h  # kernel returns y in x.dtype, h_final fp32


def _ssd_fwd_res(x, dt, A, B_, C_, *, interpret=False, chunk=128):
    y, hfin, h_chunk = _ssd_scan(x, dt, A, B_, C_, chunk=chunk,
                                 interpret=interpret, return_residuals=True)
    return (y, hfin), (x, dt, A, B_, C_, h_chunk)


def _ssd_bwd(res, cts, *, interpret=False, chunk=128):
    x, dt, A, B_, C_, h_chunk = res
    dy, dhfin = cts
    return _ssd_scan_bwd(x, dt, A, B_, C_, h_chunk, dy, dhfin, chunk=chunk,
                         interpret=interpret)


def _nag_ref(p, m, v, g, *, lr, b1=0.99, b2=0.95, eps=1e-8, wd=0.01, mu_t, mu_next,
             mu_prod, mu_prod_next, bc2, discount=True, block=1024):
    del block
    return _ref.nag_update_ref(p, m, v, g, lr=lr, b1=b1, b2=b2, eps=eps, wd=wd,
                               mu_t=mu_t, mu_next=mu_next, mu_prod=mu_prod,
                               mu_prod_next=mu_prod_next, bc2=bc2, discount=discount)


def _rmsnorm_residual_ref(x, h, scale, *, eps=1e-6, block_rows=8):
    del block_rows
    from repro.kernels.rmsnorm_residual import rmsnorm_residual_ref
    return rmsnorm_residual_ref(x, h, scale, eps)


def _rmsnorm_residual_fwd_res(x, h, scale, *, interpret=False, eps=1e-6, block_rows=8):
    r, y = _rmsnorm_residual(x, h, scale, eps=eps, block_rows=block_rows,
                             interpret=interpret)
    return (r, y), (r, scale)  # r is a forward output: saved, never recomputed


def _rmsnorm_residual_bwd_wrap(res, cts, *, interpret=False, eps=1e-6, block_rows=8):
    r, scale = res
    dr, dy = cts
    dxh, dscale = _rmsnorm_residual_bwd(r, scale, dr, dy, eps=eps,
                                        block_rows=block_rows, interpret=interpret)
    return dxh, dxh, dscale.astype(scale.dtype)  # x and h share the cotangent


def _attn_case(B, H, Hkv, S, d, blk, **kw):
    def make(key, dtype):
        q = jax.random.normal(key, (B, H, S, d)).astype(dtype)
        k = jax.random.normal(jax.random.fold_in(key, 1), (B, Hkv, S, d)).astype(dtype)
        v = jax.random.normal(jax.random.fold_in(key, 2), (B, Hkv, S, d)).astype(dtype)
        return (q, k, v), dict(block_q=blk, block_k=blk, **kw)
    return make


def _ssd_case(b, S, H, P, G, N, chunk):
    def make(key, dtype):
        x = jax.random.normal(key, (b, S, H, P)).astype(dtype)
        dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1), (b, S, H))) * 0.1
        A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (H,)) * 0.3)
        B_ = (jax.random.normal(jax.random.fold_in(key, 3), (b, S, G, N)) * 0.3).astype(dtype)
        C_ = (jax.random.normal(jax.random.fold_in(key, 4), (b, S, G, N)) * 0.3).astype(dtype)
        return (x, dt, A, B_, C_), dict(chunk=chunk)
    return make


def _nag_case(n, block):
    def make(key, dtype):
        p = jax.random.normal(key, (n,))
        m = jax.random.normal(jax.random.fold_in(key, 1), (n,)) * 0.1
        v = jnp.abs(jax.random.normal(jax.random.fold_in(key, 2), (n,))) * 0.01
        g = jax.random.normal(jax.random.fold_in(key, 3), (n,)).astype(dtype)  # bf16 grads
        kw = dict(lr=1e-3, mu_t=0.95, mu_next=0.96, mu_prod=0.9,
                  mu_prod_next=0.87, bc2=0.05, block=block)
        return (p, m, v, g), kw
    return make


def _rms_case(shape, block_rows=8):
    def make(key, dtype):
        x = jax.random.normal(key, shape).astype(dtype)
        h = jax.random.normal(jax.random.fold_in(key, 1), shape).astype(dtype)
        scale = jax.random.normal(jax.random.fold_in(key, 2), (shape[-1],)) * 0.1
        return (x, h, scale), dict(block_rows=block_rows)
    return make


def _paged_case(B, H, Hkv, d, PS, n_pages, maxp, **kw):
    def make(key, dtype):
        q = jax.random.normal(key, (B, H, d)).astype(dtype)
        kp = jax.random.normal(jax.random.fold_in(key, 1),
                               (n_pages, PS, Hkv, d)).astype(dtype)
        vp = jax.random.normal(jax.random.fold_in(key, 2),
                               (n_pages, PS, Hkv, d)).astype(dtype)
        # non-contiguous page chains: a random permutation of the pool, so the
        # kernel's table-chased gathers are exercised, not an identity layout
        pt = jax.random.permutation(jax.random.fold_in(key, 3),
                                    n_pages)[:B * maxp].reshape(B, maxp)
        ln = jax.random.randint(jax.random.fold_in(key, 4), (B,), 1, maxp * PS + 1)
        return (q, kp, vp, pt.astype(jnp.int32), ln.astype(jnp.int32)), dict(**kw)
    return make


register(
    # serving decode read (launch/serve.py): one query token per sequence
    # against a paged KV pool. Inference-only — no dedicated backward; the
    # ref-VJP fallback covers dispatch_grad should anyone differentiate it.
    "paged_attn_decode", pallas=_paged_attn_decode, ref=_paged_attn_decode_ref,
    cases=(
        ParityCase("gqa_ragged_lengths", _paged_case(3, 4, 2, 32, 8, 16, 4)),
        ParityCase("mha_two_pages", _paged_case(2, 2, 2, 16, 16, 8, 2)),
        ParityCase("window_softcap", _paged_case(2, 4, 4, 16, 8, 12, 3,
                                                 window=5, softcap=20.0)),
        ParityCase("single_token", _paged_case(1, 2, 1, 32, 4, 4, 1)),
    ))

register(
    "flash_attention", pallas=_flash_attention, ref=_attention_ref,
    fwd_res=_attention_fwd_res, bwd=_attention_bwd,
    cases=(
        ParityCase("gqa_aligned", _attn_case(2, 4, 2, 128, 32, 64)),
        ParityCase("mqa_ragged_seq", _attn_case(1, 4, 1, 96, 32, 64)),     # S % blk != 0
        ParityCase("tiny_unaligned", _attn_case(1, 2, 2, 33, 16, 32)),     # non-tile rows
        ParityCase("window_softcap", _attn_case(2, 2, 2, 64, 32, 32,
                                                window=16, softcap=30.0)),
        ParityCase("noncausal", _attn_case(1, 2, 2, 64, 32, 32, causal=False)),
    ))

register(
    "ssd_scan", pallas=_ssd_scan, ref=_ssd_ref,
    fwd_res=_ssd_fwd_res, bwd=_ssd_bwd,
    cases=(
        ParityCase("grouped_chunked", _ssd_case(2, 64, 4, 16, 2, 8, chunk=32),
                   tol_f32=5e-4, tol_bf16=4e-2),
        ParityCase("single_group", _ssd_case(1, 48, 2, 8, 1, 8, chunk=16),
                   tol_f32=5e-4, tol_bf16=4e-2),
        ParityCase("ragged_one_chunk", _ssd_case(1, 30, 2, 8, 1, 4, chunk=30),
                   tol_f32=5e-4, tol_bf16=4e-2),
    ))

register(
    # optimizer step: applied under lax.stop_gradient semantics in the engine,
    # so no dedicated backward; the ref-VJP fallback covers dispatch_grad for
    # the parity suite's grad cases.
    "nag_update", pallas=_nag_update, ref=_nag_ref,
    cases=(
        ParityCase("aligned", _nag_case(4096, 1024), tol_f32=2e-6, grad_tol_f32=2e-5),
        ParityCase("ragged", _nag_case(5000, 1024), tol_f32=2e-6, grad_tol_f32=2e-5),
        ParityCase("tiny_subblock", _nag_case(7, 8), tol_f32=2e-6, grad_tol_f32=2e-5),
    ))

register(
    "rmsnorm_residual", pallas=_rmsnorm_residual, ref=_rmsnorm_residual_ref,
    fwd_res=_rmsnorm_residual_fwd_res, bwd=_rmsnorm_residual_bwd_wrap,
    cases=(
        ParityCase("batched_3d", _rms_case((2, 16, 64))),
        ParityCase("ragged_rows", _rms_case((3, 5, 48))),   # rows % block_rows != 0
        ParityCase("flat_2d", _rms_case((7, 96))),
    ))
