"""Mamba-2 SSD chunked scan for TPU (Pallas).

Adaptation of the SSD block decomposition (arXiv:2405.21060 Sec. 6) to the TPU
memory hierarchy: each grid step loads one (chunk x headdim) x-tile and the
matching B/C/dt tiles into VMEM, does the intra-chunk quadratic part on the MXU
(L-masked C Bᵀ), and carries the running inter-chunk state [N, P] in VMEM scratch
across the sequential chunk axis — the CUDA version's cross-block shared-memory
handoff becomes TPU's sequential-grid scratch persistence.

Grid: (B*H, n_chunks) — chunk axis last (sequential on TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hfin_ref, h_scr, *, chunk, n_chunks):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0].astype(jnp.float32)  # [c, P]
    dt = dt_ref[0].astype(jnp.float32)  # [c, 1]
    A = a_ref[0].astype(jnp.float32)  # [1, 1]
    Bm = b_ref[0].astype(jnp.float32)  # [c, N]
    Cm = c_ref[0].astype(jnp.float32)  # [c, N]

    da = dt * A  # [c,1], negative
    cum = jnp.cumsum(da, axis=0)  # [c,1]
    # intra-chunk: L[i,j] = exp(cum_i - cum_j) for i >= j
    li = cum - cum.T  # [c, c] (broadcast)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(ii >= jj, jnp.exp(li), 0.0)
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # [c,c]
    y_intra = jax.lax.dot_general(scores * L * dt.T, x, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)  # [c,P]

    # inter-chunk: contribution of the incoming state
    w_in = jnp.exp(cum)  # [c,1]
    y_inter = w_in * jax.lax.dot_general(Cm, h_scr[...], (((1,), (0,)), ((), ())),
                                         preferred_element_type=jnp.float32)
    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: h' = exp(cum_end) h + sum_j exp(cum_end - cum_j) dt_j B_j x_j^T
    seg_end = cum[-1:, :]  # [1,1]
    w_end = jnp.exp(seg_end - cum) * dt  # [c,1]
    newstate = jax.lax.dot_general(Bm * w_end, x, (((0,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32)  # [N,P]
    h_scr[...] = h_scr[...] * jnp.exp(seg_end[0]) + newstate

    @pl.when(ci == n_chunks - 1)
    def _finish():
        hfin_ref[0] = h_scr[...].astype(hfin_ref.dtype)


def ssd_scan(x, dt, A, B_, C_, *, chunk=128, interpret=None):
    """x [b,S,H,P]; dt [b,S,H]; A [H]; B_,C_ [b,S,G,N]. Returns (y, h_final).

    Matches kernels.ref.ssd_ref (sequential recurrence oracle).
    """
    b, S, H, Pd = x.shape
    G, N = B_.shape[2], B_.shape[3]
    rep = H // G
    if interpret is None:
        interpret = jax.devices()[0].platform == "cpu"
    chunk = min(chunk, S)
    assert S % chunk == 0, "pad sequence to a chunk multiple"
    n_chunks = S // chunk

    # flatten (b, H) into the grid's first axis; broadcast B/C per head group
    xf = x.swapaxes(1, 2).reshape(b * H, S, Pd)
    dtf = dt.swapaxes(1, 2).reshape(b * H, S, 1)
    Bf = jnp.repeat(B_.swapaxes(1, 2), rep, axis=1).reshape(b * H, S, N)
    Cf = jnp.repeat(C_.swapaxes(1, 2), rep, axis=1).reshape(b * H, S, N)
    Af = jnp.broadcast_to(A[None, :], (b, H)).reshape(b * H, 1, 1)

    y, hfin = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk, n_chunks=n_chunks),
        grid=(b * H, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, Pd), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, chunk, 1), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, 1, 1), lambda i, c: (i, 0, 0)),
            pl.BlockSpec((1, chunk, N), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda i, c: (i, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, Pd), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, N, Pd), lambda i, c: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * H, S, Pd), x.dtype),
            jax.ShapeDtypeStruct((b * H, N, Pd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, Pd), jnp.float32)],
        interpret=interpret,
    )(xf, dtf, Af, Bf, Cf)
    y = y.reshape(b, H, S, Pd).swapaxes(1, 2)
    hfin = hfin.reshape(b, H, N, Pd)
    return y, hfin
