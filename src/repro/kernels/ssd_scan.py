"""Mamba-2 SSD chunked scan for TPU (Pallas).

Adaptation of the SSD block decomposition (arXiv:2405.21060 Sec. 6) to the TPU
memory hierarchy: each grid step loads one (chunk x headdim) x-tile and the
matching B/C/dt tiles into VMEM, does the intra-chunk quadratic part on the MXU
(L-masked C Bᵀ), and carries the running inter-chunk state [N, P] in VMEM scratch
across the sequential chunk axis — the CUDA version's cross-block shared-memory
handoff becomes TPU's sequential-grid scratch persistence.

Forward grid: (B*H, n_chunks) — chunk axis last (sequential on TPU). With
``return_residuals=True`` the forward also emits the state *entering* each chunk
(the chunk-boundary states), which is all the backward needs: intra-chunk
quantities are cheap to rebuild from (x, dt, A, B, C) per tile, while the
boundary states are exactly what a reverse pass cannot recompute without
re-running the whole forward scan.

Backward: same grid iterated in *reverse* chunk order (via the BlockSpec index
maps), carrying dh — the cotangent of the chunk-boundary state — in VMEM scratch.
Each step rebuilds the chunk's decay/score tiles, emits dx/ddt/dB/dC for that
chunk, accumulates the per-(b,head) dA partial in scratch, and propagates
dh_in = exp(cum_end) * dh_out + (w_in ⊙ C)ᵀ dy to the previous chunk.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hfin_ref, *rest,
            chunk, n_chunks, save_states):
    if save_states:
        hprev_ref, h_scr = rest
    else:
        hprev_ref = None
        (h_scr,) = rest
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    if hprev_ref is not None:  # state entering this chunk (residual for bwd)
        hprev_ref[0, 0] = h_scr[...]

    x = x_ref[0].astype(jnp.float32)  # [c, P]
    dt = dt_ref[0].astype(jnp.float32)  # [c, 1]
    A = a_ref[0].astype(jnp.float32)  # [1, 1]
    Bm = b_ref[0].astype(jnp.float32)  # [c, N]
    Cm = c_ref[0].astype(jnp.float32)  # [c, N]

    da = dt * A  # [c,1], negative
    cum = jnp.cumsum(da, axis=0)  # [c,1]
    # intra-chunk: L[i,j] = exp(cum_i - cum_j) for i >= j
    li = cum - cum.T  # [c, c] (broadcast)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(ii >= jj, jnp.exp(li), 0.0)
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # [c,c]
    y_intra = jax.lax.dot_general(scores * L * dt.T, x, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)  # [c,P]

    # inter-chunk: contribution of the incoming state
    w_in = jnp.exp(cum)  # [c,1]
    y_inter = w_in * jax.lax.dot_general(Cm, h_scr[...], (((1,), (0,)), ((), ())),
                                         preferred_element_type=jnp.float32)
    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: h' = exp(cum_end) h + sum_j exp(cum_end - cum_j) dt_j B_j x_j^T
    seg_end = cum[-1:, :]  # [1,1]
    w_end = jnp.exp(seg_end - cum) * dt  # [c,1]
    newstate = jax.lax.dot_general(Bm * w_end, x, (((0,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32)  # [N,P]
    h_scr[...] = h_scr[...] * jnp.exp(seg_end[0]) + newstate

    @pl.when(ci == n_chunks - 1)
    def _finish():
        hfin_ref[0] = h_scr[...].astype(hfin_ref.dtype)


def _bwd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, hprev_ref, dy_ref, dhfin_ref,
                dx_ref, ddt_ref, da_ref, db_ref, dc_ref, dh_scr, dA_scr, *,
                chunk, n_chunks):
    """One reversed chunk step. All refs are indexed at the *reversed* chunk
    (index maps below), so program_id(1)==0 processes the LAST chunk."""
    cr = pl.program_id(1)

    @pl.when(cr == 0)
    def _init():
        dh_scr[...] = dhfin_ref[0].astype(jnp.float32)
        dA_scr[...] = jnp.zeros_like(dA_scr)

    x = x_ref[0].astype(jnp.float32)  # [c, P]
    dt = dt_ref[0].astype(jnp.float32)  # [c, 1]
    A = a_ref[0].astype(jnp.float32)  # [1, 1]
    Bm = b_ref[0].astype(jnp.float32)  # [c, N]
    Cm = c_ref[0].astype(jnp.float32)  # [c, N]
    h_in = hprev_ref[0, 0]  # [N, P] f32, state entering this chunk
    dy = dy_ref[0].astype(jnp.float32)  # [c, P]
    dh = dh_scr[...]  # [N, P]: cotangent of this chunk's OUTPUT state

    # rebuild the forward's per-chunk tiles
    da = dt * A
    cum = jnp.cumsum(da, axis=0)  # [c,1]
    w_in = jnp.exp(cum)  # [c,1]
    seg_end = cum[-1:, :]  # [1,1]
    eexp = jnp.exp(seg_end - cum)  # [c,1]
    e = eexp * dt  # [c,1]  (the forward's w_end)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    tri = ii >= jj
    L = jnp.where(tri, jnp.exp(cum - cum.T), 0.0)  # [c,c]
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # [c,c]
    W = scores * L * dt.T  # [c,c]: y_intra = W @ x

    dot = lambda a_, b_, dims: jax.lax.dot_general(
        a_, b_, (dims, ((), ())), preferred_element_type=jnp.float32)
    # dW from y_intra = W x; contract P
    dW = dot(dy, x, ((1,), (1,)))  # [c,c]
    dscores = dW * L * dt.T
    dL = dW * scores * dt.T
    M = dL * L  # zero off-triangle (L=0 there)

    # dx: intra Wᵀ dy + state (B ⊙ e) dh
    dx = dot(W, dy, ((0,), (0,))) + dot(Bm * e, dh, ((1,), (0,)))  # [c,P]
    xdh = dot(x, dh, ((1,), (1,)))  # [c,N]: x · dh over P
    dB = dot(dscores, Cm, ((0,), (0,))) + e * xdh  # [c,N]
    dC = dot(dscores, Bm, ((1,), (0,))) + w_in * dot(dy, h_in, ((1,), (1,)))  # [c,N]

    # cotangent of cum (then reverse-cumsum -> da)
    de = jnp.sum(Bm * xdh, axis=1, keepdims=True)  # [c,1]: d h_out / d e_j
    Chin = dot(Cm, h_in, ((1,), (0,)))  # [c,P]
    dwin = jnp.sum(dy * Chin, axis=1, keepdims=True)  # [c,1]
    dcum = (jnp.sum(M, axis=1, keepdims=True) - jnp.sum(M, axis=0)[:, None]
            + dwin * w_in - de * e)
    # seg_end = cum[-1] collects the w_end exponent and the carried-state decay
    dseg = jnp.sum(de * e) + jnp.exp(seg_end[0, 0]) * jnp.sum(dh * h_in)
    is_last = jax.lax.broadcasted_iota(jnp.int32, (chunk, 1), 0) == chunk - 1
    dcum = dcum + jnp.where(is_last, dseg, 0.0)
    # cum = cumsum(da): d da_k = sum_{i>=k} dcum_i  (reverse cumsum)
    dda = jnp.sum(dcum, axis=0, keepdims=True) - jnp.cumsum(dcum, axis=0) + dcum
    ddt = (dda * A + jnp.sum(dW * scores * L, axis=0)[:, None]  # W's direct dt_j
           + eexp * de)                                         # e's direct dt_j
    dA_scr[...] += jnp.sum(dda * dt).reshape(1, 1)

    # propagate to the previous chunk's output state
    dh_scr[...] = jnp.exp(seg_end[0, 0]) * dh + dot(Cm * w_in, dy, ((0,), (0,)))

    dx_ref[0] = dx.astype(dx_ref.dtype)
    ddt_ref[0] = ddt.astype(ddt_ref.dtype)
    db_ref[0] = dB.astype(db_ref.dtype)
    dc_ref[0] = dC.astype(dc_ref.dtype)

    @pl.when(cr == n_chunks - 1)
    def _finish():
        da_ref[0] = dA_scr[...].astype(da_ref.dtype)


def _flatten(x, dt, A, B_, C_):
    """User layout -> kernel layout: (b, H) fused into the grid's first axis."""
    b, S, H, Pd = x.shape
    G, N = B_.shape[2], B_.shape[3]
    rep = H // G
    xf = x.swapaxes(1, 2).reshape(b * H, S, Pd)
    dtf = dt.swapaxes(1, 2).reshape(b * H, S, 1)
    Bf = jnp.repeat(B_.swapaxes(1, 2), rep, axis=1).reshape(b * H, S, N)
    Cf = jnp.repeat(C_.swapaxes(1, 2), rep, axis=1).reshape(b * H, S, N)
    Af = jnp.broadcast_to(A[None, :], (b, H)).reshape(b * H, 1, 1)
    return xf, dtf, Af, Bf, Cf


def ssd_scan(x, dt, A, B_, C_, *, chunk=128, interpret=None,
             return_residuals=False):
    """x [b,S,H,P]; dt [b,S,H]; A [H]; B_,C_ [b,S,G,N]. Returns (y, h_final).

    Matches kernels.ref.ssd_ref (sequential recurrence oracle). With
    ``return_residuals=True`` returns (y, h_final, h_chunk) where h_chunk
    [b*H, n_chunks, N, P] (f32, kernel layout) holds the state entering each
    chunk — the boundary residuals consumed by ``ssd_scan_bwd``.
    """
    b, S, H, Pd = x.shape
    G, N = B_.shape[2], B_.shape[3]
    if interpret is None:
        interpret = jax.devices()[0].platform == "cpu"
    chunk = min(chunk, S)
    assert S % chunk == 0, "pad sequence to a chunk multiple"
    n_chunks = S // chunk

    xf, dtf, Af, Bf, Cf = _flatten(x, dt, A, B_, C_)

    out_specs = [
        pl.BlockSpec((1, chunk, Pd), lambda i, c: (i, c, 0)),
        pl.BlockSpec((1, N, Pd), lambda i, c: (i, 0, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((b * H, S, Pd), x.dtype),
        jax.ShapeDtypeStruct((b * H, N, Pd), jnp.float32),
    ]
    if return_residuals:
        out_specs.append(pl.BlockSpec((1, 1, N, Pd), lambda i, c: (i, c, 0, 0)))
        out_shape.append(jax.ShapeDtypeStruct((b * H, n_chunks, N, Pd), jnp.float32))
    outs = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk, n_chunks=n_chunks,
                          save_states=return_residuals),
        grid=(b * H, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, Pd), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, chunk, 1), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, 1, 1), lambda i, c: (i, 0, 0)),
            pl.BlockSpec((1, chunk, N), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda i, c: (i, c, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((N, Pd), jnp.float32)],
        interpret=interpret,
    )(xf, dtf, Af, Bf, Cf)
    y = outs[0].reshape(b, H, S, Pd).swapaxes(1, 2)
    hfin = outs[1].reshape(b, H, N, Pd)
    if return_residuals:
        return y, hfin, outs[2]
    return y, hfin


def ssd_scan_bwd(x, dt, A, B_, C_, h_chunk, dy, dhfin, *, chunk=128,
                 interpret=None):
    """Reverse chunked recurrence. Returns (dx, ddt, dA, dB, dC).

    Inputs are the forward's primals plus the saved chunk-boundary states
    ``h_chunk`` [b*H, n_chunks, N, P] and the output cotangents (dy [b,S,H,P],
    dhfin [b,H,N,P]). dB/dC are group-summed back to the [b,S,G,N] layout.
    """
    b, S, H, Pd = x.shape
    G, N = B_.shape[2], B_.shape[3]
    rep = H // G
    if interpret is None:
        interpret = jax.devices()[0].platform == "cpu"
    chunk = min(chunk, S)
    assert S % chunk == 0, "pad sequence to a chunk multiple"
    n_chunks = S // chunk

    xf, dtf, Af, Bf, Cf = _flatten(x, dt, A, B_, C_)
    dyf = dy.swapaxes(1, 2).reshape(b * H, S, Pd)
    dhfinf = dhfin.reshape(b * H, N, Pd)

    rev = lambda c: n_chunks - 1 - c  # iterate chunks back-to-front
    seq_spec = lambda width: pl.BlockSpec((1, chunk, width),
                                          lambda i, c: (i, rev(c), 0))
    dxf, ddtf, dAf, dBf, dCf = pl.pallas_call(
        functools.partial(_bwd_kernel, chunk=chunk, n_chunks=n_chunks),
        grid=(b * H, n_chunks),
        in_specs=[
            seq_spec(Pd),                                            # x
            seq_spec(1),                                             # dt
            pl.BlockSpec((1, 1, 1), lambda i, c: (i, 0, 0)),         # A
            seq_spec(N),                                             # B
            seq_spec(N),                                             # C
            pl.BlockSpec((1, 1, N, Pd), lambda i, c: (i, rev(c), 0, 0)),  # h_in
            seq_spec(Pd),                                            # dy
            pl.BlockSpec((1, N, Pd), lambda i, c: (i, 0, 0)),        # dhfin
        ],
        out_specs=[
            seq_spec(Pd),                                            # dx
            seq_spec(1),                                             # ddt
            pl.BlockSpec((1, 1, 1), lambda i, c: (i, 0, 0)),         # dA partial
            seq_spec(N),                                             # dB
            seq_spec(N),                                             # dC
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * H, S, Pd), jnp.float32),
            jax.ShapeDtypeStruct((b * H, S, 1), jnp.float32),
            jax.ShapeDtypeStruct((b * H, 1, 1), jnp.float32),
            jax.ShapeDtypeStruct((b * H, S, N), jnp.float32),
            jax.ShapeDtypeStruct((b * H, S, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, Pd), jnp.float32),
                        pltpu.VMEM((1, 1), jnp.float32)],
        interpret=interpret,
    )(xf, dtf, Af, Bf, Cf, h_chunk, dyf, dhfinf)

    dx = dxf.reshape(b, H, S, Pd).swapaxes(1, 2).astype(x.dtype)
    ddt = ddtf.reshape(b, H, S).swapaxes(1, 2).astype(dt.dtype)
    dA = dAf.reshape(b, H).sum(axis=0).astype(A.dtype)
    # un-broadcast the head-group repeat: head h = g * rep + r, sum over r
    dB = (dBf.reshape(b, G, rep, S, N).sum(axis=2).swapaxes(1, 2)).astype(B_.dtype)
    dC = (dCf.reshape(b, G, rep, S, N).sum(axis=2).swapaxes(1, 2)).astype(C_.dtype)
    return dx, ddt, dA, dB, dC
