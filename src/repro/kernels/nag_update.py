"""Fused delay-corrected NAdam update (the paper's optimizer) for TPU (Pallas).

At 1B+ params the optimizer tick is pure HBM bandwidth: p/m/v/g are each read and
p/m/v written — 7 streams. Unfused XLA emits separate kernels per buffer chain;
this kernel makes exactly one pass over (8,128)-aligned VREG tiles, computing the
(1-mu_t)-discounted Nesterov step (paper Eq. 10 / NAdam form) in registers.

Grid: (n_tiles,) over the flattened parameter vector.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(p_ref, m_ref, v_ref, g_ref, s_ref, po_ref, mo_ref, vo_ref, *, discount):
    lr, b1, b2, eps, wd, mu_t, mu_next, mu_prod, mu_prod_next, bc2 = [
        s_ref[0, i] for i in range(10)]
    p = p_ref[...]
    m = m_ref[...]
    v = v_ref[...]
    g = g_ref[...].astype(jnp.float32)
    m_new = b1 * m + (1 - b1) * g
    v_new = b2 * v + (1 - b2) * g * g
    denom = jnp.sqrt(v_new / bc2) + eps
    if discount:
        mhat = mu_next * m_new / (1 - mu_prod_next) + (1 - mu_t) * g / (1 - mu_prod)
    else:
        mhat = mu_next * m_new / (1 - mu_prod_next) + g
    po_ref[...] = p * (1 - lr * wd) - lr * mhat / denom
    mo_ref[...] = m_new
    vo_ref[...] = v_new


def nag_update(p, m, v, g, *, lr, b1=0.99, b2=0.95, eps=1e-8, wd=0.01,
               mu_t, mu_next, mu_prod, mu_prod_next, bc2, discount=True,
               block=1024, interpret=None):
    """Flat fp32 p/m/v and grad g (any dtype). Returns (p', m', v')."""
    if interpret is None:
        interpret = jax.devices()[0].platform == "cpu"
    n = p.size
    nb = -(-n // block)
    pad = nb * block - n

    def prep(x, dt=jnp.float32):
        x = x.reshape(-1).astype(dt)
        return jnp.pad(x, (0, pad)).reshape(nb, block)

    scalars = jnp.stack([jnp.asarray(x, jnp.float32) for x in
                         (lr, b1, b2, eps, wd, mu_t, mu_next, mu_prod,
                          mu_prod_next, bc2)]).reshape(1, 10)
    outs = pl.pallas_call(
        functools.partial(_kernel, discount=discount),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, 10), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((nb, block), jnp.float32)] * 3,
        interpret=interpret,
    )(prep(p), prep(m), prep(v), prep(g), scalars)
    shape = p.shape
    return tuple(o.reshape(-1)[:n].reshape(shape) for o in outs)
