"""Flash attention for TPU (Pallas): q/kv-blocked online softmax in VMEM.

TPU adaptation of the IO-aware attention idea (FlashAttention, arXiv:2205.14135):
instead of CUDA warps/shared-memory, blocks are staged HBM->VMEM by BlockSpec and
the MXU consumes (block_q x d) @ (d x block_k) tiles; the kv-block axis is the
*last* grid axis, which TPU iterates sequentially per core, so the running softmax
state (m, l, acc) lives in VMEM scratch across kv steps. Supports causal masking,
sliding windows, and gemma-style logit softcap. Block sizes default to MXU-aligned
(128) multiples.

Forward grid: (batch*kv_heads*group, num_q_blocks, num_kv_blocks). With
``return_residuals=True`` the forward also emits the per-row logsumexp, which is
all the backward needs to rebuild attention probabilities blockwise.

Backward (FlashAttention-2 recurrence, arXiv:2307.08691): never materializes the
S x S matrix. Probabilities are recomputed per tile as p = exp(s - lse) from the
saved (o, lse) residuals, and ds = p * (dp - delta) with delta = rowsum(do * o).
Two passes:
  - dk/dv: grid (B*H, num_kv_blocks, num_q_blocks) — kv-parallel, the q axis is
    last (sequential) so dk/dv accumulate in VMEM scratch across q tiles;
  - dq:    grid (B*H, num_q_blocks, num_kv_blocks) — q-parallel, kv sequential,
    dq accumulates in VMEM scratch.
Mask gradients: masked entries have p = 0 so they drop out of every product; the
softcap gradient rescales ds by sech^2 = 1 - (s_capped/cap)^2.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *rest, scale, causal, window, softcap,
            block_q, block_k, seq_len, with_lse):
    if with_lse:
        lse_ref, m_scr, l_scr, acc_scr = rest
    else:
        lse_ref = None
        m_scr, l_scr, acc_scr = rest
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)  # [bq, d]
    k = k_ref[0].astype(jnp.float32)  # [bk, d]
    v = v_ref[0].astype(jnp.float32)  # [bk, d]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale  # [bq, bk]
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = k_pos < seq_len
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]  # [bq, 1]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)  # [bq, bk]
    alpha = jnp.exp(m_prev - m_new)  # [bq, 1]
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)
        if lse_ref is not None:
            lse_ref[0] = m_scr[...] + jnp.log(denom)


def _mask_and_p(q, k, lse, *, qi, ki, scale, causal, window, softcap,
                block_q, block_k, seq_len):
    """Shared backward tile math: recompute capped scores and p = exp(s - lse).

    Returns (p, s_capped) with masked entries of p zeroed. Padded / future q rows
    need no extra mask: their do and delta are zero, so every product they enter
    vanishes.
    """
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale  # [bq, bk]
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = k_pos < seq_len
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    p = jnp.where(mask, jnp.exp(s - lse), 0.0)  # [bq, bk]
    return p, s


def _ds_from(p, s, dp, delta, softcap):
    """ds (grad wrt the pre-softcap scaled scores) from p and dp = do @ v^T."""
    ds = p * (dp - delta)  # grad wrt capped scores
    if softcap is not None:
        ds = ds * (1.0 - (s / softcap) ** 2)  # sech^2 of the softcap tanh
    return ds


def _bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                    dk_ref, dv_ref, dk_scr, dv_scr, *, scale, causal, window,
                    softcap, block_q, block_k, seq_len):
    ki = pl.program_id(1)
    qi = pl.program_id(2)
    nq = pl.num_programs(2)

    @pl.when(qi == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    q = q_ref[0].astype(jnp.float32)      # [bq, d]
    k = k_ref[0].astype(jnp.float32)      # [bk, d]
    v = v_ref[0].astype(jnp.float32)      # [bk, d]
    do = do_ref[0].astype(jnp.float32)    # [bq, d]
    lse = lse_ref[0]                      # [bq, 1] f32
    delta = delta_ref[0]                  # [bq, 1] f32

    p, s = _mask_and_p(q, k, lse, qi=qi, ki=ki, scale=scale, causal=causal,
                       window=window, softcap=softcap, block_q=block_q,
                       block_k=block_k, seq_len=seq_len)
    dv_scr[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32)  # [bk, d]
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [bq, bk]
    ds = _ds_from(p, s, dp, delta, softcap)
    dk_scr[...] += jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32) * scale

    @pl.when(qi == nq - 1)
    def _finish():
        dk_ref[0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_scr[...].astype(dv_ref.dtype)


def _bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                   dq_ref, dq_scr, *, scale, causal, window, softcap,
                   block_q, block_k, seq_len):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    do = do_ref[0].astype(jnp.float32)
    lse = lse_ref[0]
    delta = delta_ref[0]

    p, s = _mask_and_p(q, k, lse, qi=qi, ki=ki, scale=scale, causal=causal,
                       window=window, softcap=softcap, block_q=block_q,
                       block_k=block_k, seq_len=seq_len)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = _ds_from(p, s, dp, delta, softcap)
    dq_scr[...] += jax.lax.dot_general(ds, k, (((1,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32) * scale

    @pl.when(ki == nk - 1)
    def _finish():
        dq_ref[0] = dq_scr[...].astype(dq_ref.dtype)


def _blocks_and_pad(q, k, block_q, block_k):
    Sq = q.shape[2]
    Sk = k.shape[2]
    block_q = min(block_q, max(8, Sq))
    block_k = min(block_k, max(8, Sk))
    pq = (-Sq) % block_q
    pk = (-Sk) % block_k
    return block_q, block_k, pq, pk


def flash_attention(q, k, v, *, causal=True, window=None, softcap=None,
                    scale=None, block_q=128, block_k=128, interpret=None,
                    return_residuals=False):
    """q [B, H, Sq, d]; k, v [B, Hkv, Sk, d] with H = Hkv * G. Returns [B, H, Sq, d].

    Sq/Sk are padded to block multiples internally; padded kv is masked out.
    With ``return_residuals=True`` also returns the row logsumexp [B, H, Sq] (f32),
    the only extra residual the backward kernels need.
    """
    B, H, Sq, d = q.shape
    _, Hkv, Sk, _ = k.shape
    G = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    if interpret is None:
        interpret = jax.devices()[0].platform == "cpu"

    block_q, block_k, pq, pk = _blocks_and_pad(q, k, block_q, block_k)
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    Sqp, Skp = Sq + pq, Sk + pk

    qf = qp.reshape(B * H, Sqp, d)
    kf = jnp.repeat(kp, G, axis=1).reshape(B * H, Skp, d)
    vf = jnp.repeat(vp, G, axis=1).reshape(B * H, Skp, d)

    grid = (B * H, Sqp // block_q, Skp // block_k)
    in_specs = [
        pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
    ]
    out_specs = [pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0))]
    out_shape = [jax.ShapeDtypeStruct((B * H, Sqp, d), q.dtype)]
    if return_residuals:
        out_specs.append(pl.BlockSpec((1, block_q, 1), lambda b, i, j: (b, i, 0)))
        out_shape.append(jax.ShapeDtypeStruct((B * H, Sqp, 1), jnp.float32))
    outs = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, window=window,
                          softcap=softcap, block_q=block_q, block_k=block_k,
                          seq_len=Sk, with_lse=return_residuals),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs if return_residuals else out_specs[0],
        out_shape=out_shape if return_residuals else out_shape[0],
        scratch_shapes=[  # running softmax state (m, l, acc) in VMEM
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    if return_residuals:
        out, lse = outs
        return (out.reshape(B, H, Sqp, d)[:, :, :Sq, :],
                lse.reshape(B, H, Sqp)[:, :, :Sq])
    return outs.reshape(B, H, Sqp, d)[:, :, :Sq, :]


def flash_attention_bwd(q, k, v, o, lse, do, *, causal=True, window=None,
                        softcap=None, scale=None, block_q=128, block_k=128,
                        interpret=None):
    """Backward from saved residuals. Returns (dq, dk, dv) in the input dtypes.

    q/o/do [B, H, Sq, d]; k, v [B, Hkv, Sk, d]; lse [B, H, Sq] f32. dk/dv are
    group-summed back to the Hkv layout (the forward broadcast k/v over G).
    """
    B, H, Sq, d = q.shape
    _, Hkv, Sk, _ = k.shape
    G = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    if interpret is None:
        interpret = jax.devices()[0].platform == "cpu"

    block_q, block_k, pq, pk = _blocks_and_pad(q, k, block_q, block_k)
    Sqp, Skp = Sq + pq, Sk + pk
    pad_q = ((0, 0), (0, 0), (0, pq), (0, 0))
    pad_k = ((0, 0), (0, 0), (0, pk), (0, 0))
    qf = jnp.pad(q, pad_q).reshape(B * H, Sqp, d)
    kf = jnp.repeat(jnp.pad(k, pad_k), G, axis=1).reshape(B * H, Skp, d)
    vf = jnp.repeat(jnp.pad(v, pad_k), G, axis=1).reshape(B * H, Skp, d)
    dof = jnp.pad(do, pad_q).reshape(B * H, Sqp, d)
    of = jnp.pad(o, pad_q).reshape(B * H, Sqp, d)
    lsef = jnp.pad(lse, ((0, 0), (0, 0), (0, pq))).reshape(B * H, Sqp, 1)
    # delta_i = rowsum(do_i * o_i): one fused elementwise-reduce pass in XLA;
    # zero on padded rows, which is what zeroes their ds contributions in-kernel.
    delta = jnp.sum(dof.astype(jnp.float32) * of.astype(jnp.float32),
                    axis=-1, keepdims=True)

    kw = dict(scale=scale, causal=causal, window=window, softcap=softcap,
              block_q=block_q, block_k=block_k, seq_len=Sk)
    q_spec = pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0))
    r_spec = pl.BlockSpec((1, block_q, 1), lambda b, i, j: (b, i, 0))
    k_spec = pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0))

    # kv-parallel pass: q axis last (sequential), dk/dv accumulate in VMEM
    qT_spec = pl.BlockSpec((1, block_q, d), lambda b, j, i: (b, i, 0))
    rT_spec = pl.BlockSpec((1, block_q, 1), lambda b, j, i: (b, i, 0))
    kT_spec = pl.BlockSpec((1, block_k, d), lambda b, j, i: (b, j, 0))
    dkf, dvf = pl.pallas_call(
        functools.partial(_bwd_dkv_kernel, **kw),
        grid=(B * H, Skp // block_k, Sqp // block_q),
        in_specs=[qT_spec, kT_spec, kT_spec, qT_spec, rT_spec, rT_spec],
        out_specs=[kT_spec, kT_spec],
        out_shape=[jax.ShapeDtypeStruct((B * H, Skp, d), jnp.float32)] * 2,
        scratch_shapes=[pltpu.VMEM((block_k, d), jnp.float32)] * 2,
        interpret=interpret,
    )(qf, kf, vf, dof, lsef, delta)

    # q-parallel pass: kv axis last (sequential), dq accumulates in VMEM
    dqf = pl.pallas_call(
        functools.partial(_bwd_dq_kernel, **kw),
        grid=(B * H, Sqp // block_q, Skp // block_k),
        in_specs=[q_spec, k_spec, k_spec, q_spec, r_spec, r_spec],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct((B * H, Sqp, d), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_q, d), jnp.float32)],
        interpret=interpret,
    )(qf, kf, vf, dof, lsef, delta)

    dq = dqf.reshape(B, H, Sqp, d)[:, :, :Sq, :].astype(q.dtype)
    # un-broadcast the GQA repeat: head h = kv * G + g, sum over g
    dk = dkf.reshape(B, Hkv, G, Skp, d).sum(axis=2)[:, :, :Sk, :].astype(k.dtype)
    dv = dvf.reshape(B, Hkv, G, Skp, d).sum(axis=2)[:, :, :Sk, :].astype(v.dtype)
    return dq, dk, dv
