"""Flash attention for TPU (Pallas): q/kv-blocked online softmax in VMEM.

TPU adaptation of the IO-aware attention idea (FlashAttention, arXiv:2205.14135):
instead of CUDA warps/shared-memory, blocks are staged HBM->VMEM by BlockSpec and
the MXU consumes (block_q x d) @ (d x block_k) tiles; the kv-block axis is the
*last* grid axis, which TPU iterates sequentially per core, so the running softmax
state (m, l, acc) lives in VMEM scratch across kv steps. Supports causal masking,
sliding windows, and gemma-style logit softcap. Block sizes default to MXU-aligned
(128) multiples.

Grid: (batch*kv_heads*group, num_q_blocks, num_kv_blocks).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale, causal, window, softcap, block_q, block_k, seq_len):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)  # [bq, d]
    k = k_ref[0].astype(jnp.float32)  # [bk, d]
    v = v_ref[0].astype(jnp.float32)  # [bk, d]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale  # [bq, bk]
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = k_pos < seq_len
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]  # [bq, 1]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)  # [bq, bk]
    alpha = jnp.exp(m_prev - m_new)  # [bq, 1]
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, window=None, softcap=None,
                    scale=None, block_q=128, block_k=128, interpret=None):
    """q [B, H, Sq, d]; k, v [B, Hkv, Sk, d] with H = Hkv * G. Returns [B, H, Sq, d].

    Sq/Sk are padded to block multiples internally; padded kv is masked out.
    """
    B, H, Sq, d = q.shape
    _, Hkv, Sk, _ = k.shape
    G = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    if interpret is None:
        interpret = jax.devices()[0].platform == "cpu"

    block_q = min(block_q, max(8, Sq))
    block_k = min(block_k, max(8, Sk))
    pq = (-Sq) % block_q
    pk = (-Sk) % block_k
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    Sqp, Skp = Sq + pq, Sk + pk

    qf = qp.reshape(B * H, Sqp, d)
    kf = jnp.repeat(kp, G, axis=1).reshape(B * H, Skp, d)
    vf = jnp.repeat(vp, G, axis=1).reshape(B * H, Skp, d)

    grid = (B * H, Sqp // block_q, Skp // block_k)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, window=window,
                          softcap=softcap, block_q=block_q, block_k=block_k,
                          seq_len=Sk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sqp, d), q.dtype),
        scratch_shapes=[  # running softmax state (m, l, acc) in VMEM
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Sqp, d)[:, :, :Sq, :]
