"""Jit'd public wrappers for the Pallas kernels.

Model/optimizer code routes through kernels/dispatch.py (backend registry);
these wrappers are the standalone jit entry points for notebooks/benchmarks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention
from repro.kernels.nag_update import nag_update
from repro.kernels.ssd_scan import ssd_scan

flash_attention_op = jax.jit(
    flash_attention,
    static_argnames=("causal", "window", "softcap", "scale", "block_q", "block_k",
                     "interpret"))

ssd_scan_op = jax.jit(ssd_scan, static_argnames=("chunk", "interpret"))

nag_update_op = jax.jit(
    nag_update,
    static_argnames=("b1", "b2", "eps", "wd", "discount", "block", "interpret"))


def fused_nadam_tree(params, grads, m, v, *, lr, count, mu_prod, b1=0.99, b2=0.95,
                     eps=1e-8, wd=0.01, psi=0.004, discount=True, interpret=None):
    """Tree-level fused NAdam step using the Pallas kernel per leaf.

    Mirrors optim.optimizers.nadam (same mu warmup bookkeeping); returns
    (new_params, new_m, new_v, new_mu_prod).
    """
    c = count + 1
    cf = c.astype(jnp.float32)
    mu_t = b1 * (1.0 - 0.5 * 0.96 ** (cf * psi))
    mu_next = b1 * (1.0 - 0.5 * 0.96 ** ((cf + 1) * psi))
    mp = mu_prod * mu_t
    mpn = mp * mu_next
    bc2 = 1 - b2 ** cf

    def leaf(p, g, m_, v_):
        return nag_update(p, m_, v_, g, lr=lr, b1=b1, b2=b2, eps=eps, wd=wd,
                          mu_t=mu_t, mu_next=mu_next, mu_prod=mp, mu_prod_next=mpn,
                          bc2=bc2, discount=discount, interpret=interpret)

    flat_p, td = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(m)
    flat_v = jax.tree.leaves(v)
    outs = [leaf(p, g, m_, v_) for p, g, m_, v_ in zip(flat_p, flat_g, flat_m, flat_v)]
    newp = jax.tree.unflatten(td, [o[0] for o in outs])
    newm = jax.tree.unflatten(td, [o[1] for o in outs])
    newv = jax.tree.unflatten(td, [o[2] for o in outs])
    return newp, newm, newv, mp
