"""Fused residual-add + RMSNorm for TPU (Pallas).

The pre-norm block boundary `y = rmsnorm(x + h); out = x + h` reads/writes x and h
twice when unfused. This kernel makes one pass per (rows, d) tile: computes the
residual sum, its RMS statistics (f32), and both outputs in VREGs.

Grid: (n_row_tiles,) over flattened [tokens, d].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, h_ref, s_ref, r_ref, y_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    h = h_ref[...].astype(jnp.float32)
    r = x + h
    var = jnp.mean(r * r, axis=-1, keepdims=True)
    y = r * jax.lax.rsqrt(var + eps) * (1.0 + s_ref[...].astype(jnp.float32))
    r_ref[...] = r.astype(r_ref.dtype)
    y_ref[...] = y.astype(y_ref.dtype)


def rmsnorm_residual(x, h, scale, *, eps=1e-6, block_rows=8, interpret=None):
    """x, h: [..., d]; scale: [d]. Returns (residual=x+h, y=rmsnorm(residual)*(1+scale))."""
    if interpret is None:
        interpret = jax.devices()[0].platform == "cpu"
    shape = x.shape
    d = shape[-1]
    xf = x.reshape(-1, d)
    hf = h.reshape(-1, d)
    n = xf.shape[0]
    block_rows = min(block_rows, n)
    pad = (-n) % block_rows
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
        hf = jnp.pad(hf, ((0, pad), (0, 0)))
    nb = (n + pad) // block_rows
    r, y = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct(((n + pad), d), x.dtype)] * 2,
        interpret=interpret,
    )(xf, hf, scale)
    return r[:n].reshape(shape), y[:n].reshape(shape)


def rmsnorm_residual_ref(x, h, scale, eps=1e-6):
    r = x.astype(jnp.float32) + h.astype(jnp.float32)
    var = jnp.mean(r * r, axis=-1, keepdims=True)
    y = r * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return r.astype(x.dtype), y.astype(x.dtype)
