"""Fused residual-add + RMSNorm for TPU (Pallas).

The pre-norm block boundary `y = rmsnorm(x + h); out = x + h` reads/writes x and h
twice when unfused. This kernel makes one pass per (rows, d) tile: computes the
residual sum, its RMS statistics (f32), and both outputs in VREGs.

Grid: (n_row_tiles,) over flattened [tokens, d].

Backward: the forward's first output r = x + h IS the residual — nothing else is
saved and nothing is recomputed. One pass per tile rebuilds the RMS statistics
from r, emits d(x) = d(h) = dr + rsqrt-chain(dy), and a per-tile partial of
dscale (reduced across tiles outside the kernel, where the row-tile axis is
parallel-safe).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, h_ref, s_ref, r_ref, y_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    h = h_ref[...].astype(jnp.float32)
    r = x + h
    var = jnp.mean(r * r, axis=-1, keepdims=True)
    y = r * jax.lax.rsqrt(var + eps) * (1.0 + s_ref[...].astype(jnp.float32))
    r_ref[...] = r.astype(r_ref.dtype)
    y_ref[...] = y.astype(y_ref.dtype)


def rmsnorm_residual(x, h, scale, *, eps=1e-6, block_rows=8, interpret=None):
    """x, h: [..., d]; scale: [d]. Returns (residual=x+h, y=rmsnorm(residual)*(1+scale))."""
    if interpret is None:
        interpret = jax.devices()[0].platform == "cpu"
    shape = x.shape
    d = shape[-1]
    xf = x.reshape(-1, d)
    hf = h.reshape(-1, d)
    n = xf.shape[0]
    block_rows = min(block_rows, n)
    pad = (-n) % block_rows
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
        hf = jnp.pad(hf, ((0, pad), (0, 0)))
    nb = (n + pad) // block_rows
    r, y = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct(((n + pad), d), x.dtype)] * 2,
        interpret=interpret,
    )(xf, hf, scale)
    return r[:n].reshape(shape), y[:n].reshape(shape)


def _bwd_kernel(r_ref, s_ref, dr_ref, dy_ref, dxh_ref, dsc_ref, *, eps):
    r = r_ref[...].astype(jnp.float32)
    s = s_ref[...].astype(jnp.float32)  # [d]
    drc = dr_ref[...].astype(jnp.float32)
    dyc = dy_ref[...].astype(jnp.float32)
    var = jnp.mean(r * r, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    rhat = r * inv
    dsc_ref[...] = jnp.sum(dyc * rhat, axis=0, keepdims=True)  # [1, d] partial
    drhat = dyc * (1.0 + s)
    dr_norm = inv * (drhat - rhat * jnp.mean(drhat * rhat, axis=-1, keepdims=True))
    dxh_ref[...] = (drc + dr_norm).astype(dxh_ref.dtype)


def rmsnorm_residual_bwd(r, scale, dr, dy, *, eps=1e-6, block_rows=8,
                         interpret=None):
    """Backward from the saved residual stream r = x + h (a forward OUTPUT).

    dr/dy are the cotangents of the forward's (r, y). Returns (dxh, dscale):
    dxh is the shared cotangent of x and h (both enter only through r), dscale
    is f32 [d].
    """
    if interpret is None:
        interpret = jax.devices()[0].platform == "cpu"
    shape = r.shape
    d = shape[-1]
    rf = r.reshape(-1, d)
    drf = dr.reshape(-1, d)
    dyf = dy.reshape(-1, d)
    n = rf.shape[0]
    block_rows = min(block_rows, n)
    pad = (-n) % block_rows
    if pad:  # zero rows contribute zero to every product below
        rf = jnp.pad(rf, ((0, pad), (0, 0)))
        drf = jnp.pad(drf, ((0, pad), (0, 0)))
        dyf = jnp.pad(dyf, ((0, pad), (0, 0)))
    nb = (n + pad) // block_rows
    dxh, dsc = pl.pallas_call(
        functools.partial(_bwd_kernel, eps=eps),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (i, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct(((n + pad), d), r.dtype),
                   jax.ShapeDtypeStruct((nb, d), jnp.float32)],
        interpret=interpret,
    )(rf, scale, drf, dyf)
    return dxh[:n].reshape(shape), jnp.sum(dsc, axis=0)


def rmsnorm_residual_ref(x, h, scale, eps=1e-6):
    r = x.astype(jnp.float32) + h.astype(jnp.float32)
    var = jnp.mean(r * r, axis=-1, keepdims=True)
    y = r * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return r.astype(x.dtype), y.astype(x.dtype)
