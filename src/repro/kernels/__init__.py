"""Pallas TPU kernels (validated in interpret mode against ref.py oracles):
flash_attention, ssd_scan (Mamba-2 SSD), nag_update (fused delay-corrected NAdam),
rmsnorm_residual. Public jit'd wrappers in ops.py."""
