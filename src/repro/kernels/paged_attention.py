"""Paged-attention decode kernel: flash decode over a fixed-size KV page pool.

The serving path (launch/serve.py) keeps each sequence's KV cache as a chain of
fixed-size pages inside one shared pool, so sequences of wildly different
lengths share memory and pages freed at retirement are recycled (the stash.py
ring discipline, applied to serving). This op is the read side: one decode step
of grouped causal attention where the keys/values are gathered *by the kernel*
through a page table instead of living contiguously.

Shapes (one query token per sequence — decode):

  q          [B, H, d]              current-step queries
  k_pages    [n_pages, PS, Hkv, d]  shared key pool (PS = page size)
  v_pages    [n_pages, PS, Hkv, d]  shared value pool
  page_table [B, MAXP] int32        page ids per sequence, in order; unused
                                    entries MUST hold a valid pool index (0 is
                                    fine) — masking, not the table, bounds reads
  lengths    [B] int32              tokens live in the cache per sequence,
                                    INCLUDING the current step's token

Returns [B, H, d] in q.dtype.

The Pallas kernel runs grid (B, Hkv, MAXP) with the page axis last (sequential
on TPU), streaming one page per step through an online-softmax accumulator in
VMEM — the flash_attention.py discipline. The page table and lengths ride in as
scalar-prefetch operands so the k/v BlockSpec index_map can chase
``page_table[b, j]`` while the next block's DMA is being issued.

Masking: key position ``j*PS + t`` is live iff ``< lengths[b]``; with a sliding
window also ``> lengths[b] - 1 - window`` (identical semantics to
layers._mask_bias with q_pos = lengths-1). Fully-masked rows (inactive lanes)
degrade to a uniform average of pool garbage — finite, and ignored by callers.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def paged_attn_decode_ref(q, k_pages, v_pages, page_table, lengths, *,
                          scale: Optional[float] = None,
                          window: Optional[int] = None,
                          softcap: Optional[float] = None):
    """Pure-jnp oracle: gather the pages densely, mask by length, attend."""
    B, H, d = q.shape
    n_pages, PS, Hkv, _ = k_pages.shape
    MAXP = page_table.shape[1]
    G = H // Hkv
    sc = scale if scale is not None else 1.0 / math.sqrt(d)
    L = MAXP * PS
    k = k_pages[page_table].reshape(B, L, Hkv, d)  # [B, MAXP, PS, Hkv, d] ->
    v = v_pages[page_table].reshape(B, L, Hkv, d)
    qg = q.reshape(B, Hkv, G, d).astype(jnp.float32)
    s = jnp.einsum("bhgd,blhd->bhgl", qg, k.astype(jnp.float32)) * sc
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    pos = jnp.arange(L)[None, :]
    ok = pos < lengths[:, None]
    if window is not None:
        ok &= pos > (lengths[:, None] - 1 - window)
    s = jnp.where(ok[:, None, None, :], s, NEG_INF)
    # manual softmax so fully-masked rows match the kernel (uniform, not NaN)
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - m)
    l = jnp.sum(e, axis=-1, keepdims=True)
    p = e / jnp.maximum(l, 1e-30)
    out = jnp.einsum("bhgl,blhd->bhgd", p, v.astype(jnp.float32))
    return out.reshape(B, H, d).astype(q.dtype)


def _decode_kernel(pt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref, *, scale, window, softcap,
                   page_size, n_pages_grid):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)        # [G, d]
    k = k_ref[0, :, 0, :].astype(jnp.float32)  # [PS, d]
    v = v_ref[0, :, 0, :].astype(jnp.float32)  # [PS, d]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale  # [G, PS]
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    L = len_ref[b]
    kpos = j * page_size + jax.lax.broadcasted_iota(jnp.int32, (1, page_size), 1)
    ok = kpos < L
    if window is not None:
        ok &= kpos > L - 1 - window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]                         # [G, 1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                      # [G, PS]
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == n_pages_grid - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def paged_attn_decode(q, k_pages, v_pages, page_table, lengths, *,
                      scale: Optional[float] = None,
                      window: Optional[int] = None,
                      softcap: Optional[float] = None,
                      interpret: Optional[bool] = None):
    """Pallas paged decode attention (see module docstring for the contract)."""
    B, H, d = q.shape
    n_pages, PS, Hkv, dk = k_pages.shape
    MAXP = page_table.shape[1]
    if H % Hkv != 0:
        raise ValueError(f"H={H} not a multiple of Hkv={Hkv}")
    if dk != d or v_pages.shape != k_pages.shape:
        raise ValueError("q/k_pages/v_pages head-dim or pool-shape mismatch")
    if page_table.shape[0] != B or lengths.shape != (B,):
        raise ValueError("page_table/lengths batch mismatch")
    G = H // Hkv
    sc = scale if scale is not None else 1.0 / math.sqrt(d)
    if interpret is None:
        interpret = jax.devices()[0].platform == "cpu"

    q4 = q.reshape(B, Hkv, G, d)
    kernel = functools.partial(
        _decode_kernel, scale=sc, window=window, softcap=softcap,
        page_size=PS, n_pages_grid=MAXP)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, MAXP),
        in_specs=[
            pl.BlockSpec((1, 1, G, d), lambda b, h, j, pt, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, PS, 1, d), lambda b, h, j, pt, ln: (pt[b, j], 0, h, 0)),
            pl.BlockSpec((1, PS, 1, d), lambda b, h, j, pt, ln: (pt[b, j], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, d), lambda b, h, j, pt, ln: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, d), q.dtype),
        interpret=interpret,
    )(page_table.astype(jnp.int32), lengths.astype(jnp.int32), q4, k_pages, v_pages)
    return out.reshape(B, H, d)
