"""Production mesh builders (functions, never module-level constants)."""
from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    """jax.make_mesh across jax versions: axis_types= (Auto) only where supported."""
    try:
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    except (AttributeError, TypeError):
        # older jax (< 0.5): no AxisType / axis_types kwarg; axes are Auto already
        return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16,16)=('data','model') — 256 chips.
    Multi-pod:  (2,16,16)=('pod','data','model') — 512 chips, 'pod' carries the
    pipeline stages over the slow inter-pod links (DESIGN.md §5)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2, *, multi_pod: bool = False):
    """Small mesh for CPU tests (requires xla_force_host_platform_device_count)."""
    shape = ((2, n_data, n_model) if multi_pod else (n_data, n_model))
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)
