"""ShapeDtypeStruct input stand-ins for every (arch x shape) cell — weak-type-correct,
shardable, zero allocation. The dry-run lowers against these.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config
from repro.models.layers import ModelCfg


@dataclasses.dataclass(frozen=True)
class Cell:
    arch: str
    shape: str
    seq: int
    batch: int
    kind: str  # train | prefill | decode
    accum: int  # K microbatches per update (train only)


def make_cell(arch: str, shape: str, accum: Optional[int] = None) -> Cell:
    seq, batch, kind = SHAPES[shape]
    if accum is None:
        accum = default_accum(arch, shape) if kind == "train" else 1
    return Cell(arch, shape, seq, batch, kind, accum)


def default_accum(arch: str, shape: str) -> int:
    """K chosen so the per-device microbatch activation footprint fits HBM."""
    big = {"dbrx_132b", "internlm2_20b", "gemma3_12b", "gemma2_9b", "zamba2_7b"}
    from repro.configs import norm_name

    return 16 if norm_name(arch) in big else 8


def tune_cfg(cfg: ModelCfg, cell: Cell) -> ModelCfg:
    """Per-cell model knobs: q-chunk long attention, chunk big-vocab xent,
    seq-chunk the MoE channel mix at prefill scale."""
    upd = {}
    if cell.kind == "prefill" and cell.seq > 8192:
        upd["attn_q_chunk"] = 1024
        if cfg.moe:
            upd["mlp_s_chunk"] = 2048
    if cell.kind == "train" and cell.seq >= 4096:
        upd["attn_q_chunk"] = 1024
    if cell.kind == "train" and cfg.vocab_size >= 64000 and not cfg.xent_chunk:
        upd["xent_chunk"] = 512
    if cfg.dtype != jnp.bfloat16:
        upd["dtype"] = jnp.bfloat16  # TPU target dtype for dry-runs
    return dataclasses.replace(cfg, **upd) if upd else cfg


def train_batch_specs(cfg: ModelCfg, cell: Cell):
    K = cell.accum
    b = cell.batch // K
    assert b * K == cell.batch, f"accum {K} must divide global batch {cell.batch}"
    S = cell.seq
    sds = {
        "tokens": jax.ShapeDtypeStruct((K, b, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((K, b, S), jnp.int32),
    }
    if cfg.enc_periods:
        sds["frames"] = jax.ShapeDtypeStruct((K, b, cfg.n_frames, cfg.d_model), jnp.bfloat16)
    if cfg.n_prefix_img:
        sds["patches"] = jax.ShapeDtypeStruct((K, b, cfg.n_prefix_img, cfg.d_model), jnp.bfloat16)
    return sds


def prefill_batch_specs(cfg: ModelCfg, cell: Cell):
    sds = {"tokens": jax.ShapeDtypeStruct((cell.batch, cell.seq), jnp.int32)}
    if cfg.enc_periods:
        sds["frames"] = jax.ShapeDtypeStruct((cell.batch, cfg.n_frames, cfg.d_model), jnp.bfloat16)
    if cfg.n_prefix_img:
        sds["patches"] = jax.ShapeDtypeStruct((cell.batch, cfg.n_prefix_img, cfg.d_model), jnp.bfloat16)
    return sds


def decode_token_specs(cell: Cell):
    return (jax.ShapeDtypeStruct((cell.batch, 1), jnp.int32),
            jax.ShapeDtypeStruct((), jnp.int32))
