import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"
# ^ MUST run before any jax import (device count locks at first init).

"""Multi-pod dry-run: lower + compile every (architecture x input shape) cell on the
production meshes, print memory/cost analysis, and dump roofline raw terms.

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out report.json]

Single-pod (16,16): pjit async train_step (paper method as first-class feature) or
serve steps. Multi-pod (2,16,16): 'pod' carries cross-pod parallelism — mode 'pp'
(default) uses the shard_map 1F1B async pipeline over the pod axis (the paper's
setting: stages over slow links); mode 'dp' shards the global batch over
('pod','data') as a fallback sanity path.
"""
import argparse
import json
import re
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis import sanitize
from repro.configs import ARCH_IDS, ASSIGNED, SHAPES, cell_runnable, get_config, norm_name
from repro.core.engine import AsyncTrainer, EngineCfg
from repro.launch import specs as S
from repro.launch.mesh import make_production_mesh
from repro.models import lm
from repro.parallel import sharding as shd


def _maybe(spec_tree, sds_tree, mesh):
    """Drop sharded dims that do not divide (e.g. batch=1 cells)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def fix(spec, sds):
        out = []
        for d, names in enumerate(spec):
            if names is None:
                out.append(None)
                continue
            ns = names if isinstance(names, tuple) else (names,)
            tot = int(np.prod([sizes[n] for n in ns]))
            out.append(names if sds.shape[d] % tot == 0 else None)
        return P(*out)

    return jax.tree.map(fix, spec_tree, sds_tree,
                        is_leaf=lambda x: isinstance(x, P))


def lower_train(cfg, cell, mesh, method="ours", n_stages=4, pod_mode="dp"):
    multi = "pod" in mesh.axis_names
    if multi and pod_mode == "pp":
        from repro.parallel import pipeline_spmd
        return pipeline_spmd.lower_pipeline_train(cfg, cell, mesh, method=method)
    ecfg = EngineCfg(n_stages=n_stages, update_interval=cell.accum,
                     collect_metrics=False, stash_dtype=jnp.bfloat16,
                     total_steps=50000, warmup_steps=3000)
    tr = AsyncTrainer(cfg, ecfg, method)
    state_sds = jax.eval_shape(tr.init, jax.random.PRNGKey(0))
    batch_sds = S.train_batch_specs(cfg, cell)

    state_spec = shd.spec_for_tree(state_sds, mesh, extra_data_axis="pod" if multi else None)
    b_spec = jax.tree.map(
        lambda x: shd.batch_spec(mesh, len(x.shape), leading_micro=True, pod_data=multi),
        batch_sds)
    state_spec = _maybe(state_spec, state_sds, mesh)
    b_spec = _maybe(b_spec, batch_sds, mesh)

    with mesh:
        jitted = jax.jit(
            tr.step,
            donate_argnums=(0,),
            in_shardings=(jax.tree.map(lambda s: NamedSharding(mesh, s), state_spec,
                                       is_leaf=lambda x: isinstance(x, P)),
                          jax.tree.map(lambda s: NamedSharding(mesh, s), b_spec,
                                       is_leaf=lambda x: isinstance(x, P))),
        )
        lowered = jitted.lower(state_sds, batch_sds)
    return lowered


def lower_prefill(cfg, cell, mesh):
    batch_sds = S.prefill_batch_specs(cfg, cell)
    multi = "pod" in mesh.axis_names
    params_sds = jax.eval_shape(lambda k: lm.init_lm(k, cfg), jax.random.PRNGKey(0))
    p_spec = _maybe(shd.spec_for_tree(params_sds, mesh), params_sds, mesh)
    b_spec = _maybe(jax.tree.map(
        lambda x: shd.batch_spec(mesh, len(x.shape), leading_micro=False, pod_data=multi),
        batch_sds), batch_sds, mesh)

    def fn(params, batch):
        return lm.serve_prefill(params, batch, cfg, max_len=cell.seq)

    with mesh:
        lowered = jax.jit(
            fn,
            in_shardings=(jax.tree.map(lambda s: NamedSharding(mesh, s), p_spec,
                                       is_leaf=lambda x: isinstance(x, P)),
                          jax.tree.map(lambda s: NamedSharding(mesh, s), b_spec,
                                       is_leaf=lambda x: isinstance(x, P))),
        ).lower(params_sds, batch_sds)
    return lowered


def lower_decode(cfg, cell, mesh):
    params_sds = jax.eval_shape(lambda k: lm.init_lm(k, cfg), jax.random.PRNGKey(0))
    cache_sds = jax.eval_shape(lambda: lm.init_caches(cfg, cell.batch, cell.seq))
    if cfg.enc_periods:
        cache_sds["enc_out"] = jax.ShapeDtypeStruct(
            (cell.batch, cfg.n_frames, cfg.d_model), cfg.dtype)
    tok_sds, pos_sds = S.decode_token_specs(cell)

    p_spec = _maybe(shd.spec_for_tree(params_sds, mesh), params_sds, mesh)
    c_spec = _maybe(shd.cache_spec_tree(cache_sds, mesh), cache_sds, mesh)

    def fn(params, caches, tok, pos):
        return lm.serve_decode(params, caches, tok, cfg, pos)

    with mesh:
        lowered = jax.jit(
            fn,
            donate_argnums=(1,),  # caches update in place
            in_shardings=(
                jax.tree.map(lambda s: NamedSharding(mesh, s), p_spec,
                             is_leaf=lambda x: isinstance(x, P)),
                jax.tree.map(lambda s: NamedSharding(mesh, s), c_spec,
                             is_leaf=lambda x: isinstance(x, P)),
                NamedSharding(mesh, P(None, None)),
                NamedSharding(mesh, P()),
            ),
        ).lower(params_sds, cache_sds, tok_sds, pos_sds)
    return lowered


_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?(?:\.\d+)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f64": 8,
                "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8, "f8e4m3fn": 1,
                "f8e5m2": 1, "s16": 2, "u16": 2}


def collective_bytes(hlo_text: str) -> dict:
    """Sum output bytes of every collective op in optimized HLO (per device).

    '-done' halves of async pairs are skipped to avoid double counting.
    """
    out = {}
    for m in _COLL_RE.finditer(hlo_text):
        shapes, kind, suffix = m.group(1), m.group(2), m.group(3)
        if suffix == "-done":
            continue
        total = 0
        for dt, dims in _SHAPE_RE.findall(shapes):
            nb = _DTYPE_BYTES.get(dt)
            if nb is None:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * nb
        out[kind] = out.get(kind, 0) + total
    return out


def analyse(lowered, label: str, n_chips: int):
    t0 = time.perf_counter()
    compiled = lowered.compile()
    dt = time.perf_counter() - t0
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # jax < 0.5 returns [dict] per module
        ca = ca[0] if ca else {}
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    # donated outputs alias their inputs: true live bytes = args + temps + (out - aliased)
    out_extra = max(0, ma.output_size_in_bytes - ma.alias_size_in_bytes)
    rec = {
        "cell": label,
        "compile_s": round(dt, 1),
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "per_device_bytes": int(ma.argument_size_in_bytes + out_extra
                                + ma.temp_size_in_bytes),
        "arg_bytes": int(ma.argument_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "aliased_bytes": int(ma.alias_size_in_bytes),
        "collective_bytes": coll,
        "n_chips": n_chips,
    }
    return rec, compiled


def run_cell(arch, shape, *, multi_pod=False, method="ours", n_stages=4,
             pod_mode="pp", accum=None):
    ok, reason = cell_runnable(arch, shape)
    if not ok:
        return {"cell": f"{arch}/{shape}", "skipped": reason}
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(mesh.devices.shape))
    cell = S.make_cell(arch, shape, accum=accum)
    cfg = S.tune_cfg(get_config(arch), cell)
    if cell.kind == "train":
        lowered = lower_train(cfg, cell, mesh, method=method, n_stages=n_stages,
                              pod_mode=pod_mode if multi_pod else "dp")
    elif cell.kind == "prefill":
        lowered = lower_prefill(cfg, cell, mesh)
    else:
        lowered = lower_decode(cfg, cell, mesh)
    tag = "multi" if multi_pod else "single"
    rec, compiled = analyse(lowered, f"{arch}/{shape}/{tag}", n_chips)
    rec["kind"] = cell.kind
    rec["accum"] = cell.accum
    return rec


def sim_schedule_report(n_stages: int, accum: int, ticks: int, models: list,
                        churn=None, faults=None) -> list:
    """Compute-free pipeline-schedule dry-run: run the event runtime's 1F1B
    discipline (core/runtime.simulate_schedule) under each delay model — and
    optionally a churn (leave/join) schedule and/or a fault-injection spec —
    and report makespan / per-stage utilization / observed-staleness envelope /
    outage + mailbox memory cost / retransmit + escalation counts: capacity
    planning for stragglers, jittery links, elastic membership, and lossy
    transports without compiling a single HLO."""
    from repro.core.runtime import simulate_schedule

    recs = []
    for spec in models:
        r = simulate_schedule(P=n_stages, K=accum, n_ticks=ticks,
                              delay_model=spec, churn=churn, faults=faults)
        rec = {
            "delay_model": spec,
            "P": n_stages, "K": accum, "ticks": ticks,
            "makespan": round(r["makespan"], 3),
            "ticks_per_time": round(ticks / r["makespan"], 4),
            "utilization": [round(u, 3) for u in r["utilization"]],
            "max_tau_obs": list(r["max_tau_obs"]),
            "max_stash": list(r["max_stash"]),
        }
        if accum > 1:
            # steady-state per-stage per-microbatch delay groups (last tick):
            # the [P, K] row the engine's per-microbatch replay consumes —
            # under fixed delays this equals delay.stage_mb_delays(P, K)
            rec["steady_tau_groups"] = [list(g) for g in r["tau_groups"][-1]]
        if churn is not None or faults is not None:
            rec["outage_time"] = [round(t, 3) for t in r["outage_time"]]
            rec["mailbox_high_water"] = [list(hw) for hw in r["mailbox_high_water"]]
        if churn is not None:
            rec["churn"] = churn
        if faults is not None:
            rec["faults"] = faults
            rec["retransmits"] = r["retransmits"]
            rec["escalations"] = r["escalations"]
        recs.append(rec)
    return recs


def main():
    sanitize.apply(verbose=True)  # REPRO_SANITIZE=1 fail-fast mode
    ap = argparse.ArgumentParser(
        epilog="Delay-model spec grammar (fixed:/jitter:/straggler:/outage:/"
               "trace:) and churn windows (STAGE,START,DURATION[/...]): "
               "docs/cli.md. trace:PATH replays measured latencies recorded "
               "by `train --runtime event --record-trace PATH` (a bundled "
               "example lives at examples/trace_p4.json).")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--method", default="ours")
    ap.add_argument("--n-stages", type=int, default=4)
    ap.add_argument("--pod-mode", default="pp", choices=["pp", "dp"])
    ap.add_argument("--accum", type=int, default=None)
    ap.add_argument("--out", default=None)
    ap.add_argument("--sim-schedule", action="store_true",
                    help="event-runtime schedule simulation only (no compiles)")
    ap.add_argument("--sim-ticks", type=int, default=100)
    ap.add_argument("--sim-models", default="fixed;jitter:0.3;straggler:0,4.0",
                    help="';'-separated delay-model specs (see core/events.py)")
    ap.add_argument("--sim-churn", default=None,
                    help="leave/join windows STAGE,START,DURATION[/...] applied "
                         "to every --sim-models cell (see core/events.ChurnModel)")
    ap.add_argument("--sim-faults", default=None,
                    help="fault-injection spec (drop=P,dup=P,crash=N@T...) "
                         "applied to every --sim-models cell — message-level "
                         "faults only; payload faults need real compute "
                         "(see core/faults.py and docs/cli.md)")
    ap.add_argument("--sim-serve", default=None, metavar="N,RATE",
                    help="compute-free serving dry-run: N Poisson requests at "
                         "RATE req/s through runtime.simulate_serve_schedule "
                         "(slots/pages from --serve-slots/--serve-pages)")
    ap.add_argument("--serve-slots", type=int, default=4)
    ap.add_argument("--serve-pages", type=int, default=64)
    ap.add_argument("--serve-page-size", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.sim_serve:
        from repro.core.events import poisson_trace
        from repro.core.runtime import simulate_serve_schedule

        n, rate = args.sim_serve.split(",")
        trace = poisson_trace(int(n), rate=float(rate), seed=args.seed)
        r = simulate_serve_schedule(trace, n_slots=args.serve_slots,
                                    page_size=args.serve_page_size,
                                    n_pages=args.serve_pages)
        ttft = r.pop("ttft")
        r["ttft_p50"] = round(ttft[len(ttft) // 2], 4) if ttft else None
        r["ttft_p99"] = round(ttft[max(len(ttft) * 99 // 100 - 1, 0)], 4) if ttft else None
        r.pop("tpot")
        print(json.dumps(r, default=float), flush=True)
        if args.out:
            with open(args.out, "w") as f:
                json.dump(r, f, indent=1, default=float)
        return

    if args.sim_schedule:
        recs = sim_schedule_report(args.n_stages, args.accum or 1, args.sim_ticks,
                                   args.sim_models.split(";"),
                                   churn=args.sim_churn, faults=args.sim_faults)
        for rec in recs:
            print(json.dumps(rec), flush=True)
        if args.out:
            with open(args.out, "w") as f:
                json.dump(recs, f, indent=1)
        return

    cells = []
    if args.all:
        for a in ASSIGNED:
            for s in SHAPES:
                cells.append((a, s))
    else:
        cells = [(args.arch, args.shape)]

    recs = []
    for a, s in cells:
        try:
            rec = run_cell(a, s, multi_pod=args.multi_pod, method=args.method,
                           n_stages=args.n_stages, pod_mode=args.pod_mode,
                           accum=args.accum)
        except Exception as e:
            rec = {"cell": f"{a}/{s}", "error": f"{type(e).__name__}: {e}"}
        recs.append(rec)
        print(json.dumps(rec), flush=True)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(recs, f, indent=1)
    nerr = sum(1 for r in recs if "error" in r)
    print(f"# {len(recs)} cells, {nerr} errors", file=sys.stderr)
    sys.exit(1 if nerr else 0)


if __name__ == "__main__":
    main()
