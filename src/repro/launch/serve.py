"""Serving launcher: batched prefill + decode loop with a KV/SSD cache.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --reduced \
      --batch 4 --prompt-len 32 --gen 16

The decode jit donates the cache argument (``donate_argnums``): the per-layer
KV/SSD buffers are updated in place instead of being re-allocated every
generated token, which is what keeps steady-state decode allocation-free. The
launcher reports steady-state tok/s separately from the compile-inclusive
first-token figure.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import lm


def generate(params, cfg, prompt_tokens, gen_len, *, temperature=0.0, key=None,
             return_stats=False):
    """Greedy / temperature decoding. Returns tokens [B, gen_len]; with
    ``return_stats=True`` returns (tokens, stats) where stats separates
    compile-inclusive prefill+first-step time from steady-state decode."""
    B, S = prompt_tokens.shape
    max_len = S + gen_len
    batch = {"tokens": prompt_tokens}
    prefill = jax.jit(lambda p, b: lm.serve_prefill(p, b, cfg, max_len=max_len))
    t0 = time.perf_counter()
    logits, caches = prefill(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0
    # donate the cache: decode writes one position per step, so the input and
    # output cache buffers alias and the loop is allocation-free at steady state
    decode = jax.jit(lambda p, c, t, pos: lm.serve_decode(p, c, t, cfg, pos),
                     donate_argnums=(1,))
    toks = []
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    t_first = t_steady = 0.0
    for i in range(gen_len):
        toks.append(tok)
        t0 = time.perf_counter()
        logits, caches = decode(params, caches, tok, jnp.asarray(S + i, jnp.int32))
        if temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits[:, -1] / temperature)[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        if return_stats:  # per-token sync only when timing: the plain decode
            jax.block_until_ready(tok)  # loop keeps dispatching ahead of device
            if i == 0:
                t_first = time.perf_counter() - t0  # includes decode compile
            else:
                t_steady += time.perf_counter() - t0
    out = jnp.concatenate(toks, axis=1)
    if not return_stats:
        return out
    steady_steps = max(gen_len - 1, 1)
    stats = {
        "prefill_s": t_prefill,
        "first_token_s": t_first,
        "steady_s": t_steady,
        "steady_tok_s": B * steady_steps / t_steady if t_steady > 0 else float("nan"),
    }
    return out, stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    key = jax.random.PRNGKey(args.seed)
    params = lm.init_lm(key, cfg)
    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab_size)
    t0 = time.perf_counter()
    out, stats = generate(params, cfg, prompt.astype(jnp.int32), args.gen,
                          return_stats=True)
    dt = time.perf_counter() - t0
    ntok = args.batch * args.gen
    print(f"generated {out.shape} in {dt:.2f}s ({ntok/dt:.1f} tok/s incl. compile)")
    print(f"prefill {stats['prefill_s']:.2f}s; first token {stats['first_token_s']:.2f}s "
          f"(incl. decode compile); steady-state {stats['steady_tok_s']:.1f} tok/s")
    print("sample:", out[0, :16].tolist())


if __name__ == "__main__":
    main()
