"""Serving launcher: batched prefill + decode loop with a KV/SSD cache.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --reduced \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import lm


def generate(params, cfg, prompt_tokens, gen_len, *, temperature=0.0, key=None):
    B, S = prompt_tokens.shape
    max_len = S + gen_len
    batch = {"tokens": prompt_tokens}
    prefill = jax.jit(lambda p, b: lm.serve_prefill(p, b, cfg, max_len=max_len))
    logits, caches = prefill(params, batch)
    decode = jax.jit(lambda p, c, t, pos: lm.serve_decode(p, c, t, cfg, pos))
    toks = []
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    for i in range(gen_len):
        toks.append(tok)
        logits, caches = decode(params, caches, tok, jnp.asarray(S + i, jnp.int32))
        if temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits[:, -1] / temperature)[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    return jnp.concatenate(toks, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    key = jax.random.PRNGKey(args.seed)
    params = lm.init_lm(key, cfg)
    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab_size)
    t0 = time.time()
    out = generate(params, cfg, prompt.astype(jnp.int32), args.gen)
    dt = time.time() - t0
    ntok = args.batch * args.gen
    print(f"generated {out.shape} in {dt:.2f}s ({ntok/dt:.1f} tok/s incl. compile)")
    print("sample:", out[0, :16].tolist())


if __name__ == "__main__":
    main()
