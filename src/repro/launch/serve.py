"""Serving launcher: continuously-batched inference on the event runtime.

Two entry points:

- ``generate`` — the original uniform-batch path: one prefill, then a dense
  lock-step decode loop (every sequence the same length). Kept as the
  equivalence oracle for the engine below and for quick smoke runs.
- ``ServeEngine`` — a continuously-batched service: requests (core/events.py
  ``Request``) arrive on an event queue, are admitted into one of ``n_slots``
  decode lanes when a lane AND enough KV pages are free (the in-flight-cap
  admission discipline of the training runtime, applied to inference), prefill
  one at a time (ragged prompts, right-padded to a page-aligned bucket), then
  join the shared decode batch at the next step. Finished sequences retire at
  any step and their pages return to the ``PagePool`` free list for reuse —
  the stash.py ring discipline applied to serving memory.

  KV lives in fixed-size pages (``lm.init_paged_caches``) read by the
  ``paged_attn_decode`` dispatch op; SSD (mamba2) state is per-lane. Archs with
  SSD blocks prefill at exact prompt length (right-padding would corrupt the
  recurrent state); attention-only archs prefill in page-aligned buckets, which
  is exact under the causal mask.

Quickstart:

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --reduced \
      --batch 4 --prompt-len 32 --gen 16

  # continuous batching under Poisson traffic (the load generator)
  PYTHONPATH=src python -m repro.launch.serve --arch nanogpt-134m --reduced \
      --engine --requests 16 --rate 8.0 --gen 4,8

The decode jit donates the cache argument (``donate_argnums``): the page pools
are updated in place instead of re-allocated every step, which keeps
steady-state decode allocation-free. Flag grammar: docs/cli.md.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import sanitize
from repro.configs import get_config
from repro.core import events
from repro.models import lm


def generate(params, cfg, prompt_tokens, gen_len, *, temperature=0.0, key=None,
             return_stats=False):
    """Greedy / temperature decoding. Returns tokens [B, gen_len]; with
    ``return_stats=True`` returns (tokens, stats) where stats separates
    compile-inclusive prefill+first-step time from steady-state decode."""
    if temperature > 0 and key is None:
        raise ValueError(
            "generate(temperature>0) samples and needs a PRNG key: pass "
            "key=jax.random.PRNGKey(seed) (the CLI derives one from --seed)")
    B, S = prompt_tokens.shape
    max_len = S + gen_len
    batch = {"tokens": prompt_tokens}
    prefill = jax.jit(lambda p, b: lm.serve_prefill(p, b, cfg, max_len=max_len))
    t0 = time.perf_counter()
    logits, caches = prefill(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0
    # donate the cache: decode writes one position per step, so the input and
    # output cache buffers alias and the loop is allocation-free at steady state
    decode = jax.jit(lambda p, c, t, pos: lm.serve_decode(p, c, t, cfg, pos),
                     donate_argnums=(1,))
    toks = []
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    t_first = t_steady = 0.0
    for i in range(gen_len):
        toks.append(tok)
        t0 = time.perf_counter()
        logits, caches = decode(params, caches, tok, jnp.asarray(S + i, jnp.int32))
        if temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits[:, -1] / temperature)[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        if return_stats:  # per-token sync only when timing: the plain decode
            # lint: allow-host-sync(per-token latency timing is the point of return_stats; the plain decode loop below dispatches ahead)
            jax.block_until_ready(tok)
            if i == 0:
                t_first = time.perf_counter() - t0  # includes decode compile
            else:
                t_steady += time.perf_counter() - t0
    out = jnp.concatenate(toks, axis=1)
    if not return_stats:
        return out
    steady_steps = max(gen_len - 1, 1)
    stats = {
        "prefill_s": t_prefill,
        "first_token_s": t_first,
        "steady_s": t_steady,
        "steady_tok_s": B * steady_steps / t_steady if t_steady > 0 else float("nan"),
    }
    return out, stats


# ---------------------------------------------------------------------------
# Page pool: the serving-side stash ring
# ---------------------------------------------------------------------------


class PagePool:
    """Free-list allocator over the shared KV page pool.

    LIFO reuse (freshly-freed pages are handed out first) makes recycling
    observable: ``high_water`` is the peak number of simultaneously-live pages,
    asserted by tests/test_serve.py to prove retirement actually recycles."""

    def __init__(self, n_pages: int):
        if n_pages < 1:
            raise ValueError(f"n_pages must be >= 1, got {n_pages}")
        self.n_pages = n_pages
        self._free = list(range(n_pages - 1, -1, -1))  # pop() yields 0, 1, ...
        self.high_water = 0

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return self.n_pages - len(self._free)

    def alloc(self, n: int) -> Optional[list]:
        if n > len(self._free):
            return None
        ids = [self._free.pop() for _ in range(n)]
        self.high_water = max(self.high_water, self.in_use)
        return ids

    def free(self, ids: Sequence[int]) -> None:
        for i in ids:
            if not 0 <= i < self.n_pages or i in self._free:
                raise ValueError(f"double/invalid free of page {i}")
        self._free.extend(reversed(list(ids)))  # LIFO: reuse newest-freed first


@dataclasses.dataclass(frozen=True)
class ServeCfg:
    """Engine knobs. n_slots is the decode in-flight cap (admission control);
    max_pages_per_seq is the page-table width — the serving analogue of the
    stash ring depth bound (requests that would overflow it are rejected at
    submit with a sizing hint, mirroring stash._check_tau)."""

    n_slots: int = 4
    page_size: int = 8
    n_pages: int = 64
    max_pages_per_seq: int = 8
    prefill_bucket: int = 0  # pad prompts up to a multiple of this (0: one page)
    temperature: float = 0.0
    seed: int = 0
    # service-level controls (0 = unbounded / disabled). A request that cannot
    # start within ttft_deadline_s is shed from the admission queue; one that
    # cannot finish within deadline_s of arrival is evicted mid-decode (its
    # pages return to the pool, its partial tokens are reported); arrivals
    # beyond max_queue waiting requests are rejected outright. All three are
    # counted in run()'s return — load shedding is observable, never silent.
    ttft_deadline_s: float = 0.0
    deadline_s: float = 0.0
    max_queue: int = 0


class ServeEngine:
    """Continuously-batched serving over the paged caches (module docstring)."""

    def __init__(self, params, cfg, scfg: ServeCfg = ServeCfg()):
        if scfg.n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {scfg.n_slots}")
        if scfg.ttft_deadline_s < 0 or scfg.deadline_s < 0 or scfg.max_queue < 0:
            raise ValueError(
                f"deadlines/max_queue must be >= 0 (0 disables), got "
                f"ttft_deadline_s={scfg.ttft_deadline_s} "
                f"deadline_s={scfg.deadline_s} max_queue={scfg.max_queue}")
        bucket = scfg.prefill_bucket or scfg.page_size
        if bucket % scfg.page_size:
            raise ValueError(
                f"prefill_bucket ({bucket}) must be a multiple of "
                f"page_size ({scfg.page_size})")
        self.params, self.cfg, self.scfg = params, cfg, scfg
        self._bucket = bucket
        # SSD recurrences integrate every input token, so right-padded prompts
        # would corrupt the state: those archs prefill at exact prompt length
        # (one retrace per distinct length; attention pages stay page-padded
        # inside write_prefill_pages).
        self._exact_prefill = any(
            b.mixer == "ssm" for b in cfg.pattern + cfg.prelude)
        self.caches = lm.init_paged_caches(  # raises for unsupported archs
            cfg, scfg.n_slots, scfg.n_pages, scfg.page_size)
        self.pool = PagePool(scfg.n_pages)
        B, MAXP = scfg.n_slots, scfg.max_pages_per_seq
        self._page_table = np.zeros((B, MAXP), np.int32)
        self._lengths = np.zeros(B, np.int32)
        self._active = np.zeros(B, bool)
        self._tokens = np.zeros((B, 1), np.int32)
        self._slot_req: list = [None] * B  # per-lane in-flight request state
        self._key = jax.random.PRNGKey(scfg.seed)
        self._decode = jax.jit(lm.serve_decode_paged, static_argnames="cfg",
                               donate_argnums=(1,))
        self._prefill = jax.jit(self._prefill_to_pages, donate_argnums=(1,))

    # -- jitted bodies ------------------------------------------------------

    def _prefill_to_pages(self, params, paged, tokens, last_pos, page_ids, slot):
        logits, dense = lm.serve_prefill(params, {"tokens": tokens}, self.cfg,
                                         last_pos=last_pos)
        paged = lm.write_prefill_pages(paged, dense, page_ids, slot,
                                       self.scfg.page_size)
        return logits[:, -1], paged

    # -- admission ----------------------------------------------------------

    def pages_needed(self, req: events.Request) -> int:
        PS = self.scfg.page_size
        bucket = self._bucket_len(req.prompt_len)
        return max(-(-bucket // PS), -(-(req.prompt_len + req.gen_len) // PS))

    def _bucket_len(self, prompt_len: int) -> int:
        if self._exact_prefill:
            return prompt_len
        return -(-prompt_len // self._bucket) * self._bucket

    def _check_request(self, req: events.Request) -> None:
        if req.prompt_len < 1 or req.gen_len < 1:
            raise ValueError(f"request {req.rid}: prompt_len and gen_len must "
                             f"be >= 1, got {(req.prompt_len, req.gen_len)}")
        need = self.pages_needed(req)
        if need > self.scfg.max_pages_per_seq:
            raise ValueError(
                f"request {req.rid} needs {need} pages > max_pages_per_seq="
                f"{self.scfg.max_pages_per_seq}; raise max_pages_per_seq or "
                f"page_size (the serving analogue of a stash ring too shallow "
                f"for the observed delay)")
        if need > self.scfg.n_pages:
            raise ValueError(
                f"request {req.rid} needs {need} pages > pool n_pages="
                f"{self.scfg.n_pages}; raise n_pages")

    def _sample(self, row_logits, rid: int, idx: int) -> int:
        if self.scfg.temperature <= 0:
            return int(np.argmax(np.asarray(row_logits)))
        # keyed per (request, emitted index): retirement/admission churn in the
        # batch never perturbs another request's sample stream
        k = jax.random.fold_in(jax.random.fold_in(self._key, rid), idx)
        return int(jax.random.categorical(
            k, jnp.asarray(row_logits) / self.scfg.temperature))

    # -- the serving loop ---------------------------------------------------

    def run(self, requests: Sequence[events.Request],
            prompts: Optional[Dict[int, np.ndarray]] = None) -> dict:
        """Serve a whole trace; returns per-request results + service metrics.

        prompts: optional {rid: 1-D int32 prompt tokens} (defaults to synthetic
        tokens keyed by (seed, rid)). The clock is wall time, fast-forwarded
        over idle gaps so a sparse trace doesn't sleep through its own bench.
        """
        for r in requests:
            self._check_request(r)
        prompts = dict(prompts or {})
        for r in requests:
            if r.rid not in prompts:
                k = jax.random.fold_in(jax.random.PRNGKey(self.scfg.seed ^ 0x5EED), r.rid)
                # lint: allow-host-sync(one-time prompt materialization at trace setup, before the decode hot loop starts)
                prompts[r.rid] = np.asarray(jax.random.randint(
                    k, (r.prompt_len,), 0, self.cfg.vocab_size), np.int32)
            elif len(prompts[r.rid]) != r.prompt_len:
                raise ValueError(f"prompt for rid {r.rid} has length "
                                 f"{len(prompts[r.rid])} != {r.prompt_len}")

        q = events.EventQueue()
        for r in requests:
            q.push(r.arrival, "arrive", stage=0, mb=r.rid, payload=r)
        waiting: list = []  # admission queue, FIFO
        results: dict = {}
        step_times: list = []
        step_tokens: list = []  # active lanes per step = tokens emitted by it
        n_rejected = n_shed = n_evicted = 0
        scfg = self.scfg
        t0 = time.perf_counter()
        skew = 0.0  # virtual fast-forward over idle gaps

        def now() -> float:
            return time.perf_counter() - t0 + skew

        # the finally block is the page-leak firewall: whatever unwinds out of
        # the loop (an injected decode exception, a KeyboardInterrupt), every
        # active lane's pages go back to the pool before the stack does —
        # tests/test_serve.py asserts the pool drains to full after a crash
        try:
            while q or waiting or self._active.any():
                # 1) ingest arrivals up to the current clock; if idle, jump ahead
                if not self._active.any() and not waiting and q:
                    skew = max(skew, q.next_time() - (time.perf_counter() - t0))
                for ev in q.pop_until(now()):
                    if scfg.max_queue and len(waiting) >= scfg.max_queue:
                        n_rejected += 1  # bounded queue: counted, not silent
                        results[ev.payload.rid] = {"rejected": True}
                        continue
                    waiting.append(ev.payload)

                # 2) shed waiters whose time-to-first-token deadline already
                # passed — admitting them would burn a prefill on a request the
                # client has given up on
                if scfg.ttft_deadline_s:
                    t_now = now()
                    still = []
                    for req in waiting:
                        if t_now - req.arrival > scfg.ttft_deadline_s:
                            n_shed += 1
                            results[req.rid] = {"shed": True,
                                                "waited_s": t_now - req.arrival}
                        else:
                            still.append(req)
                    waiting = still

                # 3) admission: a free lane AND enough free pages (in-flight caps)
                while waiting:
                    req = waiting[0]
                    free_slots = np.flatnonzero(~self._active)
                    if not free_slots.size:
                        break
                    ids = self.pool.alloc(self.pages_needed(req))
                    if ids is None:
                        break
                    waiting.pop(0)
                    slot = int(free_slots[0])
                    self._admit(req, prompts[req.rid], slot, ids, results, now)

                # 4) one continuous-batching decode step over all active lanes
                if self._active.any():
                    step_tokens.append(int(self._active.sum()))
                    t_step = time.perf_counter()
                    logits, self.caches = self._decode(
                        self.params, self.caches, jnp.asarray(self._tokens),
                        self.cfg, jnp.asarray(self._page_table),
                        jnp.asarray(self._lengths), jnp.asarray(self._active))
                    # lint: allow-host-sync(sampling boundary: tokens are drawn on host each decode step, one gather per step by design)
                    logits = np.asarray(logits)
                    step_times.append(time.perf_counter() - t_step)
                    t_now = now()
                    for slot in np.flatnonzero(self._active):
                        st = self._slot_req[slot]
                        tok = self._sample(logits[slot], st["req"].rid,
                                           len(st["tokens"]))
                        st["tokens"].append(tok)
                        self._lengths[slot] += 1
                        self._tokens[slot, 0] = tok
                        if len(st["tokens"]) >= st["req"].gen_len:
                            self._retire(int(slot), t_now, results)
                        elif (scfg.deadline_s and
                              t_now - st["req"].arrival > scfg.deadline_s):
                            # total-latency breach: evict, return the lane and
                            # its pages, report the partial generation
                            n_evicted += 1
                            self._retire(int(slot), t_now, results, evicted=True)
        finally:
            for slot in np.flatnonzero(self._active):
                st = self._slot_req[slot]
                if st is not None:
                    self.pool.free(st["pages"])
                    self._slot_req[slot] = None
            self._active[:] = False

        makespan = now()
        gen_tokens = sum(len(r["tokens"]) for r in results.values()
                         if r and "tokens" in r)
        completed = sum(1 for r in results.values()
                        if r and "tokens" in r and not r.get("evicted"))
        steady_t = sum(step_times[1:])  # first decode step pays compile
        steady_n = sum(step_tokens[1:])
        return {
            "results": results,
            "makespan_s": makespan,
            "gen_tokens": gen_tokens,
            "tok_s": gen_tokens / makespan if makespan > 0 else float("nan"),
            "steady_tok_s": steady_n / steady_t if steady_t > 0 else float("nan"),
            "decode_steps": len(step_times),
            "step_times_s": step_times,
            "completed": completed,
            "rejected": n_rejected,
            "shed": n_shed,
            "evicted": n_evicted,
            "pages": {"total": self.pool.n_pages,
                      "high_water": self.pool.high_water},
        }

    def _admit(self, req, prompt, slot, page_ids, results, now) -> None:
        scfg = self.scfg
        bucket = self._bucket_len(req.prompt_len)
        padded = np.zeros((1, bucket), np.int32)
        padded[0, :req.prompt_len] = prompt
        n_prompt_pages = -(-bucket // scfg.page_size)
        self._page_table[slot, :len(page_ids)] = page_ids
        logits, self.caches = self._prefill(
            self.params, self.caches, jnp.asarray(padded),
            jnp.asarray([req.prompt_len - 1], jnp.int32),
            jnp.asarray(page_ids[:n_prompt_pages], jnp.int32),
            jnp.asarray(slot, jnp.int32))
        logits = np.asarray(jax.block_until_ready(logits))
        t_first = now()
        first = self._sample(logits[0], req.rid, 0)
        self._slot_req[slot] = {"req": req, "pages": list(page_ids),
                                "tokens": [first], "t_first": t_first}
        results[req.rid] = None  # placeholder keeps completion order visible
        self._lengths[slot] = req.prompt_len
        self._tokens[slot, 0] = first
        self._active[slot] = True
        if req.gen_len <= 1:
            self._retire(slot, t_first, results)

    def _retire(self, slot: int, t_done: float, results: dict,
                evicted: bool = False) -> None:
        st = self._slot_req[slot]
        req = st["req"]
        self.pool.free(st["pages"])
        self._active[slot] = False
        self._slot_req[slot] = None
        n_decode = max(len(st["tokens"]) - 1, 1)
        results[req.rid] = {
            "tokens": st["tokens"],  # partial when evicted
            "ttft_s": st["t_first"] - req.arrival,
            "tpot_s": (t_done - st["t_first"]) / n_decode,
            "done_s": t_done,
        }
        if evicted:
            results[req.rid]["evicted"] = True


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def make_demo_inputs(cfg, seed: int, batch: int, prompt_len: int):
    """Init params and a synthetic prompt from INDEPENDENT key splits.

    (Regression surface: the launcher used to reuse one key for both, making
    the prompt a deterministic function of the weights' randomness.)"""
    k_init, k_prompt, k_sample = jax.random.split(jax.random.PRNGKey(seed), 3)
    params = lm.init_lm(k_init, cfg)
    prompt = jax.random.randint(k_prompt, (batch, prompt_len), 0, cfg.vocab_size)
    return params, prompt.astype(jnp.int32), k_sample


def _positive_int(name):
    def parse(s):
        v = int(s)
        if v < 1:
            raise argparse.ArgumentTypeError(f"{name} must be >= 1, got {v}")
        return v
    return parse


def _len_range(s: str) -> tuple:
    """'LO,HI' or a single 'N' -> (lo, hi) inclusive."""
    parts = [int(x) for x in s.split(",")]
    if len(parts) == 1:
        parts = parts * 2
    if len(parts) != 2 or parts[0] < 1 or parts[1] < parts[0]:
        raise argparse.ArgumentTypeError(
            f"length range must be 'N' or 'LO,HI' with 1 <= LO <= HI, got {s!r}")
    return tuple(parts)


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=_positive_int("--batch"), default=4)
    ap.add_argument("--prompt-len", type=_positive_int("--prompt-len"), default=32)
    ap.add_argument("--gen", type=_len_range, default=(16, 16),
                    help="tokens to generate: N, or LO,HI sampled per request "
                         "in --engine mode")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    # continuous-batching engine + load generator
    ap.add_argument("--engine", action="store_true",
                    help="serve a Poisson trace through the continuous-batching "
                         "engine instead of one uniform batch")
    ap.add_argument("--requests", type=_positive_int("--requests"), default=16)
    ap.add_argument("--rate", type=float, default=8.0,
                    help="Poisson arrival rate (req/s) for --engine")
    ap.add_argument("--prompt-lens", type=_len_range, default=None,
                    help="LO,HI prompt-length range for --engine "
                         "(default: --prompt-len for both ends)")
    ap.add_argument("--slots", type=_positive_int("--slots"), default=4)
    ap.add_argument("--page-size", type=_positive_int("--page-size"), default=8)
    ap.add_argument("--pages", type=_positive_int("--pages"), default=64)
    ap.add_argument("--max-pages-per-seq", type=_positive_int("--max-pages-per-seq"),
                    default=8)
    ap.add_argument("--ttft-deadline-ms", type=float, default=0.0,
                    help="shed waiting requests that cannot see a first token "
                         "within this many ms of arrival (0 = no deadline)")
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="evict requests still decoding this many ms after "
                         "arrival; pages return to the pool and the partial "
                         "generation is reported (0 = no deadline)")
    ap.add_argument("--max-queue", type=int, default=0,
                    help="reject arrivals beyond this many waiting requests "
                         "(0 = unbounded admission queue)")
    return ap


def main(argv=None):
    sanitize.apply(verbose=True)  # REPRO_SANITIZE=1 fail-fast mode
    args = build_parser().parse_args(argv)
    cfg = get_config(args.arch, reduced=args.reduced)
    params, prompt, k_sample = make_demo_inputs(cfg, args.seed, args.batch,
                                                args.prompt_len)
    if args.engine:
        scfg = ServeCfg(n_slots=args.slots, page_size=args.page_size,
                        n_pages=args.pages, max_pages_per_seq=args.max_pages_per_seq,
                        temperature=args.temperature, seed=args.seed,
                        ttft_deadline_s=args.ttft_deadline_ms / 1e3,
                        deadline_s=args.deadline_ms / 1e3,
                        max_queue=args.max_queue)
        trace = events.poisson_trace(
            args.requests, rate=args.rate, seed=args.seed,
            prompt_lens=args.prompt_lens or (args.prompt_len, args.prompt_len),
            gen_lens=args.gen)
        out = ServeEngine(params, cfg, scfg).run(trace)
        # shed/rejected entries never started, so they carry no ttft
        ttfts = sorted(r["ttft_s"] for r in out["results"].values()
                       if r and "ttft_s" in r)
        print(f"served {len(trace)} requests ({out['completed']} completed, "
              f"{out['evicted']} evicted, {out['shed']} shed, "
              f"{out['rejected']} rejected), {out['gen_tokens']} tokens in "
              f"{out['makespan_s']:.2f}s ({out['tok_s']:.1f} tok/s; steady "
              f"{out['steady_tok_s']:.1f} tok/s)")
        if ttfts:
            print(f"ttft p50 {ttfts[len(ttfts) // 2]:.3f}s  max {ttfts[-1]:.3f}s; "
                  f"pages high-water {out['pages']['high_water']}/{out['pages']['total']}")
        return
    gen_len = args.gen[0]
    t0 = time.perf_counter()
    out, stats = generate(params, cfg, prompt, gen_len,
                          temperature=args.temperature,
                          key=k_sample if args.temperature > 0 else None,
                          return_stats=True)
    dt = time.perf_counter() - t0
    ntok = args.batch * gen_len
    print(f"generated {out.shape} in {dt:.2f}s ({ntok/dt:.1f} tok/s incl. compile)")
    print(f"prefill {stats['prefill_s']:.2f}s; first token {stats['first_token_s']:.2f}s "
          f"(incl. decode compile); steady-state {stats['steady_tok_s']:.1f} tok/s")
    print("sample:", out[0, :16].tolist())


if __name__ == "__main__":
    main()
