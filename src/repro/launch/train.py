"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch nanogpt-134m --reduced \
      --method ours --stages 8 --steps 200 --ckpt-dir /tmp/run1

Runs the async-PP engine on the available devices (CPU-friendly at reduced scale;
pjit-sharded under the production mesh when launched on a real TPU slice). All the
fault-tolerance machinery is on: periodic checkpoints, exact resume, preemption-safe
exit. On a multi-pod mesh, pass --multi-pod to use the cross-pod SPMD 1F1B pipeline.

--runtime event swaps the single-jit stash-replay engine for the event-driven
asynchronous runtime (core/runtime.py): per-stage workers, sampled latencies
(--delay-model fixed|jitter:S|straggler:STAGE,FACTOR[,PERIOD]|trace:PATH), and
observed-staleness feedback. Checkpoints remain engine-compatible AsyncStates.
--record-trace out.json additionally measures every stage's real fwd/bwd
latency and writes it in the TraceDelay JSON schema, closing the calibration
loop: replay the measured distribution with --delay-model trace:out.json or
`dryrun --sim-schedule --sim-models trace:out.json`. Spec grammars for
--delay-model/--churn and the trace schema are documented in docs/cli.md.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax

from repro.analysis import sanitize
from repro.configs import get_config
from repro.core.engine import AsyncTrainer, EngineCfg
from repro.data.synthetic import make_batch_fn
from repro.ft import loop as ftloop


def run_event_loop(trainer, batch_fn, steps, *, delay_model=None, in_flight=None,
                   churn=None, seed=0, ckpt_dir=None, ckpt_every=0, log_every=0,
                   record_trace=None, faults=None, watchdog=None,
                   max_rollbacks=8, log_fn=print):
    """Event-runtime counterpart of ft.loop.train_loop: resume + periodic ckpt.

    churn: optional events.ChurnModel / spec ("STAGE,START,DURATION[/...]") of
    scheduled leave/join windows on the simulated clock. Windows run inside
    whichever checkpoint chunk reaches them (a window straddling a chunk's
    natural end just delays that chunk's drain until the join fires).

    record_trace: optional path; measures real per-stage fwd/bwd latencies
    (host wall-clock, device-synced per op) and writes them there in the
    TraceDelay JSON schema at the end of the run (docs/cli.md). The first
    tick's samples pay JAX compilation (seconds vs steady-state milliseconds)
    and would replay as a recurring op cost, so the recorder is reset after a
    one-tick warmup chunk — training itself is unaffected.

    faults: optional faults.FaultModel / spec ("nan_grad=0.01,drop=0.005,
    crash=2@40", docs/cli.md) injected into the runtime. watchdog: optional
    faults.DivergenceWatchdog / spec; requires ckpt_dir + ckpt_every. Each
    checkpoint chunk's losses + quarantine counters feed the watchdog BEFORE
    the chunk is checkpointed; on a trip the chunk is discarded — the loop
    rolls back to the newest checkpoint that passes integrity verification
    (checkpoint.restore_latest), re-derives stash/tau state via
    checkpoint.restage, bumps the fault model's epoch (transient faults
    re-sample on replay rather than deterministically re-firing), and resumes.
    More than max_rollbacks rollbacks raises — a divergence the rollback
    cannot clear should fail loudly, not loop forever (DESIGN.md §11)."""
    from repro.checkpoint import checkpoint as ckpt
    from repro.core import faults as faults_mod
    from repro.core.runtime import EventRuntime, RuntimeCfg

    import math

    fm = faults_mod.make_fault_model(faults, seed=seed)
    wd = faults_mod.make_watchdog(watchdog)
    if wd is not None and not (ckpt_dir and ckpt_every):
        raise ValueError("watchdog rollback requires ckpt_dir + ckpt_every "
                         "(it restores the last valid checkpoint)")
    rt = EventRuntime(trainer, RuntimeCfg(delay_model=delay_model,
                                          in_flight=in_flight, churn=churn,
                                          record_trace=bool(record_trace),
                                          seed=seed, faults=fm))
    rt.init(jax.random.PRNGKey(seed))
    resumed_from = -1
    if ckpt_dir:
        # restore against the runtime-counter-free template so checkpoints
        # written by EITHER execution path load (the jit engine's ckpts have
        # no extra['rt']; init_from_state treats it as optional either way —
        # only the simulated clock resets when resuming a jit-engine ckpt).
        # restore_latest steps past truncated/corrupt files (DESIGN.md §11).
        restored, meta, _, _ = ckpt.restore_latest(
            ckpt_dir, rt.export_state(include_runtime=False))
        if restored is not None:
            rt.init_from_state(restored)
            resumed_from = meta["step"]
    res = ftloop.LoopResult(resumed_from=resumed_from)
    t0 = time.perf_counter()
    done = rt._u_done
    if wd is not None and done < steps:
        # guarantee a rollback target exists before the first faulty chunk
        ckpt.save_step(ckpt_dir, rt.export_state(), done)
    # chunk at the gcd of the cadences so `done` lands exactly on every
    # checkpoint/log boundary; save/log only on their own boundaries
    cadence = math.gcd(ckpt_every if ckpt_dir else 0, log_every) or 25
    # first-tick ops compile; their samples must not pollute the saved trace
    warmed = not record_trace
    while done < steps:
        # align to the cadence grid (a resumed step may start off-boundary)
        chunk = min(cadence - done % cadence, steps - done)
        if not warmed:
            chunk = 1
        r = rt.run(batch_fn, chunk)
        if not warmed:
            if rt._u_done < steps:  # keep the only samples of a 1-tick run
                rt.reset_recorder()
            warmed = True
        chunk_skips = sum(r.nonfinite_skipped)
        res.nonfinite_skipped += chunk_skips
        res.retransmits += r.retransmits
        trip = (wd.observe_chunk(r.losses, chunk_skips)
                if wd is not None else None)
        if trip is not None:
            # rollback: this chunk's trajectory is discarded (never saved, and
            # its losses stay out of res); resume from the last valid ckpt
            res.rollbacks += 1
            if res.rollbacks > max_rollbacks:
                raise RuntimeError(
                    f"watchdog tripped {res.rollbacks} times "
                    f"(max_rollbacks={max_rollbacks}); last reason: {trip}")
            if fm is not None:
                fm.epoch += 1  # injected faults are transient: re-sample
            state, meta, path, step = ckpt.restore_latest(
                ckpt_dir, rt.export_state(include_runtime=False))
            if state is None:
                raise RuntimeError(
                    f"watchdog tripped ({trip}) but no valid checkpoint "
                    f"remains in {ckpt_dir}")
            # restage re-derives stash/tau state from the restored weights
            # (staleness history resets — the documented elastic-event
            # behaviour) and zeroes the quarantine counters
            rt.init_from_state(ckpt.restage(state, trainer, trainer))
            wd.reset()
            done = rt._u_done
            log_fn(f"watchdog: {trip}; rolled back to step {step} "
                   f"(rollback {res.rollbacks}/{max_rollbacks})")
            continue
        res.losses.extend(r.losses)
        res.metrics.extend(r.metrics)
        done = rt._u_done
        at_end = done >= steps
        if ckpt_dir and ckpt_every and (done % ckpt_every == 0 or at_end):
            ckpt.save_step(ckpt_dir, rt.export_state(), done)
            if fm is not None and fm.ckpt_trunc > 0:
                p = os.path.join(ckpt_dir, f"ckpt-{done}.npz")
                if fm.maybe_truncate_checkpoint(p, done):
                    log_fn(f"faults: truncated {p} (ckpt_trunc injection)")
        if log_every and (done % log_every == 0 or at_end):
            # at K > 1 the per-stage mean is fractional; show the per-microbatch
            # group (the lossless form the engine's [P, K] dynamic path replays)
            tau_s = (f"tau_groups={r.tau_groups[-1]}"
                     if trainer.ecfg.update_interval > 1
                     else f"tau_obs={r.taus[-1]}")
            log_fn(f"step {done}: loss={res.losses[-1]:.4f} "
                   f"{tau_s} util={tuple(round(u, 2) for u in r.utilization)}")
    res.wall_s = time.perf_counter() - t0
    if record_trace:
        if len(rt.recorder):
            rt.recorder.save(record_trace)
            log_fn(f"wrote {len(rt.recorder)} measured op latencies to "
                   f"{record_trace} (replay: --delay-model trace:{record_trace})")
        else:
            # e.g. resumed at/after --steps: nothing ran, so a saved file would
            # be all MIN_LATENCY placeholders — refuse to corrupt calibration
            log_fn(f"no op latencies recorded (nothing ran beyond the resumed "
                   f"step); not writing {record_trace}")
    return rt, res


def run_mesh(args, cfg, ecfg, seq, log_fn=print):
    """--mesh execution path: R replica pipelines on the event runtime,
    cross-replica sync per the mesh spec — fully-async gossip SyncEvents
    (swarm.MeshTrainer) or the legacy round-barrier (SwarmTrainer.run_event).
    Returns (out_dict, wall_s); out_dict carries per-replica losses plus the
    per-replica vs replicated optimizer-memory report (the ZeRO-1 claim)."""
    from repro.core.events import make_mesh_spec
    from repro.core.swarm import MeshCfg, MeshTrainer, SwarmCfg, SwarmTrainer
    from repro.optim import optimizers as opt_mod

    spec = make_mesh_spec(args.mesh)
    R = args.replicas
    batch_fns = [make_batch_fn(cfg, args.accum, args.batch, seq,
                               seed=args.seed + r)[0] for r in range(R)]
    key = jax.random.PRNGKey(args.seed)
    dms = [args.delay_model] * R
    t0 = time.perf_counter()
    if spec.mode == "gossip":
        opt_shard = (args.opt_shard == "on" or
                     (args.opt_shard == "auto" and not args.mesh_compress))
        mcfg = MeshCfg(replicas=R, period=spec.period, fanout=spec.fanout,
                       compress=args.mesh_compress, opt_shard=opt_shard,
                       max_stale_rounds=args.max_stale_rounds,
                       sync_delay=args.sync_delay, seed=args.seed)
        mt = MeshTrainer(cfg, ecfg, args.method, mcfg)
        out = mt.run_gossip(batch_fns, args.steps, key=key, delay_models=dms,
                            in_flight=args.in_flight)
        log_fn(f"mesh gossip: {out['n_rounds']} rounds, "
               f"absorbed={out['absorbed']} stale_dropped={out['stale_dropped']} "
               f"unabsorbed={out['unabsorbed']} makespan={out['makespan']:.2f}")
    else:
        sw = SwarmTrainer(cfg, ecfg, args.method,
                          SwarmCfg(replicas=R, sync_every=spec.period,
                                   compress=args.mesh_compress))
        out = sw.run_event(batch_fns, args.steps, key=key, delay_models=dms,
                           in_flight=args.in_flight, churn=args.churn)
        rts = out["runtimes"]
        P = sw.inner.P
        opt_bytes = sum(opt_mod.optimizer_memory_bytes(rts[0]._stages[i].opt)
                        for i in range(P))
        out["opt_bytes_per_replica"] = opt_bytes
        out["opt_bytes_replicated"] = opt_bytes
        log_fn(f"mesh barrier: {out['n_syncs']} syncs")
    wall = time.perf_counter() - t0
    log_fn(f"optimizer memory: {out['opt_bytes_per_replica']} bytes/replica "
           f"(replicated baseline: {out['opt_bytes_replicated']})")
    return out, wall


def main():
    sanitize.apply(verbose=True)  # REPRO_SANITIZE=1 fail-fast mode
    ap = argparse.ArgumentParser(
        epilog="Spec grammars for --delay-model (fixed:/jitter:/straggler:/"
               "outage:/trace:), --churn (STAGE,START,DURATION[/...]), "
               "--mesh (gossip:PERIOD[,FANOUT] | barrier:PERIOD), and the "
               "--record-trace TraceDelay JSON schema: docs/cli.md")
    ap.add_argument("--arch", default="nanogpt-134m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--method", default="ours")
    ap.add_argument("--stages", type=int, default=8)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--out", default=None)
    ap.add_argument("--runtime", default="jit", choices=["jit", "event"],
                    help="jit = single-program stash-replay engine; "
                         "event = discrete-event async runtime")
    ap.add_argument("--delay-model", default="fixed",
                    help="event runtime latency model (see core/events.py)")
    ap.add_argument("--in-flight", type=int, default=None,
                    help="event runtime per-stage buffer override (elastic)")
    ap.add_argument("--churn", default=None,
                    help="event runtime leave/join windows: "
                         "STAGE,START,DURATION[/STAGE,START,DURATION...] "
                         "on the simulated clock (see core/events.ChurnModel)")
    ap.add_argument("--churn-slack", type=int, default=None,
                    help="bound on the extra in-flight microbatches upstream "
                         "stages may buffer during an outage (default: "
                         "unbounded — the outage is paid fully in memory)")
    ap.add_argument("--record-trace", default=None, metavar="PATH",
                    help="measure real per-stage fwd/bwd latencies during an "
                         "event-runtime run and write them to PATH in the "
                         "TraceDelay JSON schema (replay with --delay-model "
                         "trace:PATH or dryrun --sim-models trace:PATH; "
                         "see docs/cli.md)")
    ap.add_argument("--max-dynamic-delay", type=int, default=None)
    ap.add_argument("--faults", default=None,
                    help="event runtime fault injection: "
                         "nan_grad=P,nan_act=P,drop=P,dup=P,ckpt_trunc=P,"
                         "crash=N@T[,crash=N@T...][,crash_dur=S] "
                         "(keyed-deterministic; see docs/cli.md)")
    ap.add_argument("--watchdog", default="auto",
                    help="divergence watchdog: 'auto' (on iff --faults and "
                         "--ckpt-dir), 'on', 'off', or "
                         "beta=B,factor=F,margin=M,warmup=W,skips=S; trips "
                         "roll back to the last valid checkpoint")
    ap.add_argument("--max-rollbacks", type=int, default=8,
                    help="abort after this many watchdog rollbacks")
    ap.add_argument("--mesh", default=None, metavar="SPEC",
                    help="2D mesh data parallelism across --replicas replica "
                         "pipelines: gossip:PERIOD[,FANOUT] (fully-async "
                         "SyncEvent averaging, core/swarm.MeshTrainer) or "
                         "barrier:PERIOD (legacy round-barrier sync); "
                         "see docs/cli.md")
    ap.add_argument("--replicas", type=int, default=2,
                    help="replica count R for --mesh")
    ap.add_argument("--sync-delay", default=None,
                    help="gossip sync-hop latency model: fixed[:LAT] | "
                         "jitter:BASE,SIGMA (default: zero latency)")
    ap.add_argument("--opt-shard", default="auto", choices=["auto", "on", "off"],
                    help="ZeRO-1 shard the optimizer state across replicas "
                         "(gossip mesh only; auto = on unless --mesh-compress)")
    ap.add_argument("--mesh-compress", action="store_true",
                    help="int8 + error-feedback compression on mesh sync deltas")
    ap.add_argument("--max-stale-rounds", type=int, default=1,
                    help="gossip absorption staleness bound (rounds), the "
                         "cross-replica analogue of stash depth")
    args = ap.parse_args()

    if args.mesh:
        from repro.core.events import make_mesh_spec

        try:
            mesh_spec = make_mesh_spec(args.mesh)
        except ValueError as e:
            ap.error(str(e))
        if args.ckpt_dir or args.faults or args.record_trace:
            ap.error("--mesh does not compose with --ckpt-dir/--faults/"
                     "--record-trace (mesh runs drive raw EventRuntimes; "
                     "checkpoint replica states via checkpoint."
                     "zero1_merge_states from library code)")
        if args.runtime != "event":
            args.runtime = "event"  # mesh is event-driven by construction
        if mesh_spec.mode == "gossip" and args.churn:
            ap.error("--churn on a gossip mesh is unsupported: membership "
                     "churn composes with barrier mode (--mesh barrier:N) "
                     "or with per-replica RuntimeCfg.churn in library code")
        if args.opt_shard == "on" and args.mesh_compress:
            ap.error("--opt-shard on + --mesh-compress are mutually exclusive "
                     "(quantized averaging would corrupt the owner-"
                     "authoritative ZeRO-1 segments)")
        if args.opt_shard == "on" and mesh_spec.mode == "barrier":
            ap.error("--opt-shard requires a gossip mesh (the barrier path "
                     "keeps the replicated layout)")
    elif args.sync_delay or args.mesh_compress:
        ap.error("--sync-delay/--mesh-compress/--opt-shard require --mesh")

    if args.record_trace and args.runtime != "event":
        ap.error("--record-trace requires --runtime event (latencies are "
                 "measured per stage dispatch; the jit engine has no per-op "
                 "boundary to time)")
    if args.churn_slack is not None and not args.churn:
        ap.error("--churn-slack requires --churn")
    if args.faults and args.runtime != "event":
        ap.error("--faults requires --runtime event (injection happens at the "
                 "event runtime's message/dispatch boundaries)")
    watchdog = args.watchdog
    if watchdog == "auto":
        watchdog = "on" if (args.faults and args.ckpt_dir) else None
    elif watchdog in ("off", "none", ""):
        watchdog = None
    if watchdog is not None and not (args.ckpt_dir and args.ckpt_every):
        ap.error("--watchdog needs --ckpt-dir and --ckpt-every > 0 "
                 "(rollback restores the last valid checkpoint)")

    cfg = get_config(args.arch, reduced=args.reduced)
    seq = args.seq or (64 if args.reduced else 512)
    ecfg = EngineCfg(n_stages=args.stages, update_interval=args.accum, lr=args.lr,
                     warmup_steps=args.warmup, total_steps=args.steps,
                     max_dynamic_delay=args.max_dynamic_delay)
    if args.mesh:
        out, wall = run_mesh(args, cfg, ecfg, seq)
        finals = [l[-1] if l else float("nan") for l in out["losses"]]
        steps_done = [len(l) for l in out["losses"]]
        print(f"final loss per replica: "
              f"{[f'{l:.4f}' for l in finals]}  "
              f"(steps={steps_done}, {wall:.1f}s)")
        if args.out:
            with open(args.out, "w") as f:
                json.dump({"losses": out["losses"],
                           "steps_done": steps_done,
                           "opt_bytes_per_replica": out["opt_bytes_per_replica"],
                           "opt_bytes_replicated": out["opt_bytes_replicated"],
                           "absorbed": out.get("absorbed"),
                           "stale_dropped": out.get("stale_dropped"),
                           "unabsorbed": out.get("unabsorbed"),
                           "makespan": out.get("makespan")}, f)
        return

    trainer = AsyncTrainer(cfg, ecfg, args.method)
    batch_fn, src = make_batch_fn(cfg, args.accum, args.batch, seq, seed=args.seed)
    if args.runtime == "event":
        from repro.core.events import make_churn_model

        churn = (make_churn_model(args.churn, slack=args.churn_slack)
                 if args.churn else None)
        _, res = run_event_loop(
            trainer, batch_fn, args.steps, delay_model=args.delay_model,
            in_flight=args.in_flight, churn=churn, seed=args.seed,
            ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
            log_every=args.log_every, record_trace=args.record_trace,
            faults=args.faults, watchdog=watchdog,
            max_rollbacks=args.max_rollbacks)
    else:
        state, res = ftloop.train_loop(
            trainer, batch_fn, args.steps, ckpt_dir=args.ckpt_dir,
            ckpt_every=args.ckpt_every, key=jax.random.PRNGKey(args.seed),
            log_every=args.log_every)
    last = f"{res.losses[-1]:.4f}" if res.losses else "n/a (resumed at/after --steps)"
    print(f"final loss: {last}  (entropy floor ~{src.entropy_floor():.3f}, "
          f"{res.wall_s:.1f}s, resumed_from={res.resumed_from})")
    if res.nonfinite_skipped or res.rollbacks or res.retransmits:
        print(f"recovery: nonfinite_skipped={res.nonfinite_skipped} "
              f"rollbacks={res.rollbacks} retransmits={res.retransmits}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"losses": res.losses, "metrics": res.metrics}, f)


if __name__ == "__main__":
    main()
