"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch nanogpt-134m --reduced \
      --method ours --stages 8 --steps 200 --ckpt-dir /tmp/run1

Runs the async-PP engine on the available devices (CPU-friendly at reduced scale;
pjit-sharded under the production mesh when launched on a real TPU slice). All the
fault-tolerance machinery is on: periodic checkpoints, exact resume, preemption-safe
exit. On a multi-pod mesh, pass --multi-pod to use the cross-pod SPMD 1F1B pipeline.
"""
from __future__ import annotations

import argparse
import json
import os

import jax

from repro.configs import get_config
from repro.core.engine import AsyncTrainer, EngineCfg
from repro.data.synthetic import make_batch_fn
from repro.ft import loop as ftloop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="nanogpt-134m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--method", default="ours")
    ap.add_argument("--stages", type=int, default=8)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    seq = args.seq or (64 if args.reduced else 512)
    ecfg = EngineCfg(n_stages=args.stages, update_interval=args.accum, lr=args.lr,
                     warmup_steps=args.warmup, total_steps=args.steps)
    trainer = AsyncTrainer(cfg, ecfg, args.method)
    batch_fn, src = make_batch_fn(cfg, args.accum, args.batch, seq, seed=args.seed)
    state, res = ftloop.train_loop(
        trainer, batch_fn, args.steps, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, key=jax.random.PRNGKey(args.seed),
        log_every=args.log_every)
    print(f"final loss: {res.losses[-1]:.4f}  (entropy floor ~{src.entropy_floor():.3f}, "
          f"{res.wall_s:.1f}s, resumed_from={res.resumed_from})")
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"losses": res.losses, "metrics": res.metrics}, f)


if __name__ == "__main__":
    main()
