import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"
# ^ before any jax import.

"""Roofline-term extraction (single-pod production mesh).

XLA's cost_analysis counts a while-loop body ONCE, so rolled scans hide
(trip_count x) the real FLOPs/bytes. Every cost in our programs is *bilinear* in
(n_periods P, accum K): F(P,K) = K*(alpha*P + beta) + (gamma*P + delta).
Four small fully-unrolled compiles — (p1,K1),(p2,K1),(p1,K2),(p2,K2) — identify the
coefficients exactly; we then evaluate at the full (P,K). Memory and the collective
*schedule* come from the rolled full-size compile (launch.dryrun), where while-loop
peak memory is the body's peak (accurate).

Hardware model (TPU v5e-like, per chip): 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI.

  compute term    = FLOPs_step   / (chips * 197e12)
  memory term     = bytes_step   / (chips * 819e9)
  collective term = coll_bytes   / (chips * 50e9)      [per-device bytes already]

cost_analysis reports *per-device* flops/bytes; we keep everything per-device and
divide only by per-chip peaks.
"""
import argparse
import dataclasses
import json
import sys
import time

import jax
import numpy as np

from repro.configs import ASSIGNED, SHAPES, cell_runnable, get_config, norm_name
from repro.launch import specs as S
from repro.launch.dryrun import analyse, lower_decode, lower_prefill, lower_train
from repro.launch.mesh import make_production_mesh

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def _counts(rec):
    coll = float(sum(rec["collective_bytes"].values()))
    return np.array([rec["flops"], rec["bytes_accessed"], coll])


def _small_cell(cell, batch):
    return dataclasses.replace(cell, batch=batch, accum=1)


def _pick_periods(cfg):
    P_full = cfg.n_periods
    p2 = min(4, P_full)
    p1 = max(1, p2 // 2)
    if p1 == p2:  # tiny models
        p1, p2 = max(1, p2 - 1), p2
    return p1, p2


def measure_train(arch: str, shape: str, *, method="ours", n_stages=4, verbose=True,
                  bilinear=False, cfg_overrides=None, accum=None):
    """Two fully-unrolled compiles at (p1, K=1), (p2, K=1) give the exact linear
    law F_mb(P) = a*P + b per microbatch-step; the full step is K * F_mb(P).
    The only approximation is that the optimizer/stash update (executed once per
    step) is counted K times — an analytically-bounded <1% overcount in FLOPs
    (~10 flops/param vs 6*N*tokens) noted per record. With bilinear=True the K
    dimension is identified exactly with two extra compiles (used for the
    hillclimb cells)."""
    mesh = make_production_mesh()
    cell = S.make_cell(arch, shape, accum=accum)
    cfg0 = S.tune_cfg(get_config(arch), cell)
    if cfg_overrides:
        cfg0 = dataclasses.replace(cfg0, **cfg_overrides)
    P_full, K_full = cfg0.n_periods, cell.accum
    p1, p2 = _pick_periods(cfg0)
    mb = cell.batch // K_full

    points = [(p1, 1), (p2, 1)] + ([(p1, 2), (p2, 2)] if bilinear else [])
    pts = {}
    for (p, k) in points:
        c = dataclasses.replace(cell, batch=mb * k, accum=k)
        cfg = dataclasses.replace(cfg0, unroll=True, n_periods=p)
        if cfg.enc_periods:
            cfg = dataclasses.replace(cfg, enc_periods=max(1, cfg.enc_periods * p // P_full))
        st = min(n_stages, p)
        lowered = lower_train(cfg, c, mesh, method=method, n_stages=st)
        rec, _ = analyse(lowered, f"{arch}/{shape}/p{p}k{k}", 256)
        pts[(p, k)] = (_counts(rec), rec)
        if verbose:
            print(f"  fit point p={p} K={k}: flops={rec['flops']:.3e} "
                  f"({rec['compile_s']}s)", file=sys.stderr, flush=True)

    dp = p2 - p1
    if bilinear:
        c11, c21, c12, c22 = (pts[(p1, 1)][0], pts[(p2, 1)][0],
                              pts[(p1, 2)][0], pts[(p2, 2)][0])
        a_k1 = (c21 - c11) / dp              # alpha + gamma
        a_k2 = (c22 - c12) / dp              # 2 alpha + gamma
        alpha = a_k2 - a_k1
        gamma = a_k1 - alpha
        beta = (c12 - c11) - alpha * p1
        delta = c11 - (alpha * p1 + beta) - gamma * p1
        full = K_full * (alpha * P_full + beta) + gamma * P_full + delta
    else:
        c1, c2 = pts[(p1, 1)][0], pts[(p2, 1)][0]
        a = (c2 - c1) / dp
        full = K_full * (c2 + a * (P_full - p2))
    useful = model_flops_per_device(cfg0, cell, mesh)
    rec = roofline_record(arch, shape, "train", full, useful,
                          pts[(p2, 1)][1], K=K_full, P=P_full)
    rec["fit"] = {"points": {f"p{p}k{k}": v[0].tolist() for (p, k), v in pts.items()},
                  "bilinear": bilinear,
                  "note": "opt update counted K times in linear mode (<1% flops)"}
    return rec


def measure_serve(arch: str, shape: str, verbose=True, cfg_overrides=None):
    mesh = make_production_mesh()
    cell = S.make_cell(arch, shape)
    cfg0 = S.tune_cfg(get_config(arch), cell)
    if cfg_overrides:
        cfg0 = dataclasses.replace(cfg0, **cfg_overrides)
    P_full = cfg0.n_periods
    p1, p2 = _pick_periods(cfg0)
    kind = cell.kind

    pts = {}
    for p in (p1, p2):
        cfg = dataclasses.replace(cfg0, unroll=True, n_periods=p)
        if cfg.enc_periods:
            cfg = dataclasses.replace(cfg, enc_periods=max(1, cfg.enc_periods * p // P_full))
        lowered = (lower_prefill if kind == "prefill" else lower_decode)(cfg, cell, mesh)
        rec, _ = analyse(lowered, f"{arch}/{shape}/p{p}", 256)
        pts[p] = (_counts(rec), rec)
        if verbose:
            print(f"  fit point p={p}: flops={rec['flops']:.3e} ({rec['compile_s']}s)",
                  file=sys.stderr, flush=True)

    a = (pts[p2][0] - pts[p1][0]) / (p2 - p1)
    full = pts[p2][0] + a * (P_full - p2)
    useful = model_flops_per_device(cfg0, cell, mesh)
    return roofline_record(arch, shape, kind, full, useful, pts[p2][1], K=1, P=P_full)


def roofline_record(arch, shape, kind, counts, useful_flops, sample_rec, *, K, P):
    flops, bytes_, coll = [float(x) for x in counts]
    t_comp = flops / PEAK_FLOPS
    t_mem = bytes_ / HBM_BW
    t_coll = coll / ICI_BW
    terms = {"compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll}
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    return {
        "cell": f"{arch}/{shape}",
        "kind": kind,
        "P_periods": P,
        "K": K,
        "flops_per_device": flops,
        "bytes_per_device": bytes_,
        "collective_bytes_per_device": coll,
        **{k: round(v * 1e3, 3) for k, v in
           {"compute_ms": t_comp, "memory_ms": t_mem, "collective_ms": t_coll}.items()},
        "dominant": dom.replace("_s", ""),
        "model_flops_per_device": useful_flops,
        "useful_flops_ratio": round(useful_flops / max(flops, 1.0), 4),
        "roofline_fraction": round((useful_flops / PEAK_FLOPS) / max(bound, 1e-12), 4),
        "collective_kinds": sample_rec["collective_bytes"],
    }


# ---------------------------------------------------------------------------
# Analytic MODEL_FLOPS (6 N D for dense / 6 N_active D for MoE), per device
# ---------------------------------------------------------------------------


def count_params(cfg) -> tuple:
    """(total_params, active_params) analytic."""
    from repro.models import lm as lm_mod

    shapes = jax.eval_shape(lambda k: lm_mod.init_lm(k, cfg), jax.random.PRNGKey(0))
    total = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes))
    active = total
    if cfg.moe:
        mc = cfg.moe
        # each token runs top_k of n_experts
        per_expert = 3 * cfg.d_model * mc.d_ff_expert
        n_moe_layers = sum(1 for b in cfg.pattern if b.mlp == "moe") * cfg.n_periods
        active = total - n_moe_layers * (mc.n_experts - mc.top_k) * per_expert
    return total, active


def model_flops_per_device(cfg, cell, mesh) -> float:
    """MODEL_FLOPS = 6 * N_active * tokens (train) or 2 * N_active * tokens (serve),
    divided over all chips (matching cost_analysis' per-device convention)."""
    total, active = count_params(cfg)
    n_chips = int(np.prod(mesh.devices.shape))
    if cell.kind == "train":
        tokens = cell.batch * cell.seq
        f = 6.0 * active * tokens
    elif cell.kind == "prefill":
        tokens = cell.batch * cell.seq
        f = 2.0 * active * tokens
    else:  # decode: one token per sequence
        f = 2.0 * active * cell.batch
    return f / n_chips


def run(arch, shape, **kw):
    ok, reason = cell_runnable(arch, shape)
    if not ok:
        return {"cell": f"{arch}/{shape}", "skipped": reason}
    kind = SHAPES[shape][2]
    if kind == "train":
        return measure_train(arch, shape, **kw)
    return measure_serve(arch, shape)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    cells = ([(a, s) for a in ASSIGNED for s in SHAPES] if args.all
             else [(args.arch, args.shape)])
    recs = []
    for a, s in cells:
        t0 = time.perf_counter()
        try:
            rec = run(a, s)
        except Exception as e:
            rec = {"cell": f"{a}/{s}", "error": f"{type(e).__name__}: {e}"}
        rec["wall_s"] = round(time.perf_counter() - t0, 1)
        recs.append(rec)
        print(json.dumps(rec), flush=True)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(recs, f, indent=1)


if __name__ == "__main__":
    main()
