"""Fault-injection + self-healing suite (DESIGN.md §11).

Covers the four recovery layers against the faults core/faults.py injects:
keyed-deterministic fault sampling and the spec grammar; the empty-model
bitwise no-op contract; non-finite quarantine in the engine update; message
drop/dup recovery (retransmit-with-backoff, Mailbox dedupe, escalation to the
churn outage path); checkpoint integrity (checksums, torn-write fallback,
tolerant retention) and crash-consistent resume; and the divergence watchdog's
rollback loop end to end.
"""
import math
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.configs import get_config
from repro.core import faults
from repro.core.engine import AsyncTrainer, EngineCfg
from repro.core.events import ChurnModel, Mailbox
from repro.core.runtime import EventRuntime, RuntimeCfg, simulate_schedule
from repro.launch.train import run_event_loop
from repro.models import lm


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("nanogpt_134m", reduced=True)
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 4, 33), 0, cfg.vocab_size)
    batch = {"tokens": toks[..., :-1], "labels": toks[..., 1:]}
    return cfg, params, batch


def _ecfg(**kw):
    kw.setdefault("n_stages", 4)
    kw.setdefault("lr", 1e-3)
    kw.setdefault("constant_lr", True)
    kw.setdefault("collect_metrics", False)
    return EngineCfg(**kw)


# ---- spec grammar + keyed determinism ---------------------------------------


def test_fault_spec_grammar():
    fm = faults.make_fault_model("faults:nan_grad=0.01,drop=0.005,crash=2@40")
    assert fm.nan_grad == 0.01 and fm.drop == 0.005
    assert fm.crashes == ((2, 40.0),) and not fm.is_empty
    # bare (untagged) form, repeated crash plans, crash_dur
    fm2 = faults.make_fault_model("crash=1@5,crash=3@90,crash_dur=2.5,dup=0.1")
    assert fm2.crashes == ((1, 5.0), (3, 90.0)) and fm2.crash_duration == 2.5
    assert faults.make_fault_model(None) is None
    assert faults.make_fault_model("") is None
    assert faults.make_fault_model(fm) is fm  # passthrough
    for bad in ("bogus=0.1", "nan_grad=2.0", "nan_grad", "drop=0.1,drop=0.2",
                "crash=40", "other:nan_grad=0.1", "crash=0@5"):
        with pytest.raises(ValueError):
            faults.make_fault_model(bad)


def test_fault_draws_are_keyed_not_stateful():
    """Same (seed, epoch, kind, stage, mb, attempt) -> same draw, in any call
    order; epoch re-salts every draw (the transient-fault rollback contract)."""
    a = faults.FaultModel(nan_grad=0.5, drop=0.5, seed=7)
    b = faults.FaultModel(nan_grad=0.5, drop=0.5, seed=7)
    keys = [(s, m) for s in range(4) for m in range(32)]
    hits_a = [a.hit("nan_grad", s, m) for s, m in keys]
    hits_b = [b.hit("nan_grad", s, m) for s, m in reversed(keys)]
    assert hits_a == list(reversed(hits_b))
    assert any(hits_a) and not all(hits_a)
    # fwd/bwd edges draw independently; attempts re-draw
    assert any(a.drop_hit("fwd", s, m, 0) != a.drop_hit("bwd", s, m, 0)
               for s, m in keys)
    assert any(a.drop_hit("fwd", s, m, 0) != a.drop_hit("fwd", s, m, 1)
               for s, m in keys)
    b.epoch = 1
    assert hits_a != [b.hit("nan_grad", s, m) for s, m in keys]
    # poison values cover both non-finite classes
    vals = {a.poison_value(s, m) for s, m in keys}
    assert any(math.isnan(v) for v in vals) and math.inf in vals


def test_crash_outages_map_onto_churn():
    fm = faults.FaultModel(crashes=((3, 10.0),), crash_duration=4.0, seed=1)
    outs = fm.crash_outages(P=4)
    assert len(outs) == 3
    assert all(0 <= o.stage < 4 for o in outs)
    assert all(o.duration == 4.0 for o in outs)
    # staggered: validates as a churn plan even if one stage is hit twice
    ChurnModel(outs).validate(4)
    assert fm.crash_outages(P=4) == outs  # deterministic


# ---- divergence watchdog -----------------------------------------------------


def test_watchdog_spec():
    assert faults.make_watchdog(None) is None
    assert faults.make_watchdog("off") is None
    wd = faults.make_watchdog("on")
    assert isinstance(wd, faults.DivergenceWatchdog)
    wd2 = faults.make_watchdog("factor=5,skips=1,warmup=2")
    assert wd2.spike_factor == 5.0 and wd2.skip_limit == 1 and wd2.warmup == 2
    assert faults.make_watchdog(wd) is wd
    for bad in ("bogus=1", "factor=0.5", "beta=1.5", "factor=3,factor=4"):
        with pytest.raises(ValueError):
            faults.make_watchdog(bad)


def test_watchdog_trips():
    wd = faults.DivergenceWatchdog(beta=0.5, spike_factor=2.0, margin=0.1,
                                   warmup=3, skip_limit=2)
    # steady losses: no trip, EMA warms up
    assert wd.observe_chunk([1.0, 1.0, 1.0, 1.0]) is None
    # spike after warmup
    assert "spike" in wd.observe_chunk([1.0, 5.0])
    wd.reset()
    # within warmup the same spike is tolerated (EMA still seeding)
    assert wd.observe_chunk([1.0, 5.0]) is None
    wd.reset()
    # non-finite loss trips immediately
    assert "non-finite" in wd.observe_chunk([1.0, float("nan")])
    wd.reset()
    # quarantine budget: accumulates across dirty chunks, resets on clean ones
    assert wd.observe_chunk([1.0], nonfinite_delta=1) is None
    assert "quarantined" in wd.observe_chunk([1.0], nonfinite_delta=1)
    assert wd.observe_chunk([1.0], nonfinite_delta=1) is None  # reset by trip
    assert wd.observe_chunk([1.0], nonfinite_delta=0) is None  # clean: budget clears
    assert wd.observe_chunk([1.0], nonfinite_delta=1) is None


# ---- message faults: Mailbox dedupe + sim-level recovery --------------------


def test_mailbox_strict_vs_dedupe():
    box = Mailbox()
    box.put(0, "x")
    with pytest.raises(RuntimeError):
        box.put(0, "y")  # strict mode: duplicate delivery is a protocol bug
    dbox = Mailbox(dedupe=True)
    dbox.put(0, "x")
    dbox.put(0, "y")           # duplicate of a buffered message
    assert dbox.take(0) == "x"
    dbox.put(0, "z")           # duplicate of an already-consumed message
    assert dbox.duplicates == 2
    dbox.put(1, "w")
    assert dbox.take(1) == "w"


def test_sim_drop_recovers_by_retransmit():
    base = simulate_schedule(P=4, n_ticks=30)
    lossy = simulate_schedule(P=4, n_ticks=30, faults="drop=0.1,dup=0.1")
    assert lossy["retransmits"] > 0
    # every tick still completes; drops cost time, never progress
    assert len(lossy["taus"]) == 30
    assert lossy["makespan"] > base["makespan"]
    # keyed: the same spec replays identically
    again = simulate_schedule(P=4, n_ticks=30, faults="drop=0.1,dup=0.1")
    assert again["retransmits"] == lossy["retransmits"]
    assert again["makespan"] == lossy["makespan"]


def test_sim_persistent_drop_escalates_to_outage():
    """A stage the transport repeatedly cannot reach is escalated into a
    synthesized leave/join (the PR 4 outage path) instead of deadlocking."""
    r = simulate_schedule(P=4, n_ticks=20, faults="drop=0.45",
                          retry_timeout=2.0, escalate_after=2)
    assert r["escalations"] >= 1
    assert max(r["outage_time"]) > 0.0  # the synthesized window was paid
    assert len(r["taus"]) == 20         # and the run still completed


def test_sim_empty_fault_model_is_noop():
    base = simulate_schedule(P=4, n_ticks=25)
    empty = simulate_schedule(P=4, n_ticks=25, faults=faults.FaultModel())
    assert empty["makespan"] == base["makespan"]
    assert empty["taus"] == base["taus"]
    assert empty["retransmits"] == 0 and empty["escalations"] == 0


# ---- checkpoint integrity ----------------------------------------------------


def _tiny_state(scale=1.0):
    return {"w": np.arange(12, dtype=np.float32).reshape(3, 4) * scale,
            "b": np.ones(5, np.float32) * scale}


def test_save_writes_checksums_and_roundtrips(tmp_path):
    p = str(tmp_path / "ckpt-1.npz")
    ckpt.save(p, _tiny_state(), 1)
    state, meta = ckpt.restore(p, _tiny_state(0.0))
    assert meta["step"] == 1
    assert set(meta["crc32"]) == {"['w']", "['b']"}
    np.testing.assert_array_equal(np.asarray(state["w"]), _tiny_state()["w"])


def test_truncated_newest_falls_back_to_previous(tmp_path):
    d = str(tmp_path)
    for step in (5, 10):
        ckpt.save_step(d, _tiny_state(float(step)), step)
    newest = os.path.join(d, "ckpt-10.npz")
    size = os.path.getsize(newest)
    with open(newest, "r+b") as f:
        f.truncate(size // 2)  # torn write
    path, step = ckpt.latest(d)
    assert step == 5  # cheap probe already skips the torn file
    state, meta, path, step = ckpt.restore_latest(d, _tiny_state(0.0))
    assert step == 5 and meta["step"] == 5
    np.testing.assert_array_equal(np.asarray(state["w"]), _tiny_state(5.0)["w"])


def test_bitflip_detected_and_skipped(tmp_path):
    d = str(tmp_path)
    for step in (1, 2):
        ckpt.save_step(d, _tiny_state(float(step)), step)
    newest = os.path.join(d, "ckpt-2.npz")
    blob = bytearray(open(newest, "rb").read())
    # flip a byte inside the array payload region (past the zip local header)
    blob[len(blob) // 2] ^= 0xFF
    open(newest, "wb").write(bytes(blob))
    with pytest.raises(Exception):  # CorruptCheckpointError or zip-layer CRC
        ckpt.restore(newest, _tiny_state(0.0))
    state, meta, _, step = ckpt.restore_latest(d, _tiny_state(0.0))
    assert step == 1
    np.testing.assert_array_equal(np.asarray(state["w"]), _tiny_state(1.0)["w"])


def test_nothing_restorable_returns_none(tmp_path):
    d = str(tmp_path)
    ckpt.save_step(d, _tiny_state(), 3)
    with open(os.path.join(d, "ckpt-3.npz"), "r+b") as f:
        f.truncate(1)
    assert ckpt.latest(d) == (None, -1)
    assert ckpt.restore_latest(d, _tiny_state(0.0)) == (None, None, None, -1)


def test_retention_survives_remove_failure(tmp_path, monkeypatch):
    """A concurrently-deleted / permission-locked stale checkpoint must not
    kill the training loop: retention logs and continues."""
    d = str(tmp_path)
    for step in range(1, 5):
        ckpt.save_step(d, _tiny_state(), step, keep=2)

    def deny(path):
        raise OSError(13, "Permission denied", path)

    monkeypatch.setattr(os, "remove", deny)
    ckpt.save_step(d, _tiny_state(), 5, keep=2)  # must not raise
    assert os.path.exists(os.path.join(d, "ckpt-5.npz"))


def test_maybe_truncate_checkpoint_keyed(tmp_path):
    p = str(tmp_path / "ckpt-7.npz")
    ckpt.save(p, _tiny_state(), 7)
    size = os.path.getsize(p)
    assert not faults.FaultModel(ckpt_trunc=0.0).maybe_truncate_checkpoint(p, 7)
    assert os.path.getsize(p) == size
    assert faults.FaultModel(ckpt_trunc=1.0).maybe_truncate_checkpoint(p, 7)
    assert os.path.getsize(p) == size // 2
    assert not ckpt._readable(p)


# ---- runtime e2e: quarantine, transport recovery, no-op contract ------------


def test_zero_rate_fault_model_is_bitwise_noop_at_k4(setup):
    """FaultModel() must leave the K=4 event-runtime trajectory bit-identical
    to faults=None: the runtime never consults an empty model."""
    cfg, params, _ = setup
    toks = jax.random.randint(jax.random.PRNGKey(2), (4, 2, 33), 0,
                              cfg.vocab_size)
    kbatch = {"tokens": toks[..., :-1], "labels": toks[..., 1:]}
    ecfg = _ecfg(update_interval=4)
    runs = {}
    for tag, fm in (("none", None), ("empty", faults.FaultModel())):
        rt = EventRuntime(AsyncTrainer(cfg, ecfg, "ours"),
                          RuntimeCfg(faults=fm))
        rt.init_from_params(params)
        res = rt.run(lambda t: kbatch, 6)
        runs[tag] = (res, rt.export_state(include_runtime=False))
    assert runs["none"][0].losses == runs["empty"][0].losses  # exact, not allclose
    assert runs["none"][0].taus == runs["empty"][0].taus
    assert runs["empty"][0].retransmits == 0
    assert runs["empty"][0].duplicates == 0
    for a, b in zip(jax.tree.leaves(runs["none"][1].params),
                    jax.tree.leaves(runs["empty"][1].params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_nan_grad_quarantine_keeps_run_finite(setup):
    cfg, params, batch = setup
    rt = EventRuntime(AsyncTrainer(cfg, _ecfg(), "ours"),
                      RuntimeCfg(faults="nan_grad=0.3"))
    rt.init_from_params(params)
    res = rt.run(lambda t: batch, 8)
    assert sum(res.nonfinite_skipped) > 0
    assert all(math.isfinite(l) for l in res.losses)
    state = rt.export_state()
    for leaf in jax.tree.leaves(state.params):
        assert bool(jnp.all(jnp.isfinite(leaf)))
    # the per-stage counters ride along in the checkpointable state
    assert tuple(int(e["rt"]["nonfinite_skipped"]) for e in state.extra) == \
        res.nonfinite_skipped


def test_runtime_drop_dup_recovery_matches_sim_twin(setup):
    """Message faults on the real runtime: retransmits keep every tick
    completing, duplicates are absorbed, and the compute-free twin predicts
    the transport behaviour event for event."""
    cfg, params, batch = setup
    spec = "drop=0.15,dup=0.2"
    rt = EventRuntime(AsyncTrainer(cfg, _ecfg(), "ours"),
                      RuntimeCfg(faults=spec))
    rt.init_from_params(params)
    res = rt.run(lambda t: batch, 8)
    assert len(res.losses) == 8
    assert all(math.isfinite(l) for l in res.losses)
    assert res.retransmits > 0 and res.duplicates > 0
    sim = simulate_schedule(P=4, n_ticks=8, faults=spec)
    assert sim["retransmits"] == res.retransmits
    assert [tuple(t) for t in sim["taus"]] == [tuple(t) for t in res.taus]


# ---- crash consistency + watchdog rollback e2e ------------------------------


def test_resume_after_torn_checkpoint_matches_baseline(setup, tmp_path):
    """Crash-consistency: run 10 ticks checkpointing every 5, tear the newest
    checkpoint, resume. The resumed run must restart from step 5 and replay
    ticks 6-10 to the same trajectory as the never-crashed run."""
    cfg, params, batch = setup
    d = str(tmp_path / "ck")

    def fresh():
        tr = AsyncTrainer(cfg, _ecfg(), "ours")
        # deterministic init shared across runs via the module fixture params
        return tr

    _, res1 = run_event_loop(fresh(), lambda t: batch, 10, seed=0,
                             ckpt_dir=d, ckpt_every=5, log_fn=lambda *_: None)
    assert res1.resumed_from == -1 and len(res1.losses) == 10
    newest = os.path.join(d, "ckpt-10.npz")
    with open(newest, "r+b") as f:
        f.truncate(os.path.getsize(newest) // 2)
    _, res2 = run_event_loop(fresh(), lambda t: batch, 10, seed=0,
                             ckpt_dir=d, ckpt_every=5, log_fn=lambda *_: None)
    assert res2.resumed_from == 5
    assert len(res2.losses) == 5  # replays exactly ticks 6..10
    dl = np.abs(np.asarray(res2.losses) - np.asarray(res1.losses[5:]))
    # PR 4 rejoin tolerance: the replay is fp-close, not bit-identical, since
    # jit_step init/restage ordering differs from the uninterrupted trajectory
    assert dl.max() < 0.4 and dl.mean() < 0.2, res2.losses


def test_watchdog_rollback_reaches_final_step(setup, tmp_path):
    """The acceptance chaos run in miniature: nan_grad + a crash, one
    invocation, must reach the final tick with quarantined updates and at
    least one watchdog rollback, ending at a finite loss."""
    cfg, params, batch = setup
    # nan_grad=0.02 @ seed 0 is a pinned schedule: epoch 0 poisons exactly
    # (tick 0, stage 0); the rollback's epoch bump re-samples to a clean run
    rt, res = run_event_loop(
        AsyncTrainer(cfg, _ecfg(), "ours"), lambda t: batch, 8, seed=0,
        ckpt_dir=str(tmp_path / "ck"), ckpt_every=4,
        faults="nan_grad=0.02,crash=1@6",
        watchdog="warmup=3,skips=1", max_rollbacks=5,
        log_fn=lambda *_: None)
    assert rt._u_done >= 8
    assert len(res.losses) == 8
    assert res.nonfinite_skipped > 0
    assert res.rollbacks >= 1
    assert all(math.isfinite(l) for l in res.losses)
