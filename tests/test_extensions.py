"""Tests for the beyond-paper extensions: bundled corpus, utilization analytics,
fused rmsnorm+residual kernel, delay-adaptive straggler model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import delay, utilization as U
from repro.data.corpus import CharCorpus
from repro.ft.loop import adaptive_gamma
from repro.kernels.rmsnorm_residual import rmsnorm_residual, rmsnorm_residual_ref


def test_char_corpus_roundtrip_and_batches():
    c = CharCorpus()
    assert 20 < c.vocab_size < 100
    b = c.batch(3, 2, 4, 32)
    assert b["tokens"].shape == (2, 4, 32)
    np.testing.assert_array_equal(np.asarray(b["tokens"][..., 1:]),
                                  np.asarray(b["labels"][..., :-1]))
    b2 = c.batch(3, 2, 4, 32)
    np.testing.assert_array_equal(np.asarray(b["tokens"]), np.asarray(b2["tokens"]))
    s = c.decode(b["tokens"][0, 0, :12])
    assert len(s) == 12 and all(ch in c.vocab for ch in s)


def test_char_corpus_trains():
    from repro.configs import get_config
    from repro.core.engine import AsyncTrainer, EngineCfg

    c = CharCorpus()
    cfg = get_config("nanogpt_134m", reduced=True, vocab_size=c.vocab_size)
    tr = AsyncTrainer(cfg, EngineCfg(n_stages=4, lr=2e-3, constant_lr=True), "ours")
    state = tr.init(jax.random.PRNGKey(0))
    step = tr.jit_step()
    losses = []
    for i in range(25):
        state, m = step(state, c.batch(i, 1, 8, 32))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]  # real text, learnable


def test_utilization_model():
    g = U.gpipe_timing(P=8, M=4, L=24)
    a = U.async_timing(P=8, M=4, L=24)
    assert a.utilization == 1.0 and a.bubble_frac == 0.0
    assert g.bubble_frac == pytest.approx((8 - 1) / (4 + 8 - 1))
    assert g.iter_time > a.iter_time
    # paper Fig. 5 shape: gpipe slowdown grows much faster with stages than async
    g_slow = U.relative_slowdown(24, 4, M=4, L=24, kind="gpipe")
    a_slow = U.relative_slowdown(24, 4, M=4, L=24, kind="async")
    assert g_slow > 2.0 * a_slow
    assert a_slow < 1.5


def test_straggler_effective_delay_and_gamma():
    taus = delay.stage_delays(4, 1)  # (3, 2, 1, 0)
    adj = U.straggler_effective_delay(taus, slow_stage=1, slow_factor=2.0)
    assert adj[1] > taus[1] and adj[0] > taus[0] and adj[3] == taus[3]
    # delay-adaptive momentum rises toward 0.99 with delay
    g_small = adaptive_gamma(1, 8)
    g_big = adaptive_gamma(8, 8)
    assert 0.9 <= g_small < g_big <= 0.99


@pytest.mark.parametrize("shape,d", [((4, 8, 64), 64), ((3, 128), 128), ((7, 96), 96)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_residual_kernel(shape, d, dtype):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, shape).astype(dtype)
    h = jax.random.normal(jax.random.fold_in(key, 1), shape).astype(dtype)
    scale = jax.random.normal(jax.random.fold_in(key, 2), (d,)) * 0.1
    r, y = rmsnorm_residual(x, h, scale)
    rr, yr = rmsnorm_residual_ref(x, h, scale)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(r, np.float32), np.asarray(rr, np.float32),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(yr, np.float32),
                               rtol=tol, atol=tol)


def test_rmsnorm_residual_matches_model_layer():
    """Kernel output equals models.layers.rmsnorm_apply on the summed input."""
    from repro.models import layers as L

    key = jax.random.PRNGKey(5)
    x = jax.random.normal(key, (2, 16, 32))
    h = jax.random.normal(jax.random.fold_in(key, 1), (2, 16, 32))
    scale = jax.random.normal(jax.random.fold_in(key, 2), (32,)) * 0.05
    _, y = rmsnorm_residual(x, h, scale)
    want = L.rmsnorm_apply({"scale": scale}, x + h)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), rtol=2e-5, atol=2e-5)
