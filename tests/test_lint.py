"""repro-lint fixture suite (ISSUE 9): every rule must fire on a known-bad
snippet (including minimal reproductions of the PR 7 key-reuse and PR 4
host-sync bugs, asserted to fail on the old code shapes), pragma/baseline
suppression must be honored, and the live tree must lint clean within the
suppression budget."""
import json
import os
import textwrap

import pytest

from repro.analysis import engine
from repro.analysis.rules import reg001  # noqa: F401  (registers all rules)

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def lint_snippet(tmp_path, relpath, code, rule_ids, baseline=None):
    """Write a fixture file into a fake repo tree and lint it."""
    path = tmp_path / relpath
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(textwrap.dedent(code))
    baseline_path = None
    if baseline is not None:
        baseline_path = tmp_path / "lint_baseline.json"
        baseline_path.write_text(json.dumps(baseline))
    return engine.lint_tree(
        str(tmp_path), rules=[engine.RULES[r] for r in rule_ids],
        baseline_path=str(baseline_path) if baseline_path else None)


# ---- RNG001: PRNG key reuse (the PR 7 bug class) ---------------------------


PR7_BUG = """
    import jax

    def make_demo_inputs(cfg, seed):
        key = jax.random.PRNGKey(seed)
        params = init_lm(key, cfg)  # helper consumes the key...
        prompt = jax.random.randint(key, (4,), 0, 100)  # ...then it is reused
        return params, prompt
"""

PR7_FIXED = """
    import jax

    def make_demo_inputs(cfg, seed):
        k_init, k_prompt = jax.random.split(jax.random.PRNGKey(seed))
        params = init_lm(k_init, cfg)
        prompt = jax.random.randint(k_prompt, (4,), 0, 100)
        return params, prompt
"""


def test_rng001_fires_on_pr7_key_reuse(tmp_path):
    res = lint_snippet(tmp_path, "src/repro/core/demo.py", PR7_BUG, ["RNG001"])
    assert [f.rule for f in res.findings] == ["RNG001"], res.findings
    assert "key" in res.findings[0].message


def test_rng001_clean_on_pr7_fixed_shape(tmp_path):
    res = lint_snippet(tmp_path, "src/repro/core/demo.py", PR7_FIXED, ["RNG001"])
    assert res.findings == []


def test_rng001_fires_on_loop_carried_key(tmp_path):
    bad = """
        import jax

        def sample(key, n):
            out = []
            for i in range(n):
                out.append(jax.random.normal(key))  # same draw every iteration
            return out
    """
    res = lint_snippet(tmp_path, "src/repro/core/demo.py", bad, ["RNG001"])
    assert [f.rule for f in res.findings] == ["RNG001"]

    good = """
        import jax

        def sample(key, n):
            out = []
            for i in range(n):
                key, sub = jax.random.split(key)
                out.append(jax.random.normal(sub))
            return out
    """
    res = lint_snippet(tmp_path, "src/repro/core/demo.py", good, ["RNG001"])
    assert res.findings == []


# ---- RNG002: hardcoded PRNGKey literal in library code ---------------------


RNG002_BUG = """
    import jax

    def init_or_default(trainer, key=None):
        return trainer.init(key if key is not None else jax.random.PRNGKey(0))
"""


def test_rng002_fires_in_library_code(tmp_path):
    res = lint_snippet(tmp_path, "src/repro/core/demo.py", RNG002_BUG, ["RNG002"])
    assert [f.rule for f in res.findings] == ["RNG002"]
    assert "PRNGKey(0)" in res.findings[0].message


def test_rng002_exempts_launchers_and_eval_shape(tmp_path):
    # launchers own the seed: same snippet under launch/ is clean
    res = lint_snippet(tmp_path, "src/repro/launch/demo.py", RNG002_BUG, ["RNG002"])
    assert res.findings == []
    # eval_shape probes never execute, so the literal cannot bias results
    probe = """
        import jax

        def param_shapes(init_fn, cfg):
            return jax.eval_shape(lambda k: init_fn(k, cfg),
                                  jax.random.PRNGKey(0))
    """
    res = lint_snippet(tmp_path, "src/repro/models/demo.py", probe, ["RNG002"])
    assert res.findings == []


# ---- DET001: stateful nondeterminism ---------------------------------------


def test_det001_fires_on_global_rng_and_wall_clock(tmp_path):
    bad = """
        import time
        import numpy as np

        def jitter(scale):
            np.random.seed(0)
            t0 = time.time()
            return np.random.uniform() * scale, t0
    """
    res = lint_snippet(tmp_path, "src/repro/core/demo.py", bad, ["DET001"])
    assert sorted(f.rule for f in res.findings) == ["DET001"] * 3
    msgs = " ".join(f.message for f in res.findings)
    assert "np.random.seed" in msgs and "time.time" in msgs


def test_det001_allows_keyed_philox_and_perf_counter(tmp_path):
    good = """
        import time
        import numpy as np

        def draw(seed, sid):
            rng = np.random.Generator(np.random.Philox(key=seed ^ sid))
            t0 = time.perf_counter()
            return rng.uniform(), time.perf_counter() - t0
    """
    res = lint_snippet(tmp_path, "src/repro/core/demo.py", good, ["DET001"])
    assert res.findings == []


# ---- SYNC001: host sync in loop (the PR 4 stall class) ---------------------


PR4_BUG = """
    import jax

    step = jax.jit(lambda s: s)

    def run(state, n):
        losses = []
        for i in range(n):
            state, m = step(state)
            losses.append(float(m))  # per-forward host sync: serializes dispatch
        return losses
"""


def test_sync001_fires_on_pr4_host_sync(tmp_path):
    res = lint_snippet(tmp_path, "src/repro/core/demo.py", PR4_BUG, ["SYNC001"])
    assert [f.rule for f in res.findings] == ["SYNC001"]
    assert "float" in res.findings[0].message


def test_sync001_scope_and_host_parsing_exempt(tmp_path):
    # out of scope (not core/ or launch/serve.py): clean
    res = lint_snippet(tmp_path, "src/repro/ft/demo.py", PR4_BUG, ["SYNC001"])
    assert res.findings == []
    # host-side string parsing in a loop is not a device sync
    parsing = """
        def parse(specs):
            out = []
            for spec in specs:
                parts = spec.split(",")
                out.append(float(parts[0]))
            return out
    """
    res = lint_snippet(tmp_path, "src/repro/core/demo.py", parsing, ["SYNC001"])
    assert res.findings == []


def test_sync001_device_get_and_item_always_fire(tmp_path):
    bad = """
        import jax

        def drain(vals):
            out = []
            while vals:
                out.append(jax.device_get(vals.pop()))
                out.append(vals[0].item() if vals else 0)
            return out
    """
    res = lint_snippet(tmp_path, "src/repro/core/demo.py", bad, ["SYNC001"])
    assert sorted(f.rule for f in res.findings) == ["SYNC001"] * 2


def test_sync001_pragma_suppression(tmp_path):
    pragma = PR4_BUG.replace(
        "losses.append(float(m))  # per-forward host sync: serializes dispatch",
        "# lint: allow-host-sync(demo drain boundary)\n"
        "            losses.append(float(m))")
    res = lint_snippet(tmp_path, "src/repro/core/demo.py", pragma, ["SYNC001"])
    assert res.findings == []
    assert [s.via for s in res.suppressions] == ["pragma"]
    assert res.suppressions[0].reason == "demo drain boundary"


def test_pragma_without_reason_does_not_suppress(tmp_path):
    pragma = PR4_BUG.replace(
        "losses.append(float(m))  # per-forward host sync: serializes dispatch",
        "losses.append(float(m))  # lint: allow-host-sync()")
    res = lint_snippet(tmp_path, "src/repro/core/demo.py", pragma, ["SYNC001"])
    assert [f.rule for f in res.findings] == ["SYNC001"]


# ---- DON001: use after donation --------------------------------------------


DON_BUG = """
    import jax

    decode = jax.jit(lambda p, c: (p, c), donate_argnums=(1,))

    def run(params, cache):
        logits, _ = decode(params, cache)
        return logits, cache  # cache buffer was donated: this read is invalid
"""


def test_don001_fires_on_use_after_donation(tmp_path):
    res = lint_snippet(tmp_path, "src/repro/core/demo.py", DON_BUG, ["DON001"])
    assert [f.rule for f in res.findings] == ["DON001"]
    assert "cache" in res.findings[0].message


def test_don001_clean_when_result_rebinds_donated_ref(tmp_path):
    good = DON_BUG.replace("logits, _ = decode", "logits, cache = decode")
    res = lint_snippet(tmp_path, "src/repro/core/demo.py", good, ["DON001"])
    assert res.findings == []


def test_don001_fires_on_loop_carried_donation(tmp_path):
    bad = """
        import jax

        decode = jax.jit(lambda p, c: (p, c), donate_argnums=(1,))

        def run(params, cache, n):
            outs = []
            for i in range(n):
                logits, _ = decode(params, cache)  # next iteration: donated ref
                outs.append(logits)
            return outs
    """
    res = lint_snippet(tmp_path, "src/repro/core/demo.py", bad, ["DON001"])
    assert [f.rule for f in res.findings] == ["DON001"]

    good = bad.replace("logits, _ = decode", "logits, cache = decode")
    res = lint_snippet(tmp_path, "src/repro/core/demo.py", good, ["DON001"])
    assert res.findings == []


# ---- REG001: registry/docs consistency -------------------------------------


def test_reg001_method_table_detects_missing_and_stale(tmp_path):
    (tmp_path / "README.md").write_text(
        "## Method registry\n\n"
        "| method | optimizer | fwd | bwd | corr | tau | mem |\n"
        "|---|---|---|---|---|---|---|\n"
        "| `no_such_method` | adam | live | live | — | obs | O(1) |\n")
    problems = reg001.method_table_problems(str(tmp_path))
    assert any("missing" in p for p in problems)
    assert any("stale" in p and "no_such_method" in p for p in problems)


def test_reg001_bench_artifacts_detect_missing(tmp_path):
    (tmp_path / "README.md").write_text(
        "See artifacts/BENCH_nonexistent.json for numbers.\n"
        "BENCH_planned_thing.json is planned.\n")
    problems = reg001.bench_artifact_problems(str(tmp_path), docs=["README.md"])
    assert len(problems) == 1
    assert "BENCH_nonexistent.json" in problems[0]
    assert "BENCH_planned_thing.json" not in problems[0]


def test_reg001_dispatch_requires_documented_ref_vjp(tmp_path):
    # real registry, doctored source: strip the ref-VJP notes and every
    # bwd-less op must be flagged
    src = open(os.path.join(ROOT, "src/repro/kernels/dispatch.py")).read()
    assert "ref-VJP" in src
    doctored = src.replace("ref-VJP", "redacted")
    dst = tmp_path / "src" / "repro" / "kernels"
    dst.mkdir(parents=True)
    (dst / "dispatch.py").write_text(doctored)
    problems = reg001.dispatch_registry_problems(str(tmp_path))
    assert any("nag_update" in p and "ref-VJP" in p for p in problems)
    # the live tree documents every fallback
    assert reg001.dispatch_registry_problems(ROOT) == []


# ---- baseline suppression ---------------------------------------------------


def test_baseline_suppression_with_contains_match(tmp_path):
    baseline = {"version": 1, "suppress": [
        {"rule": "SYNC001", "path": "src/repro/core/demo.py",
         "contains": "float(m)", "reason": "fixture debt"}]}
    res = lint_snippet(tmp_path, "src/repro/core/demo.py", PR4_BUG,
                       ["SYNC001"], baseline=baseline)
    assert res.findings == []
    assert [s.via for s in res.suppressions] == ["baseline"]
    assert res.suppressions[0].reason == "fixture debt"


def test_baseline_entry_requires_reason(tmp_path):
    baseline = {"version": 1, "suppress": [
        {"rule": "SYNC001", "path": "src/repro/core/demo.py"}]}
    with pytest.raises(ValueError, match="reason"):
        lint_snippet(tmp_path, "src/repro/core/demo.py", PR4_BUG,
                     ["SYNC001"], baseline=baseline)


# ---- the live tree ----------------------------------------------------------


def test_live_tree_lints_clean_within_budget():
    res = engine.lint_tree(ROOT)
    assert res.errors == []
    assert res.findings == [], "\n".join(f.render() for f in res.findings)
    # acceptance budget: <= 5 suppressions, every one pragma'd with a reason
    assert len(res.suppressions) <= 5, res.suppressions
    for s in res.suppressions:
        assert s.reason.strip(), s
        assert s.via == "pragma", s  # no baseline debt in-tree


def test_cli_json_exit_status(tmp_path):
    import subprocess
    import sys

    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"),
               JAX_PLATFORMS="cpu")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", "--format=json",
         "--root", ROOT],
        capture_output=True, text=True, env=env)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    payload = json.loads(proc.stdout)
    assert payload["total"] == 0
    assert set(payload["rules"]) == set(engine.RULES)
