"""Event-driven runtime: simulation-vs-engine equivalence suite.

The headline contract (ISSUE 2): under a *fixed* uniform DelayModel the
discrete-event 1F1B runtime reproduces the single-jit stash-replay engine
tick-for-tick — identical loss/parameter trajectories within fp tolerance — so
every paper result transfers to the event-driven execution path. Stochastic
delay models then exercise the dynamic-tau machinery: observed staleness varies
per tick, the per-microbatch stash grows exactly to the max observed delay + 1,
and the jit engine's dynamic-tau path (step(..., taus=...)) replays the
runtime's observed schedule bit-for-bit through the same ring buffers.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import delay
from repro.core.engine import AsyncTrainer, EngineCfg
from repro.core.events import (FixedDelay, JitterDelay, StragglerDelay,
                               make_delay_model)
from repro.core.runtime import EventRuntime, RuntimeCfg, simulate_schedule
from repro.models import lm

N_TICKS = 20


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("nanogpt_134m", reduced=True)
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 4, 33), 0, cfg.vocab_size)
    batch = {"tokens": toks[..., :-1], "labels": toks[..., 1:]}
    return cfg, params, batch


def _ecfg(**kw):
    kw.setdefault("n_stages", 4)
    kw.setdefault("lr", 1e-3)
    kw.setdefault("constant_lr", True)
    kw.setdefault("collect_metrics", False)
    return EngineCfg(**kw)


# ---- schedule-level equivalence ---------------------------------------------


@pytest.mark.parametrize("P,K", [(1, 1), (2, 1), (4, 1), (8, 1), (4, 2), (8, 4)])
def test_schedule_sim_reaches_eq5_steady_state(P, K):
    """Fixed uniform delays: the event discipline's steady-state observed taus
    are exactly the closed-form schedule of Eq. 5 (K=1; within the accumulation
    floor for K>1), and peak stash size is tau_i + 1 (the engine's ring depth)."""
    sim = simulate_schedule(P=P, K=K, n_ticks=4 * P + 8)
    want = delay.stage_delays(P, K)
    got = sim["taus"][-1]
    if K == 1:
        assert tuple(int(t) for t in got) == want
        assert sim["max_stash"] == tuple(t + 1 for t in want)
    else:
        # accumulation averages the microbatch delays: within 1 update of Eq. 5
        assert all(abs(g - w) <= 1.0 for g, w in zip(got, want))
    assert all(0.0 < u <= 1.0 + 1e-9 for u in sim["utilization"])
    # observed staleness is monotone non-increasing along the pipeline
    assert all(got[s] >= got[s + 1] for s in range(P - 1))


def test_schedule_sim_straggler_grows_delay():
    """A straggling stage with elastic buffers converts slowness into observed
    delay (the async-PP story): upstream taus grow past the Eq. 5 schedule."""
    base = simulate_schedule(P=4, n_ticks=40)
    slow = simulate_schedule(P=4, n_ticks=40, delay_model="straggler:1,5.0",
                             in_flight=8)
    assert max(slow["max_tau_obs"]) > max(base["max_tau_obs"])
    assert slow["max_stash"][0] == slow["max_tau_obs"][0] + 1
    # the straggler itself is the busy one; everyone else waits
    assert slow["utilization"][1] > slow["utilization"][0]


# ---- engine equivalence (the headline test) ---------------------------------


@pytest.mark.parametrize("method", ["ours", "pipedream", "gpipe"])
def test_event_runtime_matches_engine_fixed_delays(setup, method):
    """FixedDelay + K=1: event-driven losses == jit-engine losses over
    N_TICKS >= 20 ticks (atol 1e-5), and final params agree."""
    cfg, params, batch = setup
    ecfg = _ecfg()
    tr = AsyncTrainer(cfg, ecfg, method)
    s = tr.init_from_params(params)
    step = tr.jit_step(donate=False)
    eng_losses = []
    for _ in range(N_TICKS):
        s, m = step(s, batch)
        eng_losses.append(float(m["loss"]))

    rt = EventRuntime(AsyncTrainer(cfg, ecfg, method))
    rt.init_from_params(params)
    res = rt.run(lambda t: batch, N_TICKS)

    np.testing.assert_allclose(res.losses, eng_losses, rtol=1e-5, atol=1e-5)
    if method != "gpipe":
        # steady-state observed schedule == Eq. 5
        assert tuple(int(t) for t in res.taus[-1]) == tr.taus
        assert res.max_stash == tuple(t + 1 for t in tr.taus)
    for a, b in zip(jax.tree.leaves(s.params),
                    jax.tree.leaves(rt.export_state(include_runtime=False).params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_bwd_heavy_latencies_preserve_equivalence(setup):
    """The discipline (capacity + backward priority + in-order), not the exact
    latencies, pins the schedule: a 3x-backward-cost fixed model still matches."""
    cfg, params, batch = setup
    ecfg = _ecfg()
    tr = AsyncTrainer(cfg, ecfg, "ours")
    s = tr.init_from_params(params)
    step = tr.jit_step(donate=False)
    eng = []
    for _ in range(10):
        s, m = step(s, batch)
        eng.append(float(m["loss"]))
    rt = EventRuntime(AsyncTrainer(cfg, ecfg, "ours"),
                      RuntimeCfg(delay_model=FixedDelay(fwd=1.0, bwd=3.0, comm=0.5)))
    rt.init_from_params(params)
    res = rt.run(lambda t: batch, 10)
    np.testing.assert_allclose(res.losses, eng, rtol=1e-5, atol=1e-5)


def test_observed_taus_drive_dynamic_engine(setup):
    """Tau-consuming method (lr discount): the jit engine's dynamic-tau path,
    fed the runtime's OBSERVED per-tick schedule (warmup included), reproduces
    the event-driven trajectory — the generalized stash replays Eq. 7 under
    arbitrary tau_t."""
    cfg, params, batch = setup
    ecfg = _ecfg(max_dynamic_delay=4)
    assert AsyncTrainer(cfg, ecfg, "ours_lr").method.tau_consuming
    rt = EventRuntime(AsyncTrainer(cfg, ecfg, "ours_lr"))
    rt.init_from_params(params)
    res = rt.run(lambda t: batch, 12)
    # warmup: observed staleness ramps 0 -> tau_i instead of assuming Eq. 5
    assert tuple(res.taus[0]) == (0.0, 0.0, 0.0, 0.0)
    assert tuple(int(t) for t in res.taus[-1]) == (3, 2, 1, 0)

    tr = AsyncTrainer(cfg, ecfg, "ours_lr")
    s = tr.init_from_params(params)
    step = tr.jit_step(donate=False)
    eng = []
    for t in range(12):
        taus_t = jnp.asarray(np.array(res.taus[t]), jnp.int32)
        s, m = step(s, batch, taus_t)
        eng.append(float(m["loss"]))
    np.testing.assert_allclose(res.losses, eng, rtol=1e-5, atol=1e-5)


# ---- stochastic delays: dynamic tau + stash-depth contract ------------------


@pytest.mark.parametrize("dm,in_flight", [
    (JitterDelay(sigma=0.5, seed=3), None),
    (StragglerDelay(slow_stage=1, factor=5.0), 8),
    (make_delay_model("straggler:0,3.0,6"), 6),
])
def test_stochastic_delays_dynamic_tau(setup, dm, in_flight):
    cfg, params, batch = setup
    rt = EventRuntime(AsyncTrainer(cfg, _ecfg(), "ours"),
                      RuntimeCfg(delay_model=dm, in_flight=in_flight))
    rt.init_from_params(params)
    res = rt.run(lambda t: batch, 14)
    assert np.isfinite(res.losses).all()
    # stash depth == max observed delay + 1, per stage, never beyond capacity
    caps = rt.caps
    for s in range(4):
        assert res.max_stash[s] == int(res.max_tau_obs[s]) + 1
        assert res.max_stash[s] <= caps[s]
    # delays actually moved (a straggler/jitter run is not the fixed schedule)
    flat = {tuple(t) for t in res.taus}
    assert len(flat) > 1


def test_straggler_grows_observed_delay_beyond_schedule(setup):
    cfg, params, batch = setup
    rt = EventRuntime(AsyncTrainer(cfg, _ecfg(), "ours"),
                      RuntimeCfg(delay_model=StragglerDelay(slow_stage=1, factor=5.0),
                                 in_flight=8))
    rt.init_from_params(params)
    res = rt.run(lambda t: batch, 14)
    assert res.max_tau_obs[0] > delay.max_delay(4, 1)  # beyond Eq. 5's tau_1
    assert res.max_stash[0] == int(res.max_tau_obs[0]) + 1


def test_grad_accum_runtime_runs(setup):
    """K=2 accumulation: per-stage grads accumulate over K microbatches before
    each update; observed taus shrink toward Eq. 5's 1/K scaling."""
    cfg, params, _ = setup
    toks = jax.random.randint(jax.random.PRNGKey(9), (2, 4, 33), 0, cfg.vocab_size)
    batch = {"tokens": toks[..., :-1], "labels": toks[..., 1:]}
    ecfg = _ecfg(update_interval=2)
    rt = EventRuntime(AsyncTrainer(cfg, ecfg, "ours"))
    rt.init_from_params(params)
    res = rt.run(lambda t: batch, 8)
    assert np.isfinite(res.losses).all()
    want = delay.stage_delays(4, 2)
    got = res.taus[-1]
    assert all(abs(g - w) <= 1.0 for g, w in zip(got, want))
    assert all(got[s] >= got[s + 1] for s in range(3))


# ---- checkpointing ----------------------------------------------------------


def test_runtime_checkpoint_roundtrip(setup, tmp_path):
    """Runtime state (counters in AsyncState.extra['rt']) save/restores exactly;
    the resumed run replays the original trajectory bit-for-bit."""
    from repro.checkpoint import checkpoint as ckpt

    cfg, params, batch = setup
    ecfg = _ecfg()
    batch_fn = lambda t: batch
    rt = EventRuntime(AsyncTrainer(cfg, ecfg, "ours"))
    rt.init_from_params(params)
    rt.run(batch_fn, 4)
    path = str(tmp_path / "rt.npz")
    ckpt.save(path, rt.export_state(), 4)

    rt2 = EventRuntime(AsyncTrainer(cfg, ecfg, "ours"))
    template = rt2.init_from_params(params).export_state()
    restored, meta = ckpt.restore(path, template)
    assert meta["step"] == 4
    rt2.init_from_state(restored)
    assert rt2._u_done == 4
    r1 = rt.run(batch_fn, 4)
    r2 = rt2.run(batch_fn, 4)
    np.testing.assert_array_equal(r1.losses, r2.losses)


def test_simulate_schedule_agrees_with_runtime_under_jitter(setup):
    """The compute-free planner and the real runtime implement ONE discipline:
    under the same keyed stochastic delay model they produce identical observed
    tau schedules and stash high-water marks, event for event."""
    cfg, params, batch = setup
    dm = JitterDelay(sigma=0.6, seed=11)
    rt = EventRuntime(AsyncTrainer(cfg, _ecfg(), "ours"),
                      RuntimeCfg(delay_model=dm))
    rt.init_from_params(params)
    res = rt.run(lambda t: batch, 12)
    sim = simulate_schedule(P=4, K=1, n_ticks=12, delay_model=dm)
    assert [tuple(t) for t in sim["taus"]] == [tuple(t) for t in res.taus]
    assert tuple(sim["max_stash"]) == res.max_stash
    assert tuple(sim["max_tau_obs"]) == res.max_tau_obs
    np.testing.assert_allclose(sim["makespan"], res.makespan, rtol=1e-9)


def test_jit_engine_checkpoint_resumes_under_event_runtime(setup, tmp_path):
    """Cross-path resume: a checkpoint written by the jit-engine loop (no
    extra['rt'] counters) restores into the event runtime via the
    counter-free template, exactly as launch/train.py --runtime event does."""
    from repro.checkpoint import checkpoint as ckpt

    cfg, params, batch = setup
    ecfg = _ecfg()
    tr = AsyncTrainer(cfg, ecfg, "ours")
    s = tr.init_from_params(params)
    step = tr.jit_step(donate=False)
    for _ in range(3):
        s, _ = step(s, batch)
    ckpt.save_step(str(tmp_path), s, 3)

    rt = EventRuntime(AsyncTrainer(cfg, ecfg, "ours"))
    rt.init_from_params(params)
    path, _ = ckpt.latest(str(tmp_path))
    restored, meta = ckpt.restore(path, rt.export_state(include_runtime=False))
    assert meta["step"] == 3
    rt.init_from_state(restored)
    assert rt._u_done == 3
    res = rt.run(lambda t: batch, 3)
    assert np.isfinite(res.losses).all()


def test_runtime_state_loads_into_jit_engine(setup):
    """export_state(include_runtime=False) is a plain engine AsyncState: the
    jit engine resumes from an event-runtime run (staleness history re-warmed,
    like checkpoint.restage on elastic events)."""
    cfg, params, batch = setup
    ecfg = _ecfg()
    rt = EventRuntime(AsyncTrainer(cfg, ecfg, "ours"))
    rt.init_from_params(params)
    rt.run(lambda t: batch, 5)
    state = rt.export_state(include_runtime=False)
    tr = AsyncTrainer(cfg, ecfg, "ours")
    tr.init_from_params(params)  # builds stage fns
    step = tr.jit_step(donate=False)
    assert int(state.step) == 5
    for _ in range(3):
        state, m = step(state, batch)
        assert np.isfinite(float(m["loss"]))
