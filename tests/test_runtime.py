"""Event-driven runtime: simulation-vs-engine equivalence suite.

The headline contract (ISSUE 2): under a *fixed* uniform DelayModel the
discrete-event 1F1B runtime reproduces the single-jit stash-replay engine
tick-for-tick — identical loss/parameter trajectories within fp tolerance — so
every paper result transfers to the event-driven execution path. Stochastic
delay models then exercise the dynamic-tau machinery: observed staleness varies
per tick, the per-microbatch stash grows exactly to the max observed delay + 1,
and the jit engine's dynamic-tau path (step(..., taus=...)) replays the
runtime's observed schedule bit-for-bit through the same ring buffers.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import delay
from repro.core.events import (ChurnModel, FixedDelay, JitterDelay, Outage,
                               OutageDelay, StragglerDelay, TraceDelay,
                               TraceRecorder, make_churn_model, make_delay_model)
from repro.core.engine import AsyncTrainer, EngineCfg
from repro.core.methods import get_method
from repro.core.runtime import EventRuntime, RuntimeCfg, simulate_schedule
from repro.models import lm

N_TICKS = 20


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("nanogpt_134m", reduced=True)
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 4, 33), 0, cfg.vocab_size)
    batch = {"tokens": toks[..., :-1], "labels": toks[..., 1:]}
    return cfg, params, batch


def _ecfg(**kw):
    kw.setdefault("n_stages", 4)
    kw.setdefault("lr", 1e-3)
    kw.setdefault("constant_lr", True)
    kw.setdefault("collect_metrics", False)
    return EngineCfg(**kw)


# ---- schedule-level equivalence ---------------------------------------------


@pytest.mark.parametrize("P,K", [(1, 1), (2, 1), (4, 1), (8, 1), (4, 2), (8, 4)])
def test_schedule_sim_reaches_eq5_steady_state(P, K):
    """Fixed uniform delays: the event discipline's steady-state observed taus
    are exactly the closed-form schedule of Eq. 5 (K=1; within the accumulation
    floor for K>1), and peak stash size is tau_i + 1 (the engine's ring depth)."""
    sim = simulate_schedule(P=P, K=K, n_ticks=4 * P + 8)
    want = delay.stage_delays(P, K)
    got = sim["taus"][-1]
    if K == 1:
        assert tuple(int(t) for t in got) == want
        assert sim["max_stash"] == tuple(t + 1 for t in want)
    else:
        # accumulation averages the microbatch delays: within 1 update of Eq. 5
        assert all(abs(g - w) <= 1.0 for g, w in zip(got, want))
    assert all(0.0 < u <= 1.0 + 1e-9 for u in sim["utilization"])
    # observed staleness is monotone non-increasing along the pipeline
    assert all(got[s] >= got[s + 1] for s in range(P - 1))


def test_schedule_sim_straggler_grows_delay():
    """A straggling stage with elastic buffers converts slowness into observed
    delay (the async-PP story): upstream taus grow past the Eq. 5 schedule."""
    base = simulate_schedule(P=4, n_ticks=40)
    slow = simulate_schedule(P=4, n_ticks=40, delay_model="straggler:1,5.0",
                             in_flight=8)
    assert max(slow["max_tau_obs"]) > max(base["max_tau_obs"])
    assert slow["max_stash"][0] == slow["max_tau_obs"][0] + 1
    # the straggler itself is the busy one; everyone else waits
    assert slow["utilization"][1] > slow["utilization"][0]


# ---- engine equivalence (the headline test) ---------------------------------


@pytest.mark.parametrize("method", ["ours", "pipedream", "gpipe"])
def test_event_runtime_matches_engine_fixed_delays(setup, method):
    """FixedDelay + K=1: event-driven losses == jit-engine losses over
    N_TICKS >= 20 ticks (atol 1e-5), and final params agree."""
    cfg, params, batch = setup
    ecfg = _ecfg()
    tr = AsyncTrainer(cfg, ecfg, method)
    s = tr.init_from_params(params)
    step = tr.jit_step(donate=False)
    eng_losses = []
    for _ in range(N_TICKS):
        s, m = step(s, batch)
        eng_losses.append(float(m["loss"]))

    rt = EventRuntime(AsyncTrainer(cfg, ecfg, method))
    rt.init_from_params(params)
    res = rt.run(lambda t: batch, N_TICKS)

    np.testing.assert_allclose(res.losses, eng_losses, rtol=1e-5, atol=1e-5)
    if method != "gpipe":
        # steady-state observed schedule == Eq. 5
        assert tuple(int(t) for t in res.taus[-1]) == tr.taus
        assert res.max_stash == tuple(t + 1 for t in tr.taus)
    for a, b in zip(jax.tree.leaves(s.params),
                    jax.tree.leaves(rt.export_state(include_runtime=False).params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_bwd_heavy_latencies_preserve_equivalence(setup):
    """The discipline (capacity + backward priority + in-order), not the exact
    latencies, pins the schedule: a 3x-backward-cost fixed model still matches."""
    cfg, params, batch = setup
    ecfg = _ecfg()
    tr = AsyncTrainer(cfg, ecfg, "ours")
    s = tr.init_from_params(params)
    step = tr.jit_step(donate=False)
    eng = []
    for _ in range(10):
        s, m = step(s, batch)
        eng.append(float(m["loss"]))
    rt = EventRuntime(AsyncTrainer(cfg, ecfg, "ours"),
                      RuntimeCfg(delay_model=FixedDelay(fwd=1.0, bwd=3.0, comm=0.5)))
    rt.init_from_params(params)
    res = rt.run(lambda t: batch, 10)
    np.testing.assert_allclose(res.losses, eng, rtol=1e-5, atol=1e-5)


def test_observed_taus_drive_dynamic_engine(setup):
    """Tau-consuming method (lr discount): the jit engine's dynamic-tau path,
    fed the runtime's OBSERVED per-tick schedule (warmup included), reproduces
    the event-driven trajectory — the generalized stash replays Eq. 7 under
    arbitrary tau_t."""
    cfg, params, batch = setup
    ecfg = _ecfg(max_dynamic_delay=4)
    assert AsyncTrainer(cfg, ecfg, "ours_lr").method.tau_consuming
    rt = EventRuntime(AsyncTrainer(cfg, ecfg, "ours_lr"))
    rt.init_from_params(params)
    res = rt.run(lambda t: batch, 12)
    # warmup: observed staleness ramps 0 -> tau_i instead of assuming Eq. 5
    assert tuple(res.taus[0]) == (0.0, 0.0, 0.0, 0.0)
    assert tuple(int(t) for t in res.taus[-1]) == (3, 2, 1, 0)

    tr = AsyncTrainer(cfg, ecfg, "ours_lr")
    s = tr.init_from_params(params)
    step = tr.jit_step(donate=False)
    eng = []
    for t in range(12):
        taus_t = jnp.asarray(np.array(res.taus[t]), jnp.int32)
        s, m = step(s, batch, taus_t)
        eng.append(float(m["loss"]))
    np.testing.assert_allclose(res.losses, eng, rtol=1e-5, atol=1e-5)


# ---- tau_source: observed-staleness-adaptive methods (DESIGN.md §10) --------


def test_observed_tau_momentum_differs_and_matches_dynamic_engine(setup):
    """The tau_source contract (DESIGN.md §10): under a straggler delay model
    `ours_delay_adaptive` (tau_source="observed" — delay-keyed momentum) (a)
    sees the exact same observed schedule as its stage-index twin yet produces
    a measurably different trajectory (only the observed variant's momentum
    reacts to the inflated tau), and (b) the jit engine's dynamic-tau path
    `step(..., taus=...)`, driven with the runtime's recorded per-tick tau
    vectors, reproduces the observed-variant trajectory within the standard
    engine-equivalence tolerance (atol 1e-5)."""
    import dataclasses as dc

    cfg, params, batch = setup
    dm = StragglerDelay(slow_stage=1, factor=5.0)
    ecfg = _ecfg(max_dynamic_delay=8)
    n = 14

    m_obs = get_method("ours_delay_adaptive")
    assert m_obs.tau_source == "observed" and m_obs.tau_consuming
    rt_obs = EventRuntime(AsyncTrainer(cfg, ecfg, m_obs),
                          RuntimeCfg(delay_model=dm, in_flight=8))
    rt_obs.init_from_params(params)
    res_obs = rt_obs.run(lambda t: batch, n)

    m_idx = dc.replace(m_obs, name="ours_delay_adaptive_stage_index",
                       tau_source="stage_index")
    assert not m_idx.tau_consuming  # corrections pinned to the Eq. 5 schedule
    rt_idx = EventRuntime(AsyncTrainer(cfg, ecfg, m_idx),
                          RuntimeCfg(delay_model=dm, in_flight=8))
    rt_idx.init_from_params(params)
    res_idx = rt_idx.run(lambda t: batch, n)

    # the event order is method-independent: identical observed schedules
    assert [tuple(t) for t in res_obs.taus] == [tuple(t) for t in res_idx.taus]
    # the straggler pushed observed tau past Eq. 5, so the two momentum
    # keyings actually disagree — and the trajectories measurably split
    assert max(res_obs.max_tau_obs) > delay.max_delay(4, 1)
    diff = np.abs(np.asarray(res_obs.losses) - np.asarray(res_idx.losses))
    assert diff.max() > 1e-4

    # (b) engine dynamic-tau path replays the observed-variant trajectory
    tr = AsyncTrainer(cfg, ecfg, m_obs)
    s = tr.init_from_params(params)
    step = tr.jit_step(donate=False)
    eng = []
    for t in range(n):
        s, m = step(s, batch, jnp.asarray(np.array(res_obs.taus[t]), jnp.int32))
        eng.append(float(m["loss"]))
    np.testing.assert_allclose(res_obs.losses, eng, rtol=1e-5, atol=1e-5)


def test_stage_index_source_pins_corrections_under_fixed_delays(setup):
    """Under FixedDelay at K=1 the observed steady-state schedule IS Eq. 5 and
    delay_momentum(tau_i) == stage_momentum(i): after the warmup ramp the two
    tau sources converge to the same update math, so the variants' losses
    agree tick-for-tick once warmup taus reach steady state — the documented
    'steady-state special case' of DESIGN.md §10."""
    import dataclasses as dc

    cfg, params, batch = setup
    m_obs = get_method("ours_delay_adaptive")
    m_idx = dc.replace(m_obs, name="x", tau_source="stage_index")
    losses = {}
    for tag, meth in (("obs", m_obs), ("idx", m_idx)):
        rt = EventRuntime(AsyncTrainer(cfg, _ecfg(), meth))
        rt.init_from_params(params)
        losses[tag] = rt.run(lambda t: batch, 12).losses
    # warmup differs (observed tau ramps 0 -> tau_i; the stage-index variant
    # applies full Eq. 13 momentum from tick 0) ...
    assert not np.allclose(losses["obs"][:6], losses["idx"][:6], atol=1e-7)
    # ... and the trajectories stay close overall: same steady-state math,
    # only the short warmup keying differs
    np.testing.assert_allclose(losses["obs"], losses["idx"], atol=0.1)


def test_dynamic_taus_length_validated(setup):
    cfg, params, batch = setup
    tr = AsyncTrainer(cfg, _ecfg(max_dynamic_delay=2), "ours_lr")
    s = tr.init_from_params(params)
    with pytest.raises(ValueError, match="length-4"):
        tr.step(s, batch, taus=jnp.zeros((3,), jnp.int32))


# ---- trace calibration: record -> save -> from_json -> replay ---------------


def test_trace_record_save_replay_roundtrip(setup, tmp_path):
    """The calibration loop (DESIGN.md §10): latencies recorded from a real
    run (RuntimeCfg.record_trace — the --record-trace hook) save in the
    TraceDelay JSON schema, load back via from_json bit-identically (schema
    stability), and replay DETERMINISTICALLY — the same file drives identical
    schedules through the compute-free simulator and the full event runtime."""
    cfg, params, batch = setup
    rt = EventRuntime(AsyncTrainer(cfg, _ecfg(), "ours"),
                      RuntimeCfg(record_trace=True))
    rt.init_from_params(params)
    rt.run(lambda t: batch, 6)
    rec = rt.recorder
    assert len(rec) == 2 * 4 * 6  # fwd+bwd x stages x microbatches
    path = str(tmp_path / "trace.json")
    rec.save(path)

    td = TraceDelay.from_json(path)
    assert td.traces == rec.traces()  # JSON roundtrip is exact
    assert td.traces["version"] == 1
    assert (td.traces["P"], td.traces["K"]) == (4, 1)
    for op in ("fwd", "bwd", "comm"):
        assert len(td.traces[op]) == 4  # one row per stage
    assert all(len(row) == 6 for row in td.traces["fwd"])
    assert all(x > 0 for row in td.traces["bwd"] for x in row)
    # replay serves the measured value for the measured microbatch
    assert td.latency(2, "fwd", 3) == td.traces["fwd"][2][3]
    assert isinstance(make_delay_model(f"trace:{path}"), TraceDelay)

    sim1 = simulate_schedule(P=4, n_ticks=6, delay_model=f"trace:{path}")
    sim2 = simulate_schedule(P=4, n_ticks=6, delay_model=f"trace:{path}")
    assert sim1 == sim2  # deterministic replay, field for field
    rt2 = EventRuntime(AsyncTrainer(cfg, _ecfg(), "ours"),
                       RuntimeCfg(delay_model=f"trace:{path}"))
    rt2.init_from_params(params)
    res2 = rt2.run(lambda t: batch, 6)
    assert [tuple(t) for t in sim1["taus"]] == [tuple(t) for t in res2.taus]
    assert tuple(sim1["max_stash"]) == res2.max_stash
    np.testing.assert_allclose(sim1["makespan"], res2.makespan, rtol=1e-9)


def test_trace_recorder_empty_stage_rows_replayable():
    """A recorder that saw no ops for a stage still emits a replayable row
    (MIN_LATENCY placeholder) instead of an empty list TraceDelay would
    index-error on."""
    rec = TraceRecorder(2)
    rec.add(0, "fwd", 0, 0.5)
    td = rec.to_delay()
    assert td.latency(0, "fwd", 0) == 0.5
    assert td.latency(1, "fwd", 0) > 0.0  # placeholder, not a crash


# ---- stochastic delays: dynamic tau + stash-depth contract ------------------


@pytest.mark.parametrize("dm,in_flight", [
    (JitterDelay(sigma=0.5, seed=3), None),
    (StragglerDelay(slow_stage=1, factor=5.0), 8),
    (make_delay_model("straggler:0,3.0,6"), 6),
])
def test_stochastic_delays_dynamic_tau(setup, dm, in_flight):
    cfg, params, batch = setup
    rt = EventRuntime(AsyncTrainer(cfg, _ecfg(), "ours"),
                      RuntimeCfg(delay_model=dm, in_flight=in_flight))
    rt.init_from_params(params)
    res = rt.run(lambda t: batch, 14)
    assert np.isfinite(res.losses).all()
    # stash depth == max observed delay + 1, per stage, never beyond capacity
    caps = rt.caps
    for s in range(4):
        assert res.max_stash[s] == int(res.max_tau_obs[s]) + 1
        assert res.max_stash[s] <= caps[s]
    # delays actually moved (a straggler/jitter run is not the fixed schedule)
    flat = {tuple(t) for t in res.taus}
    assert len(flat) > 1


def test_straggler_grows_observed_delay_beyond_schedule(setup):
    cfg, params, batch = setup
    rt = EventRuntime(AsyncTrainer(cfg, _ecfg(), "ours"),
                      RuntimeCfg(delay_model=StragglerDelay(slow_stage=1, factor=5.0),
                                 in_flight=8))
    rt.init_from_params(params)
    res = rt.run(lambda t: batch, 14)
    assert res.max_tau_obs[0] > delay.max_delay(4, 1)  # beyond Eq. 5's tau_1
    assert res.max_stash[0] == int(res.max_tau_obs[0]) + 1


def test_grad_accum_runtime_runs(setup):
    """K=2 accumulation: per-stage grads accumulate over K microbatches before
    each update; observed taus shrink toward Eq. 5's 1/K scaling."""
    cfg, params, _ = setup
    toks = jax.random.randint(jax.random.PRNGKey(9), (2, 4, 33), 0, cfg.vocab_size)
    batch = {"tokens": toks[..., :-1], "labels": toks[..., 1:]}
    ecfg = _ecfg(update_interval=2)
    rt = EventRuntime(AsyncTrainer(cfg, ecfg, "ours"))
    rt.init_from_params(params)
    res = rt.run(lambda t: batch, 8)
    assert np.isfinite(res.losses).all()
    want = delay.stage_delays(4, 2)
    got = res.taus[-1]
    assert all(abs(g - w) <= 1.0 for g, w in zip(got, want))
    assert all(got[s] >= got[s + 1] for s in range(3))


# ---- elastic churn: leave/join as first-class runtime events ----------------


def test_churn_rejoin_completes_without_drain_and_matches_restage(setup):
    """The rejoin equivalence + liveness contract (ISSUE 4, DESIGN.md §9):
    with a scheduled leave/join window under FixedDelay the event runtime
    completes the whole horizon in ONE run() call — no drain, no restage. The
    outage is paid in stash depth and observed tau (peak stash == max observed
    tau + 1 still holds; upstream tau grows past the Eq. 5 schedule), and once
    the stale backlog flushes the loss trajectory matches a drain +
    checkpoint.restage baseline within the documented tolerance (per-tick
    |dloss| < 0.4, window mean < 0.2 on the reduced config — the two runs pay
    the same outage through different mechanisms, memory vs a barrier, and
    re-converge; they are not bit-equal by design)."""
    from repro.checkpoint import checkpoint as ckpt

    cfg, params, batch = setup
    ecfg = _ecfg()
    bf = lambda t: batch
    n_total = 24
    # stage 2 leaves at t=18 (~tick 6 at fwd=1/bwd=2) and rejoins 3 ticks later
    rt = EventRuntime(AsyncTrainer(cfg, ecfg, "ours"), RuntimeCfg(churn="2,18,9"))
    rt.init_from_params(params)
    res = rt.run(bf, n_total)  # liveness: a single un-drained run completes

    assert res.outage_time[2] == pytest.approx(9.0)
    # the outage is absorbed as observed staleness, not a barrier: upstream
    # kept forwarding (elastic caps), so tau grew beyond the Eq. 5 schedule
    # and the dead stage's forward mailbox buffered the run-ahead
    assert res.max_tau_obs[0] > delay.max_delay(4, 1)
    assert res.mailbox_high_water[2][0] > 1
    for s in range(4):
        assert res.max_stash[s] == int(res.max_tau_obs[s]) + 1

    # drain + restage baseline: stop at tick 6, reset staleness history the
    # pre-churn way, continue for the remaining ticks on the same batches
    tr_pre = AsyncTrainer(cfg, ecfg, "ours")
    rt_pre = EventRuntime(tr_pre)
    rt_pre.init_from_params(params)
    pre = rt_pre.run(bf, 6)
    tr_post = AsyncTrainer(cfg, ecfg, "ours")
    tr_post.init_from_params(params)
    restaged = ckpt.restage(rt_pre.export_state(include_runtime=False),
                            tr_pre, tr_post)
    rt_post = EventRuntime(tr_post)
    rt_post.init_from_state(restaged)
    post = rt_post.run(bf, n_total - 6)
    base = list(pre.losses) + list(post.losses)

    diff = np.abs(np.asarray(res.losses) - np.asarray(base))
    np.testing.assert_allclose(diff[:6], 0.0, atol=1e-6)  # identical pre-leave
    flushed = 17  # rejoin tick (~9) + max observed tau: stale backlog cleared
    assert diff[flushed:].max() < 0.4
    assert diff[flushed:].mean() < 0.2


def test_zero_length_outage_is_bitwise_noop(setup):
    """A zero-duration outage exercises the full churn path (leave + join
    events, membership bookkeeping) and must be a no-op: the RuntimeResult is
    bitwise identical to today's churn-free runtime."""
    cfg, params, batch = setup
    ecfg = _ecfg()
    rt0 = EventRuntime(AsyncTrainer(cfg, ecfg, "ours"))
    rt0.init_from_params(params)
    r0 = rt0.run(lambda t: batch, 8)
    rtz = EventRuntime(AsyncTrainer(cfg, ecfg, "ours"),
                       RuntimeCfg(churn=ChurnModel((Outage(1, 5.0, 0.0),))))
    rtz.init_from_params(params)
    rz = rtz.run(lambda t: batch, 8)
    assert rz.losses == r0.losses  # float-exact, not just allclose
    assert rz.taus == r0.taus
    assert rz.makespan == r0.makespan
    assert rz.utilization == r0.utilization
    assert rz.max_stash == r0.max_stash
    assert rz.max_tau_obs == r0.max_tau_obs
    assert rz.mailbox_high_water == r0.mailbox_high_water
    assert rz.outage_time == (0.0,) * 4
    assert rz.metrics == r0.metrics
    assert rz.timeline is None and r0.timeline is None


def test_simulate_schedule_matches_runtime_under_churn(setup):
    """The compute-free planner implements the SAME membership rules: under a
    churn window (on top of jitter) it reproduces the full runtime's observed
    taus, stash/mailbox high-water, outage accounting, and makespan."""
    cfg, params, batch = setup
    dm = JitterDelay(sigma=0.4, seed=5)
    churn = "1,12,8"
    rt = EventRuntime(AsyncTrainer(cfg, _ecfg(), "ours"),
                      RuntimeCfg(delay_model=dm, churn=churn))
    rt.init_from_params(params)
    res = rt.run(lambda t: batch, 14)
    sim = simulate_schedule(P=4, K=1, n_ticks=14, delay_model=dm, churn=churn)
    assert [tuple(t) for t in sim["taus"]] == [tuple(t) for t in res.taus]
    assert tuple(sim["max_stash"]) == res.max_stash
    assert tuple(sim["max_tau_obs"]) == res.max_tau_obs
    assert sim["outage_time"] == res.outage_time
    assert sim["mailbox_high_water"] == res.mailbox_high_water
    np.testing.assert_allclose(sim["makespan"], res.makespan, rtol=1e-9)


def test_sim_churn_outage_paid_in_memory_and_tau():
    """Schedule-level churn story: a bounded slack caps the upstream run-ahead;
    unbounded slack converts the whole outage into stash/mailbox depth."""
    base = simulate_schedule(P=4, n_ticks=40)
    out = simulate_schedule(P=4, n_ticks=40, churn="2,30,30")
    assert out["outage_time"] == (0.0, 0.0, 30.0, 0.0)
    assert out["makespan"] > base["makespan"]
    assert max(out["max_tau_obs"]) > max(base["max_tau_obs"])
    assert out["max_stash"][0] == out["max_tau_obs"][0] + 1
    # dead stage's forward mailbox buffered the upstream run-ahead
    assert out["mailbox_high_water"][2][0] > base["mailbox_high_water"][2][0]
    # bounded slack: stage 0's stash may only exceed its 1F1B cap by slack
    slacked = simulate_schedule(P=4, n_ticks=40,
                                churn=ChurnModel((Outage(2, 30.0, 30.0),), slack=2))
    assert slacked["max_stash"][0] <= 4 + 2
    assert slacked["max_stash"][0] < out["max_stash"][0]


def test_churn_spans_chunked_runs_without_refiring(setup):
    """Churn windows live on the absolute simulated clock: chunked run() calls
    (the checkpoint cadence in launch/train.py) must fire each outage exactly
    once, and a window beyond the current chunk just waits its turn."""
    cfg, params, batch = setup
    bf = lambda t: batch
    rt = EventRuntime(AsyncTrainer(cfg, _ecfg(), "ours"),
                      RuntimeCfg(churn="1,40,6"))
    rt.init_from_params(params)
    r1 = rt.run(bf, 4)  # drains around t~21: outage not reached yet
    r2 = rt.run(bf, 8)  # outage fires inside this chunk
    r3 = rt.run(bf, 4)  # must NOT re-fire
    assert r1.outage_time == (0.0,) * 4
    assert r2.outage_time[1] == pytest.approx(6.0)
    assert r3.outage_time == (0.0,) * 4
    assert np.isfinite(r1.losses + r2.losses + r3.losses).all()


# ---- drain invariants + mailbox memory --------------------------------------


def test_drain_invariants_and_mailbox_caps_under_jitter(setup):
    """At drain every stage's stash, carries, and both mailboxes are empty, and
    the reported mailbox high-water is tied to the in-flight caps: activations
    buffered at stage s are bounded by stage s-1's cap, cotangents at stage s
    by its own cap (stage 0's forward box is the preloaded data source)."""
    cfg, params, batch = setup
    rt = EventRuntime(AsyncTrainer(cfg, _ecfg(), "ours"),
                      RuntimeCfg(delay_model=JitterDelay(sigma=0.6, seed=9)))
    rt.init_from_params(params)
    res = rt.run(lambda t: batch, 12)
    caps = rt.caps
    for st in rt._stages:
        assert not st.stash and not st.carries
        assert len(st.fwd_box) == 0 and len(st.bwd_box) == 0
        assert st.acc_n == 0 and st.in_flight == 0
    for s in range(1, 4):
        assert 1 <= res.mailbox_high_water[s][0] <= caps[s - 1]
    for s in range(4):
        assert 1 <= res.mailbox_high_water[s][1] <= caps[s]
    assert res.mailbox_high_water[0][0] == 12  # source box: whole run preloaded


# ---- spec parsing (delay + churn grammars) ----------------------------------


def test_delay_spec_roundtrip():
    m = make_delay_model("fixed:2.0,3.0,0.5")
    assert (m.fwd, m.bwd, m.comm) == (2.0, 3.0, 0.5)
    j = make_delay_model("jitter:0.4", seed=7)
    assert isinstance(j, JitterDelay) and j.sigma == 0.4 and j.seed == 7
    j2 = make_delay_model("jitter:0.4,2.0,4.0,0.25", seed=3)
    assert (j2.sigma, j2.fwd, j2.bwd, j2.comm, j2.seed) == (0.4, 2.0, 4.0, 0.25, 3)
    s = make_delay_model("straggler:1,5.0,6")
    assert (s.slow_stage, s.factor, s.period) == (1, 5.0, 6)
    o = make_delay_model("outage:2,10,20,8.0")
    assert isinstance(o, OutageDelay)
    assert (o.stage, o.mb_start, o.mb_end, o.factor) == (2, 10, 20, 8.0)
    # the degraded window actually slows the stage's compute ops
    assert o.latency(2, "fwd", 15) == 8.0 and o.latency(2, "fwd", 25) == 1.0
    assert o.latency(1, "fwd", 15) == 1.0


@pytest.mark.parametrize("bad", [
    "warp", "fixed:1,2,3,4", "jitter:0.3,1.0", "jitter:0.3,1.0,2.0",
    "straggler:0,4.0,6,9", "outage:1,2", "outage:1,2,3,4,5", "jitter:0.3,,1,2",
])
def test_delay_spec_rejects_malformed(bad):
    with pytest.raises(ValueError):
        make_delay_model(bad)


def test_churn_spec_roundtrip():
    cm = make_churn_model("churn:1,10,5/2,30,4")
    assert cm.outages == (Outage(1, 10.0, 5.0), Outage(2, 30.0, 4.0))
    assert cm.slack is None
    cm2 = make_churn_model("0,3,0", slack=2)
    assert cm2.outages == (Outage(0, 3.0, 0.0),) and cm2.slack == 2
    # model passthrough + slack override
    cm3 = make_churn_model(cm, slack=1)
    assert cm3.outages == cm.outages and cm3.slack == 1
    for bad in ("churn:1,10", "churn:1,10,5,7", "drop:1,10,5", "1,,5"):
        with pytest.raises(ValueError):
            make_churn_model(bad)
    with pytest.raises(ValueError):
        ChurnModel((Outage(0, -1.0, 5.0),))
    with pytest.raises(ValueError):
        ChurnModel((Outage(0, 1.0, -5.0),))
    with pytest.raises(ValueError):
        make_churn_model("5,1,1").validate(4)  # stage out of range for P=4


# ---- checkpointing ----------------------------------------------------------


def test_runtime_checkpoint_roundtrip(setup, tmp_path):
    """Runtime state (counters in AsyncState.extra['rt']) save/restores exactly;
    the resumed run replays the original trajectory bit-for-bit."""
    from repro.checkpoint import checkpoint as ckpt

    cfg, params, batch = setup
    ecfg = _ecfg()
    batch_fn = lambda t: batch
    rt = EventRuntime(AsyncTrainer(cfg, ecfg, "ours"))
    rt.init_from_params(params)
    rt.run(batch_fn, 4)
    path = str(tmp_path / "rt.npz")
    ckpt.save(path, rt.export_state(), 4)

    rt2 = EventRuntime(AsyncTrainer(cfg, ecfg, "ours"))
    template = rt2.init_from_params(params).export_state()
    restored, meta = ckpt.restore(path, template)
    assert meta["step"] == 4
    rt2.init_from_state(restored)
    assert rt2._u_done == 4
    r1 = rt.run(batch_fn, 4)
    r2 = rt2.run(batch_fn, 4)
    np.testing.assert_array_equal(r1.losses, r2.losses)


def test_simulate_schedule_agrees_with_runtime_under_jitter(setup):
    """The compute-free planner and the real runtime implement ONE discipline:
    under the same keyed stochastic delay model they produce identical observed
    tau schedules and stash high-water marks, event for event."""
    cfg, params, batch = setup
    dm = JitterDelay(sigma=0.6, seed=11)
    rt = EventRuntime(AsyncTrainer(cfg, _ecfg(), "ours"),
                      RuntimeCfg(delay_model=dm))
    rt.init_from_params(params)
    res = rt.run(lambda t: batch, 12)
    sim = simulate_schedule(P=4, K=1, n_ticks=12, delay_model=dm)
    assert [tuple(t) for t in sim["taus"]] == [tuple(t) for t in res.taus]
    assert tuple(sim["max_stash"]) == res.max_stash
    assert tuple(sim["max_tau_obs"]) == res.max_tau_obs
    np.testing.assert_allclose(sim["makespan"], res.makespan, rtol=1e-9)


def test_jit_engine_checkpoint_resumes_under_event_runtime(setup, tmp_path):
    """Cross-path resume: a checkpoint written by the jit-engine loop (no
    extra['rt'] counters) restores into the event runtime via the
    counter-free template, exactly as launch/train.py --runtime event does."""
    from repro.checkpoint import checkpoint as ckpt

    cfg, params, batch = setup
    ecfg = _ecfg()
    tr = AsyncTrainer(cfg, ecfg, "ours")
    s = tr.init_from_params(params)
    step = tr.jit_step(donate=False)
    for _ in range(3):
        s, _ = step(s, batch)
    ckpt.save_step(str(tmp_path), s, 3)

    rt = EventRuntime(AsyncTrainer(cfg, ecfg, "ours"))
    rt.init_from_params(params)
    path, _ = ckpt.latest(str(tmp_path))
    restored, meta = ckpt.restore(path, rt.export_state(include_runtime=False))
    assert meta["step"] == 3
    rt.init_from_state(restored)
    assert rt._u_done == 3
    res = rt.run(lambda t: batch, 3)
    assert np.isfinite(res.losses).all()


def test_runtime_state_loads_into_jit_engine(setup):
    """export_state(include_runtime=False) is a plain engine AsyncState: the
    jit engine resumes from an event-runtime run (staleness history re-warmed,
    like checkpoint.restage on elastic events)."""
    cfg, params, batch = setup
    ecfg = _ecfg()
    rt = EventRuntime(AsyncTrainer(cfg, ecfg, "ours"))
    rt.init_from_params(params)
    rt.run(lambda t: batch, 5)
    state = rt.export_state(include_runtime=False)
    tr = AsyncTrainer(cfg, ecfg, "ours")
    tr.init_from_params(params)  # builds stage fns
    step = tr.jit_step(donate=False)
    assert int(state.step) == 5
    for _ in range(3):
        state, m = step(state, batch)
        assert np.isfinite(float(m["loss"]))


# ---- K>1 per-microbatch stash replay: the event/engine equivalence gap ------


def _accum_batch(cfg, K, seed=9, mb=2, seq=33):
    toks = jax.random.randint(jax.random.PRNGKey(seed), (K, mb, seq), 0,
                              cfg.vocab_size)
    return {"tokens": toks[..., :-1], "labels": toks[..., 1:]}


@pytest.mark.parametrize("method", ["ours", "pipedream"])
def test_k4_grouped_replay_equals_event_runtime(setup, method):
    """The tentpole contract at K=4: the engine's default per-microbatch
    schedule (delay.stage_mb_delays broadcast as an int32 [P, K] matrix)
    replays each microbatch at its own stashed point and reproduces the event
    runtime tick-for-tick under FixedDelay — loss trajectories within the
    standard equivalence tolerance and matching final parameters. The OLD
    single-point idealization (all K microbatches at Eq. 5's scalar, a [P]
    vector) demonstrably does NOT satisfy this: the gap was real."""
    cfg, params, _ = setup
    K, n = 4, 5
    batch = _accum_batch(cfg, K)
    ecfg = _ecfg(update_interval=K)

    rt = EventRuntime(AsyncTrainer(cfg, ecfg, method))
    rt.init_from_params(params)
    res = rt.run(lambda t: batch, n)
    # runtime steady state is exactly the static per-microbatch schedule
    assert res.tau_groups[-1] == tuple(
        tuple(float(x) for x in g) for g in delay.stage_mb_delays(4, K))

    tr = AsyncTrainer(cfg, ecfg, method)
    s = tr.init_from_params(params)
    step = tr.jit_step(donate=False)
    eng = []
    for _ in range(n):
        s, m = step(s, batch)  # taus=None -> static [P, K] replay
        eng.append(float(m["loss"]))
    np.testing.assert_allclose(res.losses, eng, rtol=1e-5, atol=1e-5)
    rt_params = rt.export_state(include_runtime=False).params
    for a, b in zip(jax.tree.leaves(s.params), jax.tree.leaves(rt_params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)

    if method != "ours":
        return  # the gap demonstration below needs only one method's compile
    tr2 = AsyncTrainer(cfg, ecfg, method)
    s2 = tr2.init_from_params(params)
    step2 = tr2.jit_step(donate=False)
    vec = jnp.asarray(delay.stage_delays(4, K), jnp.int32)  # legacy Eq. 5 [P]
    legacy = []
    for _ in range(n):
        s2, m = step2(s2, batch, vec)
        legacy.append(float(m["loss"]))
    assert np.abs(np.asarray(legacy) - np.asarray(res.losses)).max() > 1e-4


def test_k2_observed_tau_group_matrix_drives_engine(setup):
    """Dynamic half of the tentpole: under a straggler the runtime's recorded
    per-microbatch tau groups (RuntimeResult.tau_groups) contain NON-uniform
    groups whose mean is fractional — information the old scalar feedback
    destroyed. Fed back as int32 [P, K] matrices, the engine reproduces the
    observed-staleness-adaptive trajectory; fed the rounded per-stage mean
    vector (the best the legacy path could do), it measurably does not."""
    cfg, params, _ = setup
    K, n = 2, 10
    batch = _accum_batch(cfg, K, seed=11)
    ecfg = _ecfg(update_interval=K, max_dynamic_delay=6)
    dm = StragglerDelay(slow_stage=1, factor=5.0)

    m_obs = get_method("ours_delay_adaptive")
    assert m_obs.tau_source == "observed" and m_obs.tau_consuming
    rt = EventRuntime(AsyncTrainer(cfg, ecfg, m_obs),
                      RuntimeCfg(delay_model=dm, in_flight=8))
    rt.init_from_params(params)
    res = rt.run(lambda t: batch, n)
    # precondition: the mean really is lossy on this schedule
    assert any(len(set(g)) > 1 for row in res.tau_groups for g in row)
    assert any(not float(x).is_integer() for row in res.taus for x in row)
    # groups and means are consistent views of one record
    for row, grp in zip(res.taus, res.tau_groups):
        for mean_s, g in zip(row, grp):
            assert len(g) == K and abs(mean_s - np.mean(g)) < 1e-9

    tr = AsyncTrainer(cfg, ecfg, m_obs)
    s = tr.init_from_params(params)
    step = tr.jit_step(donate=False)
    eng = []
    for t in range(n):
        mat = jnp.asarray(np.array(res.tau_groups[t]), jnp.int32)  # [P, K]
        s, m = step(s, batch, mat)
        eng.append(float(m["loss"]))
    np.testing.assert_allclose(res.losses, eng, rtol=1e-5, atol=1e-5)

    tr2 = AsyncTrainer(cfg, ecfg, m_obs)
    s2 = tr2.init_from_params(params)
    step2 = tr2.jit_step(donate=False)
    legacy = []
    for t in range(n):
        vec = jnp.asarray(np.rint(np.array(res.taus[t])), jnp.int32)  # [P]
        s2, m = step2(s2, batch, vec)
        legacy.append(float(m["loss"]))
    assert np.abs(np.asarray(legacy) - np.asarray(res.losses)).max() > 1e-4


def test_k2_churn_chunked_runs_carry_loss_groups(setup):
    """Partial K-group bookkeeping across run() calls: with churn windows
    straddling chunk boundaries at K=2, chunked execution still emits exactly
    one complete K-group per update — nothing dropped, nothing double-counted,
    the aggregation dicts fully drained after every chunk — and a repeat run
    with the same chunking reproduces the losses and tau groups exactly."""
    cfg, params, _ = setup
    K, n = 2, 8
    batch = _accum_batch(cfg, K, seed=13)
    bf = lambda t: batch
    ecfg = _ecfg(update_interval=K)

    def chunked():
        rt = EventRuntime(AsyncTrainer(cfg, ecfg, "ours"),
                          RuntimeCfg(churn="2,10,6"))
        rt.init_from_params(params)
        parts = [rt.run(bf, c) for c in (3, 3, 2)]
        # pop-on-emit left nothing behind: every group was completed and
        # consumed by the drain of the chunk that finished it
        assert rt._losses == {} and rt._taus_by_u == {}
        assert rt._tau_groups_by_u == {}
        return rt, parts

    rt1, parts1 = chunked()
    rt2, parts2 = chunked()
    losses = [l for p in parts1 for l in p.losses]
    groups = [g for p in parts1 for g in p.tau_groups]
    assert len(losses) == n and len(groups) == n
    assert all(len(g) == K for row in groups for g in row)
    assert np.isfinite(losses).all()
    # the window fired exactly once across the chunk sequence, stage 2 only
    outage = np.sum([p.outage_time for p in parts1], axis=0)
    assert outage[2] == pytest.approx(6.0)
    assert outage[0] == outage[1] == outage[3] == 0.0
    np.testing.assert_array_equal(losses, [l for p in parts2 for l in p.losses])
    assert groups == [g for p in parts2 for g in p.tau_groups]


def test_restage_roundtrip_across_accum_groups_and_stash_depths(setup):
    """checkpoint.restage across trainers with different update_interval K
    (hence different per-microbatch tau schedules and different stash ring
    depths): stashes re-derive at the target geometry instead of being copied,
    params/optimizer survive the K=2 -> K=4 -> K=2 roundtrip exactly, and the
    restaged state trains under the new trainer's event runtime."""
    from repro.checkpoint import checkpoint as ckpt

    cfg, params, _ = setup
    ecfg2 = _ecfg(update_interval=2)
    ecfg4 = _ecfg(update_interval=4)
    tr2 = AsyncTrainer(cfg, ecfg2, "ours")
    tr4 = AsyncTrainer(cfg, ecfg4, "ours")
    # geometries really differ: stage 0 ring is deeper at K=2 than K=4
    assert tr2._stash_depth(0) != tr4._stash_depth(0)

    rt = EventRuntime(AsyncTrainer(cfg, ecfg2, "ours"))
    rt.init_from_params(params)
    rt.run(lambda t: _accum_batch(cfg, 2, seed=17), 4)
    s2 = rt.export_state()

    s4 = ckpt.restage(s2, tr2, tr4)
    for i in range(4):
        depth = jax.tree.leaves(s4.stashes[i])[0].shape[0]
        assert depth == tr4._stash_depth(i)
        assert depth == max(max(tr4.taus_mb[i]), tr4.taus[i]) + 1
    for a, b in zip(jax.tree.leaves(tr2.merge_params(s2)),
                    jax.tree.leaves(tr4.merge_params(s4))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    rt4 = EventRuntime(tr4)
    rt4.init_from_state(s4)
    res4 = rt4.run(lambda t: _accum_batch(cfg, 4, seed=18), 2)
    assert np.isfinite(res4.losses).all()

    s2b = ckpt.restage(s4, tr4, AsyncTrainer(cfg, ecfg2, "ours"))
    for a, b in zip(jax.tree.leaves(tr2.merge_params(s2)),
                    jax.tree.leaves(tr2.merge_params(s2b))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(s2b.step) == int(s2.step)


def test_trace_recorder_group_warmup_discard(setup):
    """Microbatch-aware recorder warmup (TraceRecorder.discard_warmup): the
    boundary is max-recorded-mb+1 rounded UP to a whole K-group; straggling
    adds for pre-boundary microbatches are ignored by INDEX, not by object
    swap; and through the runtime at K=2 the post-reset trace holds exactly
    the post-warmup groups with the boundary recorded in the schema."""
    rec = TraceRecorder(P=2, K=4)
    rec.add(0, "fwd", 0, 1.0)
    rec.add(1, "bwd", 1, 2.0)
    assert rec.discard_warmup() == 4  # 2 mbs seen -> rounds up to one K-group
    assert len(rec) == 0
    rec.add(0, "fwd", 3, 5.0)   # straggling warmup bwd/fwd: ignored by index
    assert len(rec) == 0
    rec.add(0, "fwd", 4, 5.0)   # first post-boundary sample sticks
    assert len(rec) == 1
    assert rec.traces()["warmup_mb"] == 4
    assert rec.traces()["fwd"][0] == [5.0]

    cfg, params, _ = setup
    K = 2
    batch = _accum_batch(cfg, K, seed=19)
    rt = EventRuntime(AsyncTrainer(cfg, _ecfg(update_interval=K), "ours"),
                      RuntimeCfg(record_trace=True))
    rt.init_from_params(params)
    rec0 = rt.recorder
    rt.run(lambda t: batch, 1)
    rt.reset_recorder()
    assert rt.recorder is rec0  # reset keeps identity: late adds hit the same
    assert rt.recorder.warmup_mb == K
    rt.run(lambda t: batch, 3)
    assert len(rt.recorder) == 2 * 4 * K * 3  # fwd+bwd x P x post-warmup mbs
    td = rt.recorder.traces()
    assert td["warmup_mb"] == K and td["K"] == K
    assert all(len(row) == K * 3 for row in td["fwd"] + td["bwd"])
