"""Unit + property tests: delay model (Eq. 5) and the weight-stash ring buffers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import delay, stash


# ---- Eq. 5 ------------------------------------------------------------------


def test_delay_formula_matches_paper():
    # tau_i = floor((2(P-i)+1)/(2K))
    assert delay.stage_delays(8, 1) == (7, 6, 5, 4, 3, 2, 1, 0)
    assert delay.stage_delay(8, 8, 1) == 0  # last stage: no staleness
    assert delay.stage_delay(1, 8, 1) == 7


@given(P=st.integers(1, 64), K=st.integers(1, 8))
def test_delay_properties(P, K):
    taus = delay.stage_delays(P, K)
    assert len(taus) == P
    assert all(taus[i] >= taus[i + 1] for i in range(P - 1))  # earlier >= later
    assert taus[-1] == 0 if K >= 1 else True
    assert all(t == int(np.floor((2 * (P - i) + 1) / (2 * K)))
               for i, t in zip(range(1, P + 1), taus))
    # larger update interval K -> smaller delay
    taus2 = delay.stage_delays(P, K + 1)
    assert all(a >= b for a, b in zip(taus, taus2))


# ---- stash ring buffer -------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(depth=st.integers(1, 6), n_steps=st.integers(1, 20), tau=st.integers(0, 5))
def test_stash_replays_history(depth, n_steps, tau):
    if tau >= depth:
        return  # ring must be at least tau+1 deep
    tree = {"a": jnp.zeros((3,)), "b": jnp.ones((2, 2))}
    buf = stash.init_stash(tree, depth)
    history = [tree]
    for t in range(n_steps):
        new = jax.tree.map(lambda x: x + t + 1.0, tree)
        buf = stash.push(buf, new, jnp.asarray(t + 1))
        history.append(new)
    t_now = n_steps
    want_t = max(t_now - tau, 0)
    # entries older than the ring depth are overwritten; only valid for recent tau
    if t_now - tau >= t_now - (depth - 1):
        got = stash.get(buf, jnp.asarray(t_now), tau)
        want = history[want_t] if want_t < len(history) else history[-1]
        for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
            np.testing.assert_allclose(g, w)


@settings(max_examples=20, deadline=None)
@given(P=st.integers(1, 8), K=st.integers(1, 4), seed=st.integers(0, 99999))
def test_dynamic_tau_stash_replay_matches_eq7(P, K, seed):
    """Eq. 7 generalized to ARBITRARY dynamic delay sequences: with one ring per
    stage (depth = max schedule delay + 1, pushes every tick like the engine),
    get(t, tau_i^t) returns EXACTLY the forward point pushed at tick t - tau_i^t
    for any per-tick tau vector bounded by the ring depth — the staggered stale
    weights w^{t-tau_1}, ..., w^{t-tau_P}, warmup-clamped to the init point."""
    rng = np.random.default_rng(seed)
    depth = delay.max_delay(P, K) + 1
    base = {"w": jnp.arange(3.0), "b": {"x": jnp.ones((2, 2))}}

    def version(v):  # distinct, recognisable content per pushed tick
        return jax.tree.map(lambda x: x + 10.0 * v, base)

    bufs = [stash.init_stash(base, depth) for _ in range(P)]
    n_steps = int(rng.integers(3, 3 * depth + 4))
    for t in range(n_steps):
        # an arbitrary dynamic tau vector for this tick (any value the ring can
        # hold — not required to follow the Eq. 5 schedule or be monotone)
        tau_t = rng.integers(0, depth, size=P)
        for i in range(P):
            got = stash.get(bufs[i], jnp.asarray(t), jnp.asarray(int(tau_t[i])))
            want = version(max(t - int(tau_t[i]), 0))
            for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
                np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
        for i in range(P):
            bufs[i] = stash.push(bufs[i], version(t + 1), jnp.asarray(t + 1))


def test_validate_taus():
    assert delay.validate_taus((3, 2, 1, 0), 4) == (3, 2, 1, 0)
    with pytest.raises(ValueError, match="one entry per pipeline stage"):
        delay.validate_taus((1, 0), 4)
    with pytest.raises(ValueError, match=">= 0"):
        delay.validate_taus((1, -1), 2)


def test_depth_for():
    assert stash.depth_for(0) == 1
    assert stash.depth_for(7) == 8


def test_stash_dtype_cast():
    tree = {"w": jnp.ones((4,), jnp.float32)}
    buf = stash.init_stash(tree, 2, dtype=jnp.bfloat16)
    assert jax.tree.leaves(buf)[0].dtype == jnp.bfloat16
    out = stash.get(buf, jnp.asarray(0), 0, like=tree)
    assert jax.tree.leaves(out)[0].dtype == jnp.float32


# ---- per-microbatch schedule (stage_mb_delays) -------------------------------


def test_stage_mb_delays_known_values():
    # P=4, K=2: mb 0 of each group is staler than Eq. 5's scalar; mb K-1 IS it
    assert delay.stage_mb_delays(4, 2) == ((2, 1), (1, 1), (1, 0), (0, 0))
    assert delay.stage_mb_delays(4, 1) == ((3,), (2,), (1,), (0,))
    assert delay.max_mb_delay(4, 2) == 2
    assert delay.max_mb_delay(8, 3) == 3  # ceil(7/3) > floor(15/6) = 2


@given(P=st.integers(1, 32), K=st.integers(1, 8))
def test_stage_mb_delay_group_properties(P, K):
    mb = delay.stage_mb_delays(P, K)
    taus = delay.stage_delays(P, K)
    assert len(mb) == P and all(len(row) == K for row in mb)
    for i, row in enumerate(mb, start=1):
        # Eq. 5's scalar is exactly the LAST microbatch of the group
        assert row[-1] == delay.stage_delay(i, P, K) == taus[i - 1]
        # within a group staleness is monotone non-increasing in k
        assert all(row[k] >= row[k + 1] for k in range(K - 1))
        # closed form == ceil((P - i - k)/K) clamped at 0
        assert all(row[k] == max(-((i + k - P) // K), 0) for k in range(K))
    # across stages: earlier stages are staler, per microbatch position
    for k in range(K):
        col = [row[k] for row in mb]
        assert all(col[s] >= col[s + 1] for s in range(P - 1))
    # the group maximum is the ring-depth bound
    assert delay.max_mb_delay(P, K) == mb[0][0] == max(max(r) for r in mb)


# ---- stash depth-bound enforcement (oversized-tau regression) ----------------


def test_stash_get_oversized_tau_raises():
    """Regression (ISSUE 6 satellite): an out-of-range concrete tau used to
    silently alias a NEWER ring slot via mod wraparound; it must raise."""
    tree = {"w": jnp.arange(3.0)}
    buf = stash.init_stash(tree, 3)
    for t in range(1, 5):
        buf = stash.push(buf, jax.tree.map(lambda x: x + 10.0 * t, tree), t)
    with pytest.raises(ValueError, match="outside ring depth"):
        stash.get(buf, jnp.asarray(4), 3)  # depth 3: valid delays are 0..2
    with pytest.raises(ValueError, match="outside ring depth"):
        stash.get(buf, jnp.asarray(4), -1)
    with pytest.raises(ValueError, match="outside ring depth"):
        stash.get_group(buf, jnp.asarray(4), [0, 3])


def test_stash_get_traced_oversized_tau_saturates():
    """A TRACED oversized tau cannot raise at trace time; it saturates at the
    oldest entry (depth - 1) instead of wrapping around to a fresher slot."""
    tree = {"w": jnp.arange(3.0)}
    buf = stash.init_stash(tree, 3)
    for t in range(1, 5):
        buf = stash.push(buf, jax.tree.map(lambda x: x + 10.0 * t, tree), t)

    get = jax.jit(lambda b, t, tau: stash.get(b, t, tau))
    oldest = get(buf, jnp.asarray(4), jnp.asarray(2))
    sat = get(buf, jnp.asarray(4), jnp.asarray(5))  # 5 > depth-1: saturate
    for a, b in zip(jax.tree.leaves(sat), jax.tree.leaves(oldest)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the pre-fix behaviour read slot (4 - 5) mod 3 == slot 2 == the NEWEST
    # entry (pushed at t=4); saturation must not return that fresher point
    newest = get(buf, jnp.asarray(4), jnp.asarray(0))
    assert not np.allclose(np.asarray(jax.tree.leaves(sat)[0]),
                           np.asarray(jax.tree.leaves(newest)[0]))


def test_stash_get_group_matches_stacked_gets():
    """get_group(t, [tau_0..tau_{K-1}]) == stack of get(t, tau_k): one
    vectorized ring read per stage serves the whole accumulation group."""
    tree = {"w": jnp.arange(3.0), "b": {"x": jnp.ones((2, 2))}}
    depth = 4
    buf = stash.init_stash(tree, depth)
    for t in range(1, 7):
        buf = stash.push(buf, jax.tree.map(lambda x: x + 10.0 * t, tree), t)
    taus = [3, 1, 0, 2]
    grp = stash.get_group(buf, jnp.asarray(6), taus)
    for k, tau in enumerate(taus):
        one = stash.get(buf, jnp.asarray(6), tau)
        for a, b in zip(jax.tree.leaves(jax.tree.map(lambda x: x[k], grp)),
                        jax.tree.leaves(one)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # like= casts every microbatch row
    grp16 = stash.get_group(buf, jnp.asarray(6), taus,
                            like=jax.tree.map(lambda x: x.astype(jnp.bfloat16), tree))
    assert all(x.dtype == jnp.bfloat16 for x in jax.tree.leaves(grp16))
    with pytest.raises(ValueError, match="length-K vector"):
        stash.get_group(buf, jnp.asarray(6), jnp.zeros((2, 2), jnp.int32))


# ---- dynamic-tau validation: [P] vector and [P, K] matrix forms --------------


def test_validate_dynamic_taus_matrix_forms():
    # vector form: scalar entries pass through
    rows = delay.validate_dynamic_taus([3, 2, 1, 0], 4)
    assert rows == [3, 2, 1, 0]
    # matrix form: per-stage K-rows (nested sequences and arrays both work)
    rows = delay.validate_dynamic_taus(((2, 1), (1, 1), (1, 0), (0, 0)), 4, K=2)
    assert [tuple(r) for r in rows] == [(2, 1), (1, 1), (1, 0), (0, 0)]
    arr = jnp.asarray([[2, 1], [1, 1], [1, 0], [0, 0]], jnp.int32)
    rows = delay.validate_dynamic_taus(arr, 4, K=2)
    assert all(r.shape == (2,) for r in rows)
    with pytest.raises(ValueError, match="length-4"):
        delay.validate_dynamic_taus(jnp.zeros((3, 2), jnp.int32), 4, K=2)
    with pytest.raises(ValueError, match="rectangular"):
        delay.validate_dynamic_taus(((2, 1), (1,), (1, 0), (0, 0)), 4, K=2)
    with pytest.raises(ValueError, match="one column per"):
        delay.validate_dynamic_taus(jnp.zeros((4, 3), jnp.int32), 4, K=2)
    with pytest.raises(ValueError, match="scalar"):
        delay.validate_dynamic_taus(3, 4)
