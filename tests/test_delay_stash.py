"""Unit + property tests: delay model (Eq. 5) and the weight-stash ring buffers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import delay, stash


# ---- Eq. 5 ------------------------------------------------------------------


def test_delay_formula_matches_paper():
    # tau_i = floor((2(P-i)+1)/(2K))
    assert delay.stage_delays(8, 1) == (7, 6, 5, 4, 3, 2, 1, 0)
    assert delay.stage_delay(8, 8, 1) == 0  # last stage: no staleness
    assert delay.stage_delay(1, 8, 1) == 7


@given(P=st.integers(1, 64), K=st.integers(1, 8))
def test_delay_properties(P, K):
    taus = delay.stage_delays(P, K)
    assert len(taus) == P
    assert all(taus[i] >= taus[i + 1] for i in range(P - 1))  # earlier >= later
    assert taus[-1] == 0 if K >= 1 else True
    assert all(t == int(np.floor((2 * (P - i) + 1) / (2 * K)))
               for i, t in zip(range(1, P + 1), taus))
    # larger update interval K -> smaller delay
    taus2 = delay.stage_delays(P, K + 1)
    assert all(a >= b for a, b in zip(taus, taus2))


# ---- stash ring buffer -------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(depth=st.integers(1, 6), n_steps=st.integers(1, 20), tau=st.integers(0, 5))
def test_stash_replays_history(depth, n_steps, tau):
    if tau >= depth:
        return  # ring must be at least tau+1 deep
    tree = {"a": jnp.zeros((3,)), "b": jnp.ones((2, 2))}
    buf = stash.init_stash(tree, depth)
    history = [tree]
    for t in range(n_steps):
        new = jax.tree.map(lambda x: x + t + 1.0, tree)
        buf = stash.push(buf, new, jnp.asarray(t + 1))
        history.append(new)
    t_now = n_steps
    want_t = max(t_now - tau, 0)
    # entries older than the ring depth are overwritten; only valid for recent tau
    if t_now - tau >= t_now - (depth - 1):
        got = stash.get(buf, jnp.asarray(t_now), tau)
        want = history[want_t] if want_t < len(history) else history[-1]
        for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
            np.testing.assert_allclose(g, w)


def test_stash_dtype_cast():
    tree = {"w": jnp.ones((4,), jnp.float32)}
    buf = stash.init_stash(tree, 2, dtype=jnp.bfloat16)
    assert jax.tree.leaves(buf)[0].dtype == jnp.bfloat16
    out = stash.get(buf, jnp.asarray(0), 0, like=tree)
    assert jax.tree.leaves(out)[0].dtype == jnp.float32
