"""Serve-path correctness: incremental KV-cache decode vs full-sequence forward,
and sampling reproducibility under fixed PRNG keys."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch import serve
from repro.models import layers as L
from repro.models import lm


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("nanogpt_134m", reduced=True)
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                cfg.vocab_size).astype(jnp.int32)
    return cfg, params, prompt


def _full_last_logits(params, cfg, toks):
    """Reference: full-sequence forward (no caches) -> logits at the last pos."""
    h, _, _ = lm.forward_hidden(params, {"tokens": toks}, cfg)
    h_last = L.rmsnorm_apply(params["final_norm"], h[:, -1:, :], cfg.norm_eps)
    return lm._head_logits(params, cfg, h_last)[:, -1]


def test_greedy_decode_matches_full_forward_argmax(setup):
    """serve_prefill + serve_decode greedy tokens == re-running the FULL
    sequence through the train-path forward and taking argmax each step: the
    incremental KV/SSD-cache path changes cost, not predictions."""
    cfg, params, prompt = setup
    gen = 6
    out = serve.generate(params, cfg, prompt, gen)
    assert out.shape == (2, gen)

    seq = prompt
    ref = []
    for _ in range(gen):
        logits = _full_last_logits(params, cfg, seq)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        ref.append(tok)
        seq = jnp.concatenate([seq, tok], axis=1)
    ref = jnp.concatenate(ref, axis=1)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_prefill_logits_match_full_forward(setup):
    cfg, params, prompt = setup
    logits, _ = lm.serve_prefill(params, {"tokens": prompt}, cfg,
                                 max_len=prompt.shape[1] + 4)
    np.testing.assert_allclose(
        np.asarray(logits[:, -1]),
        np.asarray(_full_last_logits(params, cfg, prompt)),
        rtol=2e-4, atol=2e-5)


def test_generate_stats_separate_compile_from_steady_state(setup):
    """The decode loop donates its cache buffers; the stats split the
    compile-inclusive first token from steady-state throughput."""
    cfg, params, prompt = setup
    out, stats = serve.generate(params, cfg, prompt, 5, return_stats=True)
    assert out.shape == (2, 5)
    for k in ("prefill_s", "first_token_s", "steady_s", "steady_tok_s"):
        assert k in stats and np.isfinite(stats[k]), k
    assert stats["steady_tok_s"] > 0


def test_temperature_sampling_reproducible_under_fixed_key(setup):
    cfg, params, prompt = setup
    kw = dict(temperature=1.0)
    a = serve.generate(params, cfg, prompt, 8, key=jax.random.PRNGKey(7), **kw)
    b = serve.generate(params, cfg, prompt, 8, key=jax.random.PRNGKey(7), **kw)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # a different fixed key is a different (deterministic) draw
    c = serve.generate(params, cfg, prompt, 8, key=jax.random.PRNGKey(8), **kw)
    assert not np.array_equal(np.asarray(a), np.asarray(c))
