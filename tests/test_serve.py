"""Serve-path correctness: incremental KV-cache decode vs full-sequence forward,
and sampling reproducibility under fixed PRNG keys."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import events
from repro.launch import serve
from repro.models import layers as L
from repro.models import lm


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("nanogpt_134m", reduced=True)
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                cfg.vocab_size).astype(jnp.int32)
    return cfg, params, prompt


def _full_last_logits(params, cfg, toks):
    """Reference: full-sequence forward (no caches) -> logits at the last pos."""
    h, _, _ = lm.forward_hidden(params, {"tokens": toks}, cfg)
    h_last = L.rmsnorm_apply(params["final_norm"], h[:, -1:, :], cfg.norm_eps)
    return lm._head_logits(params, cfg, h_last)[:, -1]


def test_greedy_decode_matches_full_forward_argmax(setup):
    """serve_prefill + serve_decode greedy tokens == re-running the FULL
    sequence through the train-path forward and taking argmax each step: the
    incremental KV/SSD-cache path changes cost, not predictions."""
    cfg, params, prompt = setup
    gen = 6
    out = serve.generate(params, cfg, prompt, gen)
    assert out.shape == (2, gen)

    seq = prompt
    ref = []
    for _ in range(gen):
        logits = _full_last_logits(params, cfg, seq)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        ref.append(tok)
        seq = jnp.concatenate([seq, tok], axis=1)
    ref = jnp.concatenate(ref, axis=1)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_prefill_logits_match_full_forward(setup):
    cfg, params, prompt = setup
    logits, _ = lm.serve_prefill(params, {"tokens": prompt}, cfg,
                                 max_len=prompt.shape[1] + 4)
    np.testing.assert_allclose(
        np.asarray(logits[:, -1]),
        np.asarray(_full_last_logits(params, cfg, prompt)),
        rtol=2e-4, atol=2e-5)


def test_generate_stats_separate_compile_from_steady_state(setup):
    """The decode loop donates its cache buffers; the stats split the
    compile-inclusive first token from steady-state throughput."""
    cfg, params, prompt = setup
    out, stats = serve.generate(params, cfg, prompt, 5, return_stats=True)
    assert out.shape == (2, 5)
    for k in ("prefill_s", "first_token_s", "steady_s", "steady_tok_s"):
        assert k in stats and np.isfinite(stats[k]), k
    assert stats["steady_tok_s"] > 0


def test_temperature_sampling_reproducible_under_fixed_key(setup):
    cfg, params, prompt = setup
    kw = dict(temperature=1.0)
    a = serve.generate(params, cfg, prompt, 8, key=jax.random.PRNGKey(7), **kw)
    b = serve.generate(params, cfg, prompt, 8, key=jax.random.PRNGKey(7), **kw)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # a different fixed key is a different (deterministic) draw
    c = serve.generate(params, cfg, prompt, 8, key=jax.random.PRNGKey(8), **kw)
    assert not np.array_equal(np.asarray(a), np.asarray(c))


# ---------------------------------------------------------------------------
# Launcher regressions (the serve-path correctness holes)
# ---------------------------------------------------------------------------


def test_generate_temperature_without_key_raises(setup):
    """Regression: temperature>0 with key=None used to crash deep inside
    jax.random.split(None); now it's a clear up-front ValueError."""
    cfg, params, prompt = setup
    with pytest.raises(ValueError, match="PRNG key"):
        serve.generate(params, cfg, prompt, 2, temperature=0.8)


def test_make_demo_inputs_does_not_reuse_init_key():
    """Regression: the launcher reused one PRNGKey for both init_lm and the
    prompt randint, so the prompt was a deterministic function of the weight
    randomness. The fixed path must differ from the reused-key draw."""
    cfg = get_config("nanogpt_134m", reduced=True)
    _, prompt, k_sample = serve.make_demo_inputs(cfg, seed=3, batch=2,
                                                 prompt_len=16)
    reused = jax.random.randint(jax.random.PRNGKey(3), (2, 16), 0,
                                cfg.vocab_size)
    assert not np.array_equal(np.asarray(prompt), np.asarray(reused))
    # the sampling key must also be independent of the raw seed key
    assert not np.array_equal(np.asarray(k_sample),
                              np.asarray(jax.random.PRNGKey(3)))


@pytest.mark.parametrize("argv", [
    ["--gen", "0"],
    ["--prompt-len", "0"],
    ["--batch", "-1"],
    ["--gen", "5,2"],       # LO > HI
    ["--requests", "0"],
])
def test_parser_rejects_degenerate_sizes(argv):
    """Regression: --gen 0 / --prompt-len 0 used to crash mid-run with shape
    errors; the parser now rejects them up front (argparse exits with 2)."""
    with pytest.raises(SystemExit):
        serve.build_parser().parse_args(argv)


# ---------------------------------------------------------------------------
# PagePool: the serving-side stash ring
# ---------------------------------------------------------------------------


def test_page_pool_alloc_free_discipline():
    pool = serve.PagePool(4)
    a = pool.alloc(3)
    assert a == [0, 1, 2] and pool.free_pages == 1 and pool.high_water == 3
    assert pool.alloc(2) is None          # over-ask: refused, not partial
    pool.free(a)
    assert pool.free_pages == 4
    # LIFO: freshly-freed pages are handed out first (recycling observable)
    assert pool.alloc(1) == [a[0]]
    with pytest.raises(ValueError, match="double/invalid"):
        pool.free([99])


def test_engine_rejects_oversized_request_with_sizing_hint(setup):
    cfg, params, _ = setup
    scfg = serve.ServeCfg(n_slots=1, page_size=4, n_pages=8, max_pages_per_seq=2)
    eng = serve.ServeEngine(params, cfg, scfg)
    big = events.Request(rid=0, arrival=0.0, prompt_len=6, gen_len=8)
    with pytest.raises(ValueError, match="max_pages_per_seq"):
        eng.run([big])


# ---------------------------------------------------------------------------
# Continuous batching == sequential decode (the tentpole equivalence)
# ---------------------------------------------------------------------------


def test_continuous_batching_matches_sequential_argmax(setup):
    """Temp-0 engine tokens for ragged, churning requests must be argmax-exact
    against per-request sequential generate(): continuous batching, paged KV,
    slot churn and page recycling change scheduling, never predictions."""
    cfg, params, _ = setup
    reqs = [
        events.Request(rid=0, arrival=0.00, prompt_len=5, gen_len=4),
        events.Request(rid=1, arrival=0.00, prompt_len=3, gen_len=6),
        events.Request(rid=2, arrival=0.01, prompt_len=8, gen_len=2),
    ]
    prompts = {
        r.rid: np.asarray(jax.random.randint(
            jax.random.PRNGKey(100 + r.rid), (r.prompt_len,), 0,
            cfg.vocab_size), np.int32)
        for r in reqs
    }
    # 2 lanes for 3 requests: the third is admitted into a recycled lane
    scfg = serve.ServeCfg(n_slots=2, page_size=4, n_pages=16,
                          max_pages_per_seq=4)
    out = serve.ServeEngine(params, cfg, scfg).run(reqs, prompts=prompts)
    assert set(out["results"]) == {0, 1, 2}
    for r in reqs:
        ref = serve.generate(params, cfg,
                             jnp.asarray(prompts[r.rid])[None, :], r.gen_len)
        got = out["results"][r.rid]["tokens"]
        assert got == np.asarray(ref[0]).tolist(), f"rid {r.rid}"
    for res in out["results"].values():
        assert np.isfinite(res["ttft_s"]) and np.isfinite(res["tpot_s"])
    assert np.isfinite(out["steady_tok_s"]) or out["decode_steps"] <= 1


def test_page_reuse_bounds_high_water(setup):
    """Retirement must actually recycle: serving N requests through few lanes
    keeps the page high-water at the concurrent working set, well under the
    all-simultaneous demand, and drains the pool back to empty."""
    cfg, params, _ = setup
    reqs = [events.Request(rid=i, arrival=0.0, prompt_len=4, gen_len=3)
            for i in range(6)]
    scfg = serve.ServeCfg(n_slots=2, page_size=4, n_pages=16,
                          max_pages_per_seq=2)
    eng = serve.ServeEngine(params, cfg, scfg)
    out = eng.run(reqs)
    need = sum(eng.pages_needed(r) for r in reqs)   # 12 if all live at once
    per_req = eng.pages_needed(reqs[0])
    assert out["pages"]["high_water"] <= scfg.n_slots * per_req < need
    assert eng.pool.free_pages == scfg.n_pages      # everything returned
    assert len(out["results"]) == 6
    assert all(len(r["tokens"]) == 3 for r in out["results"].values())


# ---------------------------------------------------------------------------
# Deadlines, load shedding, and leak freedom (DESIGN.md §11)
# ---------------------------------------------------------------------------


def test_deadline_eviction_returns_pages(setup):
    """A request that cannot finish within deadline_s is evicted mid-decode:
    its partial tokens are reported, its lane and pages are reusable, and the
    pool drains to empty at the end."""
    cfg, params, _ = setup
    scfg = serve.ServeCfg(n_slots=2, page_size=4, n_pages=16,
                          max_pages_per_seq=8, deadline_s=1e-5)
    eng = serve.ServeEngine(params, cfg, scfg)
    reqs = [events.Request(rid=i, arrival=0.0, prompt_len=4, gen_len=12)
            for i in range(4)]
    out = eng.run(reqs)
    assert out["evicted"] >= 1
    for r in out["results"].values():
        if r.get("evicted"):
            # partial generation, with real latency metrics
            assert 1 <= len(r["tokens"]) < 12
            assert np.isfinite(r["ttft_s"])
    assert eng.pool.free_pages == scfg.n_pages
    assert not eng._active.any()


def test_ttft_shed_and_queue_rejection(setup):
    """Waiters past the ttft deadline are shed (no prefill burned); arrivals
    beyond max_queue are rejected. Both are counted, carry no latency metrics,
    and leak nothing."""
    cfg, params, _ = setup
    scfg = serve.ServeCfg(n_slots=1, page_size=4, n_pages=8,
                          max_pages_per_seq=4, ttft_deadline_s=1e-6)
    eng = serve.ServeEngine(params, cfg, scfg)
    reqs = [events.Request(rid=i, arrival=0.0, prompt_len=4, gen_len=4)
            for i in range(4)]
    out = eng.run(reqs)
    assert out["shed"] >= 1
    for r in out["results"].values():
        if r.get("shed") or r.get("rejected"):
            assert "ttft_s" not in r and "tokens" not in r
    assert eng.pool.free_pages == scfg.n_pages

    scfg2 = serve.ServeCfg(n_slots=1, page_size=4, n_pages=8,
                           max_pages_per_seq=4, max_queue=1)
    eng2 = serve.ServeEngine(params, cfg, scfg2)
    out2 = eng2.run([events.Request(rid=i, arrival=0.0, prompt_len=4, gen_len=2)
                     for i in range(5)])
    assert out2["rejected"] >= 1
    assert out2["completed"] + out2["rejected"] == 5
    assert eng2.pool.free_pages == scfg2.n_pages


def test_decode_exception_cannot_leak_pages(setup):
    """An exception unwinding out of mid-decode (injected fault, interrupt)
    must return every active lane's pages on the way out: the try/finally in
    ServeEngine.run is the leak firewall."""
    cfg, params, _ = setup
    scfg = serve.ServeCfg(n_slots=2, page_size=4, n_pages=16,
                          max_pages_per_seq=4)
    eng = serve.ServeEngine(params, cfg, scfg)
    orig, calls = eng._decode, {"n": 0}

    def boom(*a, **kw):
        calls["n"] += 1
        if calls["n"] == 2:
            raise RuntimeError("injected decode fault")
        return orig(*a, **kw)

    eng._decode = boom
    reqs = [events.Request(rid=i, arrival=0.0, prompt_len=4, gen_len=6)
            for i in range(3)]
    with pytest.raises(RuntimeError, match="injected"):
        eng.run(reqs)
    assert eng.pool.free_pages == scfg.n_pages
    assert not eng._active.any()
    assert all(s is None for s in eng._slot_req)


# ---------------------------------------------------------------------------
# Load generator: keyed Poisson traces
# ---------------------------------------------------------------------------


def test_poisson_trace_deterministic_and_keyed():
    t1 = events.poisson_trace(12, rate=4.0, seed=5, prompt_lens=(2, 9),
                              gen_lens=(1, 6))
    t2 = events.poisson_trace(12, rate=4.0, seed=5, prompt_lens=(2, 9),
                              gen_lens=(1, 6))
    assert t1 == t2
    t3 = events.poisson_trace(12, rate=4.0, seed=6, prompt_lens=(2, 9),
                              gen_lens=(1, 6))
    assert t1 != t3
    arr = [r.arrival for r in t1]
    assert arr == sorted(arr) and arr[0] >= 0
    for r in t1:
        assert 2 <= r.prompt_len <= 9 and 1 <= r.gen_len <= 6
    with pytest.raises(ValueError):
        events.poisson_trace(4, rate=0.0)
    with pytest.raises(ValueError):
        events.poisson_trace(4, prompt_lens=(5, 2))
