"""Per-arch smoke tests (reduced configs) + model-component correctness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.core.engine import AsyncTrainer, EngineCfg
from repro.models import layers as L
from repro.models import lm


def _batch_for(cfg, B=2, S=32, key=7):
    toks = jax.random.randint(jax.random.PRNGKey(key), (B, S + 1), 0, cfg.vocab_size)
    b = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg.enc_periods:
        b["frames"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(key + 1), (B, cfg.n_frames, cfg.d_model), jnp.float32)
    if cfg.n_prefix_img:
        b["patches"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(key + 2), (B, cfg.n_prefix_img, cfg.d_model), jnp.float32)
        b["prefix_len"] = cfg.n_prefix_img
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch):
    """Reduced config of the same family: one fwd + one train step, shapes + no NaNs."""
    cfg = get_config(arch, reduced=True)
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    batch = _batch_for(cfg)
    loss = lm.lm_loss(params, batch, cfg)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: NaN loss"
    assert 2.0 < float(loss) < 12.0, f"{arch}: unhealthy init loss {float(loss)}"

    # one async train step (the paper's method, P=2)
    tr = AsyncTrainer(cfg, EngineCfg(n_stages=2, lr=1e-3, constant_lr=True), "ours")
    state = tr.init_from_params(params)
    mb = jax.tree.map(lambda x: x[None] if hasattr(x, "ndim") else x,
                      {k: v for k, v in batch.items() if k != "prefix_len"})
    state, m = tr.jit_step(donate=False)(state, mb)
    assert bool(jnp.isfinite(m["loss"])), f"{arch}: NaN after step"
    for leaf in jax.tree.leaves(state.params):
        assert bool(jnp.isfinite(leaf).all()), f"{arch}: non-finite params"


@pytest.mark.parametrize("arch", ["qwen2_1_5b", "gemma2_9b", "mamba2_370m",
                                  "whisper_tiny", "deepseek_v2_lite_16b", "zamba2_7b"])
def test_decode_matches_full_forward(arch):
    cfg = get_config(arch, reduced=True)
    if cfg.moe:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    B, S = 2, 16
    batch = _batch_for(cfg, B=B, S=S)
    del batch["labels"]
    logits, caches = lm.serve_prefill(params, batch, cfg, max_len=S + 4)
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    lg2, _ = lm.serve_decode(params, caches, tok, cfg, jnp.asarray(S, jnp.int32))
    b2 = dict(batch)
    b2["tokens"] = jnp.concatenate([batch["tokens"], tok], axis=1)
    lgf, _ = lm.serve_prefill(params, b2, cfg, max_len=S + 5)
    np.testing.assert_allclose(np.asarray(lg2[:, 0]), np.asarray(lgf[:, 0]),
                               rtol=2e-3, atol=2e-3)


def test_prefix_lm_mask_bidirectional_over_prefix():
    """paligemma: prefix positions must see each other (non-causal) but causal after."""
    bias = L._mask_bias(jnp.arange(6)[None], jnp.arange(6)[None],
                        causal=True, window=None, prefix_len=3)
    b = np.asarray(bias[0])
    assert b[0, 2] == 0.0  # prefix sees forward within prefix
    assert b[0, 3] < -1e20  # but not beyond
    assert b[5, 2] == 0.0 and b[4, 5] < -1e20  # causal afterwards


def test_sliding_window_mask():
    bias = L._mask_bias(jnp.arange(8)[None], jnp.arange(8)[None],
                        causal=True, window=3, prefix_len=None)
    b = np.asarray(bias[0])
    assert b[7, 7] == 0 and b[7, 5] == 0
    assert b[7, 4] < -1e20  # outside window
    assert b[3, 5] < -1e20  # future


def test_moe_matches_dense_reference():
    cfg = get_config("dbrx_132b", reduced=True)
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    p = L.init_moe(jax.random.PRNGKey(1), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, cfg.d_model), jnp.float32)
    y, aux = L.moe_apply(p, x, cfg)

    mc = cfg.moe
    T, D = 32, cfg.d_model
    xf = x.reshape(T, D)
    probs = jax.nn.softmax(xf @ p["router"], -1)
    gates, idx = jax.lax.top_k(probs, mc.top_k)
    gates = gates / gates.sum(-1, keepdims=True)
    g = jax.nn.silu(jnp.einsum("td,edf->tef", xf, p["moe_gate"]))
    u = jnp.einsum("td,edf->tef", xf, p["moe_up"])
    h = jnp.einsum("tef,efd->ted", g * u, p["moe_down"])
    ref = jnp.zeros((T, D))
    for k in range(mc.top_k):
        sel = jnp.take_along_axis(h, idx[:, k][:, None, None].repeat(D, -1), 1)[:, 0]
        ref = ref + gates[:, k:k + 1] * sel
    np.testing.assert_allclose(np.asarray(y.reshape(T, D)), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    assert float(aux) >= 0


def test_ssd_chunked_matches_sequential():
    from repro.kernels.ref import ssd_ref

    b, S, H, P, G, N = 2, 96, 4, 16, 2, 8
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (b, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1), (b, S, H))) * 0.1
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (H,)) * 0.3)
    B_ = jax.random.normal(jax.random.fold_in(key, 3), (b, S, G, N)) * 0.3
    C_ = jax.random.normal(jax.random.fold_in(key, 4), (b, S, G, N)) * 0.3
    y1, h1 = L._ssd_chunked(x, B_, C_, dt, A, 32)
    y2, h2 = ssd_ref(x, dt, A, B_, C_)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=1e-4, atol=1e-5)


def test_zamba2_shared_block_is_shared():
    """All shared_attn occurrences use one param set (+ per-occurrence out proj)."""
    cfg = get_config("zamba2_7b", reduced=True)
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    assert "shared" in params
    # per-occurrence block params contain only the out-proj
    b2 = params["scan"]["b2"]
    assert set(b2.keys()) == {"pre_norm", "shared_out_proj"}


def test_full_configs_have_published_shapes():
    """Spot-check full (non-reduced) configs against the assignment table."""
    specs = {
        "mamba2_370m": dict(n_layers=48, d_model=1024, vocab_size=50280),
        "gemma3_12b": dict(n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8,
                           d_ff=15360, vocab_size=262144),
        "internlm2_20b": dict(n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
                              d_ff=16384, vocab_size=92544),
        "qwen2_1_5b": dict(n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
                           d_ff=8960, vocab_size=151936, qkv_bias=True),
        "gemma2_9b": dict(n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8,
                          d_ff=14336, vocab_size=256000, attn_softcap=50.0,
                          final_softcap=30.0),
        "paligemma_3b": dict(n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1,
                             d_ff=16384, vocab_size=257216, n_prefix_img=256),
        "whisper_tiny": dict(d_model=384, n_heads=6, d_ff=1536, vocab_size=51865),
        "dbrx_132b": dict(n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8,
                          vocab_size=100352),
        "deepseek_v2_lite_16b": dict(n_layers=27, d_model=2048, n_heads=16,
                                     vocab_size=102400),
        "zamba2_7b": dict(n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
                          d_ff=14336, vocab_size=32000),
    }
    for arch, want in specs.items():
        cfg = get_config(arch)
        for k, v in want.items():
            got = getattr(cfg, k)
            assert got == v, f"{arch}.{k}: {got} != {v}"
    assert get_config("dbrx_132b").moe.n_experts == 16
    assert get_config("dbrx_132b").moe.top_k == 4
    ds = get_config("deepseek_v2_lite_16b")
    assert ds.moe.n_experts == 64 and ds.moe.top_k == 6 and ds.moe.n_shared == 2
    assert ds.mla.kv_lora == 512
    assert get_config("mamba2_370m").ssm.d_state == 128
    assert get_config("zamba2_7b").ssm.d_state == 64
