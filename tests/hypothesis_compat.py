"""`hypothesis` when installed, else a seeded-random fallback with the same API.

The property tests in this suite use only `@settings(...) @given(st.integers /
st.sampled_from)`. When `hypothesis` is absent (clean CI containers), the
fallback replays each property over `max_examples` deterministic samples drawn
from a PRNG seeded by the test name — weaker than real shrinking/search, but it
keeps the properties exercised and the suite collectable everywhere.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only where hypothesis is installed
    from hypothesis import given, settings, strategies as st  # noqa: F401
except ImportError:
    import functools
    import inspect
    import zlib

    import numpy as np

    _DEFAULT_EXAMPLES = 20

    class _Strategy:
        def __init__(self, sample):
            self.sample = sample

    class st:  # noqa: N801 - mimics `hypothesis.strategies` module surface
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def sampled_from(elements):
            seq = list(elements)
            return _Strategy(lambda rng: seq[int(rng.integers(0, len(seq)))])

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(0, 2)))

    def given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES)
                rng = np.random.default_rng(zlib.crc32(fn.__name__.encode()))
                for _ in range(n):
                    drawn = {k: s.sample(rng) for k, s in strategies.items()}
                    fn(*args, **drawn, **kwargs)

            # hide the strategy params from pytest's fixture resolution, but
            # keep any remaining params (fixtures) visible for injection
            del wrapper.__wrapped__
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(parameters=[
                p for name, p in sig.parameters.items() if name not in strategies])
            wrapper._hypothesis_fallback = True
            return wrapper

        return deco

    def settings(max_examples=None, **_ignored):
        """Accepts (and ignores) deadline/derandomize/...; keeps max_examples."""

        def deco(fn):
            if max_examples is not None:
                fn._max_examples = max_examples
            return fn

        return deco
