"""Property suites for the two allocator-flavored runtime primitives, driven
by random operation sequences (hypothesis when installed, the seeded
`hypothesis_compat` fallback otherwise):

- `PagePool` (launch/serve.py): every alloc/free interleaving preserves the
  free-list invariants — LIFO reuse order, double/invalid free raises,
  `high_water` == the peak number of simultaneously-live pages, and the pool
  never loses or duplicates a page.
- `Mailbox(dedupe=True)` (core/events.py): under arbitrary drop/duplicate
  interleavings of an out-of-order transport, the consumer still sees each
  microbatch exactly once, in order, and every redelivery is counted in
  `duplicates`.
"""
import numpy as np
import pytest

from hypothesis_compat import given, settings, st
from repro.core.events import Mailbox
from repro.launch.serve import PagePool


# ---------------------------------------------------------------------------
# PagePool
# ---------------------------------------------------------------------------


def _drive_pool(n_pages, ops_seed, n_ops):
    """Random alloc/free walk against a model: returns (pool, live, peak)."""
    rng = np.random.default_rng(ops_seed)
    pool = PagePool(n_pages)
    live = []  # model of allocated ids, in allocation order
    peak = 0
    for _ in range(n_ops):
        if live and rng.integers(0, 2):
            # free a random contiguous chunk of the live set
            k = int(rng.integers(1, len(live) + 1))
            idx = int(rng.integers(0, len(live) - k + 1))
            chunk = live[idx:idx + k]
            del live[idx:idx + k]
            pool.free(chunk)
        else:
            n = int(rng.integers(1, n_pages + 1))
            got = pool.alloc(n)
            if n > n_pages - len(live):
                assert got is None  # over-ask must refuse, not partially fill
            else:
                assert got is not None and len(got) == n
                assert not (set(got) & set(live))  # no double-hand-out
                live.extend(got)
                peak = max(peak, len(live))
        assert pool.in_use == len(live)
        assert pool.free_pages == n_pages - len(live)
    return pool, live, peak


@settings(max_examples=30, deadline=None)
@given(n_pages=st.integers(min_value=1, max_value=12),
       ops_seed=st.integers(min_value=0, max_value=10_000),
       n_ops=st.integers(min_value=1, max_value=60))
def test_pagepool_invariants_under_random_walk(n_pages, ops_seed, n_ops):
    pool, live, peak = _drive_pool(n_pages, ops_seed, n_ops)
    # high_water is exactly the peak concurrent demand, never the sum
    assert pool.high_water == peak
    # conservation: free list + live model partition the page ids exactly
    assert sorted(pool._free + live) == list(range(n_pages))


@settings(max_examples=20, deadline=None)
@given(n_pages=st.integers(min_value=2, max_value=16),
       n=st.integers(min_value=1, max_value=8))
def test_pagepool_lifo_reuse(n_pages, n):
    """Freshly-freed pages are handed out first, newest-freed first — the
    property test_serve.py leans on to observe recycling."""
    n = min(n, n_pages)
    pool = PagePool(n_pages)
    first = pool.alloc(n)
    pool.free(first)
    again = pool.alloc(n)
    assert again == first  # LIFO: the exact pages just freed, same order


@settings(max_examples=20, deadline=None)
@given(n_pages=st.integers(min_value=1, max_value=8))
def test_pagepool_double_and_invalid_free_raise(n_pages):
    pool = PagePool(n_pages)
    ids = pool.alloc(1)
    pool.free(ids)
    with pytest.raises(ValueError):
        pool.free(ids)  # double free
    with pytest.raises(ValueError):
        pool.free([n_pages])  # out of range
    with pytest.raises(ValueError):
        pool.free([-1])


# ---------------------------------------------------------------------------
# Mailbox(dedupe=True)
# ---------------------------------------------------------------------------


def _lossy_transport(n_msgs, seed, dup_rate, shuffle):
    """Deliver microbatches 0..n-1 with random duplication and reordering.
    Returns the delivery schedule (a list of mb indices, each >= once)."""
    rng = np.random.default_rng(seed)
    sched = list(range(n_msgs))
    sched += [int(rng.integers(0, n_msgs))
              for _ in range(int(dup_rate * n_msgs))]
    if shuffle:
        rng.shuffle(sched)
    return sched


@settings(max_examples=30, deadline=None)
@given(n_msgs=st.integers(min_value=1, max_value=40),
       seed=st.integers(min_value=0, max_value=10_000),
       dup_rate=st.floats(min_value=0.0, max_value=2.0),
       shuffle=st.booleans())
def test_mailbox_dedupe_exactly_once_in_order(n_msgs, seed, dup_rate, shuffle):
    """At-least-once transport + receiver dedup == exactly-once, in-order
    consumption: the strict take(mb) loop sees every payload exactly once in
    microbatch order, duplicates are counted, and late redeliveries of
    already-consumed indices are still dropped."""
    box = Mailbox(dedupe=True)
    sched = _lossy_transport(n_msgs, seed, dup_rate, shuffle)
    consumed = []
    next_mb = 0
    for mb in sched:
        box.put(mb, ("payload", mb))
        while box.ready(next_mb):  # consume as soon as the head is available
            consumed.append(box.take(next_mb))
            next_mb += 1
    assert consumed == [("payload", mb) for mb in range(n_msgs)]
    assert box.duplicates == len(sched) - n_msgs
    assert len(box) == 0
    # a replay of the whole schedule after full consumption is all-duplicate
    for mb in sched:
        box.put(mb, ("late", mb))
    assert len(box) == 0 and box.duplicates == 2 * len(sched) - n_msgs


@settings(max_examples=20, deadline=None)
@given(n_msgs=st.integers(min_value=2, max_value=30),
       seed=st.integers(min_value=0, max_value=10_000))
def test_mailbox_strict_mode_raises_on_duplicate(n_msgs, seed):
    rng = np.random.default_rng(seed)
    box = Mailbox()  # strict: a duplicate is a transport bug
    mb = int(rng.integers(0, n_msgs))
    box.put(mb, "x")
    with pytest.raises(RuntimeError, match="duplicate"):
        box.put(mb, "x")


@settings(max_examples=20, deadline=None)
@given(n_msgs=st.integers(min_value=1, max_value=30),
       seed=st.integers(min_value=0, max_value=10_000))
def test_mailbox_high_water_is_peak_buffered(n_msgs, seed):
    """Deliver everything before consuming anything: high_water must equal the
    full backlog; then a fresh box consuming eagerly in delivery order keeps
    high_water at the true peak backlog, never the total count."""
    sched = _lossy_transport(n_msgs, seed, 0.0, True)
    box = Mailbox(dedupe=True)
    for mb in sched:
        box.put(mb, mb)
    assert box.high_water == n_msgs
    box2 = Mailbox(dedupe=True)
    backlog = peak = 0
    next_mb = 0
    for mb in sched:
        box2.put(mb, mb)
        backlog += 1
        peak = max(peak, backlog)
        while box2.ready(next_mb):
            box2.take(next_mb)
            next_mb += 1
            backlog -= 1
    assert box2.high_water == peak
