"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.nag_update import nag_update
from repro.kernels.ssd_scan import ssd_scan


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,Hkv,S,d,blk", [
    (1, 2, 2, 128, 64, 64),
    (2, 4, 2, 256, 64, 128),
    (1, 4, 1, 192, 32, 64),   # MQA, non-multiple seq vs block
    (2, 2, 2, 96, 128, 64),   # padding path
])
def test_flash_attention_shapes_dtypes(B, H, Hkv, S, d, blk, dtype):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, H, S, d)).astype(dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, Hkv, S, d)).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, Hkv, S, d)).astype(dtype)
    out = flash_attention(q, k, v, causal=True, block_q=blk, block_k=blk)
    want = ref.attention_ref(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("kw", [
    dict(causal=True, window=32),
    dict(causal=True, softcap=50.0),
    dict(causal=False),
    dict(causal=True, window=64, softcap=30.0),
])
def test_flash_attention_mask_variants(kw):
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (2, 4, 128, 64))
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, 2, 128, 64))
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, 2, 128, 64))
    out = flash_attention(q, k, v, block_q=64, block_k=64, **kw)
    want = ref.attention_ref(q, k, v, **kw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5, atol=2e-5)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000),
       nc=st.sampled_from([2, 4]),
       chunk=st.sampled_from([16, 32]),
       H=st.sampled_from([2, 4]),
       G=st.sampled_from([1, 2]))
def test_ssd_scan_property(seed, nc, chunk, H, G):
    if H % G:
        return
    b, S, P, N = 2, nc * chunk, 16, 8
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (b, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1), (b, S, H))) * 0.1
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (H,)) * 0.3)
    B_ = jax.random.normal(jax.random.fold_in(key, 3), (b, S, G, N)) * 0.3
    C_ = jax.random.normal(jax.random.fold_in(key, 4), (b, S, G, N)) * 0.3
    y, h = ssd_scan(x, dt, A, B_, C_, chunk=chunk)
    yr, hr = ref.ssd_ref(x, dt, A, B_, C_)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("n,block", [(100, 128), (5000, 1024), (4096, 1024), (7, 8)])
@pytest.mark.parametrize("gdtype", [jnp.float32, jnp.bfloat16])
def test_nag_update_shapes(n, block, gdtype):
    key = jax.random.PRNGKey(0)
    p = jax.random.normal(key, (n,))
    m = jax.random.normal(jax.random.fold_in(key, 1), (n,)) * 0.1
    v = jnp.abs(jax.random.normal(jax.random.fold_in(key, 2), (n,))) * 0.01
    g = jax.random.normal(jax.random.fold_in(key, 3), (n,)).astype(gdtype)
    kw = dict(lr=1e-3, mu_t=0.95, mu_next=0.96, mu_prod=0.9, mu_prod_next=0.87, bc2=0.05)
    got = nag_update(p, m, v, g, block=block, **kw)
    want = ref.nag_update_ref(p, m, v, g, b1=0.99, b2=0.95, eps=1e-8, wd=0.01, **kw)
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-6, atol=2e-6)


def test_nag_update_matches_optimizer_module():
    """The fused kernel reproduces optim.optimizers.nadam step exactly."""
    from repro.kernels.ops import fused_nadam_tree
    from repro.optim.optimizers import nadam

    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (64, 32)),
              "b": jax.random.normal(jax.random.fold_in(key, 1), (32,))}
    grads = jax.tree.map(lambda x: x * 0.01, params)
    opt = nadam(lr=1e-3, b1=0.99)
    st = opt.init(params)
    # advance a couple of steps so mu_prod is non-trivial
    p = params
    for _ in range(3):
        p, st, _ = opt.update(p, grads, st)
    ref_p, ref_st, _ = opt.update(p, grads, st)
    newp, newm, newv, mp = fused_nadam_tree(
        p, grads, st["m"], st["v"], lr=1e-3, count=st["count"], mu_prod=st["mu_prod"])
    for a, b in zip(jax.tree.leaves(newp), jax.tree.leaves(ref_p)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)
    for a, b in zip(jax.tree.leaves(newm), jax.tree.leaves(ref_st["m"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(float(mp), float(ref_st["mu_prod"]), rtol=1e-6)


# ---------------------------------------------------------------------------
# Dedicated backward kernels vs oracle VJPs (random cotangents — stronger than
# the scalar-loss grad-parity harness: exercises each output's cotangent path
# independently, including the SSD final-state cotangent)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kw", [
    dict(causal=True),
    dict(causal=True, window=32),
    dict(causal=True, softcap=30.0),
    dict(causal=False),
])
def test_flash_attention_bwd_matches_ref_vjp(kw):
    from repro.kernels.flash_attention import flash_attention, flash_attention_bwd

    key = jax.random.PRNGKey(5)
    B, H, Hkv, S, d = 2, 4, 2, 96, 32  # ragged: S % block != 0
    q = jax.random.normal(key, (B, H, S, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, Hkv, S, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, Hkv, S, d))
    do = jax.random.normal(jax.random.fold_in(key, 3), (B, H, S, d))
    o, lse = flash_attention(q, k, v, block_q=64, block_k=64,
                             return_residuals=True, **kw)
    got = flash_attention_bwd(q, k, v, o, lse, do, block_q=64, block_k=64, **kw)
    _, vjp = jax.vjp(lambda *a: ref.attention_ref(*a, **kw), q, k, v)
    want = vjp(do)
    for g, w, nm in zip(got, want, ("dq", "dk", "dv")):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=3e-5,
                                   atol=3e-5, err_msg=nm)


def test_ssd_scan_bwd_matches_sequential_oracle_vjp():
    """Reverse-scan kernel from saved chunk-boundary states == VJP of the
    SEQUENTIAL recurrence oracle, with independent cotangents for both outputs
    (y and the final state)."""
    from repro.kernels.ssd_scan import ssd_scan, ssd_scan_bwd

    key = jax.random.PRNGKey(6)
    b, S, H, P, G, N, chunk = 2, 64, 4, 16, 2, 8, 32
    x = jax.random.normal(key, (b, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1), (b, S, H))) * 0.1
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (H,)) * 0.3)
    B_ = jax.random.normal(jax.random.fold_in(key, 3), (b, S, G, N)) * 0.3
    C_ = jax.random.normal(jax.random.fold_in(key, 4), (b, S, G, N)) * 0.3
    dy = jax.random.normal(jax.random.fold_in(key, 5), (b, S, H, P))
    dhfin = jax.random.normal(jax.random.fold_in(key, 6), (b, H, N, P)) * 0.1

    y, hfin, h_chunk = ssd_scan(x, dt, A, B_, C_, chunk=chunk, return_residuals=True)
    # residual sanity: first boundary state is zero, shapes are per-chunk
    assert h_chunk.shape == (b * H, S // chunk, N, P)
    np.testing.assert_array_equal(np.asarray(h_chunk[:, 0]), 0.0)

    got = ssd_scan_bwd(x, dt, A, B_, C_, h_chunk, dy, dhfin, chunk=chunk)
    _, vjp = jax.vjp(lambda *a: ref.ssd_ref(*a), x, dt, A, B_, C_)
    want = vjp((dy, dhfin))
    for g, w, nm in zip(got, want, ("dx", "ddt", "dA", "dB", "dC")):
        scale = max(1.0, float(jnp.max(jnp.abs(w))))
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=5e-4,
                                   atol=5e-4 * scale, err_msg=nm)


def test_rmsnorm_residual_bwd_matches_ref_vjp():
    from repro.kernels.rmsnorm_residual import (rmsnorm_residual,
                                                rmsnorm_residual_bwd,
                                                rmsnorm_residual_ref)

    key = jax.random.PRNGKey(7)
    shape = (3, 5, 48)  # ragged rows vs block_rows
    x = jax.random.normal(key, shape)
    h = jax.random.normal(jax.random.fold_in(key, 1), shape)
    sc = jax.random.normal(jax.random.fold_in(key, 2), (shape[-1],)) * 0.1
    dr = jax.random.normal(jax.random.fold_in(key, 3), shape)
    dy = jax.random.normal(jax.random.fold_in(key, 4), shape)
    r, _ = rmsnorm_residual(x, h, sc)
    dxh, dscale = rmsnorm_residual_bwd(r, sc, dr, dy)
    _, vjp = jax.vjp(lambda *a: rmsnorm_residual_ref(*a), x, h, sc)
    dx_w, dh_w, dsc_w = vjp((dr, dy))
    np.testing.assert_allclose(np.asarray(dxh), np.asarray(dx_w), rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(dxh), np.asarray(dh_w), rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(dscale), np.asarray(dsc_w), rtol=2e-5, atol=2e-5)
