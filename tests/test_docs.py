"""Docs that can rot, pinned by tests (ISSUE 5; REG001 promotion in ISSUE 9).

- The README method-registry table and the BENCH-artifact references are
  checked through the SAME implementation the lint CLI uses
  (repro.analysis.rules.reg001) — one source of truth, no drifting copies.
- Intra-repo markdown links in README/DESIGN/docs must resolve (the CI docs
  leg runs this file plus the README quickstart smoke commands).
- The docs/lint.md rule table is generated-checked against the registered
  lint rules (same idiom as the method table).
- The bundled example trace (examples/trace_p4.json) must stay a valid
  TraceDelay file the quickstart's --sim-schedule command can replay.
"""
import json
import os
import re

import pytest

from repro.analysis import engine as lint_engine
from repro.analysis.rules import reg001
from repro.core.events import (TraceDelay, make_delay_model, make_mesh_spec,
                               make_sync_delay_model)
from repro.core.methods import METHODS

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

DOC_FILES = reg001.doc_files(ROOT)

# [text](target) — excluding images; target split from an optional #anchor
_LINK = re.compile(r"(?<!\!)\[[^\]]+\]\(([^)\s]+)\)")


def test_readme_method_table_matches_registry():
    # shared REG001 sub-rule: missing/stale/mismatched/unsorted rows
    assert reg001.method_table_problems(ROOT) == []


def test_readme_rows_are_complete():
    # belt and braces: the shared parser sees every registered method
    rows = reg001.readme_method_rows(ROOT)
    assert sorted(rows) == sorted(METHODS)


@pytest.mark.parametrize("doc", DOC_FILES)
def test_intra_repo_markdown_links_resolve(doc):
    path = os.path.join(ROOT, doc)
    assert os.path.exists(path), f"{doc} missing"
    with open(path) as f:
        text = f.read()
    base = os.path.dirname(path)
    bad = []
    for target in _LINK.findall(text):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        rel = target.split("#", 1)[0]
        if not rel:  # pure #anchor
            continue
        if not os.path.exists(os.path.normpath(os.path.join(base, rel))):
            bad.append(target)
    assert not bad, f"{doc} has dead intra-repo links: {bad}"


def test_example_trace_is_valid_and_replayable():
    path = os.path.join(ROOT, "examples", "trace_p4.json")
    with open(path) as f:
        raw = f.read()
    assert len(raw.strip().splitlines()) == 8  # the README-sized example
    tr = json.loads(raw)
    assert tr["version"] == 1 and tr["P"] == 4
    for op in ("fwd", "bwd", "comm"):
        assert len(tr[op]) == tr["P"]
    td = make_delay_model(f"trace:{path}")
    assert isinstance(td, TraceDelay)
    assert td.latency(0, "fwd", 0) == tr["fwd"][0][0]
    assert td.latency(1, "bwd", 5) == tr["bwd"][1][5 % len(tr["bwd"][1])]
    # the quickstart replays this through the compute-free planner
    from repro.core.runtime import simulate_schedule

    sim = simulate_schedule(P=4, n_ticks=8, delay_model=f"trace:{path}")
    assert sim["makespan"] > 0
    assert sim["taus"][-1] == (3.0, 2.0, 1.0, 0.0)  # near-uniform trace: Eq. 5


def test_bench_artifacts_named_in_docs_exist():
    """Docs-rot guard, now the REG001 bench sub-rule: every
    artifacts/BENCH_*.json a doc points at must actually exist
    (benchmarks/run.py regenerates them), unless the sentence explicitly
    flags it as stale/planned."""
    assert reg001.bench_artifact_problems(ROOT) == []


def test_dispatch_registry_is_consistent():
    """REG001 dispatch sub-rule: parity cases + bwd or documented ref-VJP."""
    assert reg001.dispatch_registry_problems(ROOT) == []


def test_cli_md_mesh_grammar_examples_parse():
    """Docs-rot guard for the --mesh / --sync-delay grammar: every spec shape
    docs/cli.md documents must parse through the real parsers, and the shapes
    it documents as errors must raise."""
    with open(os.path.join(ROOT, "docs", "cli.md")) as f:
        text = f.read()
    assert "gossip:PERIOD[,FANOUT]" in text and "barrier:PERIOD" in text
    assert "--sync-delay" in text and "jitter:BASE,SIGMA" in text

    sp = make_mesh_spec("gossip:8")
    assert (sp.mode, sp.period, sp.fanout) == ("gossip", 8, None)
    sp = make_mesh_spec("gossip:4,2")
    assert (sp.mode, sp.period, sp.fanout) == ("gossip", 4, 2)
    sp = make_mesh_spec("barrier:2")
    assert (sp.mode, sp.period, sp.fanout) == ("barrier", 2, None)
    with pytest.raises(ValueError):
        make_mesh_spec("barrier:2,1")  # documented as gossip-only
    # sync-delay shapes named in the table
    assert make_sync_delay_model("fixed").latency(0, 1, 0, 0) == 0.0
    assert make_sync_delay_model("fixed:1.5").latency(0, 1, 0, 0) == 1.5
    assert make_sync_delay_model("jitter:1.0,0.3", seed=0).latency(0, 1, 0, 0) > 0


# ---- docs/lint.md rule table vs the registered rules -----------------------

# table row: | `RULE_ID` | `pragma-slug` | rationale... |
_LINT_ROW = re.compile(r"^\|\s*`([A-Z]{3,4}\d{3})`\s*\|\s*`([a-z-]+)`\s*\|(.+)\|$")


def _lint_md_rows():
    rows = {}
    with open(os.path.join(ROOT, "docs", "lint.md")) as f:
        for line in f:
            m = _LINT_ROW.match(line.strip())
            if m:
                rows[m.group(1)] = (m.group(2), m.group(3).strip())
    return rows


def test_lint_md_rule_table_matches_registry():
    from repro.analysis import rules as _rules  # noqa: F401  (register)

    rows = _lint_md_rows()
    assert sorted(rows) == sorted(lint_engine.RULES), (
        "docs/lint.md rule table out of sync with repro.analysis rules: "
        f"missing {sorted(set(lint_engine.RULES) - set(rows))}, "
        f"stale {sorted(set(rows) - set(lint_engine.RULES))}")
    for rid, (slug, rationale) in rows.items():
        rule = lint_engine.RULES[rid]
        assert slug == rule.slug, f"{rid}: doc slug {slug!r} != {rule.slug!r}"
        assert rationale, f"{rid}: empty rationale cell"
