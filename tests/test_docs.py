"""Docs that can rot, pinned by tests (ISSUE 5).

- The README method-registry table must list exactly sorted(METHODS) with the
  registered optimizer / tau_source / memory class per method.
- Intra-repo markdown links in README/DESIGN/docs must resolve (the CI docs
  leg runs this file plus the README quickstart smoke commands).
- The bundled example trace (examples/trace_p4.json) must stay a valid
  TraceDelay file the quickstart's --sim-schedule command can replay.
"""
import json
import os
import re

import pytest

from repro.core.events import TraceDelay, make_delay_model
from repro.core.methods import METHODS

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

DOC_FILES = ["README.md", "DESIGN.md", "ROADMAP.md", "docs/cli.md"]

# markdown table row whose first cell is a backticked method name
_ROW = re.compile(r"^\|\s*`([^`]+)`\s*\|(.+)\|\s*$")
# [text](target) — excluding images; target split from an optional #anchor
_LINK = re.compile(r"(?<!\!)\[[^\]]+\]\(([^)\s]+)\)")


def _readme_method_rows():
    """Every data row of the README's '## Method registry' table — including
    rows whose method no longer exists in the registry (stale-row detection
    requires NOT filtering by METHODS membership here)."""
    rows = {}
    in_section = False
    with open(os.path.join(ROOT, "README.md")) as f:
        for line in f:
            if line.startswith("## "):
                in_section = line.strip() == "## Method registry"
                continue
            m = _ROW.match(line.strip())
            if in_section and m:
                cells = [c.strip() for c in m.group(2).split("|")]
                rows[m.group(1)] = cells
    return rows


def test_readme_method_table_matches_registry():
    rows = _readme_method_rows()
    assert sorted(rows) == sorted(METHODS), (
        "README method table out of sync with core/methods.py METHODS: "
        f"missing {sorted(set(METHODS) - set(rows))}, "
        f"stale {sorted(set(rows) - set(METHODS))}")
    for name, cells in rows.items():
        m = METHODS[name]
        # | optimizer | fwd point | bwd point | corrections | tau source | memory |
        assert len(cells) == 6, f"README row for {name} has {len(cells)} cells"
        assert cells[0] == m.optimizer, f"{name}: optimizer {cells[0]!r}"
        assert cells[1] == m.fwd_point and cells[2] == m.bwd_point, name
        assert cells[4] == m.tau_source, f"{name}: tau source {cells[4]!r}"
        assert cells[5] == m.memory, (
            f"{name}: README memory class {cells[5]!r} != registered {m.memory!r}")


def test_readme_rows_in_registry_order():
    names = list(_readme_method_rows())
    assert names == sorted(METHODS), "README table rows must be sorted by name"


@pytest.mark.parametrize("doc", DOC_FILES)
def test_intra_repo_markdown_links_resolve(doc):
    path = os.path.join(ROOT, doc)
    assert os.path.exists(path), f"{doc} missing"
    with open(path) as f:
        text = f.read()
    base = os.path.dirname(path)
    bad = []
    for target in _LINK.findall(text):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        rel = target.split("#", 1)[0]
        if not rel:  # pure #anchor
            continue
        if not os.path.exists(os.path.normpath(os.path.join(base, rel))):
            bad.append(target)
    assert not bad, f"{doc} has dead intra-repo links: {bad}"


def test_example_trace_is_valid_and_replayable():
    path = os.path.join(ROOT, "examples", "trace_p4.json")
    with open(path) as f:
        raw = f.read()
    assert len(raw.strip().splitlines()) == 8  # the README-sized example
    tr = json.loads(raw)
    assert tr["version"] == 1 and tr["P"] == 4
    for op in ("fwd", "bwd", "comm"):
        assert len(tr[op]) == tr["P"]
    td = make_delay_model(f"trace:{path}")
    assert isinstance(td, TraceDelay)
    assert td.latency(0, "fwd", 0) == tr["fwd"][0][0]
    assert td.latency(1, "bwd", 5) == tr["bwd"][1][5 % len(tr["bwd"][1])]
    # the quickstart replays this through the compute-free planner
    from repro.core.runtime import simulate_schedule

    sim = simulate_schedule(P=4, n_ticks=8, delay_model=f"trace:{path}")
    assert sim["makespan"] > 0
    assert sim["taus"][-1] == (3.0, 2.0, 1.0, 0.0)  # near-uniform trace: Eq. 5


_BENCH = re.compile(r"\b(BENCH_\w+\.json)\b")


@pytest.mark.parametrize("doc", DOC_FILES)
def test_bench_artifacts_named_in_docs_exist(doc):
    """Docs-rot guard: every artifacts/BENCH_*.json a doc points at must
    actually exist (benchmarks/run.py regenerates them), unless the sentence
    explicitly flags it as stale/planned. ISSUE 7's trigger: ROADMAP.md cited
    BENCH_kernels.json while only BENCH_runtime.json was checked in."""
    with open(os.path.join(ROOT, doc)) as f:
        lines = f.read().splitlines()
    missing = []
    for ln in lines:
        for name in _BENCH.findall(ln):
            if re.search(r"\b(stale|planned|future|TODO)\b", ln, re.I):
                continue
            if not os.path.exists(os.path.join(ROOT, "artifacts", name)):
                missing.append(name)
    assert not missing, (
        f"{doc} names benchmark artifacts that don't exist: {sorted(set(missing))}"
        " — run benchmarks/run.py (or the per-section bench) to regenerate,"
        " or mark the mention stale")
