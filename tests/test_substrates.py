"""Data pipeline, checkpointing (exact resume / preemption / elastic), SWARM, optim."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.configs import get_config
from repro.core.engine import AsyncTrainer, EngineCfg
from repro.core.swarm import SwarmCfg, SwarmTrainer
from repro.data.synthetic import SyntheticLM, make_batch_fn
from repro.ft import loop as ftloop
from repro.optim import forecast, schedules
from repro.optim.optimizers import adamw, nadam, sgd_nag


@pytest.fixture(scope="module")
def cfg():
    return get_config("nanogpt_134m", reduced=True)


# ---- data -------------------------------------------------------------------


def test_synthetic_deterministic_and_shaped(cfg):
    src = SyntheticLM(cfg.vocab_size, seed=3)
    b1 = src.batch(7, 2, 4, 16)
    b2 = src.batch(7, 2, 4, 16)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    b3 = src.batch(8, 2, 4, 16)
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))
    assert b1["tokens"].shape == (2, 4, 16)
    np.testing.assert_array_equal(np.asarray(b1["tokens"][..., 1:]),
                                  np.asarray(b1["labels"][..., :-1]))
    assert 0.0 < src.entropy_floor() < np.log(cfg.vocab_size)


def test_bigram_structure_is_learnable(cfg):
    """Next-token is perm[prev] with prob q: empirical hit rate ~ q + (1-q) p_perm."""
    src = SyntheticLM(256, q=0.7, seed=0)
    b = src.batch(0, 1, 64, 128)
    toks = np.asarray(b["tokens"][0])
    perm = np.asarray(src.perm)
    hits = (perm[toks[:, :-1]] == toks[:, 1:]).mean()
    assert 0.6 < hits < 0.85


# ---- checkpoint -------------------------------------------------------------


def test_checkpoint_exact_resume(cfg):
    ecfg = EngineCfg(n_stages=4, lr=1e-3, constant_lr=True)
    batch_fn, _ = make_batch_fn(cfg, 1, 4, 32, seed=0)
    with tempfile.TemporaryDirectory() as d:
        tr = AsyncTrainer(cfg, ecfg, "ours")
        state, _ = ftloop.train_loop(tr, batch_fn, 8, ckpt_dir=d, ckpt_every=4,
                                     key=jax.random.PRNGKey(0))
        os.remove(os.path.join(d, "ckpt-8.npz"))
        tr2 = AsyncTrainer(cfg, ecfg, "ours")
        state2, res2 = ftloop.train_loop(tr2, batch_fn, 8, ckpt_dir=d,
                                         key=jax.random.PRNGKey(0))
        assert res2.resumed_from == 4
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(state2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_preemption_recovery(cfg):
    ecfg = EngineCfg(n_stages=2, lr=1e-3, constant_lr=True)
    batch_fn, _ = make_batch_fn(cfg, 1, 4, 32, seed=1)
    with tempfile.TemporaryDirectory() as d:
        def fault(i):
            if i == 5:
                raise ftloop.SimulatedPreemption()

        with pytest.raises(ftloop.SimulatedPreemption):
            ftloop.train_loop(AsyncTrainer(cfg, ecfg, "ours"), batch_fn, 20,
                              ckpt_dir=d, ckpt_every=100, fault_hook=fault,
                              key=jax.random.PRNGKey(0))
        assert ckpt.latest(d)[1] == 5
        _, res = ftloop.train_loop(AsyncTrainer(cfg, ecfg, "ours"), batch_fn, 8,
                                   ckpt_dir=d, key=jax.random.PRNGKey(0))
        assert res.resumed_from == 5 and len(res.losses) == 3


def test_elastic_restage(cfg):
    """4-stage checkpoint resumes as a 2-stage run (elastic scaling)."""
    batch_fn, _ = make_batch_fn(cfg, 1, 4, 32, seed=2)
    e4 = EngineCfg(n_stages=4, lr=1e-3, constant_lr=True)
    tr4 = AsyncTrainer(cfg, e4, "ours")
    s4 = tr4.init(jax.random.PRNGKey(0))
    step4 = tr4.jit_step(donate=False)
    for i in range(4):
        s4, _ = step4(s4, batch_fn(i))
    tr2 = AsyncTrainer(cfg, EngineCfg(n_stages=2, lr=1e-3, constant_lr=True), "ours")
    s2 = ckpt.restage(s4, tr4, tr2)
    assert int(s2.step) == int(s4.step)
    # merged params survive the restage exactly
    m4 = tr4.merge_params(s4)
    m2 = tr2.merge_params(s2)
    for a, b in zip(jax.tree.leaves(m4), jax.tree.leaves(m2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-7)
    s2b, m = tr2.jit_step(donate=False)(s2, batch_fn(5))
    assert bool(jnp.isfinite(m["loss"]))


def test_elastic_restage_fused_optimizer(cfg, monkeypatch):
    """Restage unflattens the fused flat-buffer moments and re-flattens them
    for the new trainer (both trainers on the fused path)."""
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "interpret")
    batch_fn, _ = make_batch_fn(cfg, 1, 4, 32, seed=2)
    tr4 = AsyncTrainer(cfg, EngineCfg(n_stages=4, lr=1e-3, constant_lr=True), "ours")
    assert tr4.opt.kind == "nadam_flat"
    s4 = tr4.init(jax.random.PRNGKey(0))
    step4 = tr4.jit_step(donate=False)
    for i in range(3):
        s4, _ = step4(s4, batch_fn(i))
    tr2 = AsyncTrainer(cfg, EngineCfg(n_stages=2, lr=1e-3, constant_lr=True), "ours")
    s2 = ckpt.restage(s4, tr4, tr2)
    assert int(s2.step) == int(s4.step)
    # moments migrated, not reset
    assert float(jnp.sum(jnp.abs(s2.opt[0]["flat"]["m"]))) > 0
    assert int(s2.opt[0]["count"]) == int(s4.opt[0]["count"])
    m4 = tr4.merge_params(s4)
    m2 = tr2.merge_params(s2)
    for a, b in zip(jax.tree.leaves(m4), jax.tree.leaves(m2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-7)
    s2b, m = tr2.jit_step(donate=False)(s2, batch_fn(5))
    assert bool(jnp.isfinite(m["loss"]))


def test_checkpoint_restores_across_optimizer_layouts(cfg, monkeypatch):
    """A tree-map checkpoint resumes under the fused backend and vice versa
    (same config trained under a different REPRO_KERNEL_BACKEND)."""
    import tempfile as _tf

    ecfg = EngineCfg(n_stages=2, lr=1e-3, constant_lr=True)
    batch_fn, _ = make_batch_fn(cfg, 1, 4, 32, seed=3)
    monkeypatch.delenv("REPRO_KERNEL_BACKEND", raising=False)
    tr_tree = AsyncTrainer(cfg, ecfg, "ours")  # CPU default: tree-map nadam
    s_tree = tr_tree.init(jax.random.PRNGKey(0))
    step = tr_tree.jit_step(donate=False)
    for i in range(3):
        s_tree, _ = step(s_tree, batch_fn(i))
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "interpret")
    tr_flat = AsyncTrainer(cfg, ecfg, "ours")
    assert tr_flat.opt.kind == "nadam_flat"
    s_flat_like = tr_flat.init_from_params(tr_tree.merge_params(s_tree))
    with _tf.TemporaryDirectory() as d:
        path = os.path.join(d, "x.npz")
        # tree-map ckpt -> fused state
        ckpt.save(path, s_tree, 3)
        restored, meta = ckpt.restore(path, s_flat_like)
        assert meta["step"] == 3
        from repro.optim.optimizers import flatten_tree
        for i in range(2):
            np.testing.assert_allclose(
                np.asarray(restored.opt[i]["flat"]["m"]),
                np.asarray(flatten_tree(s_tree.opt[i]["m"])), atol=1e-7)
            np.testing.assert_allclose(
                np.asarray(restored.opt[i]["flat"]["p"]),
                np.asarray(flatten_tree(restored.params[i])), atol=1e-7)
        # and back: fused ckpt -> tree-map state
        ckpt.save(path, restored, 4)
        back, _ = ckpt.restore(path, s_tree)
        for i in range(2):
            for a, b in zip(jax.tree.leaves(back.opt[i]["m"]),
                            jax.tree.leaves(s_tree.opt[i]["m"])):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-7)
        # fused run continues from the converted state
        s_next, m = tr_flat.jit_step(donate=False)(restored, batch_fn(9))
        assert bool(jnp.isfinite(m["loss"]))


def test_checkpoint_shape_mismatch_rejected(cfg):
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "x.npz")
        ckpt.save(path, {"a": jnp.ones((3,))}, 0)
        with pytest.raises(ValueError):
            ckpt.restore(path, {"a": jnp.ones((4,))})


# ---- swarm ------------------------------------------------------------------


@pytest.mark.parametrize("compress", [False, True])
def test_swarm_stage_dp(cfg, compress):
    sw = SwarmTrainer(cfg, EngineCfg(n_stages=2, lr=2e-3, constant_lr=True),
                      "ours_nows", SwarmCfg(replicas=2, sync_every=3, compress=compress))
    ss = sw.init(jax.random.PRNGKey(0))
    step = sw.jit_step()
    f1, _ = make_batch_fn(cfg, 1, 4, 32, seed=0)
    f2, _ = make_batch_fn(cfg, 1, 4, 32, seed=9)
    losses = []
    for i in range(9):
        b = jax.tree.map(lambda a, c: jnp.stack([a, c]), f1(i), f2(i))
        ss, m = step(ss, b)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all() and losses[-1] < losses[0]
    # post-sync: replicas agree (uncompressed only; EF leaves residuals)
    if not compress:
        for p in ss.inner.params:
            for leaf in jax.tree.leaves(p):
                np.testing.assert_allclose(np.asarray(leaf[0]), np.asarray(leaf[1]),
                                           atol=1e-6)


# ---- optimizers / schedules --------------------------------------------------


def test_adamw_matches_closed_form():
    opt = adamw(lr=0.1, b1=0.9, b2=0.99, eps=0.0, wd=0.0)
    p = {"w": jnp.ones((3,))}
    g = {"w": jnp.full((3,), 2.0)}
    st = opt.init(p)
    newp, st, _ = opt.update(p, g, st)
    # first step: m_hat = g, v_hat = g^2 -> update = sign(g) * lr
    np.testing.assert_allclose(np.asarray(newp["w"]), 1.0 - 0.1, rtol=1e-6)


def test_nadam_discount_toggle_changes_step():
    # NOTE: at step 1 the bias correction (1-mu_prod) exactly cancels the (1-mu_t)
    # discount, so the variants only diverge from step 2 on.
    p1 = p2 = {"w": jnp.ones((4,))}
    g = {"w": jnp.full((4,), 0.5)}
    o1 = nadam(lr=0.1, b1=0.99, discount=True)
    o2 = nadam(lr=0.1, b1=0.99, discount=False)
    s1, s2 = o1.init(p1), o2.init(p2)
    for _ in range(3):
        p1, s1, _ = o1.update(p1, g, s1)
        p2, s2, _ = o2.update(p2, g, s2)
    assert not np.allclose(np.asarray(p1["w"]), np.asarray(p2["w"]))
    # the no-discount variant travels farther (undamped gradient term)
    assert abs(float(p2["w"][0] - 1)) > abs(float(p1["w"][0] - 1))


def test_sgd_nag_lookahead_aux():
    opt = sgd_nag(lr=0.1, gamma=0.9)
    p = {"w": jnp.ones((2,))}
    g = {"w": jnp.full((2,), 1.0)}
    st = opt.init(p)
    p1, st, aux = opt.update(p, g, st)
    look = aux["lookahead"]["w"]
    np.testing.assert_allclose(np.asarray(look),
                               np.asarray(p1["w"] + 0.9 * (p1["w"] - p["w"])), rtol=1e-6)


def test_lr_discount_schedule():
    t0 = schedules.lr_discount_factor(4, jnp.asarray(0), 100)
    tT = schedules.lr_discount_factor(4, jnp.asarray(100), 100)
    assert float(t0) == pytest.approx(0.25)  # eta / tau at t=0
    assert float(tT) == pytest.approx(1.0)  # annealed away
    assert schedules.stage_momentum(1, 8) == pytest.approx(0.9 + 0.09 * 7 / 8)
    assert schedules.stage_momentum(8, 8) == pytest.approx(0.9)


def test_warmup_cosine_shape():
    s = schedules.warmup_cosine(3e-4, 10, 100, init_lr=1e-7)
    assert float(s(jnp.asarray(0))) == pytest.approx(1e-7)
    assert float(s(jnp.asarray(10))) == pytest.approx(3e-4, rel=1e-3)
    assert float(s(jnp.asarray(100))) == pytest.approx(3e-5, rel=1e-3)


def test_polyfft_predicts_linear_trend():
    params = {"w": jnp.zeros((4,))}
    hist = 8
    st = forecast.init_history(params, hist)
    for t in range(hist):
        st = forecast.push_history(st, {"w": jnp.full((4,), float(t))}, hist)
    pred = forecast.polyfft_predict(st, hist, tau=2.0, fft_weight=0.0)
    # linear sequence 0..7, predict t=9 -> 9
    np.testing.assert_allclose(np.asarray(pred["w"]), 9.0, atol=1e-3)


def test_second_order_correction_direction():
    g = {"w": jnp.asarray([1.0, -1.0])}
    now = {"w": jnp.asarray([1.0, 1.0])}
    stale = {"w": jnp.asarray([0.0, 0.0])}
    out = forecast.second_order_correct(g, now, stale, lam=1.0)
    np.testing.assert_allclose(np.asarray(out["w"]), [2.0, 0.0])


def test_train_loop_requires_key(cfg):
    """RNG002 regression: the PRNGKey(0) fallback silently decoupled runs from
    --seed; a fresh loop must be given its key (or a pre-built state)."""
    ecfg = EngineCfg(n_stages=2, lr=1e-3, constant_lr=True)
    batch_fn, _ = make_batch_fn(cfg, 1, 2, 16, seed=0)
    with pytest.raises(ValueError, match="key"):
        ftloop.train_loop(AsyncTrainer(cfg, ecfg, "ours"), batch_fn, 1)


def test_train_loop_seeds_actually_diverge(cfg):
    """Two different seeds must produce different inits and different loss
    trajectories (the old fallback made every keyless run seed-0)."""
    ecfg = EngineCfg(n_stages=2, lr=1e-3, constant_lr=True)
    batch_fn, _ = make_batch_fn(cfg, 1, 2, 16, seed=0)
    out = {}
    for seed in (0, 1):
        tr = AsyncTrainer(cfg, ecfg, "ours")
        state, res = ftloop.train_loop(tr, batch_fn, 2,
                                       key=jax.random.PRNGKey(seed))
        out[seed] = (state, res.losses)
    s0, l0 = out[0]
    s1, l1 = out[1]
    assert l0 != l1, "seed 0 and seed 1 produced identical loss trajectories"
    diffs = [float(jnp.max(jnp.abs(a - b)))
             for a, b in zip(jax.tree.leaves(s0), jax.tree.leaves(s1))]
    assert max(diffs) > 0.0, "seed 0 and seed 1 produced identical states"
