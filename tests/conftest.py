import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# NOTE: no xla_force_host_platform_device_count here — smoke tests see 1 device.
# Multi-device tests spawn subprocesses (see test_dryrun.py) or request the
# device count via their own env before importing jax in a subprocess.
