import os
import sys
import zlib

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# NOTE: no xla_force_host_platform_device_count here — smoke tests see 1 device.
# Multi-device tests spawn subprocesses (see test_distributed.py) or request the
# device count via their own env before importing jax in a subprocess.

# REPRO_SANITIZE=1 flips the whole suite into fail-fast mode: jax_debug_nans +
# jax_enable_checks + strict (raising) non-finite quarantine. See docs/lint.md.
from repro.analysis import sanitize  # noqa: E402

sanitize.apply(verbose=True)


@pytest.fixture
def rng_key(request):
    """Deterministic per-test JAX PRNG key (seeded from the test's node id)."""
    import jax

    return jax.random.PRNGKey(zlib.crc32(request.node.nodeid.encode()) & 0x7FFFFFFF)
