"""Engine correctness: staged-VJP == autodiff, tau=0 async == sync, all methods run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import staged
from repro.core.engine import AsyncTrainer, EngineCfg
from repro.core.methods import METHODS
from repro.models import lm


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("nanogpt_134m", reduced=True)
    key = jax.random.PRNGKey(0)
    params = lm.init_lm(key, cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 4, 33), 0, cfg.vocab_size)
    batch = {"tokens": toks[..., :-1], "labels": toks[..., 1:]}
    return cfg, params, batch


def test_staged_grads_match_autodiff(setup):
    """Manual per-stage VJP chain == jax.grad of the monolithic loss."""
    cfg, params, batch = setup
    b0 = jax.tree.map(lambda x: x[0], batch)
    ref_loss, ref_grads = jax.value_and_grad(lambda p: lm.lm_loss(p, b0, cfg))(params)

    for P in (1, 2, 4):
        stages_p, ops = lm.split_stages(params, cfg, P)
        fns = staged.make_stage_fns(cfg, ops)
        loss, grads = staged.staged_loss_and_grads(fns, stages_p, stages_p, b0)
        np.testing.assert_allclose(float(loss), float(ref_loss), rtol=1e-5)
        # reassemble stage grads into monolithic layout and compare
        merged = {}
        for sp in grads:
            for k, v in sp.items():
                if k in ("scan",) and k in merged:
                    merged[k] = jax.tree.map(lambda a, b: jnp.concatenate([a, b], 0),
                                             merged[k], v)
                elif k == "tok_embed" and k in merged:
                    merged[k] = merged[k] + v  # embed used at stage0 + tied head
                elif k not in merged:
                    merged[k] = v
        for path in ("final_norm", "scan"):
            for g, r in zip(jax.tree.leaves(merged[path]), jax.tree.leaves(ref_grads[path])):
                np.testing.assert_allclose(np.asarray(g), np.asarray(r), rtol=2e-4, atol=2e-5)
        np.testing.assert_allclose(np.asarray(merged["tok_embed"]),
                                   np.asarray(ref_grads["tok_embed"]), rtol=2e-4, atol=2e-5)


def test_async_tau_zero_equals_sync(setup):
    """With all delays forced to 0, 'pipedream' == 'gpipe' exactly."""
    cfg, params, batch = setup
    e_sync = EngineCfg(n_stages=4, lr=1e-3, constant_lr=True, collect_metrics=False)
    e_async = EngineCfg(n_stages=4, lr=1e-3, constant_lr=True, collect_metrics=False,
                        straggler_delays=(0, 0, 0, 0))
    t1 = AsyncTrainer(cfg, e_sync, "gpipe")
    t2 = AsyncTrainer(cfg, e_async, "pipedream")
    s1 = t1.init_from_params(params)
    s2 = t2.init_from_params(params)
    st1, st2 = t1.jit_step(donate=False), t2.jit_step(donate=False)
    for i in range(5):
        s1, m1 = st1(s1, batch)
        s2, m2 = st2(s2, batch)
        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-6)
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("method", sorted(METHODS))
def test_all_methods_step_and_learn(setup, method):
    cfg, params, batch = setup
    ecfg = EngineCfg(n_stages=4, lr=2e-3, constant_lr=True)
    tr = AsyncTrainer(cfg, ecfg, method)
    state = tr.init_from_params(params)
    step = tr.jit_step(donate=False)
    losses = []
    for i in range(20):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0]  # memorizes a fixed batch


def test_straggler_injection_and_adaptive_momentum(setup):
    """A straggling stage = larger tau; delay-adaptive momentum keeps training."""
    cfg, params, batch = setup
    straggler = (9, 2, 1, 0)  # stage 1 struggles
    ecfg = EngineCfg(n_stages=4, lr=1e-3, constant_lr=True,
                     straggler_delays=straggler)
    tr = AsyncTrainer(cfg, ecfg, "ours_delay_adaptive")
    assert tr.taus == straggler
    state = tr.init_from_params(params)
    step = tr.jit_step(donate=False)
    losses = [float(step(state, batch)[1]["loss"])]
    for i in range(15):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all() and losses[-1] < losses[0]


def test_grad_accum_matches_big_batch(setup):
    """K microbatches accumulated == one 4x batch (sync method, same tokens)."""
    cfg, params, _ = setup
    toks = jax.random.randint(jax.random.PRNGKey(5), (4, 2, 17), 0, cfg.vocab_size)
    b_micro = {"tokens": toks[..., :-1], "labels": toks[..., 1:]}
    b_full = {"tokens": toks[..., :-1].reshape(1, 8, 16),
              "labels": toks[..., 1:].reshape(1, 8, 16)}
    ecfg = EngineCfg(n_stages=2, lr=1e-3, constant_lr=True, collect_metrics=False)
    t1 = AsyncTrainer(cfg, ecfg, "gpipe")
    s1 = t1.init_from_params(params)
    s1b, m1 = t1.jit_step(donate=False)(s1, b_micro)
    t2 = AsyncTrainer(cfg, ecfg, "gpipe")
    s2 = t2.init_from_params(params)
    s2b, m2 = t2.jit_step(donate=False)(s2, b_full)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    # atol floor: scan-accumulated vs fused-batch grads differ in f32 summation
    # order, and Adam's rsqrt normalizer amplifies that on near-zero entries
    for a, b in zip(jax.tree.leaves(s1b.params), jax.tree.leaves(s2b.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)


def test_fused_optimizer_matches_treemap(setup, monkeypatch):
    """REPRO_KERNEL_BACKEND=interpret routes 'ours' through the fused flat-buffer
    nag_update kernel; losses match the tree-map nadam path within 1e-5 over 10
    ticks (same model kernels both sides — only the optimizer path differs)."""
    cfg, params, batch = setup
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "interpret")
    ecfg = EngineCfg(n_stages=2, lr=2e-3, constant_lr=True, collect_metrics=False)
    t_fused = AsyncTrainer(cfg, ecfg, "ours")
    assert t_fused.opt.kind == "nadam_flat"  # dispatch routed the fused kernel
    ecfg_ref = EngineCfg(n_stages=2, lr=2e-3, constant_lr=True,
                         collect_metrics=False, fused_optimizer=False)
    t_ref = AsyncTrainer(cfg, ecfg_ref, "ours")
    assert t_ref.opt.kind == "nadam"
    s_f = t_fused.init_from_params(params)
    s_r = t_ref.init_from_params(params)
    step_f, step_r = t_fused.jit_step(donate=False), t_ref.jit_step(donate=False)
    for i in range(10):
        s_f, m_f = step_f(s_f, batch)
        s_r, m_r = step_r(s_r, batch)
        np.testing.assert_allclose(float(m_f["loss"]), float(m_r["loss"]),
                                   rtol=1e-5, atol=1e-5)
    # parameters agree too, not just losses
    for a, b in zip(jax.tree.leaves(s_f.params), jax.tree.leaves(s_r.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)
    # flat fp32 master copy stays bit-consistent with the pytree params
    from repro.optim.optimizers import flatten_tree
    for i in range(t_fused.P):
        np.testing.assert_array_equal(
            np.asarray(s_f.opt[i]["flat"]["p"]),
            np.asarray(flatten_tree(s_f.params[i])))


def test_fused_optimizer_metrics_and_stage_momentum(setup, monkeypatch):
    """Fused path supports Eq. 13 stage momentum + the Prop.-1 alignment metrics."""
    cfg, params, batch = setup
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "interpret")
    ecfg = EngineCfg(n_stages=2, lr=2e-3, constant_lr=True, collect_metrics=True)
    tr = AsyncTrainer(cfg, ecfg, "ours_delay_adaptive")
    assert tr.opt.kind == "nadam_flat"
    state = tr.init_from_params(params)
    step = tr.jit_step(donate=False)
    losses = []
    for i in range(8):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
        assert np.isfinite(float(m["stage1_gap_rmse"]))
    assert np.isfinite(losses).all() and losses[-1] < losses[0]


def test_merge_params_roundtrip(setup):
    cfg, params, batch = setup
    ecfg = EngineCfg(n_stages=4, lr=1e-3, constant_lr=True)
    tr = AsyncTrainer(cfg, ecfg, "ours")
    state = tr.init_from_params(params)
    merged = tr.merge_params(state)
    b0 = jax.tree.map(lambda x: x[0], batch)
    l1 = lm.lm_loss(params, b0, cfg)
    l2 = lm.lm_loss(merged, b0, cfg)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)
