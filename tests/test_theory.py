"""Empirical checks of the paper's theory (Sec. 3 / Appendix A).

Theorem 1 assumes convex, beta-smooth, **bounded-gradient** objectives — we test on
log-cosh composites (exactly that class), not quadratics (unbounded gradients).
What is measurable at finite horizons:
  - small delays (tau <= 1) at the theorem's eta = 1/beta: clean convergence;
  - any delay with the standard delay-scaled eta = 1/(beta(1+tau)) (the theorem's
    constants absorb tau; the paper itself does not claim tight constants):
    monotone-ish decrease, tau-dependent progress;
  - Fig. 7 / the discount's necessity: without (1-gamma_t) the iterates blow up by
    orders of magnitude at tau >= 3 — robust across seeds.
Proposition 1 (look-ahead/delay alignment -> 1 as gamma -> 1) is checked directly.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st


def _logcosh(key, dim):
    """Convex, beta-smooth, bounded-gradient objective: sum log cosh(A (w - opt))."""
    a = jax.random.normal(key, (dim, dim)) / np.sqrt(dim)
    opt = jax.random.normal(jax.random.fold_in(key, 1), (dim,))
    f = lambda w: jnp.sum(jnp.logaddexp(a @ (w - opt), -(a @ (w - opt))) - np.log(2))
    beta = float(jnp.linalg.eigvalsh(a.T @ a)[-1])
    return f, jax.grad(f), beta, opt


def _run_eq10(f, g, beta, opt, tau, steps, *, discount=True, offset=0.7,
              delay_scale=False, gamma_const=None):
    """Paper Eq. 10/14 with a fixed-delay gradient oracle (ring of look-aheads)."""
    eta = 1.0 / (beta * (1 + tau)) if delay_scale else 1.0 / beta
    w = opt + offset
    w_prev = w
    look = [w] * (tau + 1)
    losses, step_norms = [], []
    for t in range(1, steps + 1):
        gamma = max((t - 2) / t, 0.0) if gamma_const is None else gamma_const
        d = gamma * (w - w_prev)
        grad = g(look[0])
        coef = (1 - gamma) if discount else 1.0
        w_new = w + d - eta * coef * grad
        look = look[1:] + [w_new + gamma * (w_new - w)]
        step_norms.append(float(jnp.linalg.norm(w_new - w)))
        w_prev, w = w, w_new
        losses.append(float(f(w)))
    return losses, step_norms


@settings(max_examples=10, deadline=None, derandomize=True)
@given(seed=st.integers(0, 10_000), tau=st.integers(0, 1), dim=st.integers(2, 12))
def test_theorem1_small_delay_at_theorem_lr(seed, tau, dim):
    """tau <= 1 at eta = 1/beta: the O(1/t) regime is visible at 600 steps."""
    f, g, beta, opt = _logcosh(jax.random.PRNGKey(seed), dim)
    losses, _ = _run_eq10(f, g, beta, opt, tau, 600)
    assert np.isfinite(losses).all()
    assert losses[-1] < 2e-2 * losses[0]
    # decreasing tail, unless already at float-eps convergence
    assert losses[-1] <= max(losses[len(losses) // 4] * 0.9, 1e-6)


@settings(max_examples=10, deadline=None, derandomize=True)
@given(seed=st.integers(0, 10_000), tau=st.integers(0, 6), dim=st.integers(2, 12))
def test_theorem1_any_delay_with_scaled_lr(seed, tau, dim):
    """Any fixed delay with delay-scaled eta: stable (no blowup) and converging.

    Thm 1's bound permits an O((tau+1)^2 ln t) transient before the 1/t factor
    wins, so we assert boundedness + net progress, not monotonicity."""
    f, g, beta, opt = _logcosh(jax.random.PRNGKey(seed), dim)
    losses, _ = _run_eq10(f, g, beta, opt, tau, 800, delay_scale=True)
    assert np.isfinite(losses).all()
    assert max(losses) < 50 * losses[0] + 1.0  # bounded (no divergence)
    target = 0.05 if tau <= 2 else 0.75
    assert losses[-1] < target * losses[0] + 1e-9


def test_discount_is_necessary_under_delay():
    """Fig. 7: without the (1-gamma_t) factor, delayed NAG blows up by orders of
    magnitude; with it, iterates stay bounded and decrease."""
    for seed in (0, 5):
        f, g, beta, opt = _logcosh(jax.random.PRNGKey(seed), 8)
        good, _ = _run_eq10(f, g, beta, opt, tau=5, steps=600, delay_scale=True)
        bad, _ = _run_eq10(f, g, beta, opt, tau=5, steps=600, delay_scale=True,
                           discount=False)
        assert good[-1] < good[0]
        assert bad[-1] > 50 * good[-1]


def test_prop1_alignment_increases_with_gamma():
    """cos(Delta_t, d_bar_t) approaches 1 as gamma -> 1 (Prop. 1)."""
    f, g, beta, opt = _logcosh(jax.random.PRNGKey(1), 10)
    tau = 4
    eta = 1.0 / beta

    def run(gamma):
        w = opt + 1.0
        w_prev = w
        d_hist = [jnp.zeros((10,))] * (tau + 1)
        w_hist = [w] * (tau + 1)
        coss = []
        for t in range(1, 300):
            d = gamma * (w - w_prev)
            u = w_hist[0] + d_hist[0]
            w_new = w + d - eta * (1 - gamma) * g(u)
            delta = w_new - w_hist[0]  # Delta_t = w_t - w_{t-tau}
            dbar = d_hist[0]
            denom = jnp.linalg.norm(delta) * jnp.linalg.norm(dbar)
            if denom > 1e-12 and t > 50:
                coss.append(float(delta @ dbar / denom))
            d_hist = d_hist[1:] + [gamma * (w_new - w)]
            w_hist = w_hist[1:] + [w_new]
            w_prev, w = w, w_new
        return np.mean(coss) if coss else 0.0

    c_low, c_hi = run(0.5), run(0.99)
    assert c_hi > 0.9
    assert c_hi >= c_low - 1e-6
