"""Differential parity harness for the kernel dispatch registry.

Auto-discovers every registered op and checks pallas(interpret) against the
pure-XLA ref oracle over the op's registered shape cases (tile-aligned, ragged,
non-tile-aligned) x dtypes (fp32 and bf16 activations/grads). Adding a kernel to
kernels/dispatch.py with cases makes it covered here with no further test code.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import dispatch

REQUIRED_OPS = {"flash_attention", "ssd_scan", "nag_update", "rmsnorm_residual"}


def test_registry_covers_kernel_suite():
    assert REQUIRED_OPS <= set(dispatch.registered_ops())
    for name in dispatch.registered_ops():
        assert len(dispatch.parity_cases(name)) >= 3, f"{name}: needs >= 3 shape cases"


def _all_cases():
    for name in dispatch.registered_ops():
        for case in dispatch.parity_cases(name):
            for dtype in (jnp.float32, jnp.bfloat16):
                yield pytest.param(name, case, dtype,
                                   id=f"{name}-{case.label}-{dtype.__name__}")


@pytest.mark.parametrize("name,case,dtype", list(_all_cases()))
def test_interpret_matches_ref(name, case, dtype, rng_key):
    args, kwargs = case.make(rng_key, dtype)
    got = dispatch.dispatch(name, *args, backend="interpret", **kwargs)
    want = dispatch.dispatch(name, *args, backend="ref", **kwargs)
    tol = case.tol(dtype)
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        assert g.shape == w.shape and g.dtype == w.dtype
        np.testing.assert_allclose(np.asarray(g, np.float32), np.asarray(w, np.float32),
                                   rtol=tol, atol=tol)


@pytest.mark.parametrize("name", ["rmsnorm_residual", "flash_attention"])
def test_dispatch_grad_matches_ref_grad(name, rng_key):
    """dispatch_grad: interpret forward + ref-VJP backward == ref end-to-end grad."""
    case = dispatch.parity_cases(name)[0]
    args, kwargs = case.make(rng_key, jnp.float32)

    def loss_via(backend):
        def f(*xs):
            out = dispatch.dispatch_grad(name, *xs, backend=backend, **kwargs)
            return sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                       for l in jax.tree.leaves(out))
        return f

    g_int = jax.grad(loss_via("interpret"), argnums=tuple(range(len(args))))(*args)
    g_ref = jax.grad(loss_via("ref"), argnums=tuple(range(len(args))))(*args)
    for a, b in zip(jax.tree.leaves(g_int), jax.tree.leaves(g_ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Backend selection precedence: env var > cfg field > platform default
# ---------------------------------------------------------------------------


def test_backend_resolution_precedence(monkeypatch):
    monkeypatch.delenv(dispatch.ENV_VAR, raising=False)
    platform_default = "pallas" if jax.default_backend() == "tpu" else "ref"
    assert dispatch.resolve_backend(None) == platform_default
    assert dispatch.resolve_backend("interpret") == "interpret"  # cfg beats platform
    monkeypatch.setenv(dispatch.ENV_VAR, "interpret")
    assert dispatch.resolve_backend(None) == "interpret"
    assert dispatch.resolve_backend("ref") == "interpret"  # env beats cfg
    monkeypatch.setenv(dispatch.ENV_VAR, "bogus")
    with pytest.raises(ValueError):
        dispatch.resolve_backend(None)
    monkeypatch.delenv(dispatch.ENV_VAR, raising=False)
    with pytest.raises(ValueError):
        dispatch.resolve_backend("also-bogus")


@pytest.mark.parametrize("arch", ["nanogpt_134m", "mamba2_370m"])
def test_model_loss_and_grads_parity(arch, monkeypatch, rng_key):
    """End-to-end model wiring check: lm_loss value+grad with the dispatched
    kernels (interpret) vs the unfused path agree — covers the attention
    transpose plumbing, the deferred-residual fusion, and the SSD branch."""
    import dataclasses

    from repro.configs import get_config
    from repro.models import lm

    monkeypatch.delenv(dispatch.ENV_VAR, raising=False)
    base = get_config(arch, reduced=True)
    params = lm.init_lm(rng_key, base)
    toks = jax.random.randint(jax.random.fold_in(rng_key, 1), (2, 33),
                              0, base.vocab_size)
    batch = {"tokens": toks[..., :-1], "labels": toks[..., 1:]}

    def run(backend):
        cfg = dataclasses.replace(base, kernel_backend=backend)
        return jax.value_and_grad(lambda p: lm.lm_loss(p, batch, cfg))(params)

    l_ref, g_ref = run("ref")
    l_int, g_int = run("interpret")
    np.testing.assert_allclose(float(l_int), float(l_ref), rtol=1e-6, atol=1e-6)
    for a, b in zip(jax.tree.leaves(g_int), jax.tree.leaves(g_ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


def test_custom_positions_bypass_fused_attention(monkeypatch, rng_key):
    """Batch-supplied positions (packed sequences) must take the bias path even
    with a fused backend: results match the ref path exactly in that case."""
    import dataclasses

    from repro.configs import get_config
    from repro.models import lm

    monkeypatch.delenv(dispatch.ENV_VAR, raising=False)
    base = get_config("nanogpt_134m", reduced=True)
    params = lm.init_lm(rng_key, base)
    toks = jax.random.randint(jax.random.fold_in(rng_key, 1), (2, 33),
                              0, base.vocab_size)
    # two packed docs: positions reset mid-sequence
    pos = jnp.concatenate([jnp.arange(16), jnp.arange(16)])[None, :].repeat(2, 0)
    batch = {"tokens": toks[..., :-1], "labels": toks[..., 1:],
             "positions": pos.astype(jnp.int32)}

    losses = {}
    for backend in ("ref", "interpret"):
        cfg = dataclasses.replace(base, kernel_backend=backend)
        losses[backend] = float(lm.lm_loss(params, batch, cfg))
    assert losses["interpret"] == pytest.approx(losses["ref"], abs=1e-6)


def test_model_cfg_backend_field_routes(monkeypatch):
    from repro.models import layers as L

    monkeypatch.delenv(dispatch.ENV_VAR, raising=False)
    cfg = L.ModelCfg(kernel_backend="interpret")
    assert L.kernel_backend(cfg) == "interpret"
    monkeypatch.setenv(dispatch.ENV_VAR, "ref")
    assert L.kernel_backend(cfg) == "ref"  # env var wins over the cfg field
