"""Differential parity harness for the kernel dispatch registry.

Auto-discovers every registered op and checks pallas(interpret) against the
pure-XLA ref oracle over the op's registered shape cases (tile-aligned, ragged,
non-tile-aligned) x dtypes (fp32 and bf16 activations/grads) — for the FORWARD
outputs and, via ``jax.grad`` through ``dispatch_grad``, for the GRADIENTS
(dedicated backward kernels where registered, ref-VJP fallback elsewhere).
Adding a kernel to kernels/dispatch.py with cases makes it covered here with no
further test code.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import dispatch

REQUIRED_OPS = {"flash_attention", "ssd_scan", "nag_update", "rmsnorm_residual",
                "paged_attn_decode"}

# the training hot path must not fall back to the ref VJP for these: the whole
# point of the backward subsystem is that fwd+bwd are both fused kernel passes
REQUIRED_BWD_OPS = {"flash_attention", "ssd_scan", "rmsnorm_residual"}


def test_registry_covers_kernel_suite():
    assert REQUIRED_OPS <= set(dispatch.registered_ops())
    for name in dispatch.registered_ops():
        assert len(dispatch.parity_cases(name)) >= 3, f"{name}: needs >= 3 shape cases"


def test_backward_kernels_registered_no_ref_fallback():
    """flash_attention / ssd_scan / rmsnorm_residual carry dedicated backward
    kernels — dispatch_grad must not take the ref-VJP remat fallback for them."""
    for name in REQUIRED_BWD_OPS:
        op = dispatch.get_op(name)
        assert op.fwd_res is not None and op.bwd is not None, \
            f"{name}: missing dedicated backward (would remat through ref VJP)"


def _all_cases():
    for name in dispatch.registered_ops():
        for case in dispatch.parity_cases(name):
            for dtype in (jnp.float32, jnp.bfloat16):
                yield pytest.param(name, case, dtype,
                                   id=f"{name}-{case.label}-{dtype.__name__}")


@pytest.mark.parametrize("name,case,dtype", list(_all_cases()))
def test_interpret_matches_ref(name, case, dtype, rng_key):
    args, kwargs = case.make(rng_key, dtype)
    got = dispatch.dispatch(name, *args, backend="interpret", **kwargs)
    want = dispatch.dispatch(name, *args, backend="ref", **kwargs)
    tol = case.tol(dtype)
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        assert g.shape == w.shape and g.dtype == w.dtype
        np.testing.assert_allclose(np.asarray(g, np.float32), np.asarray(w, np.float32),
                                   rtol=tol, atol=tol)


@pytest.mark.parametrize("name,case,dtype", list(_all_cases()))
def test_grad_parity_interpret_vs_ref(name, case, dtype, rng_key):
    """jax.grad through dispatch_grad (interpret fwd + registered backward
    kernels, ref-VJP fallback for ops without one) == ref autodiff end to end,
    for every registered op x case x dtype. Gradient comparisons are normalized
    by the ref gradient's scale (grads of a quadratic loss grow with the output
    magnitude; the registered tolerances are relative-class bounds)."""
    args, kwargs = case.make(rng_key, dtype)

    def loss_via(backend):
        def f(*xs):
            out = dispatch.dispatch_grad(name, *xs, backend=backend, **kwargs)
            return sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                       for l in jax.tree.leaves(out))
        return f

    # differentiate only wrt inexact args: ops like paged_attn_decode carry
    # int32 routing operands (page tables, lengths) that have no gradient
    argnums = tuple(i for i, a in enumerate(args)
                    if jnp.issubdtype(jnp.asarray(a).dtype, jnp.inexact))
    g_int = jax.grad(loss_via("interpret"), argnums=argnums)(*args)
    g_ref = jax.grad(loss_via("ref"), argnums=argnums)(*args)
    tol = case.grad_tol(dtype)
    for i, (a, b) in enumerate(zip(jax.tree.leaves(g_int), jax.tree.leaves(g_ref))):
        assert a.shape == b.shape and a.dtype == b.dtype, f"arg {i}"
        a = np.asarray(a, np.float32)
        b = np.asarray(b, np.float32)
        scale = max(1.0, float(np.abs(b).max()))
        np.testing.assert_allclose(a, b, rtol=tol, atol=tol * scale,
                                   err_msg=f"grad wrt arg {i}")


def test_flash_attention_saved_lse_matches_ref():
    """The forward's saved backward residual (row logsumexp), not just its
    output, must match the dense oracle — a wrong lse silently skews every
    recomputed p tile in the backward."""
    from repro.kernels import ref as kref
    from repro.kernels.flash_attention import flash_attention

    key = jax.random.PRNGKey(11)
    q = jax.random.normal(key, (2, 4, 96, 32))
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, 2, 96, 32))
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, 2, 96, 32))
    o, lse = flash_attention(q, k, v, block_q=64, block_k=64, return_residuals=True)
    o_ref, lse_ref = kref.attention_ref(q, k, v, return_lse=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(lse), np.asarray(lse_ref), rtol=2e-5, atol=2e-5)


def test_dispatch_grad_vjp_cache_reuse(rng_key):
    """dispatch_grad must reuse ONE memoized custom_vjp per (op, backend,
    static kwargs) — a fresh closure per call is a new callable identity that
    re-traces at every jit call site."""
    case = dispatch.parity_cases("rmsnorm_residual")[0]
    args, kwargs = case.make(rng_key, jnp.float32)
    dispatch._VJP_CACHE.clear()
    before = dict(dispatch.vjp_cache_stats)
    dispatch.dispatch_grad("rmsnorm_residual", *args, backend="interpret", **kwargs)
    assert len(dispatch._VJP_CACHE) == 1
    cached = next(iter(dispatch._VJP_CACHE.values()))
    dispatch.dispatch_grad("rmsnorm_residual", *args, backend="interpret", **kwargs)
    assert dispatch.vjp_cache_stats["misses"] == before["misses"] + 1
    assert dispatch.vjp_cache_stats["hits"] == before["hits"] + 1
    # the second call ran the SAME callable object, not a rebuilt closure
    assert next(iter(dispatch._VJP_CACHE.values())) is cached
    # same op under different static kwargs is a distinct kernel variant
    dispatch.dispatch_grad("rmsnorm_residual", *args, backend="interpret",
                           **{**kwargs, "eps": 1e-5})
    assert len(dispatch._VJP_CACHE) == 2
    assert dispatch.vjp_cache_stats["misses"] == before["misses"] + 2


# ---------------------------------------------------------------------------
# Backend selection precedence: env var > cfg field > platform default
# ---------------------------------------------------------------------------


def test_backend_resolution_precedence(monkeypatch):
    monkeypatch.delenv(dispatch.ENV_VAR, raising=False)
    platform_default = "pallas" if jax.default_backend() == "tpu" else "ref"
    assert dispatch.resolve_backend(None) == platform_default
    assert dispatch.resolve_backend("interpret") == "interpret"  # cfg beats platform
    monkeypatch.setenv(dispatch.ENV_VAR, "interpret")
    assert dispatch.resolve_backend(None) == "interpret"
    assert dispatch.resolve_backend("ref") == "interpret"  # env beats cfg
    monkeypatch.setenv(dispatch.ENV_VAR, "bogus")
    with pytest.raises(ValueError):
        dispatch.resolve_backend(None)
    monkeypatch.delenv(dispatch.ENV_VAR, raising=False)
    with pytest.raises(ValueError):
        dispatch.resolve_backend("also-bogus")


@pytest.mark.parametrize("arch", ["nanogpt_134m", "mamba2_370m"])
def test_model_loss_and_grads_parity(arch, monkeypatch, rng_key):
    """End-to-end model wiring check: lm_loss value+grad with the dispatched
    kernels (interpret) vs the unfused path agree — covers the attention
    transpose plumbing, the deferred-residual fusion, and the SSD branch."""
    import dataclasses

    from repro.configs import get_config
    from repro.models import lm

    monkeypatch.delenv(dispatch.ENV_VAR, raising=False)
    base = get_config(arch, reduced=True)
    params = lm.init_lm(rng_key, base)
    toks = jax.random.randint(jax.random.fold_in(rng_key, 1), (2, 33),
                              0, base.vocab_size)
    batch = {"tokens": toks[..., :-1], "labels": toks[..., 1:]}

    def run(backend):
        cfg = dataclasses.replace(base, kernel_backend=backend)
        return jax.value_and_grad(lambda p: lm.lm_loss(p, batch, cfg))(params)

    l_ref, g_ref = run("ref")
    l_int, g_int = run("interpret")
    np.testing.assert_allclose(float(l_int), float(l_ref), rtol=1e-6, atol=1e-6)
    for a, b in zip(jax.tree.leaves(g_int), jax.tree.leaves(g_ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


def test_custom_positions_bypass_fused_attention(monkeypatch, rng_key):
    """Batch-supplied positions (packed sequences) must take the bias path even
    with a fused backend: results match the ref path exactly in that case."""
    import dataclasses

    from repro.configs import get_config
    from repro.models import lm

    monkeypatch.delenv(dispatch.ENV_VAR, raising=False)
    base = get_config("nanogpt_134m", reduced=True)
    params = lm.init_lm(rng_key, base)
    toks = jax.random.randint(jax.random.fold_in(rng_key, 1), (2, 33),
                              0, base.vocab_size)
    # two packed docs: positions reset mid-sequence
    pos = jnp.concatenate([jnp.arange(16), jnp.arange(16)])[None, :].repeat(2, 0)
    batch = {"tokens": toks[..., :-1], "labels": toks[..., 1:],
             "positions": pos.astype(jnp.int32)}

    losses = {}
    for backend in ("ref", "interpret"):
        cfg = dataclasses.replace(base, kernel_backend=backend)
        losses[backend] = float(lm.lm_loss(params, batch, cfg))
    assert losses["interpret"] == pytest.approx(losses["ref"], abs=1e-6)


def test_model_cfg_backend_field_routes(monkeypatch):
    from repro.models import layers as L

    monkeypatch.delenv(dispatch.ENV_VAR, raising=False)
    cfg = L.ModelCfg(kernel_backend="interpret")
    assert L.kernel_backend(cfg) == "interpret"
    monkeypatch.setenv(dispatch.ENV_VAR, "ref")
    assert L.kernel_backend(cfg) == "ref"  # env var wins over the cfg field
