"""Fully-async 2D mesh coverage (DESIGN.md §13): sync-as-events gossip vs the
barrier SwarmTrainer, the ZeRO-1 sharded optimizer, and the equivalence
contracts that pin them:

(a) sharded-vs-replicated optimizer bitwise equivalence — `nadam_flat_sharded`
    (reduce-scatter mean + per-rank shard update + all-gather) must reproduce
    `nadam_flat` on the mean gradient exactly, including on a flat buffer whose
    length does not divide the world size (zero-padding path);
(b) gossip at zero delay / full fanout with period == sync_every must reduce to
    the barrier `SwarmTrainer.run_event` baseline bitwise;
(c) the sync-event runtime and its compute-free `simulate_mesh_schedule` twin
    must agree event-for-event under a jittered sync delay model;
(d) keyed partner selection is a pure function of (seed, round) — replay exact.

Plus the golden-trajectory regression (pinned seed-0 losses) and the
`checkpoint.restage`-across-replica-counts bugfix (R=2 <-> R=4 roundtrip with
sharded optimizer state).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ck
from repro.configs import get_config
from repro.core import events
from repro.core.engine import AsyncTrainer, EngineCfg
from repro.core.events import (drive_mesh, gossip_partners, make_mesh_spec,
                               make_sync_delay_model)
from repro.core.runtime import EventRuntime, simulate_mesh_schedule
from repro.core.swarm import MeshCfg, MeshTrainer, SwarmCfg, SwarmTrainer
from repro.data.synthetic import make_batch_fn
from repro.optim import optimizers as opt


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("nanogpt_134m", reduced=True)
    f1, _ = make_batch_fn(cfg, 1, 2, 32, seed=0)
    f2, _ = make_batch_fn(cfg, 1, 2, 32, seed=17)
    return cfg, (f1, f2)


def _ecfg(**kw):
    kw.setdefault("n_stages", 2)
    kw.setdefault("lr", 2e-3)
    kw.setdefault("constant_lr", True)
    kw.setdefault("collect_metrics", False)
    return EngineCfg(**kw)


# ---------------------------------------------------------------------------
# (d) keyed partner selection: pure function of (seed, round, r, R, fanout)
# ---------------------------------------------------------------------------

def test_gossip_partners_replay_exact():
    for seed in (0, 7):
        for rnd in range(6):
            for r in range(4):
                a = gossip_partners(seed, rnd, r, 4, fanout=1)
                b = gossip_partners(seed, rnd, r, 4, fanout=1)
                assert a == b  # pure replay — no hidden state
                assert len(a) == 1 and a[0] != r and 0 <= a[0] < 4


def test_gossip_partners_full_fanout_and_bounds():
    assert gossip_partners(0, 3, 1, 4) == (0, 2, 3)  # None -> everyone else
    assert gossip_partners(0, 3, 1, 4, fanout=99) == (0, 2, 3)
    assert gossip_partners(0, 0, 0, 1) == ()  # singleton mesh: nobody to call
    got = gossip_partners(0, 5, 2, 5, fanout=2)
    assert list(got) == sorted(got) and len(got) == 2 and 2 not in got
    with pytest.raises(ValueError):
        gossip_partners(0, 0, 4, 4)
    with pytest.raises(ValueError):
        gossip_partners(0, 0, 0, 2, fanout=0)


def test_gossip_partners_vary_by_round():
    """The round is part of the Philox word: a fanout-1 selection on R=8 must
    not pick the same partner every round (that would be a keying bug)."""
    picks = {gossip_partners(0, rnd, 0, 8, fanout=1)[0] for rnd in range(16)}
    assert len(picks) > 1


# ---------------------------------------------------------------------------
# sync delay models + spec parsing
# ---------------------------------------------------------------------------

def test_sync_delay_models_and_specs():
    assert make_sync_delay_model(None).latency(0, 1, 0, 0) == 0.0
    assert make_sync_delay_model("fixed:2.5").latency(0, 1, 0, 0) == 2.5
    jd = make_sync_delay_model("jitter:1.0,0.3", seed=4)
    a = jd.latency(0, 1, 0, 7)
    assert a == jd.latency(0, 1, 0, 7) > 0.0  # keyed replay, clamped positive
    assert a != jd.latency(1, 0, 0, 7)  # direction is part of the key
    with pytest.raises(ValueError):
        make_sync_delay_model("bogus:1")


def test_mesh_spec_grammar():
    sp = make_mesh_spec("gossip:4,2")
    assert (sp.mode, sp.period, sp.fanout) == ("gossip", 4, 2)
    sp = make_mesh_spec("gossip:8")
    assert (sp.mode, sp.period, sp.fanout) == ("gossip", 8, None)
    sp = make_mesh_spec("barrier:3")
    assert (sp.mode, sp.period, sp.fanout) == ("barrier", 3, None)
    for bad in ("gossip:0", "barrier:2,1", "ring:4", "gossip"):
        with pytest.raises(ValueError):
            make_mesh_spec(bad)


def test_mesh_cfg_validation():
    with pytest.raises(ValueError, match="mutually exclusive"):
        MeshCfg(replicas=2, compress=True, opt_shard=True)
    with pytest.raises(ValueError):
        MeshCfg(replicas=0)


# ---------------------------------------------------------------------------
# (a) ZeRO-1 sharded optimizer == replicated, bitwise
# ---------------------------------------------------------------------------

def _toy_params():
    # deliberately non-divisible total length for world in {2, 4}: n = 11
    # splits as 6+5 and 3+3+3+2 (+zero padding), exercising the pad path
    return {"w": jnp.arange(1, 9, dtype=jnp.float32) * 0.1,
            "b": jnp.asarray([0.5, -0.25, 0.125], jnp.float32)}


def _toy_grads(step, r):
    k = jax.random.PRNGKey(1000 * step + r)
    ka, kb = jax.random.split(k)
    return {"w": jax.random.normal(ka, (8,)) * 0.1,
            "b": jax.random.normal(kb, (3,)) * 0.1}


@pytest.mark.parametrize("world", [2, 4])
def test_sharded_optimizer_matches_replicated_bitwise(world):
    """nadam_flat_sharded over 10 steps == nadam_flat on the mean gradient:
    params AND moments bitwise equal (the shard update is the same elementwise
    kernel on a slice of the same flat buffer, so there is no fp wiggle room)."""
    params = _toy_params()
    n = sum(int(jnp.size(l)) for l in jax.tree.leaves(params))
    assert n % world != 0  # the non-divisible case is the point

    ref = opt.nadam_flat(lr=0.05, backend="ref")
    sh = opt.nadam_flat_sharded(lr=0.05, backend="ref", world=world)
    p_ref, s_ref = params, ref.init(params)
    p_sh, s_sh = params, sh.init(params)
    for t in range(10):
        grads = [_toy_grads(t, r) for r in range(world)]
        mean = jax.tree.map(
            lambda *gs: sum(g.astype(jnp.float32) for g in gs) / world, *grads)
        p_ref, s_ref, _ = ref.update(p_ref, mean, s_ref)
        p_sh, s_sh, _ = sh.update(p_sh, grads, s_sh)
        for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_sh)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # gather the sharded moments and compare against the replicated flat
        m_sh = opt.zero1_unshard([s["m"] for s in s_sh["shards"]], n)
        v_sh = opt.zero1_unshard([s["v"] for s in s_sh["shards"]], n)
        np.testing.assert_array_equal(np.asarray(s_ref["flat"]["m"]),
                                      np.asarray(m_sh))
        np.testing.assert_array_equal(np.asarray(s_ref["flat"]["v"]),
                                      np.asarray(v_sh))


def test_owner_shard_update_freezes_foreign_segments():
    """nadam_flat_shard (the per-replica mesh optimizer) only moves its own
    1/R segment of the flat buffer; everything else is bitwise frozen until a
    gossip absorption splices in the owners' segments."""
    params = _toy_params()
    n = sum(int(jnp.size(l)) for l in jax.tree.leaves(params))
    o = opt.nadam_flat_shard(rank=1, world=4, lr=0.05, backend="ref")
    st = o.init(params)
    p2, _, _ = o.update(params, _toy_grads(0, 0), st)
    f0 = np.asarray(opt.flatten_tree(params))
    f2 = np.asarray(opt.flatten_tree(p2))
    S = opt.zero1_shard_size(n, 4)
    lo, hi = 1 * S, min(2 * S, n)
    assert not np.array_equal(f0[lo:hi], f2[lo:hi])  # own segment moved
    np.testing.assert_array_equal(f0[:lo], f2[:lo])  # foreign segments frozen
    np.testing.assert_array_equal(f0[hi:], f2[hi:])


def test_zero1_shard_roundtrip_padding():
    flat = jnp.arange(10, dtype=jnp.float32)
    shards = [opt.zero1_shard(flat, r, 4) for r in range(4)]
    assert all(int(s.shape[0]) == 3 for s in shards)
    assert float(jnp.sum(jnp.abs(shards[3][1:]))) == 0.0  # zero padding
    np.testing.assert_array_equal(
        np.asarray(opt.zero1_unshard(shards, 10)), np.asarray(flat))


# ---------------------------------------------------------------------------
# (b) gossip degenerate case == barrier baseline, bitwise
# ---------------------------------------------------------------------------

def test_gossip_degenerate_equals_barrier_bitwise(setup):
    """Zero sync delay + full fanout + period == sync_every: the fully-async
    gossip mesh must reproduce the barrier SwarmTrainer.run_event baseline
    bitwise — same losses, same stage params. This is the contract that makes
    gossip a strict generalization rather than a different algorithm."""
    cfg, (f1, f2) = setup
    key = jax.random.PRNGKey(4)
    sw = SwarmTrainer(cfg, _ecfg(), "ours", SwarmCfg(replicas=2, sync_every=2))
    base = sw.run_event([f1, f2], 4, key=key)
    mt = MeshTrainer(cfg, _ecfg(), "ours", MeshCfg(replicas=2, period=2))
    mesh = mt.run_gossip([f1, f2], 4, key=key)
    assert mesh["losses"] == base["losses"]
    for rb, rm in zip(base["runtimes"], mesh["runtimes"]):
        for i in range(sw.inner.P):
            for a, b in zip(jax.tree.leaves(rb._stages[i].params),
                            jax.tree.leaves(rm._stages[i].params)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_gossip_opt_shard_trains_and_halves_optimizer_memory(setup):
    cfg, (f1, f2) = setup
    mt = MeshTrainer(cfg, _ecfg(), "ours",
                     MeshCfg(replicas=2, period=2, opt_shard=True))
    out = mt.run_gossip([f1, f2], 4, key=jax.random.PRNGKey(5))
    assert all(np.isfinite(np.asarray(ls)).all() for ls in out["losses"])
    assert out["opt_bytes_per_replica"] * 2 == out["opt_bytes_replicated"]


def test_run_gossip_requires_key(setup):
    cfg, (f1, f2) = setup
    mt = MeshTrainer(cfg, _ecfg(), "ours", MeshCfg(replicas=2))
    with pytest.raises(ValueError, match="key"):
        mt.run_gossip([f1, f2], 2)


# ---------------------------------------------------------------------------
# (c) sync-event runtime == compute-free twin, event-for-event
# ---------------------------------------------------------------------------

def test_mesh_runtime_matches_simulated_twin_event_for_event(setup):
    """Under a jittered sync delay model and heterogeneous per-replica compute
    delays, the real mesh run and simulate_mesh_schedule must produce the SAME
    event log — times, kinds, and (replica, stage, round) coordinates. The
    twin is how schedules are studied without paying compute; this contract is
    what makes those studies trustworthy."""
    cfg, (f1, f2) = setup
    kw = dict(period=2, sync_delay="jitter:0.3,0.5", seed=3,
              delay_models=["fixed:1,2", "fixed:1.5,2.5"])
    mt = MeshTrainer(cfg, _ecfg(), "ours",
                     MeshCfg(replicas=2, period=2, seed=3,
                             sync_delay=kw["sync_delay"]))
    real = mt.run_gossip([f1, f2], 4, key=jax.random.PRNGKey(6),
                         delay_models=kw["delay_models"])
    sim = simulate_mesh_schedule(R=2, P=2, K=1, n_ticks=4, **kw)
    assert real["events"] == sim["events"]
    assert real["makespan"] == sim["makespan"]
    assert real["absorbed"] == sim["absorbed"]


def test_drive_mesh_stale_rounds_are_dropped():
    """A contribution older than max_stale_rounds behind the absorber's round
    is discarded, bounding absorption staleness the way stash depth bounds
    activation staleness."""

    class OneSlow(events.SyncDelayModel):
        def latency(self, src, dst, stage, rnd):
            # replica 1's round-0 snapshot limps in at t=2.5: the next scan is
            # replica 0's round-3 start (t=3), where src_rnd=0 < 3 - 1 -> stale
            return 1.5 if (src == 1 and rnd == 0) else 0.0

    res = drive_mesh(2, 4, sync_delay=OneSlow(),
                     run_round=lambda r, rnd: 1.0, max_stale_rounds=1)
    assert res["stale_dropped"] >= 1
    # the per-absorb stale counts in the event log reconcile with the total
    assert sum(ev[5] for ev in res["events"] if ev[0] == "absorb") \
        == res["stale_dropped"]


def test_drive_mesh_newest_contribution_supersedes():
    """Two rounds of sends from the same (src, stage) landing before one
    absorption: only the newest is absorbed, the older counts as superseded."""
    seen = []

    class Burst(events.SyncDelayModel):
        def latency(self, src, dst, stage, rnd):
            # replica 1's round-0 and round-1 sends both arrive while replica 0
            # is still in its long round 1
            return 0.0

    res = drive_mesh(
        2, 3, sync_delay=Burst(),
        run_round=lambda r, rnd: 10.0 if (r == 0 and rnd == 1) else 1.0,
        absorb=lambda r, rnd, by_stage, now: seen.append(
            (r, rnd, {s: [(src, srnd) for src, srnd, _ in v]
                      for s, v in by_stage.items()})))
    assert res["superseded"] >= 1
    # replica 0's delayed absorption saw only replica 1's newest round
    multi = [e for e in seen if e[0] == 0 and e[1] >= 1]
    for _, _, by_stage in multi:
        for contribs in by_stage.values():
            srcs = [src for src, _ in contribs]
            assert len(srcs) == len(set(srcs))


# ---------------------------------------------------------------------------
# golden-trajectory regression: pinned seed-0 losses
# ---------------------------------------------------------------------------

# `ours` @ P=4, K=2, FixedDelay, seed 0, lr 2e-3, kernel_backend="ref".
# Regenerate (only if an INTENTIONAL numerics change lands) with:
#   REPRO_KERNEL_BACKEND=ref python - <<'EOF'
#   ... EventRuntime(AsyncTrainer(cfg, ecfg, "ours")).run(bf, 8) ...  # see test
GOLDEN_SEED0_LOSSES = [6.4472653866, 6.0256867409, 5.5859067440, 5.2982575893,
                       5.0709686279, 5.1914999485, 4.7844913006, 4.7289602757]


def test_golden_trajectory_seed0(monkeypatch):
    """First 8 ticks of the flagship config are pinned to 1e-6: any silent
    numerics drift anywhere in the stack (kernels, stash replay, optimizer,
    event ordering) trips this before it can contaminate benchmarks."""
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "ref")  # env wins; pin it
    cfg = get_config("nanogpt_134m", reduced=True)
    ecfg = EngineCfg(n_stages=4, lr=2e-3, constant_lr=True,
                     collect_metrics=False, update_interval=2,
                     kernel_backend="ref")
    bf, _ = make_batch_fn(cfg, 2, 2, 32, seed=0)
    rt = EventRuntime(AsyncTrainer(cfg, ecfg, "ours"))
    rt.init(jax.random.PRNGKey(0))
    res = rt.run(bf, 8)
    np.testing.assert_allclose(res.losses, GOLDEN_SEED0_LOSSES,
                               rtol=0, atol=1e-6)


# ---------------------------------------------------------------------------
# checkpoint.restage across replica counts (the R=2 <-> R=4 roundtrip bugfix)
# ---------------------------------------------------------------------------

def test_zero1_restage_roundtrip_r2_to_r4(setup):
    """Sharded opt state cannot be restaged directly (each replica holds 1/R of
    the moments); the documented recipe is merge -> reshard at the target R.
    R=2 -> merge -> shard at R=4 -> merge again must be bit-exact on params and
    the flat p/m/v, with shard boundaries re-derived at the target R."""
    cfg, (f1, f2) = setup
    mt = MeshTrainer(cfg, _ecfg(), "ours",
                     MeshCfg(replicas=2, period=2, opt_shard=True))
    out = mt.run_gossip([f1, f2], 2, key=jax.random.PRNGKey(8))
    states = [rt.export_state() for rt in out["runtimes"]]

    merged = ck.zero1_merge_states(states)
    at4 = ck.zero1_shard_states(merged, 4)
    assert len(at4) == 4
    for r, st in enumerate(at4):
        assert int(np.asarray(st.opt[0]["rank"])) == r
        assert int(np.asarray(st.opt[0]["world"])) == 4
    merged2 = ck.zero1_merge_states(at4)
    for i in range(len(merged.params)):
        for a, b in zip(jax.tree.leaves(merged.params[i]),
                        jax.tree.leaves(merged2.params[i])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for key in ("p", "m", "v"):
            np.testing.assert_array_equal(
                np.asarray(merged.opt[i]["flat"][key]),
                np.asarray(merged2.opt[i]["flat"][key]))

    # restage on a sharded state must refuse with actionable guidance (this was
    # the silent-garbage path before the fix)
    tr_new = AsyncTrainer(cfg, _ecfg(n_stages=2), "ours")
    with pytest.raises(ValueError, match="zero1_merge_states"):
        ck.restage(states[0], mt.inner, tr_new)


def test_zero1_merge_rejects_bad_rank_sets(setup):
    cfg, (f1, f2) = setup
    mt = MeshTrainer(cfg, _ecfg(), "ours",
                     MeshCfg(replicas=2, period=2, opt_shard=True))
    out = mt.run_gossip([f1, f2], 2, key=jax.random.PRNGKey(9))
    states = [rt.export_state() for rt in out["runtimes"]]
    with pytest.raises(ValueError):
        ck.zero1_merge_states([states[0], states[0]])  # duplicate rank
    with pytest.raises(ValueError):
        ck.zero1_merge_states(states[:1])  # missing rank
