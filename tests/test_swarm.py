"""SwarmTrainer coverage: replica sync semantics, async divergence between
syncs, the int8+error-feedback compressed sync path, and the event-driven
swarm mode (per-replica EventRuntime + periodic stage-wise averaging)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.engine import EngineCfg
from repro.core.events import FixedDelay, StragglerDelay
from repro.core.swarm import SwarmCfg, SwarmTrainer, _quantize_int8_ef
from repro.data.synthetic import make_batch_fn


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("nanogpt_134m", reduced=True)
    f1, _ = make_batch_fn(cfg, 1, 2, 32, seed=0)
    f2, _ = make_batch_fn(cfg, 1, 2, 32, seed=17)

    def batch(i):  # [R=2, K=1, B, S] — each replica its own stream
        return jax.tree.map(lambda a, b: jnp.stack([a, b]), f1(i), f2(i))

    return cfg, batch, (f1, f2)


def _ecfg(**kw):
    kw.setdefault("n_stages", 2)
    kw.setdefault("lr", 2e-3)
    kw.setdefault("constant_lr", True)
    kw.setdefault("collect_metrics", False)
    return EngineCfg(**kw)


def _replica_spread(state):
    """max over leaves of max |replica_r - replica_0| on stage params."""
    out = 0.0
    for p in state.inner.params:
        for x in jax.tree.leaves(p):
            out = max(out, float(jnp.max(jnp.abs(x - x[:1]))))
    return out


def test_async_divergence_then_sync_tick_equalizes(setup):
    """Between syncs the replicas drift apart (different batch streams, local
    updates); on a sync tick the stage-wise mean makes them exactly equal."""
    cfg, batch, _ = setup
    sw = SwarmTrainer(cfg, _ecfg(), "ours_nows", SwarmCfg(replicas=2, sync_every=2))
    state = sw.init(jax.random.PRNGKey(0))
    assert _replica_spread(state) == 0.0  # identical init
    state, _ = sw.step(state, batch(0))  # t=1: no sync
    assert _replica_spread(state) > 0.0
    state, _ = sw.step(state, batch(1))  # t=2: sync tick
    assert _replica_spread(state) == 0.0
    state, _ = sw.step(state, batch(2))  # t=3: diverging again
    assert _replica_spread(state) > 0.0


def test_sync_every_tick_keeps_replicas_equal(setup):
    cfg, batch, _ = setup
    sw = SwarmTrainer(cfg, _ecfg(), "gpipe", SwarmCfg(replicas=2, sync_every=1))
    state = sw.init(jax.random.PRNGKey(1))
    losses = []
    for i in range(3):
        state, m = sw.step(state, batch(i))
        assert _replica_spread(state) == 0.0
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()


def test_quantize_int8_ef_residual_identity(rng_key):
    """The int8 quantizer's error feedback is exact bookkeeping:
    dequantized + residual == delta + carried error, and the fresh residual is
    bounded by half a quantization step per leaf."""
    k1, k2 = jax.random.split(rng_key)
    delta = {"a": jax.random.normal(k1, (16,)) * 0.1,
             "b": {"w": jax.random.normal(k2, (4, 4)) * 3.0}}
    err = jax.tree.map(lambda x: jnp.ones_like(x) * 0.01, delta)
    deq, new_err = _quantize_int8_ef(delta, err)
    for d, e, q, ne in zip(jax.tree.leaves(delta), jax.tree.leaves(err),
                           jax.tree.leaves(deq), jax.tree.leaves(new_err)):
        np.testing.assert_allclose(np.asarray(q + ne), np.asarray(d + e),
                                   rtol=1e-6, atol=1e-7)
        scale = float(jnp.max(jnp.abs(d + e))) / 127.0
        assert float(jnp.max(jnp.abs(ne))) <= 0.5 * scale + 1e-8
    # feeding the residual back shrinks what gets dropped: two rounds of EF on a
    # constant delta recover more signal than one round discards
    deq2, err2 = _quantize_int8_ef(delta, new_err)
    tot = jax.tree.map(lambda a, b: a + b, deq, deq2)
    for d, t in zip(jax.tree.leaves(delta), jax.tree.leaves(tot)):
        np.testing.assert_allclose(np.asarray(t), np.asarray(2 * d),
                                   rtol=0.02, atol=0.02)


def test_compress_path_trains_and_tracks_residuals(setup):
    cfg, batch, _ = setup
    sw = SwarmTrainer(cfg, _ecfg(), "ours_nows",
                      SwarmCfg(replicas=2, sync_every=2, compress=True))
    state = sw.init(jax.random.PRNGKey(2))
    losses = []
    for i in range(4):
        state, m = sw.step(state, batch(i))
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    # after a sync tick the error-feedback residuals are populated (non-zero)
    err_mag = max(float(jnp.max(jnp.abs(x)))
                  for e in state.err for x in jax.tree.leaves(e))
    assert err_mag > 0.0
    # compressed sync pulls replicas together but only to int8 precision
    spread = _replica_spread(state)
    assert spread > 0.0  # quantized deltas: close to the mean, not bit-equal


def test_compressed_sync_carries_per_replica_residuals(setup):
    """Regression (ISSUE 4): the EF telescope is per-replica bookkeeping —
    after a sync, applied_r + err_r' == delta_r + err_r must hold for EVERY
    replica, and cumulative applied deltas must converge to the cumulative
    true deltas as residuals accumulate. The old implementation averaged the
    residuals across replicas (`sum(es) / R`), which breaks the identity for
    any asymmetric delta (R >= 3) and turns the telescope into accumulating
    quantization drift."""
    cfg, _, _ = setup
    R = 3
    # lr=0: local updates are identity, so the sync math is fully observable
    # from the states around each step (delta_r == mean - p_r exactly)
    sw = SwarmTrainer(cfg, _ecfg(lr=0.0), "gpipe",
                      SwarmCfg(replicas=R, sync_every=1, compress=True))
    state = sw.init(jax.random.PRNGKey(5))
    # spread the replicas apart asymmetrically (replica r offset by r * 0.03)
    off = jnp.arange(R, dtype=jnp.float32) * 0.03
    perturbed = tuple(
        jax.tree.map(lambda x: x + off.reshape((R,) + (1,) * (x.ndim - 1)), p)
        for p in state.inner.params)
    state = state._replace(inner=state.inner._replace(params=perturbed))

    toks = jax.random.randint(jax.random.PRNGKey(6), (R, 1, 2, 17), 0, cfg.vocab_size)
    b = {"tokens": toks[..., :-1], "labels": toks[..., 1:]}
    step = jax.jit(sw.step)
    for _ in range(3):  # several rounds so residuals are carried, not fresh
        p0 = state.inner.params
        e0 = state.err
        state, _ = step(state, b)
        for i in range(sw.inner.P):
            mean = jax.tree.map(
                lambda x: jnp.mean(x.astype(jnp.float32), axis=0), p0[i])
            for pa, pb, mn, ea, eb in zip(
                    jax.tree.leaves(p0[i]), jax.tree.leaves(state.inner.params[i]),
                    jax.tree.leaves(mean), jax.tree.leaves(e0[i]),
                    jax.tree.leaves(state.err[i])):
                assert ea.shape == pa.shape  # residuals carry the [R] axis
                applied = pb.astype(jnp.float32) - pa.astype(jnp.float32)
                true_delta = mn[None] - pa.astype(jnp.float32)
                np.testing.assert_allclose(
                    np.asarray(applied + eb), np.asarray(true_delta + ea),
                    rtol=1e-5, atol=1e-6)
    # as residuals accumulate the compressed sync converges to the exact sync:
    # by round 3 every replica sits on the (preserved) mean to well below one
    # first-round quantization step
    spread = _replica_spread(state)
    assert spread < 1e-4, spread


def test_eval_loss_smoke(setup):
    cfg, batch, _ = setup
    sw = SwarmTrainer(cfg, _ecfg(), "gpipe", SwarmCfg(replicas=2, sync_every=1))
    state = sw.init(jax.random.PRNGKey(3))
    state, _ = sw.step(state, batch(0))
    loss = sw.eval_loss(state, batch(1))
    assert np.isfinite(float(loss))


def test_event_mode_swarm_syncs_heterogeneous_replicas(setup):
    """Async swarm through the event runtime: one replica runs a straggler
    delay model, both drain and average every sync_every updates; after the
    final sync the replica weights are identical."""
    cfg, _, (f1, f2) = setup
    sw = SwarmTrainer(cfg, _ecfg(), "ours_nows", SwarmCfg(replicas=2, sync_every=2))
    out = sw.run_event(
        [f1, f2], 4, key=jax.random.PRNGKey(4),
        delay_models=[FixedDelay(), StragglerDelay(slow_stage=0, factor=3.0)])
    assert out["n_syncs"] == 2
    assert all(np.isfinite(l).all() for l in np.asarray(out["losses"]))
    r0, r1 = out["runtimes"]
    for i in range(sw.inner.P):
        for a, b in zip(jax.tree.leaves(r0._stages[i].params),
                        jax.tree.leaves(r1._stages[i].params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_event_mode_churn_drops_replica_and_resyncs_on_rejoin(setup):
    """Churn maps to replica dropout in the event swarm: the out replica skips
    its rounds (no compute, no averaging contribution), the survivors keep
    syncing, and on rejoin the returning replica re-adopts the live means —
    after the final sync all replicas are identical again."""
    cfg, _, (f1, f2) = setup
    sw = SwarmTrainer(cfg, _ecfg(), "ours_nows", SwarmCfg(replicas=2, sync_every=2))
    out = sw.run_event([f1, f2], 6, key=jax.random.PRNGKey(7),
                       churn="1,2,2")  # replica 1 out for ticks [2, 4)
    assert out["dropped"] == [0, 1]
    assert out["n_syncs"] == 3
    assert len(out["losses"][0]) == 6 and len(out["losses"][1]) == 4
    assert all(np.isfinite(np.asarray(l)).all() for l in out["losses"])
    r0, r1 = out["runtimes"]
    assert r0._u_done == 6 and r1._u_done == 4  # rejoiner resumes, not replays
    for i in range(sw.inner.P):
        for a, b in zip(jax.tree.leaves(r0._stages[i].params),
                        jax.tree.leaves(r1._stages[i].params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_event_mode_churn_zero_duration_outage_drops_nothing(setup):
    """Outage.duration == 0 is an empty interval: it intersects no sync round,
    so no replica is dropped — the runtime-level no-op contract holds at the
    swarm level too."""
    cfg, _, (f1, f2) = setup
    sw = SwarmTrainer(cfg, _ecfg(), "ours_nows", SwarmCfg(replicas=2, sync_every=2))
    out = sw.run_event([f1, f2], 4, key=jax.random.PRNGKey(9), churn="1,3,0")
    assert out["dropped"] == [0, 0]
    assert len(out["losses"][0]) == len(out["losses"][1]) == 4


def test_event_mode_churn_rejects_all_replicas_out(setup):
    cfg, _, (f1, f2) = setup
    sw = SwarmTrainer(cfg, _ecfg(), "ours_nows", SwarmCfg(replicas=2, sync_every=2))
    with pytest.raises(RuntimeError, match="outage"):
        sw.run_event([f1, f2], 4, key=jax.random.PRNGKey(8), churn="0,0,4/1,0,4")


def test_run_event_requires_key(setup):
    """RNG002 regression: the PRNGKey(0) fallback silently decoupled the
    swarm init from --seed; run_event must be given its key."""
    cfg, _, (f1, f2) = setup
    sw = SwarmTrainer(cfg, _ecfg(), "ours_nows", SwarmCfg(replicas=2))
    with pytest.raises(ValueError, match="key"):
        sw.run_event([f1, f2], 2)


def test_run_event_seeds_actually_diverge(setup):
    """Two different keys must yield different inits and loss streams."""
    cfg, _, (f1, f2) = setup
    losses = {}
    for seed in (0, 1):
        sw = SwarmTrainer(cfg, _ecfg(), "ours_nows",
                          SwarmCfg(replicas=2, sync_every=2))
        out = sw.run_event([f1, f2], 2, key=jax.random.PRNGKey(seed))
        losses[seed] = out["losses"]
    assert losses[0] != losses[1], (
        "seed 0 and seed 1 produced identical swarm loss streams")
