"""Distribution tests: sharding rules, multi-device dry-run + SPMD pipeline.

Multi-device cases run in subprocesses so the main pytest process keeps 1 CPU
device (jax locks the device count at first init).
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_sub(code, devices=8, timeout=560):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout, env=env)
    assert r.returncode == 0, f"subprocess failed:\n{r.stdout}\n{r.stderr[-3000:]}"
    return r.stdout


def test_sharding_rules_unit():
    code = """
    import jax, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_debug_mesh
    from repro.parallel import sharding as shd
    mesh = make_debug_mesh(2, 2)
    # wq [D, H, hd]: D->data, H->model
    assert shd.spec_for(".params[0]['scan']['b0']['mixer']['wq']", (8, 4, 16), mesh) == P("data", "model", None)
    # stacked + stash axes stay unsharded
    assert shd.spec_for("['stash'][0]['scan']['b0']['mixer']['wq']", (3, 2, 8, 4, 16), mesh) == P(None, None, "data", "model", None)
    # non-divisible head count falls back to replicated on that dim
    assert shd.spec_for("['wq']", (8, 3, 16), mesh) == P("data", None, None)
    # embedding: vocab->model, embed->data
    assert shd.spec_for("['tok_embed']", (100, 8), mesh) == P("model", "data")
    # norm scales replicated
    assert shd.spec_for("['pre_norm']['scale']", (8,), mesh) == P(None)
    # moe experts on model
    assert shd.spec_for("['moe']['moe_gate']", (4, 8, 16), mesh) == P("model", "data", None)
    print("rules ok")
    """
    assert "rules ok" in _run_sub(code, devices=4)


def test_constrain_noop_without_mesh():
    import jax.numpy as jnp

    from repro.parallel import ax

    x = jnp.ones((4, 4))
    y = ax.constrain(x, "data", None)
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.slow
def test_dryrun_lowers_on_debug_mesh():
    """lower+compile the async train step and serve steps on an 8-device mesh."""
    code = """
    import jax, json
    import jax.numpy as jnp, dataclasses
    from repro.configs import get_config
    from repro.launch import specs as S
    from repro.launch.mesh import make_debug_mesh
    from repro.launch.dryrun import lower_train, lower_prefill, lower_decode, analyse
    mesh = make_debug_mesh(2, 2)
    cell = S.Cell("qwen2-1.5b", "tiny", 64, 8, "train", 2)
    cfg = get_config("qwen2-1.5b", reduced=True, dtype=jnp.bfloat16)
    lowered = lower_train(cfg, cell, mesh, method="ours", n_stages=2)
    rec, _ = analyse(lowered, "t", 4)
    assert rec["flops"] > 0
    cell2 = S.Cell("qwen2-1.5b", "tiny", 64, 4, "prefill", 1)
    rec2, _ = analyse(lower_prefill(cfg, cell2, mesh), "p", 4)
    cell3 = S.Cell("qwen2-1.5b", "tiny", 64, 4, "decode", 1)
    rec3, _ = analyse(lower_decode(cfg, cell3, mesh), "d", 4)
    print("dryrun ok", rec["flops"] > 0, rec2["flops"] > 0, rec3["flops"] > 0)
    """
    assert "dryrun ok True True True" in _run_sub(code, devices=8)


@pytest.mark.slow
def test_spmd_pipeline_trains_on_two_pods():
    code = """
    import jax, jax.numpy as jnp
    from repro.configs import get_config
    from repro.launch.mesh import make_debug_mesh
    from repro.parallel.pipeline_spmd import make_pipeline_step
    from repro.models import lm
    from repro.data.synthetic import make_batch_fn
    cfg = get_config("nanogpt_134m", reduced=True)
    mesh = make_debug_mesh(2, 2, multi_pod=True)
    init_fn, step_fn = make_pipeline_step(cfg, mesh, n_microbatches=4, lr=1e-3)
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    batch_fn, _ = make_batch_fn(cfg, 4, 4, 32, seed=0)
    with mesh:
        state = init_fn(params)
        step = jax.jit(step_fn)
        losses = []
        for i in range(8):
            state, m = step(state, batch_fn(i))
            losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses
    assert all(jnp.isfinite(jnp.asarray(losses)))
    print("pp ok", round(losses[0],3), "->", round(losses[-1],3))
    """
    assert "pp ok" in _run_sub(code, devices=8)


@pytest.mark.slow
def test_spmd_pipeline_single_pod_matches_engine():
    """n_pods=1 pipeline (zero delay) ~= engine P=1 'ours' per-microbatch updates."""
    code = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.core.engine import AsyncTrainer, EngineCfg
    from repro.launch.mesh import make_debug_mesh
    from repro.parallel.pipeline_spmd import make_pipeline_step
    from repro.models import lm
    from repro.data.synthetic import make_batch_fn
    from repro.launch.mesh import _make_mesh
    cfg = get_config("nanogpt_134m", reduced=True)
    mesh = _make_mesh((1, 2, 2), ("pod", "data", "model"))
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    batch_fn, _ = make_batch_fn(cfg, 1, 4, 32, seed=0)

    init_fn, step_fn = make_pipeline_step(cfg, mesh, n_microbatches=1, lr=1e-3)
    with mesh:
        s_pp = init_fn(params)
        step_pp = jax.jit(step_fn)
        pp_losses = []
        for i in range(6):
            s_pp, m = step_pp(s_pp, batch_fn(i))
            pp_losses.append(float(m["loss"]))

    tr = AsyncTrainer(cfg, EngineCfg(n_stages=1, lr=1e-3, constant_lr=True,
                                     collect_metrics=False), "ours")
    s_e = tr.init_from_params(params)
    step_e = tr.jit_step(donate=False)
    e_losses = []
    for i in range(6):
        s_e, m = step_e(s_e, batch_fn(i))
        e_losses.append(float(m["loss"]))
    print("pp:", [round(x, 4) for x in pp_losses])
    print("en:", [round(x, 4) for x in e_losses])
    np.testing.assert_allclose(pp_losses, e_losses, rtol=2e-2)
    print("match ok")
    """
    assert "match ok" in _run_sub(code, devices=4)
